//! Operator profiling walkthrough (paper §2 / Fig. 2): measure activation
//! sparsity with *real* PJRT execution through an [`sparoa::api::Session`],
//! combine with analytic intensity, and print the quadrant analysis that
//! motivates SparOA.
//!
//! ```bash
//! cargo run --release --example profile_operators
//! ```

use sparoa::api::{BackendChoice, SessionBuilder};
use sparoa::profiler::{quadrant_counts, quadrant_profile};

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let session = SessionBuilder::new()
        .model("mobilenet_v3_small")
        .policy("gpu")
        .backend(BackendChoice::Pjrt)
        .build()?;

    // Fresh sparsity measurement through the real execution path.
    let report = session.infer_input(&session.random_input(99))?;
    let measured = report
        .measured_sparsity
        .as_ref()
        .expect("pjrt reports measured sparsity");

    println!("fresh vs build-time sparsity (ReLU-family ops):");
    for op in &session.graph().ops {
        if matches!(op.kind,
                    sparoa::graph::OpKind::Relu
                        | sparoa::graph::OpKind::Relu6)
            && op.sparsity_out > 0.05
        {
            println!(
                "  {:32} measured {:.2}  profiled {:.2}",
                op.name, measured[op.id], op.sparsity_out
            );
        }
    }

    let profiles = quadrant_profile(session.graph());
    println!("\nquadrant counts (sparsity cut 0.4):");
    for (q, count) in quadrant_counts(&profiles) {
        println!("  {q:?}: {count}");
    }
    println!(
        "\nConclusion (paper §2.2): sparsity and intensity are orthogonal \
         — a scheduler must use both."
    );
    Ok(())
}
