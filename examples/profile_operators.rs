//! Operator profiling walkthrough (paper §2 / Fig. 2): measure activation
//! sparsity with *real* PJRT execution, combine with analytic intensity,
//! and print the quadrant analysis that motivates SparOA.
//!
//! ```bash
//! cargo run --release --example profile_operators
//! ```

use sparoa::engine::HybridEngine;
use sparoa::graph::ModelZoo;
use sparoa::profiler::{quadrant_counts, quadrant_profile};
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::Schedule;
use sparoa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let zoo = ModelZoo::load(&art)?;
    let graph = zoo.get("mobilenet_v3_small")?;
    let runtime = Runtime::new(&art)?;
    let engine = HybridEngine::new(&runtime, graph)?;

    // Fresh sparsity measurement through the real execution path.
    let mut rng = Rng::new(99);
    let n: usize = graph.input_shape_exec.iter().product();
    let input = HostTensor::new(
        graph.input_shape_exec.clone(),
        (0..n).map(|_| rng.normal() as f32).collect(),
    );
    let res = engine.infer(&input, &Schedule::uniform(graph, 1.0, "gpu"))?;

    println!("fresh vs build-time sparsity (ReLU-family ops):");
    for op in &graph.ops {
        if matches!(op.kind,
                    sparoa::graph::OpKind::Relu
                        | sparoa::graph::OpKind::Relu6)
            && op.sparsity_out > 0.05
        {
            println!(
                "  {:32} measured {:.2}  profiled {:.2}",
                op.name, res.sparsity_out[op.id], op.sparsity_out
            );
        }
    }

    let profiles = quadrant_profile(graph);
    println!("\nquadrant counts (sparsity cut 0.4):");
    for (q, count) in quadrant_counts(&profiles) {
        println!("  {q:?}: {count}");
    }
    println!(
        "\nConclusion (paper §2.2): sparsity and intensity are orthogonal \
         — a scheduler must use both."
    );
    Ok(())
}
