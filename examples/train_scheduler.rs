//! SAC scheduler training walkthrough (paper §4 / Fig. 10 companion):
//! train the agent on MobileNetV2 + AGX Orin, print the convergence
//! trace, and compare the learned plan against greedy/DP/single-device.
//!
//! ```bash
//! cargo run --release --example train_scheduler
//! ```

use sparoa::device::DeviceRegistry;
use sparoa::engine::sim::{simulate, SimOptions};
use sparoa::graph::ModelZoo;
use sparoa::scheduler::{
    dp::DpScheduler, greedy::GreedyScheduler,
    sac_sched::{SacScheduler, SacSchedulerConfig}, Schedule, ScheduleCtx,
    Scheduler,
};

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let zoo = ModelZoo::load(&art)?;
    let graph = zoo.get("mobilenet_v2")?;
    let reg = DeviceRegistry::load(
        &sparoa::repo_root().join("config/devices.json"))?;
    let device = reg.get("agx_orin")?;
    let ctx = ScheduleCtx { graph, device, thresholds: None, batch: 1 };

    let mut sac = SacScheduler::new(SacSchedulerConfig {
        episodes: 80,
        noise: 0.03,
        ..Default::default()
    });
    let plan = sac.schedule(&ctx);
    println!("SAC convergence trace (episode, eval makespan us, wall s):");
    for p in sac.trace.iter().step_by(4) {
        println!("  ep {:3}  {:9.1}us  t={:6.2}s", p.episode,
                 p.makespan_us, p.wall_s);
    }
    println!("converged after {:.1}s\n", sac.converged_after_s);

    // Compare under mild hardware dynamics (paper §6.7's regime).
    let eval = SimOptions { noise: 0.03, seed: 3, ..Default::default() };
    let greedy = GreedyScheduler.schedule(&ctx);
    let dp = DpScheduler::default().schedule(&ctx);
    for (name, sched) in [
        ("CPU-only", Schedule::uniform(graph, 0.0, "cpu")),
        ("GPU-only", Schedule::uniform(graph, 1.0, "gpu")),
        ("Greedy", greedy),
        ("DP", dp),
        ("SAC", plan),
    ] {
        let r = simulate(graph, device, &sched, &eval);
        println!(
            "{name:10} makespan {:9.0}us  gpu-share {:4.0}%  switches {:3}",
            r.makespan_us,
            100.0 * sched.gpu_share(graph),
            sched.switch_count(graph)
        );
    }
    Ok(())
}
