//! SAC scheduler training walkthrough (paper §4 / Fig. 10 companion):
//! train the agent on MobileNetV2 + AGX Orin, print the convergence
//! trace, and compare the learned plan against greedy/DP/single-device —
//! every evaluation runs through one simulator-backed
//! [`sparoa::api::Session`] with the candidate schedule swapped in.
//!
//! ```bash
//! cargo run --release --example train_scheduler
//! ```

use sparoa::api::{BackendChoice, SessionBuilder};
use sparoa::engine::sim::SimOptions;
use sparoa::scheduler::{
    dp::DpScheduler, greedy::GreedyScheduler,
    sac_sched::{SacScheduler, SacSchedulerConfig}, Schedule, ScheduleCtx,
    Scheduler,
};

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");
    // One sim-backed session owns the graph + device for the whole study;
    // candidate schedules are swapped in via set_schedule.
    let mut session = SessionBuilder::new()
        .model("mobilenet_v2")
        .device("agx_orin")
        .policy("threshold")
        .backend(BackendChoice::Sim)
        // Evaluate under mild hardware dynamics (paper §6.7's regime).
        .options(SimOptions { noise: 0.03, seed: 3, ..Default::default() })
        .build()?;

    let ctx = ScheduleCtx {
        graph: session.graph(),
        device: session.device(),
        thresholds: None,
        batch: 1,
    };
    let mut sac = SacScheduler::new(SacSchedulerConfig {
        episodes: 80,
        noise: 0.03,
        ..Default::default()
    });
    let plan = sac.schedule(&ctx);
    println!("SAC convergence trace (episode, eval makespan us, wall s):");
    for p in sac.trace.iter().step_by(4) {
        println!("  ep {:3}  {:9.1}us  t={:6.2}s", p.episode,
                 p.makespan_us, p.wall_s);
    }
    println!("converged after {:.1}s\n", sac.converged_after_s);

    let greedy = GreedyScheduler.schedule(&ctx);
    let dp = DpScheduler::default().schedule(&ctx);
    let cpu = Schedule::uniform(session.graph(), 0.0, "cpu");
    let gpu = Schedule::uniform(session.graph(), 1.0, "gpu");
    for (name, sched) in [
        ("CPU-only", cpu),
        ("GPU-only", gpu),
        ("Greedy", greedy),
        ("DP", dp),
        ("SAC", plan),
    ] {
        let gpu_share = sched.gpu_share(session.graph());
        let switches = sched.switch_count(session.graph());
        session.set_schedule(sched);
        let r = session.infer()?;
        println!(
            "{name:10} makespan {:9.0}us  gpu-share {:4.0}%  switches {:3}",
            r.makespan_us,
            100.0 * gpu_share,
            switches
        );
    }
    Ok(())
}
