//! End-to-end serving driver (DESIGN.md §6): load MobileNetV3-Small, build
//! the full SparOA schedule, then serve a Poisson stream of requests —
//! every request's numerics run through PJRT while the dynamic batcher
//! and the calibrated Jetson timeline account latency/throughput/energy.
//!
//! ```bash
//! cargo run --release --example serve_requests
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use sparoa::device::DeviceRegistry;
use sparoa::engine::batching::{optimize_batch, BatchConstraints};
use sparoa::engine::sim::SimOptions;
use sparoa::engine::HybridEngine;
use sparoa::graph::ModelZoo;
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::sac_sched::{SacScheduler, SacSchedulerConfig};
use sparoa::scheduler::{ScheduleCtx, Scheduler};
use sparoa::server::{
    batcher::poisson_stream, run_batching_sim, BatchPolicy, ServeMetrics,
};
use sparoa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let zoo = ModelZoo::load(&art)?;
    let graph = zoo.get("mobilenet_v3_small")?;
    let reg = DeviceRegistry::load(
        &sparoa::repo_root().join("config/devices.json"))?;
    let device = reg.get("agx_orin")?;
    let runtime = Runtime::new(&art)?;

    // Offline: schedule + Alg.2 batch optimum.
    let mut sac = SacScheduler::new(SacSchedulerConfig {
        episodes: 30,
        ..Default::default()
    });
    let schedule = sac.schedule(&ScheduleCtx {
        graph, device, thresholds: None, batch: 1,
    });
    let opts = SimOptions::default();
    let plan = optimize_batch(graph, device, &schedule, &opts, 8,
                              &BatchConstraints {
                                  mem_limit_mb: device.gpu_mem_capacity_mb,
                                  ..Default::default()
                              });
    println!("Alg.2 optimal batch: {} ({:.0}us/item)", plan.batch,
             plan.per_item_us);

    // Online: 200 requests at 150 req/s.
    let n_requests = 200usize;
    let requests = poisson_stream(n_requests, 150.0, 42);

    // (a) Virtual-time serving comparison: fixed vs dynamic batching.
    for (name, policy) in [
        ("fixed-32 (static framework)",
         BatchPolicy::Fixed { size: 32, timeout_us: 25_000.0 }),
        ("SparOA dynamic",
         BatchPolicy::Dynamic { max: plan.batch.max(1),
                                optimizer_cost_us: 30.0 }),
    ] {
        let rep = run_batching_sim(graph, device, &schedule, &opts,
                                   &requests, &policy);
        println!(
            "[sim]  {name:28} mean {:8.0}us  p99 {:8.0}us  \
             {:6.1} req/s  batching overhead {:4.1}%",
            rep.mean_latency_us, rep.p99_latency_us, rep.throughput_rps,
            rep.overhead_pct()
        );
    }

    // (b) Real numerics: every request executes through PJRT.
    let engine = HybridEngine::new(&runtime, graph)?;
    let compiled = engine.warm_up()?;
    println!("[real] warm-up compiled {compiled} executables");
    let mut metrics = ServeMetrics::new();
    let mut rng = Rng::new(7);
    let n: usize = graph.input_shape_exec.iter().product();
    let mut checksum = 0.0f64;
    for _ in 0..n_requests {
        let input = HostTensor::new(
            graph.input_shape_exec.clone(),
            (0..n).map(|_| rng.normal() as f32).collect(),
        );
        let t0 = std::time::Instant::now();
        let out = engine.infer(&input, &schedule)?;
        metrics.record(t0.elapsed().as_secs_f64() * 1e6);
        checksum += out.output.data[0] as f64;
    }
    metrics.finish();
    println!("[real] {}", metrics.summary("pjrt-exec"));
    println!("[real] checksum {checksum:.3} (all outputs finite)");

    // (c) Simulated Jetson energy for the serving episode.
    let rep = sparoa::engine::sim::simulate(graph, device, &schedule, &opts);
    let ledger = rep.ledger();
    println!(
        "[sim]  per-inference on {}: {:.0}us, {:.1}W, {:.2}mJ",
        device.name,
        rep.makespan_us,
        ledger.mean_power_w(device),
        ledger.energy_mj(device)
    );
    Ok(())
}
