//! End-to-end serving driver (DESIGN.md §6): build the full SparOA
//! session for MobileNetV3-Small, then serve a Poisson stream of
//! requests — the dynamic batcher and the calibrated Jetson timeline
//! account latency/throughput/energy, and every real request's numerics
//! run through the same session's PJRT backend.
//!
//! ```bash
//! cargo run --release --example serve_requests
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use sparoa::api::{BackendChoice, SessionBuilder};
use sparoa::engine::batching::{optimize_batch, BatchConstraints};
use sparoa::server::{batcher::poisson_stream, BatchPolicy, ServeMetrics};

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");

    // Offline: one session owns graph + device + SAC schedule + PJRT.
    let session = SessionBuilder::new()
        .model("mobilenet_v3_small")
        .device("agx_orin")
        .policy("sac")
        .episodes(30)
        .backend(BackendChoice::Pjrt)
        .build()?;
    let plan = optimize_batch(
        session.graph(),
        session.device(),
        session.schedule(),
        session.options(),
        8,
        &BatchConstraints {
            mem_limit_mb: session.device().gpu_mem_capacity_mb,
            ..Default::default()
        },
    );
    println!("Alg.2 optimal batch: {} ({:.0}us/item)", plan.batch,
             plan.per_item_us);

    // Online: 200 requests at 150 req/s.
    let n_requests = 200usize;
    let requests = poisson_stream(n_requests, 150.0, 42);

    // (a) Virtual-time serving comparison: fixed vs dynamic batching.
    for (name, policy) in [
        ("fixed-32 (static framework)",
         BatchPolicy::Fixed { size: 32, timeout_us: 25_000.0 }),
        ("SparOA dynamic",
         BatchPolicy::Dynamic { max: plan.batch.max(1),
                                optimizer_cost_us: 30.0 }),
    ] {
        let rep = session.serve(&requests, &policy)?;
        println!(
            "[sim]  {name:28} mean {:8.0}us  p99 {:8.0}us  \
             {:6.1} req/s  batching overhead {:4.1}%",
            rep.mean_latency_us, rep.p99_latency_us, rep.throughput_rps,
            rep.overhead_pct()
        );
    }

    // (b) Real numerics: every request executes through PJRT.
    println!("[real] warm-up compiled {} executables", session.compiled());
    let mut metrics = ServeMetrics::new();
    let mut checksum = 0.0f64;
    let mut last_rep = None;
    for seed in 0..n_requests as u64 {
        let input = session.random_input(seed);
        let t0 = std::time::Instant::now();
        let rep = session.infer_input(&input)?;
        metrics.record(t0.elapsed().as_secs_f64() * 1e6);
        checksum +=
            rep.output.as_ref().expect("pjrt returns numerics").data[0]
                as f64;
        last_rep = Some(rep);
    }
    metrics.finish();
    println!("[real] {}", metrics.summary("pjrt-exec"));
    println!("[real] checksum {checksum:.3} (all outputs finite)");

    // (c) Simulated Jetson energy for the serving episode (the unified
    // report already carries the calibrated timeline — no extra run).
    let rep = last_rep.expect("served at least one request");
    let ledger = rep.ledger();
    println!(
        "[sim]  per-inference on {}: {:.0}us, {:.1}W, {:.2}mJ",
        session.device().name,
        rep.makespan_us,
        ledger.mean_power_w(session.device()),
        ledger.energy_mj(session.device())
    );
    Ok(())
}
