//! Quickstart: load a model's AOT artifacts, schedule it with SparOA's
//! full stack (predictor -> SAC), run one real inference through PJRT and
//! print the simulated Jetson timeline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use sparoa::device::DeviceRegistry;
use sparoa::engine::sim::simulate;
use sparoa::engine::HybridEngine;
use sparoa::graph::ModelZoo;
use sparoa::predictor::ThresholdPredictor;
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::sac_sched::{SacScheduler, SacSchedulerConfig};
use sparoa::scheduler::{ScheduleCtx, Scheduler};
use sparoa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");

    // 1. Load the model zoo, device profile and PJRT runtime.
    let zoo = ModelZoo::load(&art)?;
    let graph = zoo.get("mobilenet_v3_small")?;
    let reg = DeviceRegistry::load(
        &sparoa::repo_root().join("config/devices.json"))?;
    let device = reg.get("agx_orin")?;
    let runtime = Runtime::new(&art)?;
    println!("PJRT platform: {}", runtime.platform());

    // 2. Offline phase: threshold predictor + SAC operator scheduler.
    let predictor = ThresholdPredictor::new(&runtime);
    let thresholds = predictor.predict_graph(graph)?;
    println!("predicted thresholds for {} ops", thresholds.len());
    let mut sac = SacScheduler::new(SacSchedulerConfig {
        episodes: 30,
        ..Default::default()
    });
    let schedule = sac.schedule(&ScheduleCtx {
        graph,
        device,
        thresholds: Some(&thresholds),
        batch: 1,
    });
    println!(
        "SAC schedule: {:.0}% of ops on GPU, {} device switches, \
         trained in {:.1}s",
        100.0 * schedule.gpu_share(graph),
        schedule.switch_count(graph),
        sac.converged_after_s
    );

    // 3. Simulated Jetson timeline for the schedule.
    let report = simulate(graph, device, &schedule, &Default::default());
    let ledger = report.ledger();
    println!(
        "simulated on {}: makespan {:.0}us, transfer {:.0}us, \
         power {:.1}W, energy {:.2}mJ",
        device.name, report.makespan_us, report.transfer_us,
        ledger.mean_power_w(device), ledger.energy_mj(device)
    );

    // 4. Real numerics through PJRT (exec-scale artifacts).
    let engine = HybridEngine::new(&runtime, graph)?;
    let compiled = engine.warm_up()?;
    let mut rng = Rng::new(0);
    let n: usize = graph.input_shape_exec.iter().product();
    let input = HostTensor::new(
        graph.input_shape_exec.clone(),
        (0..n).map(|_| rng.normal() as f32).collect(),
    );
    let result = engine.infer(&input, &schedule)?;
    println!(
        "real execution: {} compiled ops, output {:?}, host {:.0}us, \
         top logit {:.3}",
        compiled,
        result.output.shape,
        result.host_us,
        result
            .output
            .data
            .iter()
            .cloned()
            .fold(f32::NEG_INFINITY, f32::max)
    );
    Ok(())
}
