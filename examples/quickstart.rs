//! Quickstart: build one SparOA [`sparoa::api::Session`] — model, device,
//! threshold predictor, SAC scheduler and the PJRT backend — then run a
//! real inference and read the unified report (simulated Jetson timeline
//! + real numerics in one place).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use sparoa::api::{BackendChoice, SessionBuilder};

fn main() -> anyhow::Result<()> {
    let art = sparoa::artifacts_dir();
    anyhow::ensure!(art.join("manifest.json").exists(),
                    "run `make artifacts` first");

    // One builder call wires the whole offline phase: model zoo + device
    // profile + threshold predictor + SAC operator scheduler + PJRT.
    let session = SessionBuilder::new()
        .model("mobilenet_v3_small")
        .device("agx_orin")
        .policy("sac")
        .episodes(30)
        .use_predictor(true)
        .backend(BackendChoice::Pjrt)
        .build()?;
    println!(
        "session ready: backend={} compiled={} predictor thresholds={}",
        session.backend_name(),
        session.compiled(),
        session.thresholds().map(|t| t.len()).unwrap_or(0)
    );
    println!(
        "SAC schedule: {:.0}% of ops on GPU, {} device switches",
        100.0 * session.schedule().gpu_share(session.graph()),
        session.schedule().switch_count(session.graph())
    );

    // One real inference; the report carries both the calibrated virtual
    // timeline and the PJRT numerics.
    let report = session.infer_input(&session.random_input(0))?;
    let ledger = report.ledger();
    println!(
        "simulated on {}: makespan {:.0}us, transfer {:.0}us, \
         power {:.1}W, energy {:.2}mJ",
        session.device().name, report.makespan_us, report.transfer_us,
        ledger.mean_power_w(session.device()),
        ledger.energy_mj(session.device())
    );
    let output = report.output.as_ref().expect("pjrt returns numerics");
    println!(
        "real execution: output {:?}, host {:.0}us, top logit {:.3}",
        output.shape,
        report.host_us.unwrap_or(0.0),
        output
            .data
            .iter()
            .cloned()
            .fold(f32::NEG_INFINITY, f32::max)
    );
    Ok(())
}
