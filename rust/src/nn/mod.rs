//! Neural-network substrate: a dependency-free MLP with manual
//! backpropagation and Adam, sized for the SAC agent's policy/Q networks.
//!
//! No autograd tape — each [`Mlp`] caches its forward activations and
//! implements the exact backward pass for its own architecture
//! (dense + activation stacks).  This keeps the hot training loop
//! allocation-light and trivially auditable.

use crate::util::rng::Rng;

/// Activation for hidden layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Tanh,
    Identity,
}

impl Act {
    fn apply(self, x: f64) -> f64 {
        match self {
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Identity => x,
        }
    }
    /// derivative as a function of the activation *output* y.
    fn dydx_from_y(self, y: f64) -> f64 {
        match self {
            Act::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Tanh => 1.0 - y * y,
            Act::Identity => 1.0,
        }
    }
}

/// One dense layer, row-major weights (din x dout).
#[derive(Debug, Clone)]
pub struct Dense {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f64>,
    pub b: Vec<f64>,
    pub act: Act,
}

impl Dense {
    fn new(din: usize, dout: usize, act: Act, rng: &mut Rng) -> Self {
        let scale = (2.0 / din as f64).sqrt()
            * if act == Act::Tanh { 0.7 } else { 1.0 };
        Dense {
            din,
            dout,
            w: (0..din * dout).map(|_| rng.normal() * scale).collect(),
            b: vec![0.0; dout],
            act,
        }
    }
}

/// Forward cache for one MLP evaluation (batch of B rows).
#[derive(Debug, Clone, Default)]
pub struct Cache {
    /// activations per layer boundary: acts[0] = input, acts[L] = output.
    pub acts: Vec<Vec<f64>>,
    pub batch: usize,
}

/// Gradients matching an [`Mlp`]'s parameters.
#[derive(Debug, Clone)]
pub struct Grads {
    pub dw: Vec<Vec<f64>>,
    pub db: Vec<Vec<f64>>,
}

impl Grads {
    pub fn zeros_like(mlp: &Mlp) -> Self {
        Grads {
            dw: mlp.layers.iter().map(|l| vec![0.0; l.w.len()]).collect(),
            db: mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
        }
    }
    pub fn scale(&mut self, s: f64) {
        for g in self.dw.iter_mut().flatten() {
            *g *= s;
        }
        for g in self.db.iter_mut().flatten() {
            *g *= s;
        }
    }
    pub fn add(&mut self, other: &Grads) {
        for (a, b) in self.dw.iter_mut().zip(&other.dw) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
        for (a, b) in self.db.iter_mut().zip(&other.db) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }
}

/// A plain multilayer perceptron.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Dense>,
}

impl Mlp {
    /// `dims = [din, h1, ..., dout]`; hidden layers use `hidden_act`, the
    /// output layer is linear.
    pub fn new(dims: &[usize], hidden_act: Act, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for i in 0..dims.len() - 1 {
            let act = if i + 2 == dims.len() {
                Act::Identity
            } else {
                hidden_act
            };
            layers.push(Dense::new(dims[i], dims[i + 1], act, &mut rng));
        }
        Mlp { layers }
    }

    pub fn din(&self) -> usize {
        self.layers[0].din
    }
    pub fn dout(&self) -> usize {
        self.layers.last().unwrap().dout
    }
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward for a batch (rows of length din). Returns output + cache.
    pub fn forward(&self, x: &[f64], batch: usize) -> (Vec<f64>, Cache) {
        debug_assert_eq!(x.len(), batch * self.din());
        let mut cache =
            Cache { acts: Vec::with_capacity(self.layers.len() + 1), batch };
        cache.acts.push(x.to_vec());
        for l in &self.layers {
            let cur = cache.acts.last().unwrap();
            let mut out = vec![0.0; batch * l.dout];
            for bi in 0..batch {
                let xi = &cur[bi * l.din..(bi + 1) * l.din];
                let oi = &mut out[bi * l.dout..(bi + 1) * l.dout];
                oi.copy_from_slice(&l.b);
                for (i, &xv) in xi.iter().enumerate() {
                    let wrow = &l.w[i * l.dout..(i + 1) * l.dout];
                    for (o, &wv) in oi.iter_mut().zip(wrow) {
                        *o += xv * wv;
                    }
                }
                for o in oi.iter_mut() {
                    *o = l.act.apply(*o);
                }
            }
            cache.acts.push(out);
        }
        // one clone of the (small) output row; intermediate activations
        // are moved into the cache rather than cloned (§Perf).
        (cache.acts.last().unwrap().clone(), cache)
    }

    /// Convenience: forward one row.
    pub fn infer(&self, x: &[f64]) -> Vec<f64> {
        self.forward(x, 1).0
    }

    /// Backward: given dL/dy for the output batch, returns (grads, dL/dx).
    pub fn backward(&self, cache: &Cache, dy: &[f64]) -> (Grads, Vec<f64>) {
        let batch = cache.batch;
        let mut grads = Grads::zeros_like(self);
        let mut delta = dy.to_vec();
        for (li, l) in self.layers.iter().enumerate().rev() {
            let y = &cache.acts[li + 1];
            let x = &cache.acts[li];
            for (d, &yv) in delta.iter_mut().zip(y.iter()) {
                *d *= l.act.dydx_from_y(yv);
            }
            let mut dx = vec![0.0; batch * l.din];
            for bi in 0..batch {
                let xi = &x[bi * l.din..(bi + 1) * l.din];
                let di = &delta[bi * l.dout..(bi + 1) * l.dout];
                for (j, &dj) in di.iter().enumerate() {
                    grads.db[li][j] += dj;
                }
                for (i, &xv) in xi.iter().enumerate() {
                    let row = &mut grads.dw[li][i * l.dout..(i + 1) * l.dout];
                    for (j, &dj) in di.iter().enumerate() {
                        row[j] += xv * dj;
                    }
                }
                let dxi = &mut dx[bi * l.din..(bi + 1) * l.din];
                for (i, dxv) in dxi.iter_mut().enumerate() {
                    let wrow = &l.w[i * l.dout..(i + 1) * l.dout];
                    let mut acc = 0.0;
                    for (j, &dj) in di.iter().enumerate() {
                        acc += wrow[j] * dj;
                    }
                    *dxv = acc;
                }
            }
            delta = dx;
        }
        (grads, delta)
    }

    /// In-place Polyak update toward `src`: self = tau*src + (1-tau)*self.
    pub fn polyak_from(&mut self, src: &Mlp, tau: f64) {
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            for (a, b) in dst.w.iter_mut().zip(&s.w) {
                *a = tau * b + (1.0 - tau) * *a;
            }
            for (a, b) in dst.b.iter_mut().zip(&s.b) {
                *a = tau * b + (1.0 - tau) * *a;
            }
        }
    }
}

/// Adam optimizer state for one MLP.
#[derive(Debug, Clone)]
pub struct Adam {
    m: Grads,
    v: Grads,
    t: u64,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Adam {
    pub fn new(mlp: &Mlp, lr: f64) -> Self {
        Adam {
            m: Grads::zeros_like(mlp),
            v: Grads::zeros_like(mlp),
            t: 0,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    pub fn step(&mut self, mlp: &mut Mlp, grads: &Grads) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for li in 0..mlp.layers.len() {
            for (i, g) in grads.dw[li].iter().enumerate() {
                let m = &mut self.m.dw[li][i];
                let v = &mut self.v.dw[li][i];
                *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                mlp.layers[li].w[i] -=
                    self.lr * (*m / bc1) / ((*v / bc2).sqrt() + self.eps);
            }
            for (i, g) in grads.db[li].iter().enumerate() {
                let m = &mut self.m.db[li][i];
                let v = &mut self.v.db[li][i];
                *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                mlp.layers[li].b[i] -=
                    self.lr * (*m / bc1) / ((*v / bc2).sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of the manual backward pass.
    #[test]
    fn gradients_match_finite_difference() {
        let mlp = Mlp::new(&[3, 5, 2], Act::Tanh, 42);
        let x = [0.3, -0.7, 1.2];
        let target = [0.5, -0.25];
        let loss = |m: &Mlp| {
            let y = m.infer(&x);
            y.iter()
                .zip(&target)
                .map(|(a, b)| 0.5 * (a - b) * (a - b))
                .sum::<f64>()
        };
        let (y, cache) = mlp.forward(&x, 1);
        let dy: Vec<f64> = y.iter().zip(&target).map(|(a, b)| a - b).collect();
        let (grads, _) = mlp.backward(&cache, &dy);

        let eps = 1e-6;
        for li in 0..mlp.layers.len() {
            for wi in 0..mlp.layers[li].w.len() {
                let mut mp = mlp.clone();
                mp.layers[li].w[wi] += eps;
                let mut mm = mlp.clone();
                mm.layers[li].w[wi] -= eps;
                let fd = (loss(&mp) - loss(&mm)) / (2.0 * eps);
                let an = grads.dw[li][wi];
                assert!(
                    (fd - an).abs() < 1e-5 * (1.0 + fd.abs()),
                    "layer {li} w[{wi}]: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn input_gradient_matches_finite_difference() {
        let mlp = Mlp::new(&[4, 6, 1], Act::Relu, 7);
        let x = [0.1, 0.9, -0.4, 0.2];
        let f = |x: &[f64]| mlp.infer(x)[0];
        let (_, cache) = mlp.forward(&x, 1);
        let (_, dx) = mlp.backward(&cache, &[1.0]);
        let eps = 1e-6;
        for i in 0..x.len() {
            let mut xp = x;
            xp[i] += eps;
            let mut xm = x;
            xm[i] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 1e-5 * (1.0 + fd.abs()),
                "dx[{i}]: fd={fd} analytic={}",
                dx[i]
            );
        }
    }

    #[test]
    fn batch_forward_matches_single() {
        let mlp = Mlp::new(&[3, 4, 2], Act::Relu, 5);
        let a = [0.1, 0.2, 0.3];
        let b = [-0.5, 0.4, 0.9];
        let batched: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
        let (y, _) = mlp.forward(&batched, 2);
        let ya = mlp.infer(&a);
        let yb = mlp.infer(&b);
        assert_eq!(&y[0..2], ya.as_slice());
        assert_eq!(&y[2..4], yb.as_slice());
    }

    #[test]
    fn adam_fits_xor() {
        let mut mlp = Mlp::new(&[2, 16, 1], Act::Tanh, 3);
        let mut opt = Adam::new(&mlp, 0.01);
        let data = [
            ([0.0, 0.0], 0.0),
            ([0.0, 1.0], 1.0),
            ([1.0, 0.0], 1.0),
            ([1.0, 1.0], 0.0),
        ];
        for _ in 0..2000 {
            let mut total = Grads::zeros_like(&mlp);
            for (x, t) in &data {
                let (y, cache) = mlp.forward(x, 1);
                let (g, _) = mlp.backward(&cache, &[y[0] - t]);
                total.add(&g);
            }
            total.scale(0.25);
            opt.step(&mut mlp, &total);
        }
        for (x, t) in &data {
            let y = mlp.infer(x)[0];
            assert!((y - t).abs() < 0.1, "xor({x:?}) = {y}, want {t}");
        }
    }

    #[test]
    fn polyak_moves_toward_source() {
        let mut a = Mlp::new(&[2, 2], Act::Identity, 1);
        let b = Mlp::new(&[2, 2], Act::Identity, 2);
        let before = a.layers[0].w[0];
        a.polyak_from(&b, 0.5);
        let after = a.layers[0].w[0];
        let expect = 0.5 * before + 0.5 * b.layers[0].w[0];
        assert!((after - expect).abs() < 1e-12);
    }
}
