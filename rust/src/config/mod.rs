//! Typed configuration system: paths + engine/scheduler knobs, loadable
//! from a JSON file with CLI overrides (the launcher in main.rs).

use crate::util::json::{self, Value};
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Top-level runtime configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// artifacts directory (AOT outputs).
    pub artifacts: PathBuf,
    /// device profile id (key in devices.json).
    pub device: String,
    /// model name (key in artifacts/models).
    pub model: String,
    /// scheduling policy: sac | greedy | dp | threshold | <baseline>.
    pub policy: String,
    /// batch size (0 = let Alg. 2 pick).
    pub batch: usize,
    /// SAC training episodes.
    pub episodes: usize,
    /// hardware-dynamics noise amplitude.
    pub noise: f64,
    /// serving: request rate (req/s) and count for `serve`.
    pub request_rate: f64,
    pub num_requests: usize,
    pub seed: u64,
    /// execution backend: sim | pjrt | both (infer runs sim then real).
    pub backend: String,
    /// verbose output (per-op timelines in `infer`); bare `--verbose`.
    pub verbose: bool,
    /// serve-multi: load multiplier on every tenant's arrival rate.
    pub load: f64,
    /// serve-multi: JSON trace file to replay ("" = built-in trace).
    pub trace: String,
    /// emit machine-readable JSON instead of tables; bare `--json`.
    pub json: bool,
    /// serve-fleet: number of simulated boards.
    pub boards: usize,
    /// serve-fleet: router policy (round-robin | jsq | cost-aware).
    pub router: String,
    /// serve-fleet: run the replica autoscaler; bare `--autoscale`.
    pub autoscale: bool,
    /// serve-fleet: DVFS governor (race-to-idle | stretch-to-deadline |
    /// fixed:N | off).  `off` disables energy accounting entirely
    /// (boards dispatch at full frequency, no energy columns).
    pub governor: String,
    /// serve-fleet: per-board power cap in watts (0 = uncapped).
    pub power_cap_w: f64,
    /// serve-fleet: write a virtual-time execution trace to this path
    /// ("" = tracing disabled; zero overhead).
    pub trace_out: String,
    /// serve-fleet: trace export format (folded | chrome).  `folded` is
    /// flamegraph.pl/inferno collapsed-stack text; `chrome` is Chrome
    /// trace-event JSON loadable in Perfetto / chrome://tracing.
    pub trace_format: String,
    /// serve-fleet: fault plan JSON file to inject ("" = fault-free).
    /// See [`crate::faults::FaultPlan::from_json`] for the schema.
    pub faults: String,
    /// serve-fleet: mean time to failure, seconds of virtual time
    /// (0 = no sampled faults).  With `mttr_s` > 0 a crash/rejoin
    /// schedule is sampled per board from exponential distributions;
    /// combined with `--faults=FILE` the sampled faults are appended.
    pub mttf_s: f64,
    /// serve-fleet: mean time to repair, seconds of virtual time
    /// (used only when `mttf_s` > 0).
    pub mttr_s: f64,
    /// serve-fleet: preemption / work re-placement policy
    /// (off | deadline-burn | burn-plus-steal).  `off` keeps the
    /// run-to-completion path bit-identical to earlier releases.
    pub preempt: String,
    /// serve-fleet: hedged dispatch for deadline-at-risk interactive
    /// requests (on | off).  `off` keeps the single-copy dispatch
    /// path bit-identical to earlier releases.
    pub hedge: String,
    /// serve-fleet: gray-failure circuit breaker per board
    /// (on | off).  `off` keeps routing/steal/autoscale placement
    /// bit-identical to earlier releases.
    pub breaker: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts: crate::artifacts_dir(),
            device: "agx_orin".into(),
            model: "mobilenet_v3_small".into(),
            policy: "sac".into(),
            batch: 1,
            episodes: 60,
            noise: 0.03,
            request_rate: 50.0,
            num_requests: 200,
            seed: 1,
            // Real PJRT execution needs the `pjrt` cargo feature; the
            // stub-runtime build defaults to simulator-only.
            backend: if cfg!(feature = "pjrt") { "both" } else { "sim" }
                .into(),
            verbose: false,
            load: 1.0,
            trace: String::new(),
            json: false,
            boards: 4,
            router: "cost-aware".into(),
            autoscale: false,
            governor: "race-to-idle".into(),
            power_cap_w: 0.0,
            trace_out: String::new(),
            trace_format: "folded".into(),
            faults: String::new(),
            mttf_s: 0.0,
            mttr_s: 0.0,
            preempt: "off".into(),
            hedge: "off".into(),
            breaker: "off".into(),
        }
    }
}

/// Validate an on/off tail-tolerance switch (`hedge`, `breaker`).
fn check_on_off(key: &str, s: &str) -> Result<()> {
    anyhow::ensure!(
        matches!(s, "on" | "off"),
        "{key} must be on|off, got `{s}`"
    );
    Ok(())
}

/// Validate a `preempt` spelling: anything
/// [`crate::serve::PreemptionPolicy::parse`] accepts.
fn check_preempt(s: &str) -> Result<()> {
    anyhow::ensure!(
        crate::serve::PreemptionPolicy::parse(s).is_some(),
        "preempt must be off|deadline-burn|burn-plus-steal, got `{s}`"
    );
    Ok(())
}

/// Validate a `governor` spelling: `off` or anything
/// [`crate::power::Governor::parse`] accepts.
fn check_governor(s: &str) -> Result<()> {
    anyhow::ensure!(
        s == "off" || crate::power::Governor::parse(s).is_ok(),
        "governor must be race-to-idle|stretch-to-deadline|fixed:N|off, \
         got `{s}`"
    );
    Ok(())
}

/// Validate a `trace_format` spelling: the two exporters in
/// [`crate::obs`].
fn check_trace_format(s: &str) -> Result<()> {
    anyhow::ensure!(
        matches!(s, "folded" | "chrome"),
        "trace_format must be folded|chrome, got `{s}`"
    );
    Ok(())
}

impl Config {
    /// Load from a JSON file, falling back to defaults per field.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing config: {e}"))?;
        Self::from_json(&v)
    }

    /// Build from parsed JSON; rejects invalid enum-like values (same
    /// rules as [`Config::apply_override`]).
    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(b) = v.get("backend").as_str() {
            if !matches!(b, "sim" | "pjrt" | "both") {
                anyhow::bail!("backend must be sim|pjrt|both, got `{b}`");
            }
        }
        if let Some(r) = v.get("router").as_str() {
            if crate::serve::RouterPolicy::parse(r).is_none() {
                anyhow::bail!(
                    "router must be round-robin|jsq|cost-aware, got `{r}`"
                );
            }
        }
        if let Some(g) = v.get("governor").as_str() {
            check_governor(g)?;
        }
        if let Some(f) = v.get("trace_format").as_str() {
            check_trace_format(f)?;
        }
        if let Some(p) = v.get("preempt").as_str() {
            check_preempt(p)?;
        }
        if let Some(h) = v.get("hedge").as_str() {
            check_on_off("hedge", h)?;
        }
        if let Some(b) = v.get("breaker").as_str() {
            check_on_off("breaker", b)?;
        }
        let d = Config::default();
        Ok(Config {
            artifacts: v
                .get("artifacts")
                .as_str()
                .map(PathBuf::from)
                .unwrap_or(d.artifacts),
            device: v.get("device").as_str().unwrap_or(&d.device).into(),
            model: v.get("model").as_str().unwrap_or(&d.model).into(),
            policy: v.get("policy").as_str().unwrap_or(&d.policy).into(),
            batch: v.get("batch").as_usize().unwrap_or(d.batch),
            episodes: v.get("episodes").as_usize().unwrap_or(d.episodes),
            noise: v.get("noise").as_f64().unwrap_or(d.noise),
            request_rate: v
                .get("request_rate")
                .as_f64()
                .unwrap_or(d.request_rate),
            num_requests: v
                .get("num_requests")
                .as_usize()
                .unwrap_or(d.num_requests),
            seed: v.get("seed").as_f64().map(|x| x as u64).unwrap_or(d.seed),
            backend: v.get("backend").as_str().unwrap_or(&d.backend).into(),
            verbose: v
                .get("verbose")
                .as_bool()
                .unwrap_or(d.verbose),
            load: v.get("load").as_f64().unwrap_or(d.load),
            trace: v.get("trace").as_str().unwrap_or(&d.trace).into(),
            json: v.get("json").as_bool().unwrap_or(d.json),
            boards: v.get("boards").as_usize().unwrap_or(d.boards),
            router: v.get("router").as_str().unwrap_or(&d.router).into(),
            autoscale: v
                .get("autoscale")
                .as_bool()
                .unwrap_or(d.autoscale),
            governor: v
                .get("governor")
                .as_str()
                .unwrap_or(&d.governor)
                .into(),
            power_cap_w: v
                .get("power_cap_w")
                .as_f64()
                .unwrap_or(d.power_cap_w),
            trace_out: v
                .get("trace_out")
                .as_str()
                .unwrap_or(&d.trace_out)
                .into(),
            trace_format: v
                .get("trace_format")
                .as_str()
                .unwrap_or(&d.trace_format)
                .into(),
            faults: v.get("faults").as_str().unwrap_or(&d.faults).into(),
            mttf_s: check_mean_time(
                "mttf_s",
                v.get("mttf_s").as_f64().unwrap_or(d.mttf_s),
            )?,
            mttr_s: check_mean_time(
                "mttr_s",
                v.get("mttr_s").as_f64().unwrap_or(d.mttr_s),
            )?,
            preempt: v
                .get("preempt")
                .as_str()
                .unwrap_or(&d.preempt)
                .into(),
            hedge: v.get("hedge").as_str().unwrap_or(&d.hedge).into(),
            breaker: v
                .get("breaker")
                .as_str()
                .unwrap_or(&d.breaker)
                .into(),
        })
    }

    /// Apply `--key=value` style overrides.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "artifacts" => self.artifacts = PathBuf::from(value),
            "device" => self.device = value.into(),
            "model" => self.model = value.into(),
            "policy" => self.policy = value.into(),
            "batch" => self.batch = value.parse()?,
            "episodes" => self.episodes = value.parse()?,
            "noise" => self.noise = value.parse()?,
            "request_rate" => self.request_rate = value.parse()?,
            "num_requests" => self.num_requests = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "backend" => match value {
                "sim" | "pjrt" | "both" => self.backend = value.into(),
                other => {
                    anyhow::bail!("backend must be sim|pjrt|both, got `{other}`")
                }
            },
            "verbose" => self.verbose = parse_bool(value)?,
            "load" => self.load = value.parse()?,
            "trace" => self.trace = value.into(),
            "json" => self.json = parse_bool(value)?,
            "boards" => self.boards = value.parse()?,
            "router" => {
                anyhow::ensure!(
                    crate::serve::RouterPolicy::parse(value).is_some(),
                    "router must be round-robin|jsq|cost-aware, \
                     got `{value}`"
                );
                self.router = value.into();
            }
            "autoscale" => self.autoscale = parse_bool(value)?,
            "governor" => {
                check_governor(value)?;
                self.governor = value.into();
            }
            "power_cap_w" => {
                let w: f64 = value.parse()?;
                anyhow::ensure!(
                    w.is_finite() && w >= 0.0,
                    "power_cap_w must be >= 0 (0 = uncapped), got `{value}`"
                );
                self.power_cap_w = w;
            }
            "trace_out" => self.trace_out = value.into(),
            "trace_format" => {
                check_trace_format(value)?;
                self.trace_format = value.into();
            }
            "faults" => self.faults = value.into(),
            "mttf_s" => {
                self.mttf_s = check_mean_time("mttf_s", value.parse()?)?;
            }
            "mttr_s" => {
                self.mttr_s = check_mean_time("mttr_s", value.parse()?)?;
            }
            "preempt" => {
                check_preempt(value)?;
                self.preempt = value.into();
            }
            "hedge" => {
                check_on_off("hedge", value)?;
                self.hedge = value.into();
            }
            "breaker" => {
                check_on_off("breaker", value)?;
                self.breaker = value.into();
            }
            other => anyhow::bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    pub fn devices_json(&self) -> PathBuf {
        self.artifacts.join("devices.json")
    }
}

/// Validate an MTTF/MTTR mean: finite and non-negative (0 = off).
fn check_mean_time(key: &str, v: f64) -> Result<f64> {
    anyhow::ensure!(
        v.is_finite() && v >= 0.0,
        "{key} must be >= 0 seconds (0 = disabled), got `{v}`"
    );
    Ok(v)
}

/// Boolean flag values: bare `--flag` arrives as "true" from the CLI.
fn parse_bool(value: &str) -> Result<bool> {
    match value {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => anyhow::bail!("expected a boolean, got `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_and_overrides() {
        let v = json::parse(
            r#"{"model": "vit_b16", "batch": 4, "noise": 0.1}"#).unwrap();
        let mut c = Config::from_json(&v).unwrap();
        assert_eq!(c.model, "vit_b16");
        assert_eq!(c.batch, 4);
        assert!((c.noise - 0.1).abs() < 1e-12);
        assert_eq!(c.device, "agx_orin"); // default preserved
        c.apply_override("device", "orin_nano").unwrap();
        assert_eq!(c.device, "orin_nano");
        assert!(c.apply_override("bogus", "1").is_err());
        assert!(c.apply_override("batch", "not_a_number").is_err());
    }

    #[test]
    fn backend_and_bool_overrides() {
        let mut c = Config::default();
        let expect = if cfg!(feature = "pjrt") { "both" } else { "sim" };
        assert_eq!(c.backend, expect);
        assert!(!c.verbose);
        c.apply_override("backend", "sim").unwrap();
        assert_eq!(c.backend, "sim");
        assert!(c.apply_override("backend", "cuda").is_err());
        c.apply_override("verbose", "true").unwrap(); // bare `--verbose`
        assert!(c.verbose);
        c.apply_override("verbose", "off").unwrap();
        assert!(!c.verbose);
        assert!(c.apply_override("verbose", "maybe").is_err());
        // serve-multi knobs
        assert!((c.load - 1.0).abs() < 1e-12 && c.trace.is_empty());
        c.apply_override("load", "2.5").unwrap();
        assert!((c.load - 2.5).abs() < 1e-12);
        c.apply_override("trace", "t.json").unwrap();
        assert_eq!(c.trace, "t.json");
        c.apply_override("json", "true").unwrap(); // bare `--json`
        assert!(c.json);
        assert!(c.apply_override("load", "fast").is_err());
        // serve-fleet knobs
        assert_eq!(c.boards, 4);
        assert_eq!(c.router, "cost-aware");
        assert!(!c.autoscale); // opt-in, like every other bare flag
        c.apply_override("boards", "8").unwrap();
        assert_eq!(c.boards, 8);
        c.apply_override("router", "jsq").unwrap();
        assert_eq!(c.router, "jsq");
        assert!(c.apply_override("router", "random").is_err());
        c.apply_override("autoscale", "true").unwrap(); // bare flag
        assert!(c.autoscale);
        let bad_router = json::parse(r#"{"router": "dice"}"#).unwrap();
        assert!(Config::from_json(&bad_router).is_err());
        let good_router =
            json::parse(r#"{"router": "round-robin", "boards": 2}"#)
                .unwrap();
        let cr = Config::from_json(&good_router).unwrap();
        assert_eq!(cr.router, "round-robin");
        assert_eq!(cr.boards, 2);
        // power knobs
        assert_eq!(c.governor, "race-to-idle");
        assert_eq!(c.power_cap_w, 0.0); // uncapped
        c.apply_override("governor", "stretch-to-deadline").unwrap();
        assert_eq!(c.governor, "stretch-to-deadline");
        c.apply_override("governor", "fixed:2").unwrap();
        c.apply_override("governor", "off").unwrap();
        assert!(c.apply_override("governor", "warp-speed").is_err());
        c.apply_override("power_cap_w", "25.5").unwrap();
        assert!((c.power_cap_w - 25.5).abs() < 1e-12);
        assert!(c.apply_override("power_cap_w", "-3").is_err());
        let bad_gov = json::parse(r#"{"governor": "dice"}"#).unwrap();
        assert!(Config::from_json(&bad_gov).is_err());
        let good_gov = json::parse(
            r#"{"governor": "stretch-to-deadline", "power_cap_w": 40}"#)
            .unwrap();
        let cg = Config::from_json(&good_gov).unwrap();
        assert_eq!(cg.governor, "stretch-to-deadline");
        assert!((cg.power_cap_w - 40.0).abs() < 1e-12);
        // trace knobs
        assert!(c.trace_out.is_empty());
        assert_eq!(c.trace_format, "folded");
        c.apply_override("trace_out", "/tmp/t.folded").unwrap();
        assert_eq!(c.trace_out, "/tmp/t.folded");
        c.apply_override("trace_format", "chrome").unwrap();
        assert_eq!(c.trace_format, "chrome");
        assert!(c.apply_override("trace_format", "svg").is_err());
        let bad_fmt = json::parse(r#"{"trace_format": "svg"}"#).unwrap();
        assert!(Config::from_json(&bad_fmt).is_err());
        let good_fmt = json::parse(
            r#"{"trace_format": "chrome", "trace_out": "x.json"}"#)
            .unwrap();
        let cf = Config::from_json(&good_fmt).unwrap();
        assert_eq!(cf.trace_format, "chrome");
        assert_eq!(cf.trace_out, "x.json");
        // fault-injection knobs
        assert!(c.faults.is_empty());
        assert_eq!(c.mttf_s, 0.0);
        assert_eq!(c.mttr_s, 0.0);
        c.apply_override("faults", "plan.json").unwrap();
        assert_eq!(c.faults, "plan.json");
        c.apply_override("mttf_s", "120").unwrap();
        c.apply_override("mttr_s", "4.5").unwrap();
        assert!((c.mttf_s - 120.0).abs() < 1e-12);
        assert!((c.mttr_s - 4.5).abs() < 1e-12);
        assert!(c.apply_override("mttf_s", "-1").is_err());
        assert!(c.apply_override("mttr_s", "inf").is_err());
        let bad_mttf = json::parse(r#"{"mttf_s": -2.0}"#).unwrap();
        assert!(Config::from_json(&bad_mttf).is_err());
        let good_faults = json::parse(
            r#"{"faults": "f.json", "mttf_s": 60, "mttr_s": 2}"#)
            .unwrap();
        let cfj = Config::from_json(&good_faults).unwrap();
        assert_eq!(cfj.faults, "f.json");
        assert!((cfj.mttf_s - 60.0).abs() < 1e-12);
        assert!((cfj.mttr_s - 2.0).abs() < 1e-12);
        // preemption knob
        assert_eq!(c.preempt, "off");
        c.apply_override("preempt", "deadline-burn").unwrap();
        assert_eq!(c.preempt, "deadline-burn");
        c.apply_override("preempt", "burn-plus-steal").unwrap();
        assert_eq!(c.preempt, "burn-plus-steal");
        assert!(c.apply_override("preempt", "always").is_err());
        let bad_preempt = json::parse(r#"{"preempt": "dice"}"#).unwrap();
        assert!(Config::from_json(&bad_preempt).is_err());
        let good_preempt =
            json::parse(r#"{"preempt": "deadline-burn"}"#).unwrap();
        assert_eq!(Config::from_json(&good_preempt).unwrap().preempt,
                   "deadline-burn");
        // tail-tolerance knobs
        assert_eq!(c.hedge, "off");
        assert_eq!(c.breaker, "off");
        c.apply_override("hedge", "on").unwrap();
        assert_eq!(c.hedge, "on");
        c.apply_override("breaker", "on").unwrap();
        assert_eq!(c.breaker, "on");
        assert!(c.apply_override("hedge", "maybe").is_err());
        assert!(c.apply_override("breaker", "1").is_err());
        let bad_hedge = json::parse(r#"{"hedge": "always"}"#).unwrap();
        assert!(Config::from_json(&bad_hedge).is_err());
        let good_tail = json::parse(
            r#"{"hedge": "on", "breaker": "on"}"#).unwrap();
        let ct = Config::from_json(&good_tail).unwrap();
        assert_eq!(ct.hedge, "on");
        assert_eq!(ct.breaker, "on");
        // Config files get the same backend validation as the CLI.
        let bad = json::parse(r#"{"backend": "cuda"}"#).unwrap();
        assert!(Config::from_json(&bad).is_err());
        let good = json::parse(r#"{"backend": "sim"}"#).unwrap();
        assert_eq!(Config::from_json(&good).unwrap().backend, "sim");
    }
}
