//! Serving front-end: request generation, queueing, dynamic batching and
//! latency/throughput metrics — the online half of SparOA (§5), and the
//! substrate for the Fig. 8 batching-overhead reproduction.

pub mod batcher;
pub mod metrics;

pub use batcher::{
    run_batching, run_batching_sim, BatchPolicy, BatchingReport, Request,
};
pub use metrics::{LatencyHistogram, ServeMetrics};
