//! Request batching over a virtual-time arrival stream.
//!
//! Two policies:
//! * `Fixed` — frameworks with static batch sizes: wait until `size`
//!   requests arrive or `timeout_us` passes, then pad to `size`.  Padding
//!   slots burn compute; the wait and the padding are both *batching
//!   overhead* (Fig. 8 reports them at 15.4–28.7% for static frameworks).
//! * `Dynamic` — SparOA: take whatever the queue holds (bounded by the
//!   Alg. 2 optimum), no padding, plus a small optimizer cost per batch.

use crate::api::{ExecuteRequest, ExecutionBackend, SimBackend};
use crate::device::DeviceModel;
use crate::engine::sim::SimOptions;
use crate::graph::ModelGraph;
use crate::scheduler::Schedule;
use crate::util::rng::Rng;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub arrival_us: f64,
}

/// Poisson arrival stream at `rate` req/s.
pub fn poisson_stream(n: usize, rate_per_s: f64, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|id| {
            t += rng.exponential(rate_per_s) * 1e6;
            Request { id, arrival_us: t }
        })
        .collect()
}

#[derive(Debug, Clone)]
pub enum BatchPolicy {
    /// Pad to `size`; flush on `timeout_us`.
    Fixed { size: usize, timeout_us: f64 },
    /// Take min(queue, max) — SparOA's dynamic batching (Alg. 2 optimum).
    Dynamic { max: usize, optimizer_cost_us: f64 },
}

#[derive(Debug, Clone, Default)]
pub struct BatchingReport {
    pub n_requests: usize,
    pub n_batches: usize,
    /// pure inference time attributable to real requests, us
    pub inference_us: f64,
    /// padding waste + assembly wait + optimizer cost, us
    pub overhead_us: f64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
}

impl BatchingReport {
    /// Fig. 8's Y-axis: overhead share of end-to-end time.  An empty
    /// request stream has no end-to-end time and therefore no overhead
    /// (0.0, not NaN).
    pub fn overhead_pct(&self) -> f64 {
        let total = self.overhead_us + self.inference_us;
        if total <= 0.0 {
            return 0.0;
        }
        100.0 * self.overhead_us / total
    }
}

/// Virtual-time batching simulation of one policy on the simulator
/// backend (the Fig. 8 path; infallible).
pub fn run_batching_sim(
    graph: &ModelGraph,
    dev: &DeviceModel,
    sched: &Schedule,
    opts: &SimOptions,
    requests: &[Request],
    policy: &BatchPolicy,
) -> BatchingReport {
    run_batching(&SimBackend, graph, dev, sched, opts, requests, policy)
        .expect("sim backend is infallible")
}

/// Virtual-time batching over an arbitrary execution backend: per-batch
/// inference latency is the `makespan_us` that `backend.execute` reports
/// at each batch size (cached per size).  The arrival stream and
/// queueing always stay in virtual time; a real backend additionally
/// executes one synthesized batch per probed size (its latencies still
/// come from the shared calibrated timeline, so results match
/// [`SimBackend`] — pay the real execution only when you want the
/// numerics side effects).
pub fn run_batching(
    backend: &dyn ExecutionBackend,
    graph: &ModelGraph,
    dev: &DeviceModel,
    sched: &Schedule,
    opts: &SimOptions,
    requests: &[Request],
    policy: &BatchPolicy,
) -> Result<BatchingReport> {
    let mut now = 0.0f64;
    let mut i = 0usize;
    let mut latencies = Vec::with_capacity(requests.len());
    let mut rep = BatchingReport { n_requests: requests.len(),
                                   ..Default::default() };
    let mut batch_sizes = Vec::new();

    // Per-batch-size inference latency cache.
    let mut lat_cache: std::collections::HashMap<usize, f64> =
        std::collections::HashMap::new();
    let mut lat_of = |b: usize| -> Result<f64> {
        if let Some(&l) = lat_cache.get(&b) {
            return Ok(l);
        }
        let mut o = opts.clone();
        o.batch = b;
        let r = backend.execute(&ExecuteRequest {
            graph,
            device: dev,
            schedule: sched,
            options: &o,
            inputs: &[],
        })?;
        lat_cache.insert(b, r.makespan_us);
        Ok(r.makespan_us)
    };

    while i < requests.len() {
        // Engine idle: jump to next arrival if queue empty.
        now = now.max(requests[i].arrival_us);
        // Queue contents at `now`.
        let mut take = 0usize;
        while i + take < requests.len()
            && requests[i + take].arrival_us <= now
        {
            take += 1;
        }
        let (exec_size, real, wait_extra, policy_cost) = match policy {
            BatchPolicy::Fixed { size, timeout_us } => {
                // Wait for `size` arrivals or the timeout.
                let deadline = now + timeout_us;
                let mut k = take;
                while i + k < requests.len()
                    && requests[i + k].arrival_us <= deadline
                    && k < *size
                {
                    k += 1;
                }
                let ready_at = if k >= *size {
                    requests[i + k - 1].arrival_us.max(now)
                } else {
                    deadline
                };
                (*size, k.min(*size), ready_at - now, 0.0)
            }
            BatchPolicy::Dynamic { max, optimizer_cost_us } => {
                let k = take.clamp(1, *max);
                (k, k, 0.0, *optimizer_cost_us)
            }
        };
        now += wait_extra + policy_cost;
        let lat = lat_of(exec_size)?;
        let finish = now + lat;
        // Overhead attribution: padding slots + wait + optimizer cost.
        let pad_frac =
            (exec_size - real) as f64 / exec_size as f64;
        rep.overhead_us += lat * pad_frac + wait_extra + policy_cost;
        rep.inference_us += lat * (1.0 - pad_frac);
        for r in &requests[i..i + real] {
            latencies.push(finish - r.arrival_us);
        }
        batch_sizes.push(real);
        rep.n_batches += 1;
        i += real;
        now = finish;
    }

    rep.mean_latency_us = crate::util::stats::mean(&latencies);
    rep.p99_latency_us = crate::util::stats::percentile(&latencies, 99.0);
    rep.throughput_rps = requests.len() as f64 / (now / 1e6);
    rep.mean_batch = crate::util::stats::mean(
        &batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>());
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic graph + checked-in device profile: these tests always
    /// run — no `make artifacts` gating, no silent skips.
    fn fixture() -> (ModelGraph, DeviceModel) {
        let g = ModelGraph::synthetic("batch_fixture", 6, 1.0, 0.5);
        (g, crate::bench_support::device_profile("agx_orin"))
    }

    #[test]
    fn poisson_interarrivals_mean() {
        let reqs = poisson_stream(5000, 100.0, 3);
        let mean_gap = reqs.last().unwrap().arrival_us / 5000.0;
        assert!((mean_gap - 10_000.0).abs() < 1_000.0, "gap {mean_gap}");
        for w in reqs.windows(2) {
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
    }

    #[test]
    fn overhead_pct_is_zero_for_empty_stream() {
        let rep = BatchingReport::default();
        assert_eq!(rep.overhead_pct(), 0.0);
        let (g, dev) = fixture();
        let sched = Schedule::uniform(&g, 1.0, "gpu");
        let served = run_batching_sim(&g, &dev, &sched,
            &SimOptions::default(), &[], &BatchPolicy::Dynamic {
                max: 8, optimizer_cost_us: 30.0 });
        assert_eq!(served.n_requests, 0);
        assert_eq!(served.overhead_pct(), 0.0);
        assert!(served.overhead_pct().is_finite());
    }

    #[test]
    fn dynamic_batching_has_lower_overhead_than_fixed() {
        let (g, dev) = fixture();
        let sched = Schedule::uniform(&g, 1.0, "gpu");
        let opts = SimOptions::default();
        let reqs = poisson_stream(400, 300.0, 7);
        let fixed = run_batching_sim(&g, &dev, &sched, &opts, &reqs,
            &BatchPolicy::Fixed { size: 32, timeout_us: 20_000.0 });
        let dynamic = run_batching_sim(&g, &dev, &sched, &opts, &reqs,
            &BatchPolicy::Dynamic { max: 64, optimizer_cost_us: 30.0 });
        assert!(dynamic.overhead_pct() < fixed.overhead_pct(),
                "dyn {:.1}% vs fixed {:.1}%", dynamic.overhead_pct(),
                fixed.overhead_pct());
        assert_eq!(
            fixed.n_requests,
            dynamic.n_requests
        );
    }

    #[test]
    fn all_requests_served_exactly_once() {
        let (g, dev) = fixture();
        let sched = Schedule::uniform(&g, 1.0, "gpu");
        let reqs = poisson_stream(137, 80.0, 5);
        for policy in [
            BatchPolicy::Fixed { size: 8, timeout_us: 10_000.0 },
            BatchPolicy::Dynamic { max: 16, optimizer_cost_us: 20.0 },
        ] {
            let rep = run_batching_sim(&g, &dev, &sched,
                &SimOptions::default(), &reqs, &policy);
            assert_eq!(rep.n_requests, 137);
            assert!(rep.mean_latency_us > 0.0);
            assert!(rep.throughput_rps > 0.0);
        }
    }
}
