//! Serving metrics: latency distribution, throughput, SLO attainment.

use crate::util::stats;

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    latencies_us: Vec<f64>,
    start: Option<std::time::Instant>,
    elapsed_s: f64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        ServeMetrics { start: Some(std::time::Instant::now()),
                       ..Default::default() }
    }

    pub fn record(&mut self, latency_us: f64) {
        self.latencies_us.push(latency_us);
    }

    pub fn finish(&mut self) {
        if let Some(s) = self.start.take() {
            self.elapsed_s = s.elapsed().as_secs_f64();
        }
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }
    pub fn mean_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }
    pub fn p50_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 50.0)
    }
    pub fn p99_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 99.0)
    }
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.count() as f64 / self.elapsed_s
        } else {
            0.0
        }
    }
    /// Fraction of requests within `slo_us`.
    pub fn slo_attainment(&self, slo_us: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().filter(|&&l| l <= slo_us).count() as f64
            / self.latencies_us.len() as f64
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}us p50={:.1}us p99={:.1}us \
             throughput={:.1} req/s",
            self.count(),
            self.mean_us(),
            self.p50_us(),
            self.p99_us(),
            self.throughput_rps()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let mut m = ServeMetrics::new();
        for i in 1..=100 {
            m.record(i as f64 * 100.0);
        }
        m.finish();
        assert_eq!(m.count(), 100);
        assert!((m.mean_us() - 5050.0).abs() < 1.0);
        assert!((m.p50_us() - 5050.0).abs() < 110.0);
        assert!(m.p99_us() >= 9800.0);
        assert!((m.slo_attainment(5000.0) - 0.5).abs() < 0.02);
        assert!(m.throughput_rps() > 0.0);
    }
}
