//! Serving metrics: latency distribution, throughput, SLO attainment.
//!
//! The latency store is a fixed-size log-bucketed histogram
//! ([`LatencyHistogram`]), not a growing `Vec`: memory stays bounded
//! under sustained traffic (4 KB per histogram regardless of request
//! count) while `count`/`mean`/`min`/`max` remain exact and quantiles
//! are accurate to one bucket width (~3.7% relative).

use crate::util::json::Value;
use std::collections::BTreeMap;

/// Number of log-spaced buckets.
const BUCKETS: usize = 512;
/// Lower edge of bucket 0, microseconds.
const LO_US: f64 = 1.0;
/// Upper edge of the last bucket, microseconds (100 s).
const HI_US: f64 = 1e8;

/// Bounded-memory latency histogram with log-spaced buckets over
/// [1us, 100s].  Samples outside the range clamp into the edge buckets
/// (count/mean stay exact regardless).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// ln(bucket upper edge / lower edge), identical for every bucket.
fn ln_ratio() -> f64 {
    (HI_US / LO_US).ln() / BUCKETS as f64
}

fn bucket_of(x: f64) -> usize {
    let x = x.max(LO_US);
    (((x / LO_US).ln() / ln_ratio()) as usize).min(BUCKETS - 1)
}

/// Lower edge of bucket `i`, microseconds.
fn bucket_lo(i: usize) -> f64 {
    LO_US * (i as f64 * ln_ratio()).exp()
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, x_us: f64) {
        self.counts[bucket_of(x_us)] += 1;
        self.count += 1;
        self.sum += x_us;
        self.min = self.min.min(x_us);
        self.max = self.max.max(x_us);
    }

    /// Fold another histogram in (per-class -> aggregate roll-ups).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean (the running sum is not bucketed).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Quantile estimate, `p` in [0, 100]: geometric interpolation inside
    /// the covering bucket, clamped to the exact observed [min, max].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (p / 100.0).clamp(0.0, 1.0)
            * (self.count.saturating_sub(1)) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 > target {
                let frac = (target - cum as f64) / c as f64;
                let v = bucket_lo(i) * (frac * ln_ratio()).exp();
                return v.clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    /// Estimated fraction of samples `<= x_us` (log-linear interpolation
    /// inside the boundary bucket).
    pub fn fraction_le(&self, x_us: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if x_us >= self.max {
            return 1.0;
        }
        if x_us < self.min {
            return 0.0;
        }
        let b = bucket_of(x_us);
        let mut below = 0u64;
        for &c in &self.counts[..b] {
            below += c;
        }
        let inside = (x_us.max(LO_US) / bucket_lo(b)).ln() / ln_ratio();
        let part = self.counts[b] as f64 * inside.clamp(0.0, 1.0);
        ((below as f64 + part) / self.count as f64).clamp(0.0, 1.0)
    }

    /// Compact JSON for reports: count + mean + the standard quantiles.
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("count".into(), Value::Num(self.count as f64));
        if self.count > 0 {
            o.insert("mean_us".into(), Value::Num(self.mean_us()));
            o.insert("p50_us".into(), Value::Num(self.percentile(50.0)));
            o.insert("p95_us".into(), Value::Num(self.percentile(95.0)));
            o.insert("p99_us".into(), Value::Num(self.percentile(99.0)));
            o.insert("min_us".into(), Value::Num(self.min));
            o.insert("max_us".into(), Value::Num(self.max));
        }
        Value::Obj(o)
    }
}

/// Per-stream serving metrics over a [`LatencyHistogram`].
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    hist: LatencyHistogram,
    start: Option<std::time::Instant>,
    elapsed_s: f64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        ServeMetrics { start: Some(std::time::Instant::now()),
                       ..Default::default() }
    }

    pub fn record(&mut self, latency_us: f64) {
        self.hist.record(latency_us);
    }

    pub fn finish(&mut self) {
        if let Some(s) = self.start.take() {
            self.elapsed_s = s.elapsed().as_secs_f64();
        }
    }

    /// The underlying bounded histogram (per-class roll-ups, JSON).
    pub fn histogram(&self) -> &LatencyHistogram {
        &self.hist
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }
    pub fn mean_us(&self) -> f64 {
        self.hist.mean_us()
    }
    pub fn p50_us(&self) -> f64 {
        self.hist.percentile(50.0)
    }
    pub fn p95_us(&self) -> f64 {
        self.hist.percentile(95.0)
    }
    pub fn p99_us(&self) -> f64 {
        self.hist.percentile(99.0)
    }
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.count() as f64 / self.elapsed_s
        } else {
            0.0
        }
    }
    /// Fraction of requests within `slo_us`.
    pub fn slo_attainment(&self, slo_us: f64) -> f64 {
        self.hist.fraction_le(slo_us)
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}us p50={:.1}us p99={:.1}us \
             throughput={:.1} req/s",
            self.count(),
            self.mean_us(),
            self.p50_us(),
            self.p99_us(),
            self.throughput_rps()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let mut m = ServeMetrics::new();
        for i in 1..=100 {
            m.record(i as f64 * 100.0);
        }
        m.finish();
        assert_eq!(m.count(), 100);
        assert!((m.mean_us() - 5050.0).abs() < 1.0);
        assert!((m.p50_us() - 5050.0).abs() < 200.0);
        assert!(m.p99_us() >= 9700.0);
        assert!((m.slo_attainment(5000.0) - 0.5).abs() < 0.02);
        assert!(m.throughput_rps() > 0.0);
    }

    #[test]
    fn histogram_is_bounded_and_exact_on_count_mean() {
        let mut h = LatencyHistogram::new();
        let mut exact: Vec<f64> = Vec::new();
        let mut rng = crate::util::rng::Rng::new(41);
        for _ in 0..50_000 {
            let x = rng.exponential(1.0 / 3000.0); // mean 3000us
            h.record(x);
            exact.push(x);
        }
        assert_eq!(h.count(), 50_000);
        assert!((h.mean_us() - crate::util::stats::mean(&exact)).abs()
                < 1e-6);
        // Quantiles within one bucket width of the exact values.
        for p in [50.0, 95.0, 99.0] {
            let approx = h.percentile(p);
            let truth = crate::util::stats::percentile(&exact, p);
            let rel = (approx - truth).abs() / truth;
            assert!(rel < 0.05, "p{p}: approx {approx} vs exact {truth}");
        }
        // Memory is the fixed bucket array no matter the sample count.
        assert_eq!(h.counts.len(), BUCKETS);
    }

    #[test]
    fn histogram_merge_and_edges() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(10.0);
        a.record(100.0);
        b.record(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.mean_us() - 370.0).abs() < 1e-9);
        assert!(a.fraction_le(5.0) == 0.0);
        assert!(a.fraction_le(2000.0) == 1.0);
        // Out-of-range samples clamp into edge buckets; sums stay exact.
        let mut e = LatencyHistogram::new();
        e.record(0.0);
        e.record(1e12);
        assert_eq!(e.count(), 2);
        assert!((e.mean_us() - 5e11).abs() < 1.0);
        assert!(e.percentile(0.0) <= e.percentile(100.0));
    }

    #[test]
    fn empty_histogram_is_nan_like_stats() {
        let h = LatencyHistogram::new();
        assert!(h.mean_us().is_nan());
        assert!(h.percentile(50.0).is_nan());
        assert_eq!(h.fraction_le(10.0), 0.0);
    }
}
