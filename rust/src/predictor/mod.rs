//! Threshold-predictor client (paper §3) + the Table 3 accuracy harness.
//!
//! The trained Transformer-LSTM forward pass is an AOT HLO artifact
//! (`artifacts/predictor/thresh_predictor.hlo.txt`) queried through PJRT
//! during the *offline* scheduling phase — never on the request path.  The
//! LR baseline runs natively (a 7x2 affine map); the CNN baseline is a
//! second HLO artifact.

use crate::graph::ModelGraph;
use crate::runtime::{HostTensor, Runtime};
use crate::util::json;
use anyhow::{Context, Result};
use std::path::Path;

pub const SEQ_LEN: usize = 32;
pub const N_FEATURES: usize = 6;

/// Feature vector for one op (mirror of predictor.op_features in python).
pub fn op_features(op: &crate::graph::Op) -> [f32; N_FEATURES] {
    let s = op
        .exec_in_shapes
        .first()
        .cloned()
        .unwrap_or_else(|| op.exec_out_shape.clone());
    // Use PAPER-scale shapes for b/c/h/w features (what training saw).
    let ps = &op.paper_out_shape;
    let (b, h, w, c) = match ps.len() {
        4 => (ps[0], ps[1], ps[2], ps[3]),
        3 => (ps[0], ps[1], 1, ps[2]),
        2 => (ps[0], 1, 1, ps[1]),
        _ => (1, 1, 1, s.iter().product()),
    };
    let intensity = {
        let lf = (op.flops_paper.max(1.0)).log10();
        ((lf - 3.0) / 9.0).clamp(0.0, 1.0)
    };
    [
        op.sparsity_in as f32,
        intensity as f32,
        ((b.max(1) as f64).log2() / 8.0) as f32,
        ((c as f64 / 1024.0).min(2.0)) as f32,
        ((h as f64 / 256.0).min(2.0)) as f32,
        ((w as f64 / 256.0).min(2.0)) as f32,
    ]
}

/// The Transformer-LSTM predictor behind its HLO artifact.
pub struct ThresholdPredictor<'a> {
    runtime: &'a Runtime,
    artifact: String,
}

impl<'a> ThresholdPredictor<'a> {
    pub fn new(runtime: &'a Runtime) -> Self {
        ThresholdPredictor {
            runtime,
            artifact: "predictor/thresh_predictor.hlo.txt".into(),
        }
    }

    pub fn with_artifact(runtime: &'a Runtime, artifact: &str) -> Self {
        ThresholdPredictor { runtime, artifact: artifact.into() }
    }

    /// Predict (s*, c*) for a window of feature rows (<= SEQ_LEN).
    pub fn predict_window(&self, rows: &[[f32; N_FEATURES]])
        -> Result<Vec<(f64, f64)>>
    {
        anyhow::ensure!(rows.len() <= SEQ_LEN, "window too long");
        let mut data = vec![0.0f32; SEQ_LEN * N_FEATURES];
        for (i, r) in rows.iter().enumerate() {
            data[i * N_FEATURES..(i + 1) * N_FEATURES].copy_from_slice(r);
        }
        let x = HostTensor::new(vec![1, SEQ_LEN, N_FEATURES], data);
        let out = self.runtime.execute(&self.artifact, &[x])?;
        anyhow::ensure!(out.shape == vec![1, SEQ_LEN, 2],
                        "bad predictor output {:?}", out.shape);
        Ok((0..rows.len())
            .map(|i| (out.data[i * 2] as f64, out.data[i * 2 + 1] as f64))
            .collect())
    }

    /// Predict thresholds for every op of a model (windowed).
    pub fn predict_graph(&self, graph: &ModelGraph)
        -> Result<Vec<(f64, f64)>>
    {
        let feats: Vec<[f32; N_FEATURES]> =
            graph.ops.iter().map(op_features).collect();
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(SEQ_LEN) {
            out.extend(self.predict_window(chunk)?);
        }
        Ok(out)
    }
}

/// Native linear-regression baseline (Table 3 row "LR").
pub struct LinearPredictor {
    /// rows: [s; c], each of length N_FEATURES + 1 (bias last).
    pub w: [[f64; N_FEATURES + 1]; 2],
}

impl LinearPredictor {
    pub fn predict(&self, x: &[f32; N_FEATURES]) -> (f64, f64) {
        let mut out = [0.0f64; 2];
        for (o, row) in out.iter_mut().zip(&self.w) {
            *o = row[N_FEATURES];
            for i in 0..N_FEATURES {
                *o += row[i] * x[i] as f64;
            }
        }
        (out[0], out[1])
    }
}

/// The exported predictor evaluation dataset + frozen baselines.
pub struct PredictorDataset {
    pub seq_len: usize,
    /// test sequences: (x [T x F], y [T x 2], mask [T])
    pub sequences: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    pub lr: LinearPredictor,
    /// accuracies recorded at training time (python side), for parity
    /// checks: ours/lr/cnn -> (sparsity_acc, intensity_acc).
    pub trained_accuracy: Vec<(String, f64, f64)>,
    pub model_bytes: Vec<(String, f64)>,
}

impl PredictorDataset {
    pub fn load(artifacts: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(
            artifacts.join("predictor/dataset.json"))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("dataset.json: {e}"))?;
        let seq_len = v.f64_of("seq_len") as usize;
        let xs = v.get("test_x").as_arr().context("test_x")?;
        let ys = v.get("test_y").as_arr().context("test_y")?;
        let ms = v.get("test_mask").as_arr().context("test_mask")?;
        let mut sequences = Vec::new();
        for i in 0..xs.len() {
            let x: Vec<f32> =
                xs[i].vec_f64().iter().map(|&f| f as f32).collect();
            let y: Vec<f32> =
                ys[i].vec_f64().iter().map(|&f| f as f32).collect();
            let m: Vec<f32> =
                ms[i].vec_f64().iter().map(|&f| f as f32).collect();
            sequences.push((x, y, m));
        }
        let lrw = v.get("lr_weights");
        let mut w = [[0.0; N_FEATURES + 1]; 2];
        for (r, row) in w.iter_mut().enumerate() {
            let vals = lrw.idx(r).vec_f64();
            anyhow::ensure!(vals.len() == N_FEATURES + 1, "lr weights shape");
            row.copy_from_slice(&vals);
        }
        let acc = |k: &str| -> (f64, f64) {
            let a = v.get("accuracy").get(k).vec_f64();
            (a[0], a[1])
        };
        let trained_accuracy = ["ours", "lr", "cnn"]
            .iter()
            .map(|k| {
                let (s, c) = acc(k);
                (k.to_string(), s, c)
            })
            .collect();
        let model_bytes = ["ours", "lr", "cnn"]
            .iter()
            .map(|k| {
                (k.to_string(), v.get("model_bytes").f64_of(k))
            })
            .collect();
        Ok(PredictorDataset {
            seq_len,
            sequences,
            lr: LinearPredictor { w },
            trained_accuracy,
            model_bytes,
        })
    }
}

/// ±tol accuracy of predictions vs labels over masked positions.
pub fn accuracy(pred: &[(f64, f64)], y: &[f32], mask: &[f32], tol: f64)
    -> (f64, f64)
{
    let mut ok = [0.0f64; 2];
    let mut total = 0.0f64;
    for (i, p) in pred.iter().enumerate() {
        if mask[i] <= 0.0 {
            continue;
        }
        total += 1.0;
        if (p.0 - y[i * 2] as f64).abs() < tol {
            ok[0] += 1.0;
        }
        if (p.1 - y[i * 2 + 1] as f64).abs() < tol {
            ok[1] += 1.0;
        }
    }
    (ok[0] / total.max(1.0), ok[1] / total.max(1.0))
}

/// Run one predictor over the whole test set; returns (s_acc, c_acc).
pub fn evaluate<F>(ds: &PredictorDataset, mut f: F) -> (f64, f64)
where
    F: FnMut(&[f32]) -> Vec<(f64, f64)>,
{
    let mut s_ok = 0.0;
    let mut c_ok = 0.0;
    let mut total = 0.0f64;
    for (x, y, m) in &ds.sequences {
        let pred = f(x);
        for (i, p) in pred.iter().enumerate() {
            if m[i] <= 0.0 {
                continue;
            }
            total += 1.0;
            if (p.0 - y[i * 2] as f64).abs() < 0.1 {
                s_ok += 1.0;
            }
            if (p.1 - y[i * 2 + 1] as f64).abs() < 0.1 {
                c_ok += 1.0;
            }
        }
    }
    (s_ok / total.max(1.0), c_ok / total.max(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_predictor_affine() {
        let mut w = [[0.0; N_FEATURES + 1]; 2];
        w[0][0] = 2.0;
        w[0][N_FEATURES] = 0.5; // bias
        w[1][1] = -1.0;
        let lr = LinearPredictor { w };
        let (s, c) = lr.predict(&[0.25, 0.5, 0.0, 0.0, 0.0, 0.0]);
        assert!((s - 1.0).abs() < 1e-9);
        assert!((c + 0.5).abs() < 1e-9);
    }

    #[test]
    fn accuracy_counts_within_tolerance() {
        let pred = vec![(0.5, 0.5), (0.0, 1.0)];
        let y = vec![0.55, 0.39, 0.0, 1.0];
        let mask = vec![1.0, 1.0];
        let (s, c) = accuracy(&pred, &y, &mask, 0.1);
        assert!((s - 1.0).abs() < 1e-9);
        assert!((c - 0.5).abs() < 1e-9);
    }
}
