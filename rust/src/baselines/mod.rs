//! Behavioural models of the paper's eleven comparison systems (§6.2).
//!
//! Each baseline is a *scheduling policy* plus an *engine configuration*
//! over the shared device simulator: the figures compare policies, so
//! re-expressing each closed-source framework as its policy over a common
//! substrate is what makes the comparison reproducible (DESIGN.md §2).
//! Knobs per framework (fusion, tuned kernels, multi-stream, data path)
//! follow each system's published design.

use crate::api::{ExecuteRequest, ExecutionBackend, InferenceReport, SimBackend};
use crate::device::DeviceModel;
use crate::engine::sim::SimOptions;
use crate::graph::{ModelGraph, OpClass};
use crate::scheduler::{
    dp::DpScheduler, greedy::GreedyScheduler, sac_sched::SacScheduler,
    sac_sched::SacSchedulerConfig, threshold::ThresholdScheduler, Schedule,
    ScheduleCtx, Scheduler,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Baseline {
    CpuOnly,
    GpuOnlyPyTorch,
    TensorFlow,
    TensorRt,
    Tvm,
    Ios,
    Pos,
    CoDl,
    SparoaNoRl,
    SparoaGreedy,
    SparoaDp,
    Sparoa,
}

pub const ALL: [Baseline; 12] = [
    Baseline::CpuOnly,
    Baseline::GpuOnlyPyTorch,
    Baseline::TensorFlow,
    Baseline::TensorRt,
    Baseline::Tvm,
    Baseline::Ios,
    Baseline::Pos,
    Baseline::CoDl,
    Baseline::SparoaNoRl,
    Baseline::SparoaGreedy,
    Baseline::SparoaDp,
    Baseline::Sparoa,
];

impl Baseline {
    /// Resolve a policy/baseline name as used by the CLI and
    /// `api::SessionBuilder::policy` (accepts both the short policy
    /// aliases and the display names).
    pub fn from_name(name: &str) -> Option<Baseline> {
        Some(match name.to_ascii_lowercase().as_str() {
            "sac" | "sparoa" => Baseline::Sparoa,
            "greedy" | "sparoa-greedy" => Baseline::SparoaGreedy,
            "dp" | "sparoa-dp" => Baseline::SparoaDp,
            "threshold" | "static" | "sparoa w/o rl" => Baseline::SparoaNoRl,
            "cpu" | "cpu-only" => Baseline::CpuOnly,
            "gpu" | "pytorch" | "gpu-only (pytorch)" => {
                Baseline::GpuOnlyPyTorch
            }
            "tensorrt" => Baseline::TensorRt,
            "tvm" => Baseline::Tvm,
            "ios" => Baseline::Ios,
            "pos" => Baseline::Pos,
            "codl" => Baseline::CoDl,
            "tensorflow" => Baseline::TensorFlow,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Baseline::CpuOnly => "CPU-Only",
            Baseline::GpuOnlyPyTorch => "GPU-Only (PyTorch)",
            Baseline::TensorFlow => "TensorFlow",
            Baseline::TensorRt => "TensorRT",
            Baseline::Tvm => "TVM",
            Baseline::Ios => "IOS",
            Baseline::Pos => "POS",
            Baseline::CoDl => "CoDL",
            Baseline::SparoaNoRl => "SparOA w/o RL",
            Baseline::SparoaGreedy => "SparOA-Greedy",
            Baseline::SparoaDp => "SparOA-DP",
            Baseline::Sparoa => "SparOA",
        }
    }

    /// Engine configuration the framework effectively runs with.
    pub fn options(self, batch: usize, seed: u64) -> SimOptions {
        let base = SimOptions { batch, seed, noise: 0.0, ..Default::default() };
        match self {
            // Eager framework on a single processor: dense kernels,
            // pageable staging, one kernel per op, heavy host dispatch.
            Baseline::CpuOnly | Baseline::GpuOnlyPyTorch => SimOptions {
                pinned_memory: false,
                async_streams: false,
                sparsity_aware: false,
                inter_op_parallel: false,
                dispatch_overhead_us: 18.0,
                cpu_kernel_quality: 0.10, // eager dense ARM kernels
                fusion_factor: 0.0,
                kernel_speedup: 1.0,
                ..base
            },
            // Static graph: modest fusion, still sequential dispatch.
            Baseline::TensorFlow => SimOptions {
                pinned_memory: false,
                async_streams: false,
                sparsity_aware: false,
                inter_op_parallel: false,
                fusion_factor: 0.30,
                kernel_speedup: 0.97,
                dispatch_overhead_us: 10.0,
                cpu_kernel_quality: 0.12,
                ..base
            },
            // Kernel auto-tuning + aggressive fusion + multi-stream.
            Baseline::TensorRt => SimOptions {
                stream_pipeline_factor: 0.45,
                sparsity_aware: false,
                fusion_factor: 0.60,
                kernel_speedup: 1.08,
                inter_op_parallel: true,
                dispatch_overhead_us: 0.5,
                ..base
            },
            // Auto-scheduling compiler: tuned kernels, fusion, no streams.
            Baseline::Tvm => SimOptions {
                sparsity_aware: false,
                fusion_factor: 0.50,
                kernel_speedup: 1.08,
                inter_op_parallel: false,
                dispatch_overhead_us: 0.5,
                ..base
            },
            // Inter-operator scheduler: fusion + parallel streams.
            Baseline::Ios => SimOptions {
                stream_pipeline_factor: 0.45,
                sparsity_aware: false,
                fusion_factor: 0.50,
                kernel_speedup: 1.05,
                inter_op_parallel: true,
                dispatch_overhead_us: 0.5,
                ..base
            },
            // POS: IOS + subgraph reuse + intra-op parallelism.
            Baseline::Pos => SimOptions {
                stream_pipeline_factor: 0.45,
                sparsity_aware: false,
                fusion_factor: 0.60,
                kernel_speedup: 1.06,
                inter_op_parallel: true,
                dispatch_overhead_us: 0.5,
                ..base
            },
            // CoDL: hybrid-friendly data sharing (pinned, overlapped) but
            // dense kernels and static affinity; MACE-style engine.
            Baseline::CoDl => SimOptions {
                stream_pipeline_factor: 0.45,
                sparsity_aware: false,
                fusion_factor: 0.50,
                kernel_speedup: 1.12, // hybrid-type-friendly data layouts
                inter_op_parallel: true,
                dispatch_overhead_us: 1.0,
                cpu_kernel_quality: 0.85, // optimized but dense CPU kernels
                replicate_weights: true, // dual-layout data sharing
                ..base
            },
            // SparOA variants: sparse kernels + pinned path + CUDA-stream
            // async execution (§5); the static variant loses transfer
            // overlap (Fig. 7's transfer gap).  Dispatch is the measured
            // rust-coordinator cost (SimOptions::default()).
            // Same engine as full SparOA: the w/o-RL delta is purely
            // the static threshold plan vs the learned policy (Fig. 7).
            Baseline::SparoaNoRl => base.clone(),
            Baseline::SparoaGreedy
            | Baseline::SparoaDp
            | Baseline::Sparoa => base,
        }
    }

    /// Produce the schedule this baseline would run.
    pub fn schedule(
        self,
        graph: &ModelGraph,
        dev: &DeviceModel,
        thresholds: Option<&[(f64, f64)]>,
        batch: usize,
        episodes: usize,
    ) -> Schedule {
        let ctx = ScheduleCtx { graph, device: dev, thresholds, batch };
        match self {
            Baseline::CpuOnly => Schedule::uniform(graph, 0.0, self.name()),
            Baseline::GpuOnlyPyTorch
            | Baseline::TensorFlow
            | Baseline::TensorRt
            | Baseline::Tvm
            | Baseline::Ios
            | Baseline::Pos => Schedule::uniform(graph, 1.0, self.name()),
            Baseline::CoDl => codl_affinity(graph),
            Baseline::SparoaNoRl => ThresholdScheduler.schedule(&ctx),
            Baseline::SparoaGreedy => GreedyScheduler.schedule(&ctx),
            Baseline::SparoaDp => DpScheduler::default().schedule(&ctx),
            Baseline::Sparoa => {
                let mut s = SacScheduler::new(SacSchedulerConfig {
                    episodes,
                    ..Default::default()
                });
                s.schedule(&ctx)
            }
        }
    }

    /// Run the baseline end-to-end through the unified execution API
    /// (virtual-time backend — the figures compare policies).
    pub fn run(
        self,
        graph: &ModelGraph,
        dev: &DeviceModel,
        thresholds: Option<&[(f64, f64)]>,
        batch: usize,
        episodes: usize,
    ) -> (Schedule, InferenceReport) {
        let sched = self.schedule(graph, dev, thresholds, batch, episodes);
        let opts = self.options(batch, 1);
        let report = SimBackend
            .execute(&ExecuteRequest {
                graph,
                device: dev,
                schedule: &sched,
                options: &opts,
                inputs: &[],
            })
            .expect("sim backend is infallible");
        (sched, report)
    }
}

/// CoDL's processor-affinity heuristic: compute-heavy op types to the GPU,
/// memory-bound types to the CPU — per-op-type, not per-op (no sparsity or
/// per-instance intensity awareness).
fn codl_affinity(graph: &ModelGraph) -> Schedule {
    let mut xi = vec![1.0; graph.ops.len()];
    for op in &graph.ops {
        if !op.class.schedulable() {
            xi[op.id] = op.inputs.first().map(|&i| xi[i]).unwrap_or(1.0);
            continue;
        }
        xi[op.id] = match op.class {
            OpClass::Conv | OpClass::MatMul | OpClass::Attention => 1.0,
            OpClass::DwConv => 1.0, // CoDL keeps convolutions together
            OpClass::Norm | OpClass::Elementwise | OpClass::Pool
            | OpClass::Softmax => 0.0,
            OpClass::Other => 1.0,
        };
    }
    Schedule { xi, policy: "codl".into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    fn setup() -> Option<(ModelZoo, DeviceRegistry)> {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return None;
        }
        Some((
            ModelZoo::load(&art).unwrap(),
            DeviceRegistry::load(
                &crate::repo_root().join("config/devices.json")).unwrap(),
        ))
    }

    #[test]
    fn cpu_only_is_slowest_on_every_model() {
        let Some((zoo, reg)) = setup() else { return };
        let dev = reg.get("agx_orin").unwrap();
        for (name, g) in &zoo.graphs {
            let (_, cpu) =
                Baseline::CpuOnly.run(g, dev, None, 1, 0);
            let (_, trt) =
                Baseline::TensorRt.run(g, dev, None, 1, 0);
            assert!(cpu.makespan_us > trt.makespan_us,
                    "{name}: cpu {} vs trt {}", cpu.makespan_us,
                    trt.makespan_us);
        }
    }

    #[test]
    fn tensorrt_beats_eager_pytorch() {
        let Some((zoo, reg)) = setup() else { return };
        let dev = reg.get("agx_orin").unwrap();
        let g = zoo.get("resnet18").unwrap();
        let (_, pt) = Baseline::GpuOnlyPyTorch.run(g, dev, None, 1, 0);
        let (_, trt) = Baseline::TensorRt.run(g, dev, None, 1, 0);
        assert!(trt.makespan_us < pt.makespan_us);
    }

    #[test]
    fn codl_uses_both_processors() {
        let Some((zoo, reg)) = setup() else { return };
        let dev = reg.get("agx_orin").unwrap();
        let g = zoo.get("mobilenet_v2").unwrap();
        let (sched, rep) = Baseline::CoDl.run(g, dev, None, 1, 0);
        let share = sched.gpu_share(g);
        assert!(share > 0.1 && share < 0.95, "share {share}");
        assert!(rep.cpu_busy_us > 0.0 && rep.gpu_busy_us > 0.0);
    }
}
