//! Heterogeneous device substrate: the calibrated Jetson CPU/GPU roofline
//! simulator (substitution for the physical Orin boards — DESIGN.md §2).
//!
//! Mirrors python/compile/device_model.py exactly; `rust/tests/` checks
//! parity against a golden table.  All latencies are microseconds.

use crate::graph::OpClass;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which processor an operator (or fraction of it) runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proc {
    Cpu,
    Gpu,
}

impl Proc {
    pub fn name(self) -> &'static str {
        match self {
            Proc::Cpu => "cpu",
            Proc::Gpu => "gpu",
        }
    }
    pub fn other(self) -> Proc {
        match self {
            Proc::Cpu => Proc::Gpu,
            Proc::Gpu => Proc::Cpu,
        }
    }
}

/// One DVFS operating point of a processor: run everything
/// `latency_scale`× slower than the calibrated roofline in exchange for a
/// lower power draw.  The calibrated profile (`power_static_w` /
/// `power_dyn_w` on [`ProcModel`]) is the `latency_scale == 1.0` point.
#[derive(Debug, Clone)]
pub struct FreqState {
    /// Human-readable state name ("max", "mid", "low", ...).
    pub name: String,
    /// Latency multiplier relative to the calibrated roofline, >= 1.0
    /// (dimensionless; 1.0 == full frequency).
    pub latency_scale: f64,
    /// Static (leakage + always-on) power at this frequency, watts.
    pub static_w: f64,
    /// Dynamic power when the processor is busy at this frequency, watts.
    pub dyn_w: f64,
}

impl FreqState {
    /// Total draw while a lane is executing at this state, watts
    /// (`static_w + dyn_w`).
    pub fn busy_power_w(&self) -> f64 {
        self.static_w + self.dyn_w
    }
}

/// Per-processor roofline parameters.
#[derive(Debug, Clone)]
pub struct ProcModel {
    pub peak_gflops: f64,
    pub mem_bw_gbps: f64,
    pub launch_overhead_us: f64,
    pub util: BTreeMap<String, f64>,
    pub sparsity_elasticity: BTreeMap<String, f64>,
    pub power_static_w: f64,
    pub power_dyn_w: f64,
    /// Optional DVFS ladder (fastest first).  Empty when the profile
    /// predates frequency states; `power::LanePowerModel::from_proc`
    /// synthesizes a default ladder in that case.
    pub freq_states: Vec<FreqState>,
}

impl ProcModel {
    fn from_json(v: &Value) -> Result<Self> {
        let map = |key: &str| -> BTreeMap<String, f64> {
            v.get(key)
                .as_obj()
                .map(|o| {
                    o.iter()
                        .filter_map(|(k, x)| x.as_f64().map(|f| (k.clone(), f)))
                        .collect()
                })
                .unwrap_or_default()
        };
        let freq_states = v
            .get("freq_states")
            .as_arr()
            .map(|arr| {
                arr.iter()
                    .map(|s| FreqState {
                        name: s.str_of("name").to_string(),
                        latency_scale: s.f64_of("latency_scale"),
                        static_w: s.f64_of("static_w"),
                        dyn_w: s.f64_of("dyn_w"),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(ProcModel {
            peak_gflops: v.f64_of("peak_gflops"),
            mem_bw_gbps: v.f64_of("mem_bw_gbps"),
            launch_overhead_us: v.f64_of("launch_overhead_us"),
            util: map("util"),
            sparsity_elasticity: map("sparsity_elasticity"),
            power_static_w: v.f64_of("power_static_w"),
            power_dyn_w: v.f64_of("power_dyn_w"),
            freq_states,
        })
    }
}

/// GPU effective-bandwidth ramp: transfers below this size run below peak
/// DRAM bandwidth (kernel ramp-up, partial bursts).  Mirrored in
/// python/compile/device_model.py — the parity test pins both.
pub const GPU_BW_RAMP_BYTES: f64 = 4e6;
pub const GPU_BW_RAMP_FLOOR: f64 = 0.12;

/// Transfer-path parameters (pinned DMA + async streams).
#[derive(Debug, Clone)]
pub struct TransferModel {
    pub dma_bw_gbps: f64,
    pub dma_latency_us: f64,
    pub pageable_penalty: f64,
    pub async_overlap: f64,
}

/// One edge device (Orin Nano / AGX Orin) profile.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub id: String,
    pub name: String,
    pub cpu: ProcModel,
    pub gpu: ProcModel,
    pub transfer: TransferModel,
    pub soc_static_w: f64,
    pub gpu_mem_capacity_mb: f64,
    pub min_util_floor: f64,
}

impl DeviceModel {
    pub fn proc(&self, p: Proc) -> &ProcModel {
        match p {
            Proc::Cpu => &self.cpu,
            Proc::Gpu => &self.gpu,
        }
    }

    /// Roofline latency of one op on one processor (microseconds).
    ///
    /// `t = max(eff_flops / rate, bytes / bw) + launch`
    /// `eff_flops = flops * (1 - sparsity * elasticity[class])`
    pub fn op_latency_us(
        &self,
        proc: Proc,
        class: OpClass,
        flops: f64,
        bytes_moved: f64,
        sparsity: f64,
    ) -> f64 {
        let (t_compute, t_mem, launch) =
            self.op_cost_parts_us(proc, class, flops, bytes_moved, sparsity);
        t_compute.max(t_mem) + launch
    }

    /// Roofline components: (compute_us, mem_us, launch_us).
    pub fn op_cost_parts_us(
        &self,
        proc: Proc,
        class: OpClass,
        flops: f64,
        bytes_moved: f64,
        sparsity: f64,
    ) -> (f64, f64, f64) {
        let p = self.proc(proc);
        let key = class.key();
        let util = p
            .util
            .get(key)
            .or_else(|| p.util.get("other"))
            .copied()
            .unwrap_or(0.3)
            .max(self.min_util_floor);
        let elast = p.sparsity_elasticity.get(key).copied().unwrap_or(0.0);
        let eff = flops * (1.0 - sparsity.clamp(0.0, 1.0) * elast);
        let t_compute = eff / (p.peak_gflops * util * 1e9) * 1e6;
        // GPU DMA engines need large transfers to reach peak bandwidth;
        // small tensors see a ramp (CPU caches make it a non-issue there).
        let bw_eff = match proc {
            Proc::Gpu => {
                let ramp = (bytes_moved / GPU_BW_RAMP_BYTES)
                    .powf(0.5)
                    .clamp(GPU_BW_RAMP_FLOOR, 1.0);
                p.mem_bw_gbps * ramp
            }
            Proc::Cpu => p.mem_bw_gbps,
        };
        let t_mem = bytes_moved / (bw_eff * 1e9) * 1e6;
        (t_compute, t_mem, p.launch_overhead_us)
    }

    /// CPU<->GPU transfer latency (microseconds).
    pub fn transfer_us(&self, bytes: f64, pinned: bool, overlap: bool) -> f64 {
        let t = &self.transfer;
        let mut lat = t.dma_latency_us + bytes / (t.dma_bw_gbps * 1e9) * 1e6;
        if !pinned {
            lat *= t.pageable_penalty;
        }
        if overlap {
            lat *= 1.0 - t.async_overlap;
        }
        lat
    }
}

/// All device profiles from devices.json.
pub struct DeviceRegistry {
    pub devices: BTreeMap<String, DeviceModel>,
}

impl DeviceRegistry {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing devices.json: {e}"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut devices = BTreeMap::new();
        for (id, d) in v.get("devices").as_obj().context("devices")? {
            let t = d.get("transfer");
            devices.insert(
                id.clone(),
                DeviceModel {
                    id: id.clone(),
                    name: d.str_of("name").to_string(),
                    cpu: ProcModel::from_json(d.get("cpu"))?,
                    gpu: ProcModel::from_json(d.get("gpu"))?,
                    transfer: TransferModel {
                        dma_bw_gbps: t.f64_of("dma_bw_gbps"),
                        dma_latency_us: t.f64_of("dma_latency_us"),
                        pageable_penalty: t.f64_of("pageable_penalty"),
                        async_overlap: t.f64_of("async_overlap"),
                    },
                    soc_static_w: d.f64_of("soc_static_w"),
                    gpu_mem_capacity_mb: d.f64_of("gpu_mem_capacity_mb"),
                    min_util_floor: d.f64_of("min_util_floor"),
                },
            );
        }
        Ok(DeviceRegistry { devices })
    }

    pub fn get(&self, id: &str) -> Result<&DeviceModel> {
        self.devices
            .get(id)
            .with_context(|| format!("device `{id}` not in devices.json"))
    }
}

/// Dynamic hardware state (paper Eq. 7's M_gpu / M_cpu / O_switch terms).
///
/// Evolves as ops are dispatched: GPU memory fills with resident
/// activations/weights, CPU load tracks an EMA of recent CPU work, and
/// contention adds stochastic jitter (the "hardware dynamics" of the MDP
/// transition model, §4.1).
#[derive(Debug, Clone)]
pub struct HardwareState {
    /// GPU memory in use, MB.
    pub gpu_mem_mb: f64,
    /// GPU memory capacity, MB.
    pub gpu_cap_mb: f64,
    /// CPU load level in [0, 1].
    pub cpu_load: f64,
    /// Count of device switches so far in the episode.
    pub switches: u32,
    /// Last placement (for switch-overhead accounting).
    pub last_proc: Option<Proc>,
    rng: Rng,
    /// Contention noise amplitude (0 disables stochastic dynamics).
    pub noise: f64,
}

impl HardwareState {
    pub fn new(dev: &DeviceModel, seed: u64, noise: f64) -> Self {
        Self::with_capacity(dev.gpu_mem_capacity_mb, seed, noise)
    }

    /// Construct from a bare GPU capacity — what cost tables cache so a
    /// timeline walk needs no `DeviceModel` borrow (engine::costs).
    pub fn with_capacity(gpu_cap_mb: f64, seed: u64, noise: f64) -> Self {
        HardwareState {
            gpu_mem_mb: 0.15 * gpu_cap_mb, // framework baseline
            gpu_cap_mb,
            cpu_load: 0.1,
            switches: 0,
            last_proc: None,
            rng: Rng::new(seed),
            noise,
        }
    }

    /// Normalized GPU memory pressure in [0, 1].
    pub fn gpu_pressure(&self) -> f64 {
        (self.gpu_mem_mb / self.gpu_cap_mb).clamp(0.0, 1.0)
    }

    /// Latency multiplier from contention: GPU slows superlinearly as
    /// memory pressure approaches capacity; CPU slows with load.
    pub fn contention_factor(&mut self, proc: Proc) -> f64 {
        let base = match proc {
            Proc::Gpu => {
                let p = self.gpu_pressure();
                if p > 0.8 {
                    1.0 + 3.0 * (p - 0.8)
                } else {
                    1.0
                }
            }
            Proc::Cpu => 1.0 + 0.5 * self.cpu_load,
        };
        let jitter = 1.0 + self.noise * self.rng.normal().clamp(-2.5, 2.5);
        base * jitter.max(0.5)
    }

    /// Account an op dispatched to `proc` with the given working set.
    pub fn dispatch(&mut self, proc: Proc, bytes_out: f64, params_bytes: f64) {
        if let Some(last) = self.last_proc {
            if last != proc {
                self.switches += 1;
            }
        }
        self.last_proc = Some(proc);
        match proc {
            Proc::Gpu => {
                self.gpu_mem_mb += (bytes_out + params_bytes) / 1e6;
                // resident set decays as earlier activations are freed
                self.gpu_mem_mb = self.gpu_mem_mb.min(self.gpu_cap_mb);
                self.cpu_load *= 0.97;
            }
            Proc::Cpu => {
                self.cpu_load = (self.cpu_load * 0.9 + 0.1).min(1.0);
                self.gpu_mem_mb *= 0.995; // GPU allocator reclaims
            }
        }
    }

    /// Free activation memory after consumers are done (simplified decay).
    pub fn release(&mut self, bytes: f64) {
        self.gpu_mem_mb = (self.gpu_mem_mb - bytes / 1e6).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn test_registry() -> DeviceRegistry {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        DeviceRegistry::load(&root.join("config/devices.json")).unwrap()
    }

    #[test]
    fn loads_profiles() {
        let reg = test_registry();
        let agx = reg.get("agx_orin").unwrap();
        assert_eq!(agx.name, "NVIDIA Jetson AGX Orin");
        assert!(agx.gpu.peak_gflops > agx.cpu.peak_gflops);
        assert!(reg.get("orin_nano").is_ok());
        assert!(reg.get("nonexistent").is_err());
    }

    #[test]
    fn freq_states_parse_as_a_well_formed_ladder() {
        let reg = test_registry();
        for id in ["agx_orin", "orin_nano"] {
            let d = reg.get(id).unwrap();
            for p in [&d.cpu, &d.gpu] {
                let s = &p.freq_states;
                assert_eq!(s.len(), 3, "{id}: expected 3-state ladder");
                assert_eq!(s[0].name, "max");
                assert_eq!(s[0].latency_scale, 1.0);
                assert_eq!(s[0].static_w, p.power_static_w);
                assert_eq!(s[0].dyn_w, p.power_dyn_w);
                for w in s.windows(2) {
                    // Slower states must trade latency for power AND
                    // energy (scale x busy power strictly decreasing),
                    // or a governor would never have a reason to pick
                    // them.
                    assert!(w[1].latency_scale > w[0].latency_scale);
                    assert!(w[1].busy_power_w() < w[0].busy_power_w());
                    assert!(
                        w[1].latency_scale * w[1].busy_power_w()
                            < w[0].latency_scale * w[0].busy_power_w()
                    );
                }
            }
        }
    }

    #[test]
    fn gpu_wins_heavy_dense_cpu_wins_light() {
        let reg = test_registry();
        let d = reg.get("agx_orin").unwrap();
        // Heavy dense conv: GPU strictly faster.
        let gpu = d.op_latency_us(Proc::Gpu, OpClass::Conv, 2e9, 1e7, 0.0);
        let cpu = d.op_latency_us(Proc::Cpu, OpClass::Conv, 2e9, 1e7, 0.0);
        assert!(gpu < cpu, "gpu {gpu} vs cpu {cpu}");
        // Tiny norm op: CPU faster (GPU pays launch overhead).
        let gpu = d.op_latency_us(Proc::Gpu, OpClass::Norm, 1e4, 1e4, 0.0);
        let cpu = d.op_latency_us(Proc::Cpu, OpClass::Norm, 1e4, 1e4, 0.0);
        assert!(cpu < gpu, "cpu {cpu} vs gpu {gpu}");
    }

    #[test]
    fn sparsity_helps_cpu_more() {
        let reg = test_registry();
        let d = reg.get("agx_orin").unwrap();
        let cpu_dense = d.op_latency_us(Proc::Cpu, OpClass::Conv, 1e9, 1e5, 0.0);
        let cpu_sparse = d.op_latency_us(Proc::Cpu, OpClass::Conv, 1e9, 1e5, 0.8);
        let gpu_dense = d.op_latency_us(Proc::Gpu, OpClass::Conv, 1e9, 1e5, 0.0);
        let gpu_sparse = d.op_latency_us(Proc::Gpu, OpClass::Conv, 1e9, 1e5, 0.8);
        let cpu_gain = cpu_dense / cpu_sparse;
        let gpu_gain = gpu_dense / gpu_sparse;
        assert!(cpu_gain > 2.0, "cpu gain {cpu_gain}");
        assert!(gpu_gain < 1.3, "gpu gain {gpu_gain}");
    }

    #[test]
    fn transfer_modes() {
        let reg = test_registry();
        let d = reg.get("agx_orin").unwrap();
        let sync = d.transfer_us(1e6, true, false);
        let pageable = d.transfer_us(1e6, false, false);
        let overlapped = d.transfer_us(1e6, true, true);
        assert!(pageable > 2.0 * sync);
        assert!(overlapped < 0.3 * sync);
    }

    #[test]
    fn hardware_state_evolves() {
        let reg = test_registry();
        let d = reg.get("orin_nano").unwrap();
        let mut hs = HardwareState::new(d, 1, 0.0);
        let m0 = hs.gpu_mem_mb;
        hs.dispatch(Proc::Gpu, 50e6, 10e6);
        assert!(hs.gpu_mem_mb > m0);
        hs.dispatch(Proc::Cpu, 1e6, 0.0);
        assert_eq!(hs.switches, 1);
        assert!(hs.cpu_load > 0.1);
        hs.release(20e6);
        assert!(hs.gpu_mem_mb < m0 + 60.0);
    }

    #[test]
    fn contention_kicks_in_near_capacity() {
        let reg = test_registry();
        let d = reg.get("orin_nano").unwrap();
        let mut hs = HardwareState::new(d, 1, 0.0);
        hs.gpu_mem_mb = 0.95 * hs.gpu_cap_mb;
        assert!(hs.contention_factor(Proc::Gpu) > 1.2);
        hs.gpu_mem_mb = 0.1 * hs.gpu_cap_mb;
        assert!((hs.contention_factor(Proc::Gpu) - 1.0).abs() < 1e-9);
    }
}
