//! Multi-tenant workload generation: arrival patterns beyond Poisson.
//!
//! A [`Tenant`] binds a model + SLO class to an [`ArrivalPattern`]:
//! * `Poisson` — memoryless open-loop traffic (the classic serving
//!   assumption).
//! * `Mmpp` — a two-state Markov-modulated Poisson process: calm/burst
//!   phases with exponentially distributed dwell times (flash crowds,
//!   camera-triggered edge pipelines).
//! * `Diurnal` — a sinusoidal rate curve sampled by thinning (day/night
//!   load cycles compressed into virtual time).
//! * `Trace` — explicit arrival timestamps replayed verbatim, with a
//!   JSON round-trip ([`trace_from_json`] / [`trace_to_json`]) so real
//!   production traces can be fed to the cluster scheduler.
//!
//! [`merge_arrivals`] turns a tenant set into one globally-ordered
//! arrival stream with dense request ids — the cluster scheduler's
//! input.

use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// One tenant's arrival process (all times/rates are virtual time).
#[derive(Debug, Clone)]
pub enum ArrivalPattern {
    /// Memoryless arrivals at `rate_per_s`.
    Poisson { rate_per_s: f64, n: usize },
    /// Two-state MMPP: Poisson at `rate_lo_per_s` / `rate_hi_per_s`,
    /// switching states after exponential dwells of mean `mean_dwell_s`.
    Mmpp {
        rate_lo_per_s: f64,
        rate_hi_per_s: f64,
        mean_dwell_s: f64,
        n: usize,
    },
    /// Sinusoidal rate curve `base * (1 + amplitude * sin(2pi t/period))`
    /// sampled by thinning; `amplitude` in [0, 1].
    Diurnal {
        base_rate_per_s: f64,
        amplitude: f64,
        period_s: f64,
        n: usize,
    },
    /// Replay explicit arrival timestamps (microseconds, sorted).
    Trace { arrivals_us: Vec<f64> },
}

impl ArrivalPattern {
    /// Materialize the arrival timestamps (microseconds, ascending).
    pub fn generate(&self, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        match self {
            // One Poisson generator in the crate: the batcher's.
            ArrivalPattern::Poisson { rate_per_s, n } => {
                crate::server::batcher::poisson_stream(
                    *n, rate_per_s.max(1e-9), seed)
                    .into_iter()
                    .map(|r| r.arrival_us)
                    .collect()
            }
            ArrivalPattern::Mmpp {
                rate_lo_per_s,
                rate_hi_per_s,
                mean_dwell_s,
                n,
            } => {
                let mut out = Vec::with_capacity(*n);
                let mut t = 0.0f64;
                let mut hi = false;
                let dwell_rate = 1.0 / mean_dwell_s.max(1e-9);
                let mut next_switch =
                    rng.exponential(dwell_rate) * 1e6;
                while out.len() < *n {
                    let rate = if hi { *rate_hi_per_s } else { *rate_lo_per_s };
                    let gap = rng.exponential(rate.max(1e-9)) * 1e6;
                    if t + gap > next_switch {
                        // Memorylessness: restart the arrival clock at the
                        // state switch instead of carrying the old sample.
                        t = next_switch;
                        hi = !hi;
                        next_switch =
                            t + rng.exponential(dwell_rate) * 1e6;
                        continue;
                    }
                    t += gap;
                    out.push(t);
                }
                out
            }
            ArrivalPattern::Diurnal {
                base_rate_per_s,
                amplitude,
                period_s,
                n,
            } => {
                let amp = amplitude.clamp(0.0, 1.0);
                // Clamp the base rate itself, not just the proposal
                // rate: a zero base would make the thinning accept test
                // unsatisfiable and the loop would never fill `n`.
                let base = base_rate_per_s.max(1e-9);
                let max_rate = base * (1.0 + amp);
                let mut out = Vec::with_capacity(*n);
                let mut t = 0.0f64;
                while out.len() < *n {
                    t += rng.exponential(max_rate) * 1e6;
                    let phase = 2.0 * std::f64::consts::PI
                        * (t / 1e6)
                        / period_s.max(1e-9);
                    let rate = base * (1.0 + amp * phase.sin());
                    if rng.f64() * max_rate <= rate {
                        out.push(t);
                    }
                }
                out
            }
            ArrivalPattern::Trace { arrivals_us } => {
                let mut v = arrivals_us.clone();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            }
        }
    }

    /// Number of requests this pattern will emit.
    pub fn len(&self) -> usize {
        match self {
            ArrivalPattern::Poisson { n, .. }
            | ArrivalPattern::Mmpp { n, .. }
            | ArrivalPattern::Diurnal { n, .. } => *n,
            ArrivalPattern::Trace { arrivals_us } => arrivals_us.len(),
        }
    }

    /// True when the pattern emits no requests.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Short label for tables/reports.
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalPattern::Poisson { .. } => "poisson",
            ArrivalPattern::Mmpp { .. } => "mmpp",
            ArrivalPattern::Diurnal { .. } => "diurnal",
            ArrivalPattern::Trace { .. } => "trace",
        }
    }
}

/// One workload stream: a model, an SLO class, an arrival process.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Display name of the stream.
    pub name: String,
    /// Model name in the [`crate::serve::ModelRegistry`].
    pub model: String,
    /// Index into the cluster's SLO class table (0 = highest priority).
    pub class: usize,
    /// The stream's arrival process.
    pub pattern: ArrivalPattern,
}

/// One arrival in the merged multi-tenant stream.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Dense global request id (0..total), assigned in time order.
    pub req: usize,
    /// Index into the tenant set.
    pub tenant: usize,
    /// Arrival time, microseconds of virtual time.
    pub at_us: f64,
}

/// Generate every tenant's stream (tenant `i` uses `seed + i * 7919`) and
/// merge into one time-ordered stream with dense request ids.
pub fn merge_arrivals(tenants: &[Tenant], seed: u64) -> Vec<Arrival> {
    let mut all: Vec<(f64, usize)> = Vec::new();
    for (ti, t) in tenants.iter().enumerate() {
        for at in t.pattern.generate(seed.wrapping_add(ti as u64 * 7919)) {
            all.push((at, ti));
        }
    }
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    all.into_iter()
        .enumerate()
        .map(|(req, (at_us, tenant))| Arrival { req, tenant, at_us })
        .collect()
}

/// MMPP / diurnal parameters recovered from an arrival trace by
/// [`fit_mmpp`].  All rates are requests per second of virtual time.
#[derive(Debug, Clone, Copy)]
pub struct MmppFit {
    /// Calm-phase arrival rate (from the large inter-arrival cluster).
    pub rate_lo_per_s: f64,
    /// Burst-phase arrival rate (from the small inter-arrival cluster);
    /// `>= rate_lo_per_s` by construction.
    pub rate_hi_per_s: f64,
    /// Mean time spent in one phase before switching, seconds.
    pub mean_dwell_s: f64,
    /// Overall mean rate, `n / span`.
    pub base_rate_per_s: f64,
    /// Relative swing of the dominant rate oscillation, in [0, 1].
    pub amplitude: f64,
    /// Period of the dominant rate oscillation, seconds.
    pub period_s: f64,
    /// Empirical squared coefficient of variation of the inter-arrival
    /// gaps: ~1 for Poisson, > 1 for bursty (MMPP-like) traffic.
    pub cv2: f64,
}

/// Estimate two-state MMPP plus diurnal parameters from an arrival
/// trace (microsecond timestamps, ascending — e.g. a replay trace fed
/// to [`trace_from_json`]), so a captured production stream can be
/// re-generated synthetically at other loads via
/// [`ArrivalPattern::Mmpp`] / [`ArrivalPattern::Diurnal`].
///
/// Moment- and cluster-based, not maximum likelihood: phase rates come
/// from a 2-means split of the inter-arrival gaps, the dwell time from
/// run lengths on the same side of the cluster midpoint, and the
/// diurnal period from the dominant non-DC bin of a naive DFT over a
/// binned rate curve.  Exponential gap distributions overlap heavily,
/// so recovered rates/dwells are indicative (right order of magnitude)
/// rather than exact; `cv2` is exact by definition.
///
/// Degenerate traces are structured errors, never NaN parameters:
/// fewer than 2 arrivals (no inter-arrival gap exists), fewer than 16
/// (too short to cluster), zero time span (all timestamps identical),
/// or zero-variance gaps (a perfectly regular trace has no phase
/// structure to fit — regenerate it as `Poisson` at `n / span`).
pub fn fit_mmpp(arrivals_us: &[f64]) -> Result<MmppFit> {
    use crate::util::stats;
    let n = arrivals_us.len();
    anyhow::ensure!(
        n >= 2,
        "fit_mmpp needs at least 2 arrivals for an inter-arrival \
         gap; got {n}"
    );
    anyhow::ensure!(
        n >= 16,
        "fit_mmpp needs at least 16 arrivals to separate phases; \
         got {n}"
    );
    let span_us = arrivals_us[n - 1] - arrivals_us[0];
    anyhow::ensure!(
        span_us > 0.0,
        "trace spans zero virtual time (all {n} arrivals at the \
         same timestamp)"
    );
    let gaps: Vec<f64> = arrivals_us
        .windows(2)
        .map(|w| (w[1] - w[0]).max(0.0))
        .collect();
    let gm = stats::mean(&gaps);
    anyhow::ensure!(
        gm > 0.0,
        "trace inter-arrival gaps have zero mean over a positive \
         span (non-monotone timestamps?)"
    );
    let gs = stats::stddev(&gaps);
    anyhow::ensure!(
        gs > 0.0,
        "trace inter-arrival gaps have zero variance (perfectly \
         regular trace: no burst/calm phases to fit — use a Poisson \
         pattern at {:.3} req/s instead)",
        (n - 1) as f64 / (span_us / 1e6)
    );
    let cv2 = (gs / gm) * (gs / gm);

    // Phase rates: 2-means over the gaps, seeded from the sorted
    // halves.  Small gaps = burst phase, large gaps = calm phase.
    let mut sorted = gaps.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut c_small = stats::mean(&sorted[..gaps.len() / 2]);
    let mut c_large = stats::mean(&sorted[gaps.len() / 2..]);
    for _ in 0..32 {
        let thr = 0.5 * (c_small + c_large);
        let (mut s0, mut n0, mut s1, mut n1) = (0.0, 0usize, 0.0, 0usize);
        for &g in &gaps {
            if g <= thr {
                s0 += g;
                n0 += 1;
            } else {
                s1 += g;
                n1 += 1;
            }
        }
        if n0 == 0 || n1 == 0 {
            break;
        }
        let (ns, nl) = (s0 / n0 as f64, s1 / n1 as f64);
        let moved =
            (ns - c_small).abs() > 1e-9 || (nl - c_large).abs() > 1e-9;
        c_small = ns;
        c_large = nl;
        if !moved {
            break;
        }
    }
    let rate_hi_per_s = 1e6 / c_small.max(1e-9);
    let rate_lo_per_s = 1e6 / c_large.max(1e-9);

    // Dwell time: mean duration of runs of gaps on the same side of
    // the cluster midpoint (each run ~ one phase visit).
    let thr = 0.5 * (c_small + c_large);
    let mut dwell_sum_us = 0.0;
    let mut runs = 0usize;
    let mut run_us = 0.0;
    let mut cur_burst = gaps[0] <= thr;
    for &g in &gaps {
        let burst = g <= thr;
        if burst != cur_burst {
            dwell_sum_us += run_us;
            runs += 1;
            run_us = 0.0;
            cur_burst = burst;
        }
        run_us += g;
    }
    dwell_sum_us += run_us;
    runs += 1;
    let mean_dwell_s = dwell_sum_us / runs as f64 / 1e6;

    // Diurnal component: bin the rate curve, take the dominant non-DC
    // DFT bin as the period, and read the amplitude off smoothed
    // extrema (3-bin moving average, robust to bin noise).
    let k_bins = (n / 8).clamp(8, 256);
    let mut bins = vec![0.0f64; k_bins];
    for &t in arrivals_us {
        let j = (((t - arrivals_us[0]) / span_us) * k_bins as f64) as usize;
        bins[j.min(k_bins - 1)] += 1.0;
    }
    let bin_mean = stats::mean(&bins);
    let mut best_k = 1usize;
    let mut best_mag = -1.0f64;
    for k in 1..=k_bins / 2 {
        let (mut re, mut im) = (0.0f64, 0.0f64);
        for (j, &c) in bins.iter().enumerate() {
            let ph = 2.0 * std::f64::consts::PI * (k * j) as f64
                / k_bins as f64;
            re += (c - bin_mean) * ph.cos();
            im += (c - bin_mean) * ph.sin();
        }
        let mag = re * re + im * im;
        if mag > best_mag {
            best_mag = mag;
            best_k = k;
        }
    }
    let span_s = span_us / 1e6;
    let period_s = span_s / best_k as f64;
    let smooth: Vec<f64> = (0..k_bins)
        .map(|j| {
            (bins[(j + k_bins - 1) % k_bins]
                + bins[j]
                + bins[(j + 1) % k_bins])
                / 3.0
        })
        .collect();
    let (mut mx, mut mn) = (f64::MIN, f64::MAX);
    for &s in &smooth {
        mx = mx.max(s);
        mn = mn.min(s);
    }
    let amplitude = if mx + mn > 0.0 {
        ((mx - mn) / (mx + mn)).clamp(0.0, 1.0)
    } else {
        0.0
    };

    Ok(MmppFit {
        rate_lo_per_s,
        rate_hi_per_s,
        mean_dwell_s,
        base_rate_per_s: n as f64 / span_s,
        amplitude,
        period_s,
        cv2,
    })
}

/// Parse a replayable trace: either `{"arrivals_us": [...]}` or a bare
/// JSON array of microsecond timestamps.  Every entry must be a
/// finite, non-negative number and the timestamps must be ascending —
/// a malformed or out-of-order entry is an error naming its index,
/// never a silently shorter (or silently re-sorted) workload.
pub fn trace_from_json(text: &str) -> Result<ArrivalPattern> {
    let v = json::parse(text)
        .map_err(|e| anyhow::anyhow!("parsing trace JSON: {e}"))?;
    let items = match &v {
        Value::Arr(a) => &a[..],
        Value::Obj(_) => v
            .get("arrivals_us")
            .as_arr()
            .context("trace needs an `arrivals_us` array")?,
        _ => anyhow::bail!("trace must be a JSON array or object"),
    };
    let arr = items
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let t = x.as_f64().with_context(|| {
                format!("trace entry {i} is not a number")
            })?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "trace entry {i} has negative or non-finite \
                 timestamp {t}"
            );
            Ok(t)
        })
        .collect::<Result<Vec<f64>>>()?;
    anyhow::ensure!(!arr.is_empty(), "trace has no arrivals");
    for (i, w) in arr.windows(2).enumerate() {
        anyhow::ensure!(
            w[1] >= w[0],
            "trace entry {} is out of order: {} after {}",
            i + 1, w[1], w[0]
        );
    }
    Ok(ArrivalPattern::Trace { arrivals_us: arr })
}

/// Serialize arrival timestamps as a replayable JSON trace.
pub fn trace_to_json(arrivals_us: &[f64]) -> String {
    let obj = Value::Obj(
        [(
            "arrivals_us".to_string(),
            Value::Arr(arrivals_us.iter().map(|&x| Value::Num(x)).collect()),
        )]
        .into_iter()
        .collect(),
    );
    json::to_string(&obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn gaps(xs: &[f64]) -> Vec<f64> {
        xs.windows(2).map(|w| w[1] - w[0]).collect()
    }

    #[test]
    fn patterns_are_sorted_and_sized() {
        let pats = [
            ArrivalPattern::Poisson { rate_per_s: 100.0, n: 500 },
            ArrivalPattern::Mmpp {
                rate_lo_per_s: 20.0,
                rate_hi_per_s: 400.0,
                mean_dwell_s: 0.05,
                n: 500,
            },
            ArrivalPattern::Diurnal {
                base_rate_per_s: 100.0,
                amplitude: 0.8,
                period_s: 1.0,
                n: 500,
            },
        ];
        for p in &pats {
            let xs = p.generate(9);
            assert_eq!(xs.len(), p.len());
            for w in xs.windows(2) {
                assert!(w[1] >= w[0], "{} not sorted", p.kind());
            }
            // deterministic per seed
            assert_eq!(xs, p.generate(9));
            assert_ne!(xs, p.generate(10));
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Squared coefficient of variation of inter-arrivals: 1 for
        // Poisson, > 1 for MMPP with distinct phase rates.
        let po = ArrivalPattern::Poisson { rate_per_s: 100.0, n: 4000 }
            .generate(3);
        let mm = ArrivalPattern::Mmpp {
            rate_lo_per_s: 20.0,
            rate_hi_per_s: 500.0,
            mean_dwell_s: 0.1,
            n: 4000,
        }
        .generate(3);
        let cv2 = |xs: &[f64]| {
            let g = gaps(xs);
            let m = stats::mean(&g);
            let s = stats::stddev(&g);
            (s / m) * (s / m)
        };
        let (cp, cm) = (cv2(&po), cv2(&mm));
        assert!((cp - 1.0).abs() < 0.25, "poisson cv2 {cp}");
        assert!(cm > 1.5 * cp, "mmpp cv2 {cm} vs poisson {cp}");
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let xs = ArrivalPattern::Diurnal {
            base_rate_per_s: 200.0,
            amplitude: 0.9,
            period_s: 0.5,
            n: 3000,
        }
        .generate(5);
        // Count arrivals in the peak vs trough half-periods of each
        // cycle; the peak halves must hold clearly more.
        let period_us = 0.5e6;
        let (mut peak, mut trough) = (0u32, 0u32);
        for &t in &xs {
            let phase = (t % period_us) / period_us;
            if phase < 0.5 {
                peak += 1; // sin > 0 half
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} trough {trough}"
        );
    }

    #[test]
    fn fit_recovers_mmpp_rates_and_burstiness() {
        let xs = ArrivalPattern::Mmpp {
            rate_lo_per_s: 20.0,
            rate_hi_per_s: 500.0,
            mean_dwell_s: 0.1,
            n: 4000,
        }
        .generate(3);
        let fit = fit_mmpp(&xs).unwrap();
        // The fit's cv2 is pinned to the independently computed
        // empirical CV^2 of the gaps — exact, not approximate.
        let g = gaps(&xs);
        let (m, s) = (stats::mean(&g), stats::stddev(&g));
        let empirical = (s / m) * (s / m);
        assert!((fit.cv2 - empirical).abs() < 1e-9,
                "fit cv2 {} vs empirical {}", fit.cv2, empirical);
        assert!(fit.cv2 > 1.2, "mmpp should be bursty, cv2 {}", fit.cv2);
        assert!(fit.rate_hi_per_s > 2.0 * fit.rate_lo_per_s,
                "phases not separated: {} vs {}",
                fit.rate_hi_per_s, fit.rate_lo_per_s);
        // Cluster-based recovery is order-of-magnitude, not exact.
        for (got, want) in [
            (fit.rate_hi_per_s, 500.0),
            (fit.rate_lo_per_s, 20.0),
        ] {
            let ratio = got / want;
            assert!(ratio > 0.35 && ratio < 3.0,
                    "rate {got:.1} vs true {want:.1}");
        }
        let dwell_ratio = fit.mean_dwell_s / 0.1;
        assert!(dwell_ratio > 0.05 && dwell_ratio < 5.0,
                "dwell {} vs true 0.1", fit.mean_dwell_s);
    }

    #[test]
    fn fit_on_poisson_reads_as_non_bursty() {
        let xs = ArrivalPattern::Poisson { rate_per_s: 100.0, n: 4000 }
            .generate(11);
        let fit = fit_mmpp(&xs).unwrap();
        assert!(fit.cv2 > 0.6 && fit.cv2 < 1.5, "poisson cv2 {}", fit.cv2);
        let ratio = fit.base_rate_per_s / 100.0;
        assert!(ratio > 0.5 && ratio < 2.0,
                "base rate {}", fit.base_rate_per_s);
        // Too-short traces refuse to fit instead of guessing.
        assert!(fit_mmpp(&xs[..8]).is_err());
        assert!(fit_mmpp(&[0.0; 20]).is_err());
    }

    #[test]
    fn fit_rejects_degenerate_traces_with_structured_errors() {
        // Fewer than 2 arrivals: no inter-arrival gap exists.
        for trace in [&[][..], &[5.0][..]] {
            let err = fit_mmpp(trace).unwrap_err();
            assert!(format!("{err:#}").contains("at least 2"),
                    "unhelpful error: {err:#}");
        }
        // Zero-variance gaps (perfectly regular trace): every derived
        // parameter would be degenerate — the error says what to use
        // instead, and no NaN escapes.
        let regular: Vec<f64> = (0..64).map(|i| i as f64 * 100.0).collect();
        let err = fit_mmpp(&regular).unwrap_err();
        assert!(format!("{err:#}").contains("zero variance"),
                "unhelpful error: {err:#}");
        // Zero span: all timestamps identical.
        let err = fit_mmpp(&[7.0; 32]).unwrap_err();
        assert!(format!("{err:#}").contains("zero virtual time"),
                "unhelpful error: {err:#}");
        // Healthy traces still fit and stay finite.
        let xs = ArrivalPattern::Poisson { rate_per_s: 50.0, n: 200 }
            .generate(1);
        let fit = fit_mmpp(&xs).unwrap();
        for x in [
            fit.rate_lo_per_s, fit.rate_hi_per_s, fit.mean_dwell_s,
            fit.base_rate_per_s, fit.amplitude, fit.period_s, fit.cv2,
        ] {
            assert!(x.is_finite(), "non-finite fit param {x}");
        }
    }

    #[test]
    fn trace_json_rejects_unordered_and_negative_timestamps() {
        // Out-of-order timestamps name the offending entry index.
        let err = trace_from_json("[1.0, 5.0, 3.0]").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("entry 2") && msg.contains("out of order"),
                "unhelpful error: {msg}");
        // Negative timestamps are rejected by index too.
        let err =
            trace_from_json("{\"arrivals_us\": [0.0, -2.5, 3.0]}")
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("entry 1") && msg.contains("negative"),
                "unhelpful error: {msg}");
        // Equal adjacent timestamps are legal (simultaneous arrivals).
        assert!(trace_from_json("[1.0, 1.0, 2.0]").is_ok());
    }

    #[test]
    fn fit_recovers_diurnal_period_and_amplitude() {
        let xs = ArrivalPattern::Diurnal {
            base_rate_per_s: 200.0,
            amplitude: 0.9,
            period_s: 0.5,
            n: 4000,
        }
        .generate(5);
        let fit = fit_mmpp(&xs).unwrap();
        let ratio = fit.period_s / 0.5;
        assert!(ratio > 0.5 && ratio < 2.0,
                "period {} vs true 0.5", fit.period_s);
        assert!(fit.amplitude > 0.2,
                "oscillation missed, amplitude {}", fit.amplitude);
        let base_ratio = fit.base_rate_per_s / 200.0;
        assert!(base_ratio > 0.5 && base_ratio < 2.0,
                "base rate {}", fit.base_rate_per_s);
    }

    #[test]
    fn trace_json_roundtrip() {
        let src = vec![10.0, 250.5, 999.0, 12345.6];
        let text = trace_to_json(&src);
        let p = trace_from_json(&text).unwrap();
        assert_eq!(p.kind(), "trace");
        let xs = p.generate(0);
        assert_eq!(xs.len(), 4);
        for (a, b) in xs.iter().zip(&src) {
            assert!((a - b).abs() < 1e-9);
        }
        // bare-array form and error cases
        assert!(trace_from_json("[1.0, 2.0]").is_ok());
        assert!(trace_from_json("{\"nope\": 1}").is_err());
        assert!(trace_from_json("[]").is_err());
        assert!(trace_from_json("not json").is_err());
        // malformed entries are an error, not a shorter workload
        assert!(trace_from_json("[1.0, \"2.0\", 3.0]").is_err());
    }

    #[test]
    fn merged_stream_has_dense_ordered_ids() {
        let tenants = vec![
            Tenant {
                name: "a".into(),
                model: "m0".into(),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 50.0,
                    n: 100,
                },
            },
            Tenant {
                name: "b".into(),
                model: "m1".into(),
                class: 1,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 80.0,
                    n: 150,
                },
            },
        ];
        let merged = merge_arrivals(&tenants, 7);
        assert_eq!(merged.len(), 250);
        for (i, a) in merged.iter().enumerate() {
            assert_eq!(a.req, i);
            assert!(a.tenant < 2);
            if i > 0 {
                assert!(a.at_us >= merged[i - 1].at_us);
            }
        }
    }
}
