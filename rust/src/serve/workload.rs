//! Multi-tenant workload generation: arrival patterns beyond Poisson.
//!
//! A [`Tenant`] binds a model + SLO class to an [`ArrivalPattern`]:
//! * `Poisson` — memoryless open-loop traffic (the classic serving
//!   assumption).
//! * `Mmpp` — a two-state Markov-modulated Poisson process: calm/burst
//!   phases with exponentially distributed dwell times (flash crowds,
//!   camera-triggered edge pipelines).
//! * `Diurnal` — a sinusoidal rate curve sampled by thinning (day/night
//!   load cycles compressed into virtual time).
//! * `Trace` — explicit arrival timestamps replayed verbatim, with a
//!   JSON round-trip ([`trace_from_json`] / [`trace_to_json`]) so real
//!   production traces can be fed to the cluster scheduler.
//!
//! [`merge_arrivals`] turns a tenant set into one globally-ordered
//! arrival stream with dense request ids — the cluster scheduler's
//! input.

use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// One tenant's arrival process (all times/rates are virtual time).
#[derive(Debug, Clone)]
pub enum ArrivalPattern {
    /// Memoryless arrivals at `rate_per_s`.
    Poisson { rate_per_s: f64, n: usize },
    /// Two-state MMPP: Poisson at `rate_lo_per_s` / `rate_hi_per_s`,
    /// switching states after exponential dwells of mean `mean_dwell_s`.
    Mmpp {
        rate_lo_per_s: f64,
        rate_hi_per_s: f64,
        mean_dwell_s: f64,
        n: usize,
    },
    /// Sinusoidal rate curve `base * (1 + amplitude * sin(2pi t/period))`
    /// sampled by thinning; `amplitude` in [0, 1].
    Diurnal {
        base_rate_per_s: f64,
        amplitude: f64,
        period_s: f64,
        n: usize,
    },
    /// Replay explicit arrival timestamps (microseconds, sorted).
    Trace { arrivals_us: Vec<f64> },
}

impl ArrivalPattern {
    /// Materialize the arrival timestamps (microseconds, ascending).
    pub fn generate(&self, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        match self {
            // One Poisson generator in the crate: the batcher's.
            ArrivalPattern::Poisson { rate_per_s, n } => {
                crate::server::batcher::poisson_stream(
                    *n, rate_per_s.max(1e-9), seed)
                    .into_iter()
                    .map(|r| r.arrival_us)
                    .collect()
            }
            ArrivalPattern::Mmpp {
                rate_lo_per_s,
                rate_hi_per_s,
                mean_dwell_s,
                n,
            } => {
                let mut out = Vec::with_capacity(*n);
                let mut t = 0.0f64;
                let mut hi = false;
                let dwell_rate = 1.0 / mean_dwell_s.max(1e-9);
                let mut next_switch =
                    rng.exponential(dwell_rate) * 1e6;
                while out.len() < *n {
                    let rate = if hi { *rate_hi_per_s } else { *rate_lo_per_s };
                    let gap = rng.exponential(rate.max(1e-9)) * 1e6;
                    if t + gap > next_switch {
                        // Memorylessness: restart the arrival clock at the
                        // state switch instead of carrying the old sample.
                        t = next_switch;
                        hi = !hi;
                        next_switch =
                            t + rng.exponential(dwell_rate) * 1e6;
                        continue;
                    }
                    t += gap;
                    out.push(t);
                }
                out
            }
            ArrivalPattern::Diurnal {
                base_rate_per_s,
                amplitude,
                period_s,
                n,
            } => {
                let amp = amplitude.clamp(0.0, 1.0);
                // Clamp the base rate itself, not just the proposal
                // rate: a zero base would make the thinning accept test
                // unsatisfiable and the loop would never fill `n`.
                let base = base_rate_per_s.max(1e-9);
                let max_rate = base * (1.0 + amp);
                let mut out = Vec::with_capacity(*n);
                let mut t = 0.0f64;
                while out.len() < *n {
                    t += rng.exponential(max_rate) * 1e6;
                    let phase = 2.0 * std::f64::consts::PI
                        * (t / 1e6)
                        / period_s.max(1e-9);
                    let rate = base * (1.0 + amp * phase.sin());
                    if rng.f64() * max_rate <= rate {
                        out.push(t);
                    }
                }
                out
            }
            ArrivalPattern::Trace { arrivals_us } => {
                let mut v = arrivals_us.clone();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            }
        }
    }

    /// Number of requests this pattern will emit.
    pub fn len(&self) -> usize {
        match self {
            ArrivalPattern::Poisson { n, .. }
            | ArrivalPattern::Mmpp { n, .. }
            | ArrivalPattern::Diurnal { n, .. } => *n,
            ArrivalPattern::Trace { arrivals_us } => arrivals_us.len(),
        }
    }

    /// True when the pattern emits no requests.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Short label for tables/reports.
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalPattern::Poisson { .. } => "poisson",
            ArrivalPattern::Mmpp { .. } => "mmpp",
            ArrivalPattern::Diurnal { .. } => "diurnal",
            ArrivalPattern::Trace { .. } => "trace",
        }
    }
}

/// One workload stream: a model, an SLO class, an arrival process.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Display name of the stream.
    pub name: String,
    /// Model name in the [`crate::serve::ModelRegistry`].
    pub model: String,
    /// Index into the cluster's SLO class table (0 = highest priority).
    pub class: usize,
    /// The stream's arrival process.
    pub pattern: ArrivalPattern,
}

/// One arrival in the merged multi-tenant stream.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Dense global request id (0..total), assigned in time order.
    pub req: usize,
    /// Index into the tenant set.
    pub tenant: usize,
    /// Arrival time, microseconds of virtual time.
    pub at_us: f64,
}

/// Generate every tenant's stream (tenant `i` uses `seed + i * 7919`) and
/// merge into one time-ordered stream with dense request ids.
pub fn merge_arrivals(tenants: &[Tenant], seed: u64) -> Vec<Arrival> {
    let mut all: Vec<(f64, usize)> = Vec::new();
    for (ti, t) in tenants.iter().enumerate() {
        for at in t.pattern.generate(seed.wrapping_add(ti as u64 * 7919)) {
            all.push((at, ti));
        }
    }
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    all.into_iter()
        .enumerate()
        .map(|(req, (at_us, tenant))| Arrival { req, tenant, at_us })
        .collect()
}

/// Parse a replayable trace: either `{"arrivals_us": [...]}` or a bare
/// JSON array of microsecond timestamps.  Every entry must be a number —
/// a malformed entry is an error, never a silently shorter workload.
pub fn trace_from_json(text: &str) -> Result<ArrivalPattern> {
    let v = json::parse(text)
        .map_err(|e| anyhow::anyhow!("parsing trace JSON: {e}"))?;
    let items = match &v {
        Value::Arr(a) => &a[..],
        Value::Obj(_) => v
            .get("arrivals_us")
            .as_arr()
            .context("trace needs an `arrivals_us` array")?,
        _ => anyhow::bail!("trace must be a JSON array or object"),
    };
    let arr = items
        .iter()
        .enumerate()
        .map(|(i, x)| {
            x.as_f64().with_context(|| {
                format!("trace entry {i} is not a number")
            })
        })
        .collect::<Result<Vec<f64>>>()?;
    anyhow::ensure!(!arr.is_empty(), "trace has no arrivals");
    Ok(ArrivalPattern::Trace { arrivals_us: arr })
}

/// Serialize arrival timestamps as a replayable JSON trace.
pub fn trace_to_json(arrivals_us: &[f64]) -> String {
    let obj = Value::Obj(
        [(
            "arrivals_us".to_string(),
            Value::Arr(arrivals_us.iter().map(|&x| Value::Num(x)).collect()),
        )]
        .into_iter()
        .collect(),
    );
    json::to_string(&obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn gaps(xs: &[f64]) -> Vec<f64> {
        xs.windows(2).map(|w| w[1] - w[0]).collect()
    }

    #[test]
    fn patterns_are_sorted_and_sized() {
        let pats = [
            ArrivalPattern::Poisson { rate_per_s: 100.0, n: 500 },
            ArrivalPattern::Mmpp {
                rate_lo_per_s: 20.0,
                rate_hi_per_s: 400.0,
                mean_dwell_s: 0.05,
                n: 500,
            },
            ArrivalPattern::Diurnal {
                base_rate_per_s: 100.0,
                amplitude: 0.8,
                period_s: 1.0,
                n: 500,
            },
        ];
        for p in &pats {
            let xs = p.generate(9);
            assert_eq!(xs.len(), p.len());
            for w in xs.windows(2) {
                assert!(w[1] >= w[0], "{} not sorted", p.kind());
            }
            // deterministic per seed
            assert_eq!(xs, p.generate(9));
            assert_ne!(xs, p.generate(10));
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Squared coefficient of variation of inter-arrivals: 1 for
        // Poisson, > 1 for MMPP with distinct phase rates.
        let po = ArrivalPattern::Poisson { rate_per_s: 100.0, n: 4000 }
            .generate(3);
        let mm = ArrivalPattern::Mmpp {
            rate_lo_per_s: 20.0,
            rate_hi_per_s: 500.0,
            mean_dwell_s: 0.1,
            n: 4000,
        }
        .generate(3);
        let cv2 = |xs: &[f64]| {
            let g = gaps(xs);
            let m = stats::mean(&g);
            let s = stats::stddev(&g);
            (s / m) * (s / m)
        };
        let (cp, cm) = (cv2(&po), cv2(&mm));
        assert!((cp - 1.0).abs() < 0.25, "poisson cv2 {cp}");
        assert!(cm > 1.5 * cp, "mmpp cv2 {cm} vs poisson {cp}");
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let xs = ArrivalPattern::Diurnal {
            base_rate_per_s: 200.0,
            amplitude: 0.9,
            period_s: 0.5,
            n: 3000,
        }
        .generate(5);
        // Count arrivals in the peak vs trough half-periods of each
        // cycle; the peak halves must hold clearly more.
        let period_us = 0.5e6;
        let (mut peak, mut trough) = (0u32, 0u32);
        for &t in &xs {
            let phase = (t % period_us) / period_us;
            if phase < 0.5 {
                peak += 1; // sin > 0 half
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} trough {trough}"
        );
    }

    #[test]
    fn trace_json_roundtrip() {
        let src = vec![10.0, 250.5, 999.0, 12345.6];
        let text = trace_to_json(&src);
        let p = trace_from_json(&text).unwrap();
        assert_eq!(p.kind(), "trace");
        let xs = p.generate(0);
        assert_eq!(xs.len(), 4);
        for (a, b) in xs.iter().zip(&src) {
            assert!((a - b).abs() < 1e-9);
        }
        // bare-array form and error cases
        assert!(trace_from_json("[1.0, 2.0]").is_ok());
        assert!(trace_from_json("{\"nope\": 1}").is_err());
        assert!(trace_from_json("[]").is_err());
        assert!(trace_from_json("not json").is_err());
        // malformed entries are an error, not a shorter workload
        assert!(trace_from_json("[1.0, \"2.0\", 3.0]").is_err());
    }

    #[test]
    fn merged_stream_has_dense_ordered_ids() {
        let tenants = vec![
            Tenant {
                name: "a".into(),
                model: "m0".into(),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 50.0,
                    n: 100,
                },
            },
            Tenant {
                name: "b".into(),
                model: "m1".into(),
                class: 1,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 80.0,
                    n: 150,
                },
            },
        ];
        let merged = merge_arrivals(&tenants, 7);
        assert_eq!(merged.len(), 250);
        for (i, a) in merged.iter().enumerate() {
            assert_eq!(a.req, i);
            assert!(a.tenant < 2);
            if i > 0 {
                assert!(a.at_us >= merged[i - 1].at_us);
            }
        }
    }
}
