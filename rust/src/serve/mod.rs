//! Multi-tenant SLO-aware serving — the cluster layer above
//! [`crate::api::Session`].
//!
//! Where [`crate::server`] batches one model's request stream, this
//! module serves *many* models against shared CPU/GPU capacity:
//!
//! * [`ModelRegistry`] — N warmed sessions with per-model batch plans
//!   (Algorithm 2) for both processors and Fig. 2 sparsity/intensity
//!   signals (registry).
//! * [`SloClass`] / [`AdmissionQueues`] / [`ShedPolicy`] — per-class
//!   deadlines, bounded queues, and load shedding with exact
//!   conservation accounting (slo).
//! * [`run_cluster`] — the event-driven virtual-time cross-model
//!   scheduler (the Sparse-DySta-style dynamic tier over SparOA's
//!   static per-model schedules), plus the static-split baseline it is
//!   benchmarked against (cluster).
//! * [`run_fleet`] — N simulated boards (each an independent board
//!   scheduler over a per-board [`LaneMatrix`]) behind a front-tier
//!   [`RouterPolicy`], with replica autoscaling driven by the
//!   per-board [`PerfSnapshot`] signals (fleet).
//! * [`ArrivalPattern`] / [`Tenant`] — Poisson, bursty MMPP, diurnal
//!   and JSON-trace-replay workload generators (workload).
//! * [`PerfSnapshot`] — per-class/per-model p50/p95/p99, shed rate,
//!   attainment and utilization, with JSON output (report).  When a
//!   board runs energy-aware (a [`crate::power::PowerConfig`] installed
//!   via [`FleetOptions`]), the snapshot also carries joules, mean
//!   watts and throttle counts, judged against an [`EnergySlo`]
//!   budget alongside the latency classes.
//! * Fault injection — a [`crate::faults::FaultPlan`] installed via
//!   [`FleetOptions::faults`] schedules board crashes, lane loss and
//!   thermal slow-downs; the fleet drains crashed boards back through
//!   the front tier with deadline-aware retries, and conservation
//!   extends to offered == served + shed + failed exactly.
//! * Tail tolerance — [`TailPolicy`] (via [`FleetOptions::tail`])
//!   arms a gray-failure detector (per-board EWMA of realized vs
//!   predicted dispatch latency), a per-board circuit breaker
//!   (`Closed → Open → Probation` with seeded probe dispatches), and
//!   hedged dispatch for deadline-at-risk interactive requests with
//!   first-wins cancellation through the in-flight ledger (tail).
//!
//! The `serve-multi` / `serve-fleet` CLI subcommands and the
//! `fig13_multimodel` / `fig_fleet` benches drive the [`demo`] fleet
//! end-to-end; `rust/tests/serve_multitenant.rs` and
//! `rust/tests/serve_fleet.rs` property-test the
//! conservation/fairness/routing/autoscaling invariants.

pub mod cluster;
pub mod fleet;
pub mod registry;
pub mod report;
pub mod slo;
pub mod tail;
pub mod workload;

pub use cluster::{
    run_cluster, ClusterOptions, ClusterPolicy, LaneMatrix,
    PreemptionPolicy,
};
pub use fleet::{
    run_fleet, spread_placement, AutoscalePolicy, FleetOptions,
    FleetSnapshot, ReplicaSample, RouterPolicy, ScaleEvent,
};
pub use registry::{ModelEntry, ModelRegistry};
pub use report::{GroupStats, PerfSnapshot};
pub use slo::{
    AdmissionQueues, EnergySlo, QueuedReq, ShedPolicy, ShedReq, SloClass,
};
pub use tail::{TailParams, TailPolicy};
pub use workload::{
    fit_mmpp, merge_arrivals, trace_from_json, trace_to_json, Arrival,
    ArrivalPattern, MmppFit, Tenant,
};

/// A canonical three-model / three-class / four-pattern scenario shared
/// by the CLI demo, the `fig13_multimodel` bench and the integration
/// tests.  Falls back to synthetic models when `make artifacts` hasn't
/// run, so the demo always works.
pub mod demo {
    use super::*;
    use crate::api::{BackendChoice, Session, SessionBuilder};
    use crate::graph::{ModelGraph, ModelZoo};
    use anyhow::Result;
    use std::path::Path;

    /// (name, blocks, flops_scale, relu_sparsity) for the synthetic
    /// fallback fleet: one dense-heavy, one mid, one sparse-light model.
    const SYNTHETIC_FLEET: [(&str, usize, f64, f64); 3] = [
        ("syn_heavy", 8, 6.0, 0.1),
        ("syn_mid", 6, 1.5, 0.45),
        ("syn_light", 4, 0.3, 0.75),
    ];

    /// Artifact models used when `make artifacts` has run.
    const ARTIFACT_FLEET: [&str; 3] =
        ["mobilenet_v3_small", "resnet18", "mobilenet_v2"];

    fn build_session(
        artifacts: &Path,
        device: &str,
        model: Option<&str>,
        synthetic: Option<&ModelGraph>,
    ) -> Result<Session> {
        let mut b = SessionBuilder::new()
            .artifacts(artifacts)
            .device(device)
            .policy("greedy")
            .backend(BackendChoice::Sim);
        if let Some(g) = synthetic {
            b = b.with_graph(g.clone());
        } else if let Some(m) = model {
            b = b.model(m);
        }
        b.build()
    }

    /// Build the demo registry: artifact models when available,
    /// synthetic fleet otherwise.
    pub fn registry(artifacts: &Path, device: &str) -> Result<ModelRegistry> {
        let mut reg = ModelRegistry::new();
        let zoo = ModelZoo::load(artifacts).ok();
        let have_artifacts = zoo
            .as_ref()
            .map_or(false, |z| {
                ARTIFACT_FLEET.iter().all(|m| z.get(m).is_ok())
            });
        if have_artifacts {
            for m in ARTIFACT_FLEET {
                reg.register(build_session(
                    artifacts, device, Some(m), None)?)?;
            }
        } else {
            for (name, blocks, scale, sparsity) in SYNTHETIC_FLEET {
                let g = ModelGraph::synthetic(name, blocks, scale, sparsity);
                reg.register(build_session(
                    artifacts, device, None, Some(&g))?)?;
            }
        }
        Ok(reg)
    }

    /// Interactive (20 ms), standard (60 ms), best-effort (250 ms).
    pub fn classes() -> Vec<SloClass> {
        vec![
            SloClass::new("interactive", 20_000.0, 128, 4.0),
            SloClass::new("standard", 60_000.0, 256, 2.0),
            SloClass::new("best-effort", 250_000.0, 512, 1.0),
        ]
    }

    /// Four tenants covering all four arrival patterns (poisson, bursty
    /// MMPP, diurnal, JSON trace replay).  `load` scales every rate;
    /// `n` is the per-tenant request count; `trace` optionally replaces
    /// the built-in replay trace (e.g. from `--trace=FILE`).
    pub fn tenants(
        registry: &ModelRegistry,
        load: f64,
        n: usize,
        seed: u64,
        trace: Option<ArrivalPattern>,
    ) -> Result<Vec<Tenant>> {
        anyhow::ensure!(registry.len() >= 3, "demo fleet needs 3 models");
        anyhow::ensure!(n >= 1, "need at least 1 request per tenant");
        let load = load.max(0.01);
        let m = |i: usize| registry.get(i).name.clone();
        // Built-in replay trace: a bursty stream serialized to JSON and
        // parsed back, so the trace path is exercised end-to-end.
        let trace = match trace {
            Some(t) => t,
            None => {
                let src = ArrivalPattern::Mmpp {
                    rate_lo_per_s: 20.0 * load,
                    rate_hi_per_s: 240.0 * load,
                    mean_dwell_s: 0.08,
                    n,
                }
                .generate(seed ^ 0x5eed);
                trace_from_json(&trace_to_json(&src))?
            }
        };
        Ok(vec![
            Tenant {
                name: "vision-interactive".into(),
                model: m(0),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 90.0 * load,
                    n,
                },
            },
            Tenant {
                name: "detector-bursty".into(),
                model: m(1),
                class: 1,
                pattern: ArrivalPattern::Mmpp {
                    rate_lo_per_s: 30.0 * load,
                    rate_hi_per_s: 450.0 * load,
                    mean_dwell_s: 0.05,
                    n,
                },
            },
            Tenant {
                name: "analytics-diurnal".into(),
                model: m(2),
                class: 2,
                pattern: ArrivalPattern::Diurnal {
                    base_rate_per_s: 220.0 * load,
                    amplitude: 0.8,
                    period_s: 0.5,
                    n,
                },
            },
            Tenant {
                name: "replay-trace".into(),
                model: m(2),
                class: 0,
                pattern: trace,
            },
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_fleet_runs_end_to_end_without_artifacts() {
        // Point at a directory with no artifacts: the synthetic fleet
        // must come up and serve all four patterns on both policies.
        let artifacts = std::env::temp_dir().join("sparoa-no-artifacts");
        let reg = demo::registry(&artifacts, "agx_orin").unwrap();
        assert_eq!(reg.len(), 3);
        let classes = demo::classes();
        let tenants =
            demo::tenants(&reg, 0.2, 40, 7, None).unwrap();
        assert_eq!(tenants.len(), 4);
        let kinds: Vec<&str> =
            tenants.iter().map(|t| t.pattern.kind()).collect();
        assert!(kinds.contains(&"poisson"));
        assert!(kinds.contains(&"mmpp"));
        assert!(kinds.contains(&"diurnal"));
        assert!(kinds.contains(&"trace"));
        let arrivals = merge_arrivals(&tenants, 3);
        for policy in
            [ClusterPolicy::SparsityAware, ClusterPolicy::StaticSplit]
        {
            let snap = run_cluster(&reg, &classes, &tenants, &arrivals,
                &ClusterOptions { policy, ..Default::default() })
                .unwrap();
            assert_eq!(snap.total_offered() as usize, arrivals.len());
            assert_eq!(snap.total_served() + snap.total_shed(),
                       snap.total_offered());
        }
    }
}
