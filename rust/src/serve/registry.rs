//! [`ModelRegistry`] — N warmed [`Session`]s with per-model batch plans
//! and sparsity/intensity signals, ready for cross-model scheduling.
//!
//! Registration derives, per model:
//! * a CPU-fallback projection of the session's (typically GPU-leaning)
//!   schedule, so the cluster scheduler can place any model's batch on
//!   either processor;
//! * Algorithm-2 batch caps for both placements (the static tier of the
//!   Sparse-DySta-style split: per-model plans computed offline, consumed
//!   by the dynamic cross-model tier at dispatch time);
//! * the model's mean activation sparsity / compute intensity
//!   ([`crate::engine::batching::model_profile`]), the paper's Fig. 2
//!   signals, used as placement tie-breaks.

use crate::api::Session;
use crate::device::Proc;
use crate::engine::batching::{
    model_profile, optimize_batch, BatchConstraints,
};
use crate::scheduler::Schedule;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Mutex;

/// One registered model and its precomputed serving plans.
pub struct ModelEntry {
    /// Model name (unique within the registry).
    pub name: String,
    /// The warmed session this entry serves through.
    pub session: Session,
    /// The session's own (hybrid/GPU-leaning) schedule drives GPU-side
    /// dispatch; this projection drives CPU-side dispatch.
    pub cpu_schedule: Schedule,
    /// Algorithm-2 batch cap when dispatched on the GPU plan.
    pub gpu_batch_cap: usize,
    /// Algorithm-2 batch cap when dispatched on the CPU fallback.
    pub cpu_batch_cap: usize,
    /// Mean activation sparsity of schedulable ops, [0, 1].
    pub sparsity: f64,
    /// Mean normalized compute intensity of schedulable ops, [0, 1].
    pub intensity: f64,
    /// Memoized [`Session::probe`] makespans keyed by (placement,
    /// batch).  The cluster scheduler's event loop scores the same
    /// configurations at every dispatch decision; each one is simulated
    /// exactly once per registry lifetime (so the cache also spans
    /// repeated `run_cluster` calls over the same registry).
    probe_cache: Mutex<HashMap<(Proc, usize), f64>>,
    /// Memoized DMA fractions keyed like `probe_cache` — a separate
    /// map so the profiler's [`ModelEntry::dma_fraction`] probes never
    /// perturb the latency-oracle cache the memoization tests pin.
    dma_cache: Mutex<HashMap<(Proc, usize), f64>>,
}

impl ModelEntry {
    /// Batch cap for a placement.
    pub fn batch_cap(&self, proc: Proc) -> usize {
        match proc {
            Proc::Cpu => self.cpu_batch_cap,
            Proc::Gpu => self.gpu_batch_cap,
        }
    }

    /// Schedule used when this model's batch runs on `proc`.
    pub fn schedule_for(&self, proc: Proc) -> &Schedule {
        match proc {
            Proc::Cpu => &self.cpu_schedule,
            Proc::Gpu => self.session.schedule(),
        }
    }

    /// Memoized latency oracle: makespan (us) of one `batch`-sized
    /// inference on `proc`'s plan, probing the session's backend on the
    /// first query only.
    pub fn latency_us(&self, proc: Proc, batch: usize) -> Result<f64> {
        let key = (proc, batch);
        if let Some(&v) = self.probe_cache.lock().unwrap().get(&key) {
            return Ok(v);
        }
        let rep = self.session.probe(self.schedule_for(proc), batch)?;
        self.probe_cache
            .lock()
            .unwrap()
            .insert(key, rep.makespan_us);
        Ok(rep.makespan_us)
    }

    /// Memoized host↔device transfer share of one `batch`-sized
    /// inference on `proc`'s plan: `transfer_us / makespan_us`,
    /// clamped to [0, 1] (0 when the probe reports a zero makespan).
    /// The profiler uses it to split a batch's lane occupancy into
    /// DMA vs. compute phases; probed once per (placement, batch).
    pub fn dma_fraction(&self, proc: Proc, batch: usize) -> Result<f64> {
        let key = (proc, batch);
        if let Some(&v) = self.dma_cache.lock().unwrap().get(&key) {
            return Ok(v);
        }
        let rep = self.session.probe(self.schedule_for(proc), batch)?;
        let frac = if rep.makespan_us > 0.0 {
            (rep.transfer_us / rep.makespan_us).clamp(0.0, 1.0)
        } else {
            0.0
        };
        self.dma_cache.lock().unwrap().insert(key, frac);
        Ok(frac)
    }

    /// Cheapest makespan (us) of one `batch`-sized inference across
    /// both placements — the router's request-cost estimate.
    pub fn cheapest_latency_us(&self, batch: usize) -> Result<f64> {
        Ok(self
            .latency_us(Proc::Cpu, batch)?
            .min(self.latency_us(Proc::Gpu, batch)?))
    }

    /// Per-request cost (us) at the full Algorithm-2 batch on whichever
    /// placement amortizes better — one replica's marginal serving cost
    /// at peak efficiency, i.e. the reciprocal of its max throughput.
    /// The fleet autoscaler's load signal.
    pub fn efficient_cost_us(&self) -> Result<f64> {
        let g = self.latency_us(Proc::Gpu, self.gpu_batch_cap)?
            / self.gpu_batch_cap.max(1) as f64;
        let c = self.latency_us(Proc::Cpu, self.cpu_batch_cap)?
            / self.cpu_batch_cap.max(1) as f64;
        Ok(g.min(c))
    }
}

/// The set of models a serving cluster hosts.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a warmed session; computes both batch plans and the
    /// Fig. 2 signals.  Returns the model's registry index.
    pub fn register(&mut self, session: Session) -> Result<usize> {
        let name = session.graph().model.clone();
        anyhow::ensure!(
            self.index_of(&name).is_err(),
            "model `{name}` already registered"
        );
        let graph = session.graph();
        let (sparsity, intensity) = model_profile(graph);
        let cpu_schedule = session
            .schedule()
            .project(Proc::Cpu, &format!("{}+cpu-fallback",
                                         session.schedule().policy));
        let constraints = BatchConstraints::for_device(session.device());
        let gpu_plan = optimize_batch(
            graph,
            session.device(),
            session.schedule(),
            session.options(),
            8,
            &constraints,
        );
        // CPU batches amortize launches less; start the search low and
        // keep the cap modest so one CPU batch never monopolizes the lane.
        let cpu_constraints = BatchConstraints {
            max_batch: 16,
            ..constraints
        };
        let cpu_plan = optimize_batch(
            graph,
            session.device(),
            &cpu_schedule,
            session.options(),
            2,
            &cpu_constraints,
        );
        self.entries.push(ModelEntry {
            name,
            session,
            cpu_schedule,
            gpu_batch_cap: gpu_plan.batch.max(1),
            cpu_batch_cap: cpu_plan.batch.max(1),
            sparsity,
            intensity,
            probe_cache: Mutex::new(HashMap::new()),
            dma_cache: Mutex::new(HashMap::new()),
        });
        Ok(self.entries.len() - 1)
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no models are registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The entry at registry index `idx` (panics when out of range).
    pub fn get(&self, idx: usize) -> &ModelEntry {
        &self.entries[idx]
    }

    /// All entries, in registration order (index == registry index).
    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    /// Per-model cheapest batch-1 latency table (us), probed once —
    /// the price table the fleet router's cached backlog scores and
    /// the dispatch benches share.  Index == registry index.
    pub fn lat1_table(&self) -> Result<Vec<f64>> {
        self.entries
            .iter()
            .map(|e| e.cheapest_latency_us(1))
            .collect()
    }

    /// Per-model batch-1 latency table (us) restricted to one
    /// placement, probed once.  The fleet's fault layer prices a
    /// *degraded* board with it: a board whose GPU lane died quotes
    /// `lat1_table_for(Proc::Cpu)`, so the cost-aware router and the
    /// deadline-feasibility retry check both see the surviving lane's
    /// real price.  Index == registry index.
    pub fn lat1_table_for(&self, proc: Proc) -> Result<Vec<f64>> {
        self.entries
            .iter()
            .map(|e| e.latency_us(proc, 1))
            .collect()
    }

    /// Per-model per-request cost (us) at the efficient Alg. 2 batch —
    /// the autoscaler's load-signal table.  Index == registry index.
    pub fn efficient_cost_table(&self) -> Result<Vec<f64>> {
        self.entries
            .iter()
            .map(|e| e.efficient_cost_us())
            .collect()
    }

    /// Registry index of the model named `name`.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.entries
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!("model `{name}` not registered")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::graph::ModelGraph;

    fn session(name: &str, scale: f64, sparsity: f64) -> Session {
        let dev = crate::bench_support::device_profile("agx_orin");
        SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(name, 4, scale, sparsity))
            .with_device(dev)
            .policy("greedy")
            .build()
            .unwrap()
    }

    #[test]
    fn register_builds_dual_plans_and_signals() {
        let mut reg = ModelRegistry::new();
        let heavy = reg.register(session("heavy", 6.0, 0.05)).unwrap();
        let light = reg.register(session("light", 0.4, 0.8)).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.index_of("light").unwrap(), light);
        let h = reg.get(heavy);
        let l = reg.get(light);
        assert!(l.sparsity > h.sparsity);
        assert!(h.intensity > l.intensity);
        assert!(h.gpu_batch_cap >= 1 && h.cpu_batch_cap >= 1);
        assert!(h.cpu_batch_cap <= 16);
        // CPU projection leaves the GPU idle; GPU plan uses it.
        let on_cpu = h
            .session
            .probe(h.schedule_for(crate::device::Proc::Cpu), 1)
            .unwrap();
        assert_eq!(on_cpu.gpu_busy_us, 0.0);
        let on_gpu = h
            .session
            .probe(h.schedule_for(crate::device::Proc::Gpu), 1)
            .unwrap();
        assert!(on_gpu.makespan_us < on_cpu.makespan_us);
        // Duplicate names are rejected.
        assert!(reg.register(session("heavy", 1.0, 0.1)).is_err());
    }

    #[test]
    fn latency_oracle_memoizes_probes() {
        let mut reg = ModelRegistry::new();
        reg.register(session("memo", 2.0, 0.3)).unwrap();
        let e = reg.get(0);
        let p = crate::device::Proc::Gpu;
        let direct = e.session.probe(e.schedule_for(p), 4).unwrap();
        let l1 = e.latency_us(p, 4).unwrap();
        let l2 = e.latency_us(p, 4).unwrap();
        assert_eq!(l1, direct.makespan_us);
        assert_eq!(l1, l2);
        assert_eq!(e.probe_cache.lock().unwrap().len(), 1);
        // Distinct (placement, batch) keys populate separately.
        let _ = e.latency_us(crate::device::Proc::Cpu, 4).unwrap();
        let _ = e.latency_us(p, 8).unwrap();
        assert_eq!(e.probe_cache.lock().unwrap().len(), 3);
    }

    #[test]
    fn dma_fraction_is_bounded_and_cached_separately() {
        let mut reg = ModelRegistry::new();
        reg.register(session("dma", 2.0, 0.3)).unwrap();
        let e = reg.get(0);
        let p = crate::device::Proc::Gpu;
        let f1 = e.dma_fraction(p, 4).unwrap();
        let f2 = e.dma_fraction(p, 4).unwrap();
        assert!((0.0..=1.0).contains(&f1));
        assert!(f1 > 0.0, "a GPU plan must move some bytes");
        assert_eq!(f1, f2);
        assert_eq!(e.dma_cache.lock().unwrap().len(), 1);
        // Fraction probes never perturb the latency-oracle cache.
        assert_eq!(e.probe_cache.lock().unwrap().len(), 0);
    }

    #[test]
    fn cost_helpers_bound_each_other() {
        use crate::device::Proc;
        let mut reg = ModelRegistry::new();
        reg.register(session("costs", 2.0, 0.3)).unwrap();
        let e = reg.get(0);
        // Cheapest batch-1 latency is the min over both placements.
        let cheapest = e.cheapest_latency_us(1).unwrap();
        assert_eq!(
            cheapest,
            e.latency_us(Proc::Cpu, 1)
                .unwrap()
                .min(e.latency_us(Proc::Gpu, 1).unwrap())
        );
        // Batching amortizes: the per-request cost at the full Alg.2
        // batch stays at or below the batch-1 latency (10% headroom
        // for simulator noise at tiny caps).
        let eff = e.efficient_cost_us().unwrap();
        assert!(eff > 0.0);
        assert!(eff <= cheapest * 1.1,
                "efficient {eff} > batch-1 {cheapest}");
    }

    #[test]
    fn price_tables_match_per_entry_helpers() {
        let mut reg = ModelRegistry::new();
        reg.register(session("pt_a", 2.0, 0.3)).unwrap();
        reg.register(session("pt_b", 0.5, 0.6)).unwrap();
        let lat1 = reg.lat1_table().unwrap();
        let eff = reg.efficient_cost_table().unwrap();
        assert_eq!(lat1.len(), 2);
        assert_eq!(eff.len(), 2);
        for m in 0..2 {
            assert_eq!(lat1[m],
                       reg.get(m).cheapest_latency_us(1).unwrap());
            assert_eq!(eff[m], reg.get(m).efficient_cost_us().unwrap());
        }
        // Per-placement tables bound the cheapest table from above.
        let cpu = reg.lat1_table_for(Proc::Cpu).unwrap();
        let gpu = reg.lat1_table_for(Proc::Gpu).unwrap();
        for m in 0..2 {
            assert_eq!(lat1[m], cpu[m].min(gpu[m]));
            assert!(cpu[m] >= lat1[m] && gpu[m] >= lat1[m]);
        }
    }
}
