//! [`PerfSnapshot`] — the serving tier's unified performance report:
//! per-class and per-model latency quantiles (bounded histograms), shed
//! rates, SLO attainment and processor utilization, with compact JSON
//! output for benches and dashboards.

use crate::bench_support::Table;
use crate::power::PowerEvent;
use crate::server::LatencyHistogram;
use crate::util::json::{self, Value};
use std::collections::BTreeMap;

/// Aggregated statistics for one group (an SLO class or a model).
#[derive(Debug, Clone)]
pub struct GroupStats {
    /// Group label (class or model name).
    pub label: String,
    /// Requests offered (admitted + shed at admission).
    pub offered: u64,
    /// Requests served to completion.
    pub served: u64,
    /// Served within their deadline.
    pub met: u64,
    /// Shed by admission control.
    pub shed_admission: u64,
    /// Shed after expiring in queue.
    pub shed_expired: u64,
    /// Failed under faults: lost in a crash with no feasible retry, or
    /// stranded on a dead/degraded board (0 on fault-free runs — the
    /// JSON key is gated on it).
    pub failed: u64,
    /// End-to-end latency distribution (us) of served requests.
    pub hist: LatencyHistogram,
}

impl GroupStats {
    /// Zeroed stats for one labelled group.
    pub fn new(label: &str) -> Self {
        GroupStats {
            label: label.into(),
            offered: 0,
            served: 0,
            met: 0,
            shed_admission: 0,
            shed_expired: 0,
            failed: 0,
            hist: LatencyHistogram::new(),
        }
    }

    /// Total shed (admission + expiry), in requests.
    pub fn shed(&self) -> u64 {
        self.shed_admission + self.shed_expired
    }

    /// Served but past deadline.
    pub fn violations(&self) -> u64 {
        self.served - self.met
    }

    /// Fraction of *offered* requests served within deadline (shed
    /// requests count against attainment).
    pub fn attainment(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.met as f64 / self.offered as f64
    }

    /// Fraction of offered requests shed, in [0, 1].
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.shed() as f64 / self.offered as f64
    }

    /// Latency quantile for display: "-" when nothing was served (an
    /// empty histogram's quantiles are NaN).
    pub fn percentile_str(&self, p: f64) -> String {
        if self.served == 0 {
            "-".into()
        } else {
            format!("{:.0}us", self.hist.percentile(p))
        }
    }

    /// Compact JSON object (counts, rates in [0, 1], latency in us).
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("label".into(), Value::Str(self.label.clone()));
        o.insert("offered".into(), Value::Num(self.offered as f64));
        o.insert("served".into(), Value::Num(self.served as f64));
        o.insert("met".into(), Value::Num(self.met as f64));
        o.insert("shed".into(), Value::Num(self.shed() as f64));
        if self.failed > 0 {
            o.insert("failed".into(), Value::Num(self.failed as f64));
        }
        o.insert("shed_rate".into(), Value::Num(self.shed_rate()));
        o.insert("attainment".into(), Value::Num(self.attainment()));
        o.insert("latency".into(), self.hist.to_json());
        Value::Obj(o)
    }
}

/// One serving run's full report.
#[derive(Debug, Clone)]
pub struct PerfSnapshot {
    /// Cluster policy / board label ("cluster", "static-split", ...).
    pub policy: String,
    /// Shed policy name ("reject-new" / "shed-oldest" / ...).
    pub shed_policy: String,
    /// End-to-end virtual-time span of the run, microseconds.
    pub makespan_us: f64,
    /// Accumulated CPU-lane busy time, microseconds.
    pub cpu_busy_us: f64,
    /// Accumulated GPU-lane busy time, microseconds.
    pub gpu_busy_us: f64,
    /// Batches dispatched.
    pub n_batches: u64,
    /// Requests dispatched (sum of batch sizes).
    pub dispatched: u64,
    /// Outcomes grouped by SLO class.
    pub per_class: Vec<GroupStats>,
    /// Outcomes grouped by model.
    pub per_model: Vec<GroupStats>,
    /// Governor name ("race-to-idle" / "stretch-to-deadline" /
    /// "fixed:N"); empty when the run was not energy-aware, which also
    /// gates the energy keys out of [`PerfSnapshot::to_json`].
    pub governor: String,
    /// Total board energy over the power horizon, millijoules
    /// (busy + idle floors + SoC).
    pub energy_mj: f64,
    /// Busy-interval energy only, millijoules (Σ batch duration × rung
    /// busy power).
    pub busy_energy_mj: f64,
    /// Window the energy integral covers, microseconds (>= makespan;
    /// warm-up occupancies can extend it).
    pub power_horizon_us: f64,
    /// Σ per-lane idle floors, watts (all-idle board draw minus SoC).
    pub idle_floor_w: f64,
    /// SoC static draw, watts.
    pub soc_w: f64,
    /// Cap-binding events (governor state clamped or dispatch
    /// deferred).
    pub throttle_events: u64,
    /// Per-batch busy intervals for power-timeline reconstruction;
    /// populated only under `PowerConfig::trace` (tests), excluded from
    /// JSON, deliberately not merged across boards, and bounded at
    /// `PowerConfig::trace_cap` events (overflow counted in
    /// [`PerfSnapshot::power_trace_dropped`]).
    pub power_trace: Vec<PowerEvent>,
    /// Power-trace events dropped once `power_trace` hit its cap
    /// (counts only; the energy ledger itself stays exact).
    pub power_trace_dropped: u64,
    /// Raw profiler records in virtual time (empty unless the run was
    /// traced via `ClusterOptions::trace` / `FleetOptions::trace`).
    /// Bounded by `obs::TraceConfig::capacity`; like `power_trace`,
    /// deliberately not merged across boards — exporters want
    /// per-board streams.
    pub trace_events: Vec<crate::obs::TraceRecord>,
    /// Trace records dropped once `trace_events` hit its buffer cap
    /// (the [`PerfSnapshot::phases`] accumulators stay exact).
    pub trace_dropped: u64,
    /// Exact per-(model, class) virtual-time phase accumulators
    /// (queue-wait / DMA / compute, all microseconds) plus board
    /// idle/warm-up/capacity totals; empty (`is_empty()`) unless the
    /// run was traced.  Merges across boards by summation.
    pub phases: crate::obs::PhaseBreakdown,
    /// Board crashes absorbed (one per fail-stop event on this board,
    /// or the fleet total after merge).  0 on fault-free runs — all
    /// five fault counters gate the fault JSON keys and summary tail.
    pub failovers: u64,
    /// Requests re-dispatched after being lost in a crashed board's
    /// in-flight batch (counted once per retry attempt that re-entered
    /// a queue).
    pub retries: u64,
    /// In-flight batches retracted by crashes or lane loss (their
    /// requests were requeued, retried, or failed — never silently
    /// dropped).
    pub lost_batches: u64,
    /// Cumulative board downtime, microseconds of virtual time (sum
    /// over crash→rejoin intervals; includes the tail to run end for
    /// boards still down at the end).
    pub downtime_us: f64,
    /// Queued (not yet dispatched) requests drained off a crashed board
    /// and handed back to the front tier for re-placement.
    pub requeued: u64,
    /// In-flight batches voluntarily cancelled to rescue a
    /// higher-class deadline (preemption; their requests were requeued
    /// with arrival/deadline preserved).  0 with
    /// `PreemptionPolicy::Off` — all three preemption counters gate
    /// the preempt JSON keys and summary tail.
    pub preemptions: u64,
    /// Queued (never dispatched) requests re-placed onto another board
    /// by the work-stealing pass (counted on the victim board).
    pub steals: u64,
    /// Lane-time executed on batches that were later preempted,
    /// microseconds of virtual time (the work stayed billed as lane
    /// busy time but produced no served request).
    pub preempt_waste_us: f64,
    /// Boards flagged suspect by the gray-failure detector (one per
    /// sustained realized-vs-predicted inflation episode).  0 with
    /// `--hedge=off --breaker=off` — all six tail counters gate the
    /// tail JSON keys and summary tail.
    pub suspects: u64,
    /// Circuit-breaker trips (first opens plus failed-probe re-opens).
    pub breaker_opens: u64,
    /// Probation probe dispatches admitted (the routed request itself
    /// is the probe).
    pub probes: u64,
    /// At-risk requests hedged: clones offered to a second board.
    pub hedges: u64,
    /// Hedges whose clone finished first (the original was cancelled).
    pub hedge_wins: u64,
    /// Lane-time executed on losing hedge copies, microseconds of
    /// virtual time (duplicate work: billed as lane busy time but
    /// produced no served request beyond the winner's).
    pub hedge_waste_us: f64,
}

impl PerfSnapshot {
    /// Zeroed snapshot with one [`GroupStats`] per class and model.
    pub fn new(
        policy: &str,
        shed_policy: &str,
        class_labels: &[String],
        model_labels: &[String],
    ) -> Self {
        PerfSnapshot {
            policy: policy.into(),
            shed_policy: shed_policy.into(),
            makespan_us: 0.0,
            cpu_busy_us: 0.0,
            gpu_busy_us: 0.0,
            n_batches: 0,
            dispatched: 0,
            per_class: class_labels
                .iter()
                .map(|l| GroupStats::new(l))
                .collect(),
            per_model: model_labels
                .iter()
                .map(|l| GroupStats::new(l))
                .collect(),
            governor: String::new(),
            energy_mj: 0.0,
            busy_energy_mj: 0.0,
            power_horizon_us: 0.0,
            idle_floor_w: 0.0,
            soc_w: 0.0,
            throttle_events: 0,
            power_trace: Vec::new(),
            power_trace_dropped: 0,
            trace_events: Vec::new(),
            trace_dropped: 0,
            phases: crate::obs::PhaseBreakdown::default(),
            failovers: 0,
            retries: 0,
            lost_batches: 0,
            downtime_us: 0.0,
            requeued: 0,
            preemptions: 0,
            steals: 0,
            preempt_waste_us: 0.0,
            suspects: 0,
            breaker_opens: 0,
            probes: 0,
            hedges: 0,
            hedge_wins: 0,
            hedge_waste_us: 0.0,
        }
    }

    /// Count one offered request against its class and model groups.
    pub fn record_offered(&mut self, class: usize, model: usize) {
        self.per_class[class].offered += 1;
        self.per_model[model].offered += 1;
    }

    /// Count one served request; `latency_us` is end-to-end
    /// (arrival to batch finish), `met` whether it beat its deadline.
    pub fn record_served(&mut self, class: usize, model: usize,
                         latency_us: f64, met: bool) {
        for g in [&mut self.per_class[class], &mut self.per_model[model]] {
            g.served += 1;
            if met {
                g.met += 1;
            }
            g.hist.record(latency_us);
        }
    }

    /// Count one shed request (`at_admission`: rejected at admission
    /// vs expired in queue).
    pub fn record_shed(&mut self, class: usize, model: usize,
                       at_admission: bool) {
        for g in [&mut self.per_class[class], &mut self.per_model[model]] {
            if at_admission {
                g.shed_admission += 1;
            } else {
                g.shed_expired += 1;
            }
        }
    }

    /// Count one failed request: lost to a fault with no feasible
    /// retry (its remaining deadline could not be met on any survivor,
    /// or its retry budget ran out).  Failed requests stay in the
    /// conservation identity — offered == served + shed + failed —
    /// and count against attainment like a shed.
    pub fn record_failed(&mut self, class: usize, model: usize) {
        self.per_class[class].failed += 1;
        self.per_model[model].failed += 1;
    }

    /// Fold another snapshot's counters into this one: counts and busy
    /// times add, latency histograms merge, makespan takes the max.
    /// Group labels must match (same class table / registry) — the
    /// fleet tier uses this to build its aggregate report from
    /// per-board snapshots.
    pub fn merge_from(&mut self, other: &PerfSnapshot) {
        debug_assert_eq!(self.per_class.len(), other.per_class.len());
        debug_assert_eq!(self.per_model.len(), other.per_model.len());
        self.makespan_us = self.makespan_us.max(other.makespan_us);
        self.cpu_busy_us += other.cpu_busy_us;
        self.gpu_busy_us += other.gpu_busy_us;
        self.n_batches += other.n_batches;
        self.dispatched += other.dispatched;
        // Energy: joules add across boards, the horizon is shared
        // virtual time (max), and per-board floor wattages add so the
        // aggregate's mean_power_w stays the fleet's total draw.  The
        // per-batch trace stays per-board.
        self.energy_mj += other.energy_mj;
        self.busy_energy_mj += other.busy_energy_mj;
        self.power_horizon_us =
            self.power_horizon_us.max(other.power_horizon_us);
        self.idle_floor_w += other.idle_floor_w;
        self.soc_w += other.soc_w;
        self.throttle_events += other.throttle_events;
        // Like power_trace, raw trace_events stay per-board; only the
        // drop counters and the exact phase accumulators roll up.
        self.power_trace_dropped += other.power_trace_dropped;
        self.trace_dropped += other.trace_dropped;
        self.phases.merge_from(&other.phases);
        // Fault counters sum across boards; downtime is per-board
        // lost capacity, so it sums too (8 boards down 1 s each is
        // 8 s of lost board-time).
        self.failovers += other.failovers;
        self.retries += other.retries;
        self.lost_batches += other.lost_batches;
        self.downtime_us += other.downtime_us;
        self.requeued += other.requeued;
        self.preemptions += other.preemptions;
        self.steals += other.steals;
        self.preempt_waste_us += other.preempt_waste_us;
        self.suspects += other.suspects;
        self.breaker_opens += other.breaker_opens;
        self.probes += other.probes;
        self.hedges += other.hedges;
        self.hedge_wins += other.hedge_wins;
        self.hedge_waste_us += other.hedge_waste_us;
        if self.governor.is_empty() {
            self.governor = other.governor.clone();
        }
        for (dst, src) in self
            .per_class
            .iter_mut()
            .zip(&other.per_class)
            .chain(self.per_model.iter_mut().zip(&other.per_model))
        {
            debug_assert_eq!(dst.label, src.label,
                             "merging mismatched groups");
            dst.offered += src.offered;
            dst.served += src.served;
            dst.met += src.met;
            dst.shed_admission += src.shed_admission;
            dst.shed_expired += src.shed_expired;
            dst.failed += src.failed;
            dst.hist.merge(&src.hist);
        }
    }

    /// Requests offered, across all classes.
    pub fn total_offered(&self) -> u64 {
        self.per_class.iter().map(|g| g.offered).sum()
    }
    /// Requests served to completion, across all classes.
    pub fn total_served(&self) -> u64 {
        self.per_class.iter().map(|g| g.served).sum()
    }
    /// Requests shed (admission + expiry), across all classes.
    pub fn total_shed(&self) -> u64 {
        self.per_class.iter().map(|g| g.shed()).sum()
    }
    /// Requests served within deadline, across all classes.
    pub fn total_met(&self) -> u64 {
        self.per_class.iter().map(|g| g.met).sum()
    }
    /// Requests failed under faults, across all classes (0 on
    /// fault-free runs).
    pub fn total_failed(&self) -> u64 {
        self.per_class.iter().map(|g| g.failed).sum()
    }

    /// Whether any fault accounting is non-zero — gates the fault keys
    /// out of [`PerfSnapshot::to_json`] and the summary tail, keeping
    /// fault-free output byte-identical to the pre-fault report.
    fn fault_on(&self) -> bool {
        self.failovers != 0
            || self.retries != 0
            || self.lost_batches != 0
            || self.requeued != 0
            || self.downtime_us != 0.0
            || self.total_failed() != 0
    }

    /// Whether any preemption accounting is non-zero — gates the
    /// preempt keys out of [`PerfSnapshot::to_json`] and the summary
    /// tail, keeping `PreemptionPolicy::Off` output byte-identical to
    /// the pre-preemption report.
    fn preempt_on(&self) -> bool {
        self.preemptions != 0
            || self.steals != 0
            || self.preempt_waste_us != 0.0
    }

    /// Whether any tail-tolerance accounting is non-zero — gates the
    /// tail keys out of [`PerfSnapshot::to_json`] and the summary
    /// tail, keeping `--hedge=off --breaker=off` output byte-identical
    /// to the pre-tail report.
    fn tail_on(&self) -> bool {
        self.suspects != 0
            || self.breaker_opens != 0
            || self.probes != 0
            || self.hedges != 0
            || self.hedge_wins != 0
            || self.hedge_waste_us != 0.0
    }

    /// Fraction of all offered requests served within deadline — the
    /// headline number the overload comparison is judged on.
    pub fn aggregate_attainment(&self) -> f64 {
        let offered = self.total_offered();
        if offered == 0 {
            return 0.0;
        }
        self.total_met() as f64 / offered as f64
    }

    /// CPU busy fraction over the makespan, clamped to [0, 1] (a
    /// multi-lane board can accumulate more busy-us than makespan).
    pub fn cpu_util(&self) -> f64 {
        if self.makespan_us > 0.0 {
            (self.cpu_busy_us / self.makespan_us).min(1.0)
        } else {
            0.0
        }
    }
    /// GPU busy fraction over the makespan, clamped to [0, 1].
    pub fn gpu_util(&self) -> f64 {
        if self.makespan_us > 0.0 {
            (self.gpu_busy_us / self.makespan_us).min(1.0)
        } else {
            0.0
        }
    }
    /// Mean dispatched batch size, in requests.
    pub fn mean_batch(&self) -> f64 {
        if self.n_batches > 0 {
            self.dispatched as f64 / self.n_batches as f64
        } else {
            0.0
        }
    }

    /// Mean board draw over the power horizon, watts (0 when the run
    /// was not energy-aware).
    pub fn mean_power_w(&self) -> f64 {
        if self.power_horizon_us > 0.0 {
            self.energy_mj * 1e3 / self.power_horizon_us
        } else {
            0.0
        }
    }

    /// Energy per served inference, millijoules (total board energy —
    /// including idle/SoC floors — over requests served to completion;
    /// 0 when nothing was served or the run was not energy-aware).
    pub fn energy_per_inference_mj(&self) -> f64 {
        let served = self.total_served();
        if served > 0 {
            self.energy_mj / served as f64
        } else {
            0.0
        }
    }

    /// Full JSON object: scalars (us, rates in [0, 1]) plus per-class
    /// and per-model group arrays.
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("policy".into(), Value::Str(self.policy.clone()));
        o.insert("shed_policy".into(),
                 Value::Str(self.shed_policy.clone()));
        o.insert("makespan_us".into(), Value::Num(self.makespan_us));
        o.insert("cpu_util".into(), Value::Num(self.cpu_util()));
        o.insert("gpu_util".into(), Value::Num(self.gpu_util()));
        o.insert("mean_batch".into(), Value::Num(self.mean_batch()));
        o.insert("aggregate_attainment".into(),
                 Value::Num(self.aggregate_attainment()));
        o.insert("offered".into(), Value::Num(self.total_offered() as f64));
        o.insert("served".into(), Value::Num(self.total_served() as f64));
        o.insert("shed".into(), Value::Num(self.total_shed() as f64));
        if self.fault_on() {
            o.insert("failed".into(),
                     Value::Num(self.total_failed() as f64));
            o.insert("failovers".into(),
                     Value::Num(self.failovers as f64));
            o.insert("retries".into(), Value::Num(self.retries as f64));
            o.insert("lost_batches".into(),
                     Value::Num(self.lost_batches as f64));
            o.insert("downtime_us".into(), Value::Num(self.downtime_us));
            o.insert("requeued".into(),
                     Value::Num(self.requeued as f64));
        }
        if self.preempt_on() {
            o.insert("preemptions".into(),
                     Value::Num(self.preemptions as f64));
            o.insert("steals".into(), Value::Num(self.steals as f64));
            o.insert("preempt_waste_us".into(),
                     Value::Num(self.preempt_waste_us));
        }
        if self.tail_on() {
            o.insert("suspects".into(),
                     Value::Num(self.suspects as f64));
            o.insert("breaker_opens".into(),
                     Value::Num(self.breaker_opens as f64));
            o.insert("probes".into(), Value::Num(self.probes as f64));
            o.insert("hedges".into(), Value::Num(self.hedges as f64));
            o.insert("hedge_wins".into(),
                     Value::Num(self.hedge_wins as f64));
            o.insert("hedge_waste_us".into(),
                     Value::Num(self.hedge_waste_us));
        }
        if !self.governor.is_empty() {
            o.insert("governor".into(),
                     Value::Str(self.governor.clone()));
            o.insert("energy_mj".into(), Value::Num(self.energy_mj));
            o.insert("energy_per_inference_mj".into(),
                     Value::Num(self.energy_per_inference_mj()));
            o.insert("mean_power_w".into(),
                     Value::Num(self.mean_power_w()));
            o.insert("throttle_events".into(),
                     Value::Num(self.throttle_events as f64));
        }
        if !self.phases.is_empty() {
            o.insert("trace_events".into(),
                     Value::Num(self.trace_events.len() as f64));
            o.insert("trace_dropped".into(),
                     Value::Num(self.trace_dropped as f64));
            o.insert("phase_service_us".into(),
                     Value::Num(self.phases.service_us()));
            o.insert("phase_warmup_us".into(),
                     Value::Num(self.phases.warmup_us));
            o.insert("phase_idle_us".into(),
                     Value::Num(self.phases.idle_us));
            o.insert("phase_capacity_us".into(),
                     Value::Num(self.phases.capacity_us));
        }
        o.insert(
            "per_class".into(),
            Value::Arr(self.per_class.iter().map(|g| g.to_json()).collect()),
        );
        o.insert(
            "per_model".into(),
            Value::Arr(self.per_model.iter().map(|g| g.to_json()).collect()),
        );
        Value::Obj(o)
    }

    /// [`PerfSnapshot::to_json`] rendered to a string.
    pub fn to_json_string(&self) -> String {
        json::to_string(&self.to_json())
    }

    /// Folded-stack rendering of this board's phase accumulators
    /// (`board;model;class;phase count_us` lines, flamegraph.pl /
    /// inferno compatible; counts are integer microseconds).  The board
    /// frame is [`PerfSnapshot::policy`].  Empty on untraced runs.
    pub fn folded_trace(&self) -> String {
        let models: Vec<String> =
            self.per_model.iter().map(|g| g.label.clone()).collect();
        let classes: Vec<String> =
            self.per_class.iter().map(|g| g.label.clone()).collect();
        crate::obs::folded(&self.policy, &self.phases, &models, &classes)
    }

    /// Chrome trace-event JSON of this board's raw records (Perfetto /
    /// `chrome://tracing` loadable; timestamps are virtual-time
    /// microseconds, pid 0).  `{"traceEvents":[]}` on untraced runs.
    pub fn chrome_trace(&self) -> String {
        let models: Vec<String> =
            self.per_model.iter().map(|g| g.label.clone()).collect();
        let classes: Vec<String> =
            self.per_class.iter().map(|g| g.label.clone()).collect();
        crate::obs::chrome_trace(&[&self.trace_events], &models, &classes)
    }

    /// Per-class console table for the CLI.
    pub fn class_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["class", "offered", "served", "met", "shed", "p50", "p95",
              "p99", "attainment"],
        );
        for g in &self.per_class {
            t.row(vec![
                g.label.clone(),
                g.offered.to_string(),
                g.served.to_string(),
                g.met.to_string(),
                g.shed().to_string(),
                g.percentile_str(50.0),
                g.percentile_str(95.0),
                g.percentile_str(99.0),
                format!("{:.1}%", 100.0 * g.attainment()),
            ]);
        }
        t
    }

    /// One-line summary for logs (energy tail only on energy-aware
    /// runs).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "[{}] attainment {:.1}% ({} met / {} offered, {} shed) \
             cpu {:.0}% gpu {:.0}% mean batch {:.1}",
            self.policy,
            100.0 * self.aggregate_attainment(),
            self.total_met(),
            self.total_offered(),
            self.total_shed(),
            100.0 * self.cpu_util(),
            100.0 * self.gpu_util(),
            self.mean_batch()
        );
        if !self.governor.is_empty() {
            s.push_str(&format!(
                " | {} {:.1} mJ/inf {:.1} W mean, {} throttles",
                self.governor,
                self.energy_per_inference_mj(),
                self.mean_power_w(),
                self.throttle_events
            ));
        }
        if self.fault_on() {
            s.push_str(&format!(
                " | faults: {} failovers {} retries {} lost batches \
                 {} requeued {} failed {:.0}ms down",
                self.failovers,
                self.retries,
                self.lost_batches,
                self.requeued,
                self.total_failed(),
                self.downtime_us / 1e3
            ));
        }
        if self.preempt_on() {
            s.push_str(&format!(
                " | preempt: {} preempted {} stolen {:.1}ms wasted",
                self.preemptions,
                self.steals,
                self.preempt_waste_us / 1e3
            ));
        }
        if self.tail_on() {
            s.push_str(&format!(
                " | tail: {} suspects {} opens {} probes {} hedges \
                 ({} won) {:.1}ms hedge waste",
                self.suspects,
                self.breaker_opens,
                self.probes,
                self.hedges,
                self.hedge_wins,
                self.hedge_waste_us / 1e3
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_accounting_and_json() {
        let mut s = PerfSnapshot::new(
            "cluster",
            "reject-new",
            &["interactive".into(), "batch".into()],
            &["m0".into(), "m1".into()],
        );
        s.record_offered(0, 0);
        s.record_offered(0, 1);
        s.record_offered(1, 1);
        s.record_served(0, 0, 5_000.0, true);
        s.record_served(1, 1, 90_000.0, false);
        s.record_shed(0, 1, true);
        s.makespan_us = 100_000.0;
        s.cpu_busy_us = 30_000.0;
        s.gpu_busy_us = 80_000.0;
        s.n_batches = 2;
        s.dispatched = 2;

        assert_eq!(s.total_offered(), 3);
        assert_eq!(s.total_served(), 2);
        assert_eq!(s.total_shed(), 1);
        assert_eq!(s.total_met(), 1);
        assert!((s.aggregate_attainment() - 1.0 / 3.0).abs() < 1e-12);
        assert!((s.cpu_util() - 0.3).abs() < 1e-12);
        assert_eq!(s.per_class[0].violations(), 0);
        assert_eq!(s.per_class[1].violations(), 1);
        assert!((s.per_class[0].shed_rate() - 0.5).abs() < 1e-12);

        let text = s.to_json_string();
        let v = json::parse(&text).unwrap();
        assert_eq!(v.str_of("policy"), "cluster");
        assert_eq!(v.get("per_class").as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("per_class").idx(0).str_of("label"),
            "interactive"
        );
        assert!((v.get("aggregate_attainment").as_f64().unwrap()
            - 1.0 / 3.0)
            .abs()
            < 1e-9);
        // table renders without panicking
        s.class_table("t").print();
    }

    #[test]
    fn merge_sums_counters_and_histograms() {
        let labels = (
            vec!["hi".to_string(), "lo".to_string()],
            vec!["m0".to_string()],
        );
        let mut a = PerfSnapshot::new("fleet", "reject-new",
                                      &labels.0, &labels.1);
        let mut b = a.clone();
        a.record_offered(0, 0);
        a.record_served(0, 0, 1_000.0, true);
        a.makespan_us = 50_000.0;
        a.cpu_busy_us = 10_000.0;
        a.n_batches = 1;
        a.dispatched = 1;
        b.record_offered(1, 0);
        b.record_offered(1, 0);
        b.record_served(1, 0, 9_000.0, false);
        b.record_shed(1, 0, false);
        b.makespan_us = 80_000.0;
        b.gpu_busy_us = 20_000.0;
        b.n_batches = 1;
        b.dispatched = 1;
        a.merge_from(&b);
        assert_eq!(a.total_offered(), 3);
        assert_eq!(a.total_served(), 2);
        assert_eq!(a.total_shed(), 1);
        assert_eq!(a.total_met(), 1);
        assert_eq!(a.n_batches, 2);
        assert!((a.makespan_us - 80_000.0).abs() < 1e-9);
        assert!((a.cpu_busy_us - 10_000.0).abs() < 1e-9);
        assert!((a.gpu_busy_us - 20_000.0).abs() < 1e-9);
        assert_eq!(a.per_class[0].hist.count()
                   + a.per_class[1].hist.count(), 2);
        assert_eq!(a.per_model[0].hist.count(), 2);
    }

    #[test]
    fn fault_fields_merge_and_gate_json_keys() {
        let labels =
            (vec!["c".to_string()], vec!["m".to_string()]);
        let mut a = PerfSnapshot::new("fleet", "reject-new",
                                      &labels.0, &labels.1);
        // Fault-free: keys absent from JSON, summary has no tail.
        let v = json::parse(&a.to_json_string()).unwrap();
        assert!(v.get("failed").as_f64().is_none());
        assert!(v.get("failovers").as_f64().is_none());
        assert!(!a.summary().contains("faults:"));

        let mut b = a.clone();
        a.record_offered(0, 0);
        a.record_failed(0, 0);
        a.failovers = 1;
        a.retries = 2;
        a.lost_batches = 1;
        a.downtime_us = 40_000.0;
        a.requeued = 3;
        b.record_offered(0, 0);
        b.record_served(0, 0, 1_000.0, true);
        b.failovers = 1;
        b.downtime_us = 10_000.0;
        a.merge_from(&b);
        assert_eq!(a.total_failed(), 1);
        assert_eq!(a.failovers, 2);
        assert_eq!(a.retries, 2);
        assert_eq!(a.lost_batches, 1);
        assert_eq!(a.requeued, 3);
        assert!((a.downtime_us - 50_000.0).abs() < 1e-9);
        // Conservation with the failed arm: offered == served+shed+failed.
        assert_eq!(a.total_offered(),
                   a.total_served() + a.total_shed() + a.total_failed());
        let v = json::parse(&a.to_json_string()).unwrap();
        assert_eq!(v.get("failed").as_f64().unwrap(), 1.0);
        assert_eq!(v.get("failovers").as_f64().unwrap(), 2.0);
        assert_eq!(v.get("retries").as_f64().unwrap(), 2.0);
        assert_eq!(v.get("requeued").as_f64().unwrap(), 3.0);
        assert!((v.get("downtime_us").as_f64().unwrap() - 50_000.0)
                .abs() < 1e-9);
        // Per-class "failed" key present only where non-zero.
        assert_eq!(v.get("per_class").idx(0).get("failed")
                       .as_f64().unwrap(), 1.0);
        assert!(a.summary().contains("faults: 2 failovers"));
    }

    #[test]
    fn preempt_fields_merge_and_gate_json_keys() {
        let labels =
            (vec!["c".to_string()], vec!["m".to_string()]);
        let mut a = PerfSnapshot::new("fleet", "reject-new",
                                      &labels.0, &labels.1);
        // Preemption never fired: keys absent, summary has no tail.
        let v = json::parse(&a.to_json_string()).unwrap();
        assert!(v.get("preemptions").as_f64().is_none());
        assert!(v.get("steals").as_f64().is_none());
        assert!(v.get("preempt_waste_us").as_f64().is_none());
        assert!(!a.summary().contains("preempt:"));

        let mut b = a.clone();
        a.preemptions = 2;
        a.preempt_waste_us = 1_500.0;
        b.preemptions = 1;
        b.steals = 4;
        b.preempt_waste_us = 500.0;
        a.merge_from(&b);
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.steals, 4);
        assert!((a.preempt_waste_us - 2_000.0).abs() < 1e-9);
        let v = json::parse(&a.to_json_string()).unwrap();
        assert_eq!(v.get("preemptions").as_f64().unwrap(), 3.0);
        assert_eq!(v.get("steals").as_f64().unwrap(), 4.0);
        assert!((v.get("preempt_waste_us").as_f64().unwrap()
                 - 2_000.0).abs() < 1e-9);
        // Preemption alone never drags the fault keys in.
        assert!(v.get("failovers").as_f64().is_none());
        assert!(a.summary().contains("preempt: 3 preempted 4 stolen"));
    }

    #[test]
    fn tail_fields_merge_and_gate_json_keys() {
        let labels =
            (vec!["c".to_string()], vec!["m".to_string()]);
        let mut a = PerfSnapshot::new("fleet", "reject-new",
                                      &labels.0, &labels.1);
        // Tail machinery never fired: keys absent, summary untouched.
        let v = json::parse(&a.to_json_string()).unwrap();
        assert!(v.get("suspects").as_f64().is_none());
        assert!(v.get("breaker_opens").as_f64().is_none());
        assert!(v.get("probes").as_f64().is_none());
        assert!(v.get("hedges").as_f64().is_none());
        assert!(v.get("hedge_wins").as_f64().is_none());
        assert!(v.get("hedge_waste_us").as_f64().is_none());
        assert!(!a.summary().contains("tail:"));

        let mut b = a.clone();
        a.suspects = 1;
        a.breaker_opens = 2;
        a.probes = 3;
        a.hedge_waste_us = 800.0;
        b.suspects = 1;
        b.hedges = 5;
        b.hedge_wins = 2;
        b.hedge_waste_us = 200.0;
        a.merge_from(&b);
        assert_eq!(a.suspects, 2);
        assert_eq!(a.breaker_opens, 2);
        assert_eq!(a.probes, 3);
        assert_eq!(a.hedges, 5);
        assert_eq!(a.hedge_wins, 2);
        assert!((a.hedge_waste_us - 1_000.0).abs() < 1e-9);
        let v = json::parse(&a.to_json_string()).unwrap();
        assert_eq!(v.get("suspects").as_f64().unwrap(), 2.0);
        assert_eq!(v.get("breaker_opens").as_f64().unwrap(), 2.0);
        assert_eq!(v.get("probes").as_f64().unwrap(), 3.0);
        assert_eq!(v.get("hedges").as_f64().unwrap(), 5.0);
        assert_eq!(v.get("hedge_wins").as_f64().unwrap(), 2.0);
        assert!((v.get("hedge_waste_us").as_f64().unwrap() - 1_000.0)
                .abs() < 1e-9);
        // The tail keys never drag the fault or preempt keys in.
        assert!(v.get("failovers").as_f64().is_none());
        assert!(v.get("preemptions").as_f64().is_none());
        assert!(a.summary().contains(
            "tail: 2 suspects 2 opens 3 probes 5 hedges (2 won)"));
    }

    #[test]
    fn energy_fields_merge_and_gate_json_keys() {
        let labels =
            (vec!["c".to_string()], vec!["m".to_string()]);
        let mut a = PerfSnapshot::new("fleet", "reject-new",
                                      &labels.0, &labels.1);
        // Not energy-aware: keys absent, derived metrics zero.
        let v = json::parse(&a.to_json_string()).unwrap();
        assert!(v.get("energy_mj").as_f64().is_none());
        assert_eq!(a.mean_power_w(), 0.0);
        assert_eq!(a.energy_per_inference_mj(), 0.0);

        let mut b = a.clone();
        for (s, e, h) in
            [(&mut a, 120.0, 10_000.0), (&mut b, 80.0, 8_000.0)]
        {
            s.governor = "race-to-idle".into();
            s.energy_mj = e;
            s.busy_energy_mj = e / 2.0;
            s.power_horizon_us = h;
            s.idle_floor_w = 2.0;
            s.soc_w = 8.0;
            s.throttle_events = 3;
            s.record_offered(0, 0);
            s.record_served(0, 0, 1_000.0, true);
        }
        a.merge_from(&b);
        assert!((a.energy_mj - 200.0).abs() < 1e-12);
        assert!((a.busy_energy_mj - 100.0).abs() < 1e-12);
        assert_eq!(a.power_horizon_us, 10_000.0);
        assert!((a.idle_floor_w - 4.0).abs() < 1e-12);
        assert!((a.soc_w - 16.0).abs() < 1e-12);
        assert_eq!(a.throttle_events, 6);
        // 200 mJ over 10 ms = 20 W; 2 served -> 100 mJ/inference.
        assert!((a.mean_power_w() - 20.0).abs() < 1e-12);
        assert!((a.energy_per_inference_mj() - 100.0).abs() < 1e-12);
        let v = json::parse(&a.to_json_string()).unwrap();
        assert_eq!(v.str_of("governor"), "race-to-idle");
        assert!((v.get("energy_mj").as_f64().unwrap() - 200.0).abs()
                < 1e-9);
        assert!((v.get("mean_power_w").as_f64().unwrap() - 20.0).abs()
                < 1e-9);
        assert_eq!(v.get("throttle_events").as_f64().unwrap(), 6.0);
        assert!(a.summary().contains("mJ/inf"));
    }
}
