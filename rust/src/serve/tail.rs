//! Tail tolerance for the serving fleet: gray-failure detection,
//! per-board circuit breakers, and hedged dispatch.
//!
//! PR 8's fault layer handles *fail-stop* crashes — a board that goes
//! dark is quarantined by `Health` and its work fails over.  A
//! thermally throttled board is a **gray failure**: it keeps accepting
//! and serving work, just slower than the router's installed price
//! tables believe, so interactive requests burn deadlines there
//! silently.  This module closes that gap with three cooperating
//! mechanisms, all fleet-side and fully deterministic in virtual time:
//!
//! * **Gray-failure detector** — a per-board EWMA of the realized /
//!   predicted dispatch-latency ratio.  Predicted latency is the
//!   pre-thermal base latency the price tables are built from;
//!   realized latency is what the batch actually took (thermal
//!   stretch included, DVFS excluded — the governor's stretching is
//!   *chosen*, not a failure).  A board goes *suspect* when the EWMA
//!   exceeds [`TailParams::suspect_factor`] for
//!   [`TailParams::suspect_k`] consecutive inflated batches.
//! * **Circuit breaker** — per board, `Closed → Open → Probation →
//!   Closed`.  `Open` removes the board from routing, stealing and
//!   autoscale placement exactly like quarantine (without marking it
//!   `down`; its standing queue keeps draining).  After a cooldown it
//!   enters `Probation`, where it is routable only at seeded, jittered
//!   probe instants — the request routed then *is* the probe, and its
//!   realized-vs-predicted sample decides recovery or re-opening.
//! * **Hedged dispatch** — when a queued interactive request's wait
//!   makes its deadline at-risk on its assigned board, the fleet
//!   re-offers a clone to the next-cheapest eligible board.  First
//!   finish wins; the loser is cancelled through the in-flight ledger
//!   and `BoardPower::retract` (lane time and energy refunded), with
//!   the duplicate executed work billed to `hedge_waste_us`.  The
//!   settled-set guarantee (each request settles exactly once) holds
//!   even when both copies race a crash or a preemption — see
//!   `serve/fleet.rs` for the reconciliation protocol.
//!
//! With `--hedge=off --breaker=off` nothing here is armed and the
//! fleet output is byte-identical to the pre-tail scheduler
//! (differentially pinned by `rust/tests/serve_tail.rs`).

use crate::util::rng::Rng;

/// Which tail-tolerance mechanisms a fleet run arms.  [`TailPolicy::OFF`]
/// (the default) arms nothing and keeps the byte-identical legacy path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TailPolicy {
    /// Hedge at-risk interactive requests onto a second board.
    pub hedge: bool,
    /// Run the circuit breaker (Open/Probation route gating).  The
    /// gray-failure detector runs whenever either flag is set.
    pub breaker: bool,
}

impl TailPolicy {
    /// Everything off: no detector, no breaker, no hedging.
    pub const OFF: TailPolicy = TailPolicy { hedge: false, breaker: false };

    /// Whether any tail machinery is armed at all.
    pub fn enabled(self) -> bool {
        self.hedge || self.breaker
    }

    /// Canonical display name (`off` | `hedge` | `breaker` |
    /// `hedge+breaker`).
    pub fn name(self) -> &'static str {
        match (self.hedge, self.breaker) {
            (false, false) => "off",
            (true, false) => "hedge",
            (false, true) => "breaker",
            (true, true) => "hedge+breaker",
        }
    }
}

impl Default for TailPolicy {
    fn default() -> Self {
        TailPolicy::OFF
    }
}

/// Detector / breaker / hedging tuning knobs.  All times are
/// microseconds of virtual time; the defaults are sized for the demo
/// fleet's 20 ms interactive deadline.
#[derive(Debug, Clone, Copy)]
pub struct TailParams {
    /// EWMA smoothing for the realized/predicted latency ratio.
    pub ewma_alpha: f64,
    /// Inflation ratio above which a batch counts as inflated and the
    /// EWMA marks the board suspect.
    pub suspect_factor: f64,
    /// Consecutive inflated batches required before flagging.
    pub suspect_k: u32,
    /// How long an `Open` breaker holds the board unroutable before
    /// probation begins, us.
    pub open_cooldown_us: f64,
    /// Mean spacing between probation probes, us (jittered per probe
    /// from the seeded substream).
    pub probe_interval_us: f64,
    /// Consecutive good probes required to close the breaker.
    pub probe_close_after: u32,
    /// Seed for the per-board probe-jitter substreams.
    pub seed: u64,
}

impl Default for TailParams {
    fn default() -> Self {
        TailParams {
            ewma_alpha: 0.3,
            suspect_factor: 1.4,
            suspect_k: 3,
            open_cooldown_us: 50_000.0,
            probe_interval_us: 20_000.0,
            probe_close_after: 2,
            seed: 0x7a11,
        }
    }
}

/// Circuit-breaker state of one board.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BreakerState {
    /// Healthy: routable, samples feed the detector.
    Closed,
    /// Tripped: unroutable until `until_us`, then probation.
    Open {
        /// When the cooldown ends and probation begins, us.
        until_us: f64,
    },
    /// Recovering: routable only at probe instants.
    Probation,
}

/// What one detector sample concluded (all flags false for the common
/// healthy sample).  The fleet maps these onto board counters and
/// trace events.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SampleVerdict {
    /// The board was newly flagged suspect by this sample.
    pub suspect: bool,
    /// The breaker transitioned to `Open` (first trip or a failed
    /// probe re-opening it).
    pub opened: bool,
    /// The breaker closed (probation completed).
    pub closed: bool,
}

/// Per-board detector + breaker runtime.
#[derive(Debug, Clone)]
struct BoardTail {
    /// EWMA of realized/predicted latency, starts at 1.0 (nominal).
    ewma: f64,
    /// Consecutive inflated (ratio > factor) samples.
    streak: u32,
    state: BreakerState,
    /// Next instant a probation probe may be routed, us.
    next_probe_us: f64,
    /// Consecutive good probes in the current probation.
    good_probes: u32,
    /// Latched once flagged; re-arms when the EWMA recovers (or the
    /// breaker closes), so one sustained episode counts one suspect.
    flagged: bool,
    /// Seeded substream for probe-spacing jitter.
    rng: Rng,
}

/// Fleet-side tail-tolerance state: one detector/breaker per board.
/// Built only when [`TailPolicy::enabled`]; the fleet loop consults it
/// for routing eligibility, feeds it realized/predicted samples from
/// batch finishes, and merges its next breaker deadline into the
/// virtual clock.
#[derive(Debug)]
pub struct TailState {
    policy: TailPolicy,
    params: TailParams,
    boards: Vec<BoardTail>,
}

impl TailState {
    /// Build tail state for `n_boards` boards.  Each board gets its own
    /// jitter substream so adding boards never perturbs existing ones
    /// (same splitmix spread as `FaultPlan::sample_mttf_mttr`).
    pub fn new(policy: TailPolicy, params: TailParams,
               n_boards: usize) -> Self {
        TailState {
            policy,
            params,
            boards: (0..n_boards)
                .map(|b| BoardTail {
                    ewma: 1.0,
                    streak: 0,
                    state: BreakerState::Closed,
                    next_probe_us: 0.0,
                    good_probes: 0,
                    flagged: false,
                    rng: Rng::new(
                        params.seed
                            ^ (b as u64)
                                .wrapping_mul(0x9E3779B97F4A7C15),
                    ),
                })
                .collect(),
        }
    }

    /// The armed policy.
    pub fn policy(&self) -> TailPolicy {
        self.policy
    }

    /// The breaker state of one board.
    pub fn breaker(&self, b: usize) -> BreakerState {
        self.boards[b].state
    }

    /// Deliver cooldown expiries due by `now_us`: every `Open` board
    /// whose `until_us` has passed enters `Probation` with its first
    /// probe allowed immediately.  Call once per fleet-loop iteration
    /// before routing.
    pub fn advance(&mut self, now_us: f64) {
        for bt in &mut self.boards {
            if let BreakerState::Open { until_us } = bt.state {
                if until_us <= now_us {
                    bt.state = BreakerState::Probation;
                    bt.good_probes = 0;
                    bt.next_probe_us = now_us;
                }
            }
        }
    }

    /// Earliest future breaker deadline (an `Open` cooldown expiring),
    /// or `INFINITY`.  Merged into the fleet clock so probation begins
    /// on time even when no other event is due.
    pub fn next_event_us(&self) -> f64 {
        self.boards
            .iter()
            .filter_map(|bt| match bt.state {
                BreakerState::Open { until_us } => Some(until_us),
                _ => None,
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Whether the router (and the stealing / autoscale passes) may
    /// place work on board `b` at `now_us`.  `Open` boards are never
    /// routable; `Probation` boards only at/after their probe instant.
    pub fn routable(&self, b: usize, now_us: f64) -> bool {
        if !self.policy.breaker {
            return true;
        }
        match self.boards[b].state {
            BreakerState::Closed => true,
            BreakerState::Open { .. } => false,
            BreakerState::Probation => {
                self.boards[b].next_probe_us <= now_us
            }
        }
    }

    /// Whether a request routed to board `b` right now would be a
    /// probation probe (the caller must then [`TailState::consume_probe`]).
    pub fn is_probe(&self, b: usize) -> bool {
        self.policy.breaker
            && self.boards[b].state == BreakerState::Probation
    }

    /// Consume the probe slot just used on board `b`: schedule the
    /// next probe one jittered interval out, keeping probation
    /// low-rate and deterministic.
    pub fn consume_probe(&mut self, b: usize, now_us: f64) {
        let p = self.params.probe_interval_us;
        let bt = &mut self.boards[b];
        bt.next_probe_us =
            now_us + p * (0.75 + 0.5 * bt.rng.f64());
    }

    /// Feed one realized/predicted latency sample from a batch finish
    /// on board `b`.  `probe` marks a batch dispatched as a probation
    /// probe; non-probe samples arriving while the breaker is not
    /// `Closed` are leftovers from before the trip and are ignored.
    /// Returns what (if anything) changed so the caller can count and
    /// trace it.
    pub fn note_sample(&mut self, b: usize, pred_us: f64, real_us: f64,
                       probe: bool, now_us: f64) -> SampleVerdict {
        let mut v = SampleVerdict::default();
        if pred_us <= 0.0 || !real_us.is_finite() {
            return v;
        }
        let ratio = real_us / pred_us;
        let p = self.params;
        let bt = &mut self.boards[b];
        if probe {
            if bt.state != BreakerState::Probation {
                return v; // stale probe (breaker already moved on)
            }
            if ratio <= p.suspect_factor {
                bt.good_probes += 1;
                if bt.good_probes >= p.probe_close_after {
                    bt.state = BreakerState::Closed;
                    bt.ewma = 1.0;
                    bt.streak = 0;
                    bt.flagged = false;
                    v.closed = true;
                }
            } else {
                // A bad probe re-opens for another full cooldown.
                bt.state = BreakerState::Open {
                    until_us: now_us + p.open_cooldown_us,
                };
                bt.good_probes = 0;
                v.opened = true;
            }
            return v;
        }
        if bt.state != BreakerState::Closed {
            return v; // pre-trip leftovers settle without effect
        }
        bt.ewma = p.ewma_alpha * ratio + (1.0 - p.ewma_alpha) * bt.ewma;
        if ratio > p.suspect_factor {
            bt.streak += 1;
        } else {
            bt.streak = 0;
        }
        if bt.flagged && bt.ewma <= p.suspect_factor {
            // The episode ended on its own (detector-only mode, or a
            // thermal window closing before the breaker armed).
            bt.flagged = false;
        }
        if !bt.flagged
            && bt.ewma > p.suspect_factor
            && bt.streak >= p.suspect_k
        {
            bt.flagged = true;
            v.suspect = true;
            if self.policy.breaker {
                bt.state = BreakerState::Open {
                    until_us: now_us + p.open_cooldown_us,
                };
                v.opened = true;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed() -> TailState {
        TailState::new(
            TailPolicy { hedge: false, breaker: true },
            TailParams::default(),
            2,
        )
    }

    #[test]
    fn policy_names_and_enablement() {
        assert_eq!(TailPolicy::OFF.name(), "off");
        assert!(!TailPolicy::OFF.enabled());
        assert_eq!(TailPolicy::default(), TailPolicy::OFF);
        let hb = TailPolicy { hedge: true, breaker: true };
        assert_eq!(hb.name(), "hedge+breaker");
        assert!(hb.enabled());
        assert_eq!(
            TailPolicy { hedge: true, breaker: false }.name(),
            "hedge"
        );
        assert_eq!(
            TailPolicy { hedge: false, breaker: true }.name(),
            "breaker"
        );
    }

    #[test]
    fn sustained_inflation_flags_once_and_opens_the_breaker() {
        let mut t = armed();
        let mut opened_at = None;
        for i in 0..10 {
            let v = t.note_sample(0, 100.0, 200.0, false, i as f64);
            if v.opened {
                assert!(v.suspect, "the trip is the suspect flag");
                assert!(opened_at.is_none(), "one episode, one open");
                opened_at = Some(i);
            }
        }
        // EWMA(2.0) crosses 1.4 within the first few samples and the
        // streak gate requires >= 3 inflated batches.
        let k = opened_at.expect("sustained 2x inflation must trip");
        assert!(k >= 2, "streak gate demands k consecutive samples");
        assert!(matches!(t.breaker(0), BreakerState::Open { .. }));
        assert!(!t.routable(0, 1e9), "open is never routable");
        // The healthy board is untouched.
        assert_eq!(t.breaker(1), BreakerState::Closed);
        assert!(t.routable(1, 0.0));
    }

    #[test]
    fn one_bad_batch_does_not_flag() {
        let mut t = armed();
        let v = t.note_sample(0, 100.0, 500.0, false, 0.0);
        assert_eq!(v, SampleVerdict::default());
        // Recovery resets the streak.
        t.note_sample(0, 100.0, 300.0, false, 1.0);
        let v = t.note_sample(0, 100.0, 100.0, false, 2.0);
        assert!(!v.suspect);
        assert_eq!(t.breaker(0), BreakerState::Closed);
    }

    #[test]
    fn cooldown_probation_and_recovery_roundtrip() {
        let mut t = armed();
        for i in 0..6 {
            t.note_sample(0, 100.0, 200.0, false, 1_000.0 + i as f64);
        }
        let BreakerState::Open { until_us } = t.breaker(0) else {
            panic!("must be open");
        };
        assert_eq!(t.next_event_us(), until_us);
        // Before the cooldown: still open, advance is a no-op.
        t.advance(until_us - 1.0);
        assert!(matches!(t.breaker(0), BreakerState::Open { .. }));
        // At the cooldown: probation, probe allowed immediately.
        t.advance(until_us);
        assert_eq!(t.breaker(0), BreakerState::Probation);
        assert_eq!(t.next_event_us(), f64::INFINITY);
        assert!(t.routable(0, until_us));
        assert!(t.is_probe(0));
        t.consume_probe(0, until_us);
        assert!(
            !t.routable(0, until_us),
            "probe slot consumed: unroutable until the next instant"
        );
        // Non-probe leftovers from before the trip change nothing.
        let v = t.note_sample(0, 100.0, 900.0, false, until_us + 1.0);
        assert_eq!(v, SampleVerdict::default());
        assert_eq!(t.breaker(0), BreakerState::Probation);
        // Two good probes close it.
        let v = t.note_sample(0, 100.0, 105.0, true, until_us + 2.0);
        assert!(!v.closed);
        let v = t.note_sample(0, 100.0, 105.0, true, until_us + 3.0);
        assert!(v.closed);
        assert_eq!(t.breaker(0), BreakerState::Closed);
        assert!(t.routable(0, until_us + 3.0));
    }

    #[test]
    fn bad_probe_reopens_for_another_cooldown() {
        let mut t = armed();
        for i in 0..6 {
            t.note_sample(0, 100.0, 200.0, false, i as f64);
        }
        let BreakerState::Open { until_us } = t.breaker(0) else {
            panic!("must be open");
        };
        t.advance(until_us);
        let v = t.note_sample(0, 100.0, 400.0, true, until_us + 5.0);
        assert!(v.opened && !v.closed && !v.suspect);
        match t.breaker(0) {
            BreakerState::Open { until_us: u } => {
                assert_eq!(
                    u,
                    until_us + 5.0
                        + TailParams::default().open_cooldown_us
                );
            }
            s => panic!("expected re-open, got {s:?}"),
        }
    }

    #[test]
    fn detector_only_mode_flags_but_never_gates_routing() {
        let mut t = TailState::new(
            TailPolicy { hedge: true, breaker: false },
            TailParams::default(),
            1,
        );
        let mut suspects = 0;
        for i in 0..8 {
            let v = t.note_sample(0, 100.0, 200.0, false, i as f64);
            assert!(!v.opened && !v.closed);
            suspects += v.suspect as u32;
        }
        assert_eq!(suspects, 1, "one episode, one suspect");
        assert_eq!(t.breaker(0), BreakerState::Closed);
        assert!(t.routable(0, 0.0));
        assert!(!t.is_probe(0));
        // Recovery re-arms the latch: a second episode counts again.
        for i in 0..12 {
            t.note_sample(0, 100.0, 100.0, false, 100.0 + i as f64);
        }
        let mut again = 0;
        for i in 0..8 {
            again += t
                .note_sample(0, 100.0, 200.0, false, 200.0 + i as f64)
                .suspect as u32;
        }
        assert_eq!(again, 1, "recovered board can be re-flagged");
    }

    #[test]
    fn probe_jitter_is_seeded_deterministic() {
        let mk = || {
            let mut t = armed();
            for i in 0..6 {
                t.note_sample(0, 100.0, 200.0, false, i as f64);
            }
            let BreakerState::Open { until_us } = t.breaker(0) else {
                panic!()
            };
            t.advance(until_us);
            t.consume_probe(0, until_us);
            t.boards[0].next_probe_us
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "same seed, same probe schedule");
        let p = TailParams::default().probe_interval_us;
        // Jitter stays inside [0.75, 1.25) intervals past `now`.
        let base = a - p * 0.75;
        assert!(base >= 0.0 && a <= base + p * 1.25);
    }

    #[test]
    fn degenerate_samples_are_ignored() {
        let mut t = armed();
        assert_eq!(
            t.note_sample(0, 0.0, 100.0, false, 0.0),
            SampleVerdict::default()
        );
        assert_eq!(
            t.note_sample(0, -5.0, 100.0, false, 0.0),
            SampleVerdict::default()
        );
        assert_eq!(
            t.note_sample(0, 100.0, f64::NAN, false, 0.0),
            SampleVerdict::default()
        );
        assert_eq!(t.breaker(0), BreakerState::Closed);
    }
}
