//! Distributed multi-board serving: a fleet of N simulated boards
//! behind a front-tier router, with replica autoscaling.
//!
//! One [`crate::serve::run_cluster`] board co-schedules CPU/GPU
//! capacity across models; this module scales that out:
//!
//! * **Sharded registry.**  The [`ModelRegistry`] stays the shared
//!   *catalog* of model plans (schedules, batch caps, memoized latency
//!   probes — boards are homogeneous, so probes are placement-valid
//!   everywhere).  Each board's *shard* is its warm-replica set: a
//!   board can serve model `m` only while it hosts a replica of `m`,
//!   and each board runs its own `BoardSim` (crate-internal: admission
//!   queues + [`LaneMatrix`] + dispatch loop) over its shard.
//! * **Front-tier router.**  Every arrival is placed on exactly one
//!   board by a [`RouterPolicy`]: `RoundRobin` (per-model rotation),
//!   `JoinShortestQueue` (fewest queued requests), or `CostAware`
//!   (least estimated microseconds of standing work, pricing each
//!   board's queues through the registry's memoized latency oracle
//!   plus its in-flight lane residuals).  Cost-aware scores are
//!   dirty-flagged: each board caches its priced queued work against a
//!   mutation epoch, so routing only re-prices boards whose queues
//!   changed since the last route.
//! * **Event-heap clock.**  `run_fleet` advances virtual time off a
//!   min-heap of board wake-ups (lazily invalidated by a per-board
//!   generation); boards with no standing work and no fresh offers are
//!   never pumped, so a mostly-idle fleet costs only its active
//!   boards.
//! * **Replica autoscaler.**  A periodic control loop reads per-model
//!   attainment and queue-pressure windows from the per-board
//!   [`PerfSnapshot`]s and scales replicas up (warm a session on the
//!   least-busy board lacking one; the warm-up occupies a GPU lane for
//!   [`AutoscalePolicy::warmup_us`] of virtual time, so scaling is
//!   never free) or down (mark a replica draining — the router stops
//!   sending to it, it retires once its queue empties).  Hysteresis
//!   ([`AutoscalePolicy::hysteresis`] consecutive ticks) keeps it from
//!   flapping; the up/down thresholds leave a dead band.
//!
//! `sparoa serve-fleet` drives the demo fleet from the CLI; the
//! `fig_fleet` bench emits the fleet-level JSON report; and
//! `rust/tests/serve_fleet.rs` property-tests conservation, the
//! router ordering under skew, and autoscaler convergence/shedding.

use crate::device::Proc;
use crate::faults::{
    jittered_backoff_us, FaultChange, FaultPlan, FaultTransition,
    MAX_RETRY_ATTEMPTS,
};
use crate::power::PowerConfig;
use crate::serve::cluster::{
    BoardSim, ClusterOptions, ClusterPolicy, HedgeOutcome, LaneMatrix,
    PreemptionPolicy,
};
use crate::serve::registry::ModelRegistry;
use crate::serve::report::PerfSnapshot;
use crate::serve::slo::{QueuedReq, ShedPolicy, SloClass};
use crate::serve::tail::{
    BreakerState, TailParams, TailPolicy, TailState,
};
use crate::serve::workload::{Arrival, Tenant};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

/// Front-tier request placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Per-model rotation over the boards hosting the model.
    RoundRobin,
    /// The hosting board with the fewest queued requests.
    JoinShortestQueue,
    /// The hosting board with the least estimated standing work:
    /// queued requests priced by the memoized latency probes, plus
    /// in-flight lane residuals.
    CostAware,
}

impl RouterPolicy {
    /// Parse a CLI/config spelling (`round-robin` | `jsq` |
    /// `join-shortest-queue` | `cost-aware`).
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        Some(match s {
            "round-robin" | "rr" => RouterPolicy::RoundRobin,
            "jsq" | "join-shortest-queue" => {
                RouterPolicy::JoinShortestQueue
            }
            "cost-aware" => RouterPolicy::CostAware,
            _ => return None,
        })
    }

    /// Canonical spelling, the inverse of [`RouterPolicy::parse`].
    pub fn name(self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::CostAware => "cost-aware",
        }
    }
}

/// Replica autoscaler control knobs.  All times are microseconds of
/// virtual time.
#[derive(Debug, Clone, Copy)]
pub struct AutoscalePolicy {
    /// Control period: signals are windowed per tick.
    pub interval_us: f64,
    /// Scale a model up while its window attainment sits below this
    /// (fraction in [0, 1]).
    pub up_attainment: f64,
    /// Scale a model down while its window load per replica — offered
    /// requests priced at [`crate::serve::ModelEntry::efficient_cost_us`]
    /// over the interval — sits below this fraction of one replica's
    /// capacity.  Keep well below `up_attainment`'s implied load so the
    /// dead band prevents flapping.
    pub down_load: f64,
    /// Virtual-time cost of warming a replica: the warm-up occupies a
    /// GPU lane on the target board for this long (starting when the
    /// lane frees), and the replica serves only once it completes.
    pub warmup_us: f64,
    /// Consecutive ticks a signal must persist before acting (>= 1).
    pub hysteresis: usize,
    /// Per-model replica cap; 0 means one per board.
    pub max_per_model: usize,
    /// Queue-pressure trigger: also scale up when a model's standing
    /// backlog per replica exceeds this fraction of the interval (the
    /// predictive signal — it fires a tick before attainment
    /// collapses).
    pub pressure: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            interval_us: 50_000.0,
            up_attainment: 0.92,
            down_load: 0.45,
            warmup_us: 25_000.0,
            hysteresis: 2,
            max_per_model: 0,
            pressure: 0.6,
        }
    }
}

/// Configuration of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Lane matrix of every board (boards are homogeneous).
    pub lanes: LaneMatrix,
    /// Front-tier placement policy.
    pub router: RouterPolicy,
    /// Per-board admission shed policy.
    pub shed: ShedPolicy,
    /// Initial replica placement: `placement[b]` lists the registry
    /// indices warm on board `b` at time zero.  Every model must
    /// appear on at least one board.
    pub placement: Vec<Vec<usize>>,
    /// Autoscaler; `None` pins the placement for the whole run.
    pub autoscale: Option<AutoscalePolicy>,
    /// Per-board cluster discipline: the SparOA co-execution tier
    /// (default) or the static-split ablation — the fleet-scale
    /// energy comparison runs both (`fig_energy_serve`).
    pub policy: ClusterPolicy,
    /// Energy-aware serving: install this DVFS governor + ladder (and
    /// optional power cap, watts) on every board.  `None` serves at
    /// full frequency with no energy accounting.
    pub power: Option<PowerConfig>,
    /// `Some` enables the virtual-time profiler on every board (the
    /// buffer capacity is per board); see `ClusterOptions::trace`.
    pub trace: Option<crate::obs::TraceConfig>,
    /// Deterministic fault schedule ([`FaultPlan::none`] = fault-free;
    /// with an empty plan the run is bit-identical to the pre-fault
    /// path — no board is armed).
    pub faults: FaultPlan,
    /// Failover on a board crash (default `true`): drained queue work
    /// re-routes to survivors immediately and batches lost in flight
    /// get deadline-aware retries with capped backoff.  `false` is the
    /// ablation control: every request a crash strands is failed on
    /// the spot (still conserved — never silently lost).
    pub failover: bool,
    /// Preemption / work re-placement policy
    /// ([`PreemptionPolicy::Off`] = run-to-completion, bit-identical
    /// to the pre-preemption path; `DeadlineBurn` arms board-level
    /// batch cancellation; `BurnPlusSteal` adds the fleet's
    /// work-stealing pass).
    pub preempt: PreemptionPolicy,
    /// Tail-tolerance policy ([`TailPolicy::OFF`] = bit-identical
    /// pre-tail path): `breaker` arms the gray-failure detector and
    /// per-board circuit breaker, `hedge` arms deadline-at-risk
    /// hedged dispatch with first-wins cancellation.
    pub tail: TailPolicy,
    /// Detector / breaker / probe tuning (inert while `tail` is fully
    /// off).
    pub tail_params: TailParams,
}

impl FleetOptions {
    /// A fleet of `n_boards` two-lane boards with one replica of each
    /// of `n_models` models, spread round-robin, cost-aware routing,
    /// no autoscaling.
    pub fn new(n_boards: usize, n_models: usize) -> Self {
        FleetOptions {
            lanes: LaneMatrix::duo(),
            router: RouterPolicy::CostAware,
            shed: ShedPolicy::ShedLowestClass,
            placement: spread_placement(
                n_boards, &vec![1; n_models]),
            autoscale: None,
            policy: ClusterPolicy::SparsityAware,
            power: None,
            trace: None,
            faults: FaultPlan::none(),
            failover: true,
            preempt: PreemptionPolicy::Off,
            tail: TailPolicy::OFF,
            tail_params: TailParams::default(),
        }
    }
}

/// Spread `replicas[m]` replicas of each model over `n_boards` boards:
/// replica `r` of model `m` lands on board `(m + r) % n_boards`, at
/// most one replica of a model per board.
pub fn spread_placement(
    n_boards: usize,
    replicas: &[usize],
) -> Vec<Vec<usize>> {
    let nb = n_boards.max(1);
    let mut placement = vec![Vec::new(); nb];
    for (m, &k) in replicas.iter().enumerate() {
        for r in 0..k.clamp(1, nb) {
            placement[(m + r) % nb].push(m);
        }
    }
    placement
}

/// One autoscaler action.
#[derive(Debug, Clone, Copy)]
pub struct ScaleEvent {
    /// Virtual time of the decision, microseconds.
    pub t_us: f64,
    /// Registry index of the scaled model.
    pub model: usize,
    /// Board gaining (up) or draining (down) the replica.
    pub board: usize,
    /// true = scale up, false = drain.
    pub up: bool,
}

/// One autoscaler-tick sample of the replica map.
#[derive(Debug, Clone)]
pub struct ReplicaSample {
    /// Virtual time of the sample, microseconds.
    pub t_us: f64,
    /// Non-draining replica count per model (warming included: they
    /// are committed capacity).
    pub per_model: Vec<usize>,
}

/// A fleet run's full report: per-board snapshots, the merged
/// aggregate, and the autoscaler's trace.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    /// Router policy name.
    pub router: String,
    /// Governor name when the fleet ran energy-aware
    /// ([`FleetOptions::power`]); empty otherwise.
    pub governor: String,
    /// Whether the autoscaler ran.
    pub autoscaled: bool,
    /// Per-board lane matrix.
    pub lanes: LaneMatrix,
    /// Per-board outcomes ("fleet/board0", ...).
    pub boards: Vec<PerfSnapshot>,
    /// All boards merged ([`PerfSnapshot::merge_from`]); busy times
    /// sum across boards, so utilizations here are fleet totals over
    /// one makespan.
    pub aggregate: PerfSnapshot,
    /// Every autoscaler action, in time order.
    pub scale_events: Vec<ScaleEvent>,
    /// Replica counts sampled at every autoscaler tick, bracketed by
    /// boundary samples at t = 0 and the end of the run so the
    /// time-weighted mean covers the whole horizon (empty without
    /// autoscaling).
    pub replica_timeline: Vec<ReplicaSample>,
    /// Time-weighted mean replica count per model (the static-fleet
    /// comparison point; equals the placement counts when static).
    pub mean_replicas: Vec<f64>,
}

impl FleetSnapshot {
    /// Fraction of all offered requests served within deadline.
    pub fn aggregate_attainment(&self) -> f64 {
        self.aggregate.aggregate_attainment()
    }

    /// Requests shed fleet-wide (admission + expiry).
    pub fn total_shed(&self) -> u64 {
        self.aggregate.total_shed()
    }

    /// Fleet-wide energy per served inference, millijoules (0 unless
    /// energy-aware).  Board energies sum in the merged aggregate.
    pub fn energy_per_inference_mj(&self) -> f64 {
        self.aggregate.energy_per_inference_mj()
    }

    /// Fleet-total mean draw, watts: summed board energies over the
    /// shared virtual-time horizon (0 unless energy-aware).
    pub fn mean_power_w(&self) -> f64 {
        self.aggregate.mean_power_w()
    }

    /// Cap-binding events across all boards.
    pub fn total_throttles(&self) -> u64 {
        self.aggregate.throttle_events
    }

    /// Board crashes absorbed fleet-wide (0 on fault-free runs).
    pub fn total_failovers(&self) -> u64 {
        self.aggregate.failovers
    }

    /// Lost-in-flight requests re-admitted via deadline-aware retry.
    pub fn total_retries(&self) -> u64 {
        self.aggregate.retries
    }

    /// Requests failed under faults (unplaceable or deadline-doomed);
    /// counted in conservation alongside served and shed.
    pub fn total_failed(&self) -> u64 {
        self.aggregate.total_failed()
    }

    /// Queued requests drained off crashing boards for re-placement.
    pub fn total_requeued(&self) -> u64 {
        self.aggregate.requeued
    }

    /// Summed board down-time, microseconds of virtual time.
    pub fn total_downtime_us(&self) -> f64 {
        self.aggregate.downtime_us
    }

    /// In-flight batches voluntarily cancelled fleet-wide to rescue
    /// higher-class deadlines (0 unless preemption is armed).
    pub fn total_preemptions(&self) -> u64 {
        self.aggregate.preemptions
    }

    /// Queued requests re-placed between boards by the work-stealing
    /// pass (0 unless `BurnPlusSteal`).
    pub fn total_steals(&self) -> u64 {
        self.aggregate.steals
    }

    /// Lane time executed by batches that were later preempted,
    /// microseconds of virtual time — capacity billed as busy but
    /// never served.
    pub fn total_preempt_waste_us(&self) -> f64 {
        self.aggregate.preempt_waste_us
    }

    /// Gray-failure detector suspect flags fleet-wide (0 unless the
    /// tail layer is armed).
    pub fn total_suspects(&self) -> u64 {
        self.aggregate.suspects
    }

    /// Circuit-breaker open transitions fleet-wide.
    pub fn total_breaker_opens(&self) -> u64 {
        self.aggregate.breaker_opens
    }

    /// Probation probes admitted fleet-wide.
    pub fn total_probes(&self) -> u64 {
        self.aggregate.probes
    }

    /// Hedge clones dispatched fleet-wide.
    pub fn total_hedges(&self) -> u64 {
        self.aggregate.hedges
    }

    /// Hedged requests whose clone (not the original placement) won.
    pub fn total_hedge_wins(&self) -> u64 {
        self.aggregate.hedge_wins
    }

    /// Duplicate lane time executed by losing hedge copies,
    /// microseconds of virtual time.
    pub fn total_hedge_waste_us(&self) -> f64 {
        self.aggregate.hedge_waste_us
    }

    /// Mean per-board CPU busy fraction over the makespan, [0, 1].
    pub fn mean_cpu_util(&self) -> f64 {
        let nb = self.boards.len().max(1) as f64;
        let lanes = self.lanes.cpu.max(1) as f64;
        if self.aggregate.makespan_us > 0.0 {
            (self.aggregate.cpu_busy_us
                / (self.aggregate.makespan_us * nb * lanes))
                .min(1.0)
        } else {
            0.0
        }
    }

    /// Mean per-board GPU busy fraction over the makespan, [0, 1].
    pub fn mean_gpu_util(&self) -> f64 {
        let nb = self.boards.len().max(1) as f64;
        let lanes = self.lanes.gpu.max(1) as f64;
        if self.aggregate.makespan_us > 0.0 {
            (self.aggregate.gpu_busy_us
                / (self.aggregate.makespan_us * nb * lanes))
                .min(1.0)
        } else {
            0.0
        }
    }

    /// Fleet-level JSON report: aggregate + per-board snapshots, shed
    /// rate, mean utilizations, replica-count timeline and scale
    /// events.
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("router".into(), Value::Str(self.router.clone()));
        o.insert("governor".into(), Value::Str(self.governor.clone()));
        o.insert("autoscaled".into(), Value::Bool(self.autoscaled));
        o.insert("n_boards".into(),
                 Value::Num(self.boards.len() as f64));
        o.insert("lanes_cpu".into(), Value::Num(self.lanes.cpu as f64));
        o.insert("lanes_gpu".into(), Value::Num(self.lanes.gpu as f64));
        // The merged aggregate's own cpu_util/gpu_util divide
        // busy-time summed across boards by one makespan and clamp to
        // 1.0 — meaningless fleet-wide.  Overwrite them with the
        // per-board means so JSON consumers can't misread saturation.
        let mut agg_json = self.aggregate.to_json();
        if let Value::Obj(agg) = &mut agg_json {
            agg.insert("cpu_util".into(),
                       Value::Num(self.mean_cpu_util()));
            agg.insert("gpu_util".into(),
                       Value::Num(self.mean_gpu_util()));
        }
        o.insert("aggregate".into(), agg_json);
        o.insert(
            "shed_rate".into(),
            Value::Num(if self.aggregate.total_offered() > 0 {
                self.total_shed() as f64
                    / self.aggregate.total_offered() as f64
            } else {
                0.0
            }),
        );
        o.insert("mean_cpu_util".into(),
                 Value::Num(self.mean_cpu_util()));
        o.insert("mean_gpu_util".into(),
                 Value::Num(self.mean_gpu_util()));
        o.insert(
            "per_board".into(),
            Value::Arr(self.boards.iter().map(|b| b.to_json()).collect()),
        );
        o.insert(
            "mean_replicas".into(),
            Value::Arr(self
                .mean_replicas
                .iter()
                .map(|&x| Value::Num(x))
                .collect()),
        );
        o.insert(
            "replica_timeline".into(),
            Value::Arr(self
                .replica_timeline
                .iter()
                .map(|s| {
                    let mut t = BTreeMap::new();
                    t.insert("t_us".into(), Value::Num(s.t_us));
                    t.insert(
                        "per_model".into(),
                        Value::Arr(s
                            .per_model
                            .iter()
                            .map(|&c| Value::Num(c as f64))
                            .collect()),
                    );
                    Value::Obj(t)
                })
                .collect()),
        );
        o.insert(
            "scale_events".into(),
            Value::Arr(self
                .scale_events
                .iter()
                .map(|e| {
                    let mut t = BTreeMap::new();
                    t.insert("t_us".into(), Value::Num(e.t_us));
                    t.insert("model".into(), Value::Num(e.model as f64));
                    t.insert("board".into(), Value::Num(e.board as f64));
                    t.insert("up".into(), Value::Bool(e.up));
                    Value::Obj(t)
                })
                .collect()),
        );
        Value::Obj(o)
    }

    /// [`FleetSnapshot::to_json`] rendered to a string.
    pub fn to_json_string(&self) -> String {
        json::to_string(&self.to_json())
    }

    /// Folded-stack rendering of the whole fleet (one
    /// `board;model;class;phase count_us` block per board, boards
    /// labelled by their snapshot's `policy`, e.g. "fleet/board3");
    /// flamegraph.pl / inferno input.  Empty on untraced runs.
    pub fn folded_trace(&self) -> String {
        self.boards.iter().map(|b| b.folded_trace()).collect()
    }

    /// Chrome trace-event JSON of the whole fleet (Perfetto-loadable;
    /// `pid` = board index, `ts` = virtual-time µs).
    /// `{"traceEvents":[]}` on untraced runs.
    pub fn chrome_trace(&self) -> String {
        let models: Vec<String> = self
            .aggregate
            .per_model
            .iter()
            .map(|g| g.label.clone())
            .collect();
        let classes: Vec<String> = self
            .aggregate
            .per_class
            .iter()
            .map(|g| g.label.clone())
            .collect();
        let slices: Vec<&[crate::obs::TraceRecord]> = self
            .boards
            .iter()
            .map(|b| b.trace_events.as_slice())
            .collect();
        crate::obs::chrome_trace(&slices, &models, &classes)
    }

    /// One-line summary for logs (energy tail only on energy-aware
    /// runs).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "[fleet/{}{}] {} boards: attainment {:.1}% ({} met / {} \
             offered, {} shed) cpu {:.0}% gpu {:.0}% scale events {}",
            self.router,
            if self.autoscaled { "+autoscale" } else { "" },
            self.boards.len(),
            100.0 * self.aggregate_attainment(),
            self.aggregate.total_met(),
            self.aggregate.total_offered(),
            self.total_shed(),
            100.0 * self.mean_cpu_util(),
            100.0 * self.mean_gpu_util(),
            self.scale_events.len(),
        );
        if !self.governor.is_empty() {
            s.push_str(&format!(
                " | {} {:.1} mJ/inf {:.1} W fleet, {} throttles",
                self.governor,
                self.energy_per_inference_mj(),
                self.mean_power_w(),
                self.total_throttles()
            ));
        }
        if self.total_failovers() > 0
            || self.total_failed() > 0
            || self.total_retries() > 0
            || self.total_downtime_us() > 0.0
        {
            s.push_str(&format!(
                " | faults: {} failovers {} requeued {} retries {} \
                 failed {:.0}ms down",
                self.total_failovers(),
                self.total_requeued(),
                self.total_retries(),
                self.total_failed(),
                self.total_downtime_us() / 1e3,
            ));
        }
        if self.total_preemptions() > 0 || self.total_steals() > 0 {
            s.push_str(&format!(
                " | preempt: {} preempted {} stolen {:.1}ms wasted",
                self.total_preemptions(),
                self.total_steals(),
                self.total_preempt_waste_us() / 1e3,
            ));
        }
        if self.total_suspects() > 0
            || self.total_breaker_opens() > 0
            || self.total_probes() > 0
            || self.total_hedges() > 0
        {
            s.push_str(&format!(
                " | tail: {} suspects {} opens {} probes {} hedges \
                 ({} won) {:.1}ms hedge waste",
                self.total_suspects(),
                self.total_breaker_opens(),
                self.total_probes(),
                self.total_hedges(),
                self.total_hedge_wins(),
                self.total_hedge_waste_us() / 1e3,
            ));
        }
        s
    }
}

/// One hosted replica on one board.
#[derive(Debug, Clone, Copy)]
struct Replica {
    model: usize,
    /// The replica serves (and the router targets it) from this time.
    active_from: f64,
    /// Draining replicas take no new requests and retire once their
    /// board's queue for the model empties.
    draining: bool,
}

/// Autoscaler state across ticks.
struct AutoState {
    prev_offered: Vec<u64>,
    prev_met: Vec<u64>,
    up_streak: Vec<usize>,
    down_streak: Vec<usize>,
    /// Per-board `preempt_waste_us` at the previous tick, so each
    /// window's fresh waste can inflate the queue-pressure signal.
    prev_waste: Vec<f64>,
    next_tick_us: f64,
}

/// One outstanding hedged request: both copies (original placement
/// and clone) are hedge-marked on their boards, so their terminal
/// outcomes divert to the boards' tail outboxes instead of settling.
/// The first `Served` outcome wins and settles exactly once; the
/// losing copy is cancelled (in-flight retract / queue purge) or
/// billed as duplicate waste if it raced to completion.  `copies`
/// counts marks still standing; the entry retires at zero.
struct HedgeEntry {
    /// Original request identity (arrival/deadline preserved).
    r: QueuedReq,
    /// Board the request was first placed on.
    orig_board: usize,
    /// Board the hedge clone was re-offered to.
    clone_board: usize,
    /// Copies not yet resolved (served, cancelled, or dead).
    copies: u32,
    /// Board whose copy settled the request, once decided.
    winner: Option<usize>,
}

/// The fleet's view of per-board fault state, kept in lock-step with
/// the transitions it delivers into the boards.  The router, the
/// retry path and the autoscaler all consult [`Health::avail`] so no
/// new work is ever steered at a board that cannot serve it.
struct Health {
    down: Vec<bool>,
    cpu_down: Vec<bool>,
    gpu_down: Vec<bool>,
}

impl Health {
    fn healthy(nb: usize) -> Self {
        Health {
            down: vec![false; nb],
            cpu_down: vec![false; nb],
            gpu_down: vec![false; nb],
        }
    }

    /// Can board `b` accept new work right now?  Not crashed, and at
    /// least one lane kind alive.
    fn avail(&self, b: usize) -> bool {
        !self.down[b] && !(self.cpu_down[b] && self.gpu_down[b])
    }

    /// The batch-1 price table board `b` should quote given its lane
    /// health (`full` = cheapest placement, `cpu`/`gpu` = single-kind
    /// tables; empty slices fall back to `full` on fault-free runs).
    fn price_table<'t>(
        &self,
        b: usize,
        full: &'t [f64],
        cpu: &'t [f64],
        gpu: &'t [f64],
    ) -> &'t [f64] {
        if self.gpu_down[b] && !self.cpu_down[b] && !cpu.is_empty() {
            cpu
        } else if self.cpu_down[b]
            && !self.gpu_down[b]
            && !gpu.is_empty()
        {
            gpu
        } else {
            full
        }
    }
}

/// Orphaned requests awaiting re-placement (crash-drained queue work
/// and batches lost in flight): a min-heap on delivery time over a
/// grow-only slab.  Entries are `(request, attempt, lost-in-flight)`.
struct Pend {
    heap: BinaryHeap<Reverse<(u64, usize)>>,
    pool: Vec<(QueuedReq, u32, bool)>,
}

impl Pend {
    fn new() -> Self {
        Pend { heap: BinaryHeap::new(), pool: Vec::new() }
    }

    fn push(&mut self, at_us: f64, r: QueuedReq, attempt: u32,
            retry: bool) {
        let idx = self.pool.len();
        self.pool.push((r, attempt, retry));
        // Non-negative finite times order identically by bits.
        self.heap.push(Reverse((at_us.to_bits(), idx)));
    }

    /// Earliest pending delivery time, if any (drives the clock).
    fn next_at_us(&self) -> Option<f64> {
        self.heap
            .peek()
            .map(|Reverse((bits, _))| f64::from_bits(*bits))
    }

    /// Pop one entry due at or before `now`, if any.
    fn pop_due(&mut self, now: f64) -> Option<(QueuedReq, u32, bool)> {
        match self.heap.peek() {
            Some(Reverse((bits, _)))
                if f64::from_bits(*bits) <= now =>
            {
                let Reverse((_, idx)) = self.heap.pop().unwrap();
                Some(self.pool[idx])
            }
            _ => None,
        }
    }
}

/// Queue an orphan for a (re)delivery attempt at `at_us`, or fail it
/// on the front tier when failover is disabled, retries are
/// exhausted, or even the optimistic batch-1 price `min_price_us`
/// cannot beat its deadline.  Failed requests are *recorded* — the
/// conservation identity (offered == served + shed + failed) never
/// leaks one.
fn schedule_or_fail(
    r: QueuedReq,
    attempt: u32,
    at_us: f64,
    retry: bool,
    failover: bool,
    min_price_us: f64,
    pend: &mut Pend,
    front: &mut PerfSnapshot,
) {
    if !failover
        || attempt >= MAX_RETRY_ATTEMPTS
        || at_us + min_price_us > r.deadline_us
    {
        front.record_failed(r.class, r.model);
    } else {
        pend.push(at_us, r, attempt, retry);
    }
}

/// Serve a merged multi-tenant arrival stream on a fleet of boards
/// behind the configured router (and optionally the autoscaler), all
/// in one shared virtual clock.  The returned snapshot's aggregate
/// conserves requests: offered == served + shed == `arrivals.len()`.
pub fn run_fleet(
    registry: &ModelRegistry,
    classes: &[SloClass],
    tenants: &[Tenant],
    arrivals: &[Arrival],
    opts: &FleetOptions,
) -> Result<FleetSnapshot> {
    anyhow::ensure!(!registry.is_empty(), "registry holds no models");
    anyhow::ensure!(!classes.is_empty(), "no SLO classes configured");
    anyhow::ensure!(!opts.placement.is_empty(), "fleet needs >= 1 board");
    let nm = registry.len();
    let nb = opts.placement.len();
    let model_of: Vec<usize> = tenants
        .iter()
        .map(|t| registry.index_of(&t.model))
        .collect::<Result<_>>()?;
    for t in tenants {
        anyhow::ensure!(
            t.class < classes.len(),
            "tenant `{}` references SLO class {} of {}",
            t.name, t.class, classes.len()
        );
    }
    anyhow::ensure!(
        arrivals.windows(2).all(|w| w[0].at_us <= w[1].at_us),
        "arrivals must be time-sorted (use serve::merge_arrivals)"
    );
    let mut replicas: Vec<Vec<Replica>> = Vec::with_capacity(nb);
    for (b, models) in opts.placement.iter().enumerate() {
        let mut seen = vec![false; nm];
        for &m in models {
            anyhow::ensure!(m < nm,
                "board {b} hosts unknown model index {m} (of {nm})");
            anyhow::ensure!(!seen[m],
                "board {b} hosts model {m} twice");
            seen[m] = true;
        }
        replicas.push(
            models
                .iter()
                .map(|&m| Replica {
                    model: m,
                    active_from: 0.0,
                    draining: false,
                })
                .collect(),
        );
    }
    for m in 0..nm {
        anyhow::ensure!(
            replicas.iter().any(|p| p.iter().any(|r| r.model == m)),
            "model `{}` has no replica in the initial placement",
            registry.get(m).name
        );
    }
    if let Some(auto) = &opts.autoscale {
        anyhow::ensure!(auto.interval_us > 0.0,
                        "autoscale interval must be positive");
        anyhow::ensure!(auto.warmup_us >= 0.0,
                        "autoscale warmup must be non-negative");
        anyhow::ensure!(auto.hysteresis >= 1,
                        "autoscale hysteresis must be >= 1");
    }

    // Validate and expand the fault plan into time-sorted transitions
    // up front.  An empty plan arms nothing: the run takes the
    // pre-fault code path bit-for-bit.
    let transitions: Vec<FaultTransition> = opts.faults.timeline(nb)?;
    let fault_on = !transitions.is_empty();

    let cluster_opts = ClusterOptions {
        policy: opts.policy,
        shed: opts.shed,
        trace: opts.trace,
    };
    // Per-model price tables, probed once so neither the per-arrival
    // routing hot path nor the control loop touches the probe cache:
    // cheapest batch-1 latency (router backlog pricing, installed into
    // every board so its cached work score can use it) and per-request
    // cost at the full batch (autoscaler load signal).
    let lat1_us: Vec<f64> = registry.lat1_table()?;
    let eff_cost_us: Vec<f64> = registry.efficient_cost_table()?;

    let mut boards: Vec<BoardSim> = (0..nb)
        .map(|b| {
            BoardSim::new(
                registry,
                classes,
                &cluster_opts,
                opts.lanes,
                &format!("fleet/board{b}"),
            )
        })
        .collect::<Result<_>>()?;
    for board in boards.iter_mut() {
        board.set_price_table(lat1_us.clone());
        if let Some(pc) = &opts.power {
            board.set_power(pc)?;
        }
        if fault_on {
            board.arm_faults();
        }
        if opts.preempt.preempts() {
            board.arm_preemption(opts.preempt);
        }
        if opts.tail.enabled() {
            board.arm_tail();
        }
    }
    // Single-lane-kind price tables for degraded boards (a board whose
    // GPU lanes died quotes CPU-only batch-1 latencies to the router
    // and the retry feasibility check).  Probed only when a fault can
    // actually degrade a board.
    let lat1_cpu_us: Vec<f64> = if fault_on {
        registry.lat1_table_for(Proc::Cpu)?
    } else {
        Vec::new()
    };
    let lat1_gpu_us: Vec<f64> = if fault_on {
        registry.lat1_table_for(Proc::Gpu)?
    } else {
        Vec::new()
    };
    let mut health = Health::healthy(nb);
    let class_labels: Vec<String> =
        classes.iter().map(|c| c.name.clone()).collect();
    let model_labels: Vec<String> = registry
        .entries()
        .iter()
        .map(|e| e.name.clone())
        .collect();
    // Front-tier accounting: arrivals no live board can accept, and
    // orphans that exhaust their retries, settle here — so the
    // conservation identity stays exact even when a model's every
    // replica is dark.  Merged into the aggregate on faulty runs.
    let mut front = PerfSnapshot::new(
        "fleet/front",
        opts.shed.name(),
        &class_labels,
        &model_labels,
    );
    let mut pend = Pend::new();
    let mut ti = 0usize;

    let mut rr = vec![0usize; nm];
    let mut auto_state = AutoState {
        prev_offered: vec![0; nm],
        prev_met: vec![0; nm],
        up_streak: vec![0; nm],
        down_streak: vec![0; nm],
        prev_waste: vec![0.0; nb],
        next_tick_us: opts
            .autoscale
            .map_or(f64::INFINITY, |a| a.interval_us),
    };
    // Tail-tolerance state: the gray-failure detector + circuit
    // breakers (fleet-side) and the outstanding-hedge table.  `None`
    // keeps every tail branch dead — byte-identical output.
    let mut tail = opts
        .tail
        .enabled()
        .then(|| TailState::new(opts.tail, opts.tail_params, nb));
    let mut hedges: HashMap<usize, HedgeEntry> = HashMap::new();
    // Deterministic jitter stream for retry backoffs: simultaneous
    // failovers de-synchronize instead of re-offering in waves.
    // Fault-free, breaker-closed runs never reach a backoff site, so
    // they never draw from it — byte-stable.
    let mut backoff_rng = Rng::new(0xbacc_0ff5 ^ opts.tail_params.seed);
    let mut scale_events: Vec<ScaleEvent> = Vec::new();
    let mut timeline: Vec<ReplicaSample> = Vec::new();
    if opts.autoscale.is_some() {
        // Boundary sample so the initial placement is time-weighted
        // from t = 0 (the autoscaler only samples at its ticks).
        timeline.push(ReplicaSample {
            t_us: 0.0,
            per_model: count_active(&replicas, nm),
        });
    }
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut elig: Vec<usize> = Vec::with_capacity(nb);
    // Event-heap clock: every pumped board's wake-up lands in a
    // min-heap keyed by time, lazily invalidated by a per-board
    // generation (a board's entries go stale the moment it is pumped
    // again).  `touched[b]` marks boards that received an offer since
    // their last pump; boards with no standing work and no fresh offer
    // are provable no-ops (`pump` on an empty, untouched board returns
    // `None`) and are skipped entirely, so idle boards cost nothing.
    let mut touched = vec![false; nb];
    let mut wake_gen = vec![0u64; nb];
    let mut wakes: BinaryHeap<Reverse<(u64, usize, u64)>> =
        BinaryHeap::new();
    loop {
        // Deliver every fault transition due by `now` into its board,
        // keeping the fleet's health view (and the degraded price
        // tables) in lock-step.  Crash-drained queue work is
        // re-placed immediately; batches lost in flight come back as
        // deadline-aware retries after a capped backoff.
        while ti < transitions.len() && transitions[ti].at_us <= now {
            let tr = transitions[ti];
            ti += 1;
            let b = tr.board;
            match tr.change {
                FaultChange::BoardDown => {
                    if health.down[b] {
                        continue; // overlapping plan entry: no-op
                    }
                    let (queued, lost) = boards[b].crash(now);
                    health.down[b] = true;
                    // Pump the crashed board once (a no-op while
                    // down): it bumps `wake_gen[b]`, invalidating any
                    // stale wake-heap entry from before the crash —
                    // the drained queue can no longer honor it, and a
                    // live entry at matching generation would pin
                    // `t_next` at its time forever.
                    touched[b] = true;
                    for r in queued {
                        // A hedge-marked copy drained off the crash
                        // is a copy death, not an orphan: its twin
                        // may still serve the request.
                        if boards[b].tail_is_marked(r.req) {
                            resolve_hedge_outcome(
                                b, HedgeOutcome::Dead { req: r.req },
                                now, &mut boards, &mut hedges,
                                opts.failover, &lat1_us, &mut pend,
                                &mut front, &mut touched,
                                &mut backoff_rng,
                            );
                            continue;
                        }
                        schedule_or_fail(
                            r, 0, now, false, opts.failover,
                            lat1_us[r.model], &mut pend, &mut front,
                        );
                    }
                    for r in lost {
                        if boards[b].tail_is_marked(r.req) {
                            resolve_hedge_outcome(
                                b, HedgeOutcome::Dead { req: r.req },
                                now, &mut boards, &mut hedges,
                                opts.failover, &lat1_us, &mut pend,
                                &mut front, &mut touched,
                                &mut backoff_rng,
                            );
                            continue;
                        }
                        schedule_or_fail(
                            r, 0,
                            now + jittered_backoff_us(
                                0, &mut backoff_rng),
                            true, opts.failover, lat1_us[r.model],
                            &mut pend, &mut front,
                        );
                    }
                }
                FaultChange::BoardUp => {
                    boards[b].rejoin(now);
                    health.down[b] = false;
                    touched[b] = true;
                }
                FaultChange::LaneDown(p) => {
                    let lost = boards[b].set_lane_down(p, true, now);
                    match p {
                        Proc::Cpu => health.cpu_down[b] = true,
                        Proc::Gpu => health.gpu_down[b] = true,
                    }
                    boards[b].set_price_table(
                        health
                            .price_table(b, &lat1_us, &lat1_cpu_us,
                                         &lat1_gpu_us)
                            .to_vec(),
                    );
                    for r in lost {
                        if boards[b].tail_is_marked(r.req) {
                            resolve_hedge_outcome(
                                b, HedgeOutcome::Dead { req: r.req },
                                now, &mut boards, &mut hedges,
                                opts.failover, &lat1_us, &mut pend,
                                &mut front, &mut touched,
                                &mut backoff_rng,
                            );
                            continue;
                        }
                        schedule_or_fail(
                            r, 0,
                            now + jittered_backoff_us(
                                0, &mut backoff_rng),
                            true, opts.failover, lat1_us[r.model],
                            &mut pend, &mut front,
                        );
                    }
                    touched[b] = true;
                }
                FaultChange::LaneUp(p) => {
                    boards[b].set_lane_down(p, false, now);
                    match p {
                        Proc::Cpu => health.cpu_down[b] = false,
                        Proc::Gpu => health.gpu_down[b] = false,
                    }
                    boards[b].set_price_table(
                        health
                            .price_table(b, &lat1_us, &lat1_cpu_us,
                                         &lat1_gpu_us)
                            .to_vec(),
                    );
                    touched[b] = true;
                }
                FaultChange::ThermalOn(p, scale) => {
                    boards[b].set_thermal(p, scale);
                    touched[b] = true;
                }
                FaultChange::ThermalOff(p) => {
                    boards[b].set_thermal(p, 1.0);
                    touched[b] = true;
                }
            }
        }
        // Breaker cooldowns due by `now` move Open boards into
        // Probation (their probe clock starts at `now`).
        if let Some(t) = tail.as_mut() {
            t.advance(now);
        }
        // Re-place orphans whose delivery time has come: route to a
        // live board if one can still beat the deadline at its priced
        // batch-1 latency; back off and re-try while hosts are dark;
        // fail (exactly-once, counted) when the deadline is doomed or
        // the attempt budget runs out.
        while let Some((r, attempt, retry)) = pend.pop_due(now) {
            let m = r.model;
            eligible_boards_into(m, now, &replicas, &health,
                                 tail.as_ref(), &mut elig);
            if elig.is_empty() {
                schedule_or_fail(
                    r,
                    attempt + 1,
                    now + jittered_backoff_us(attempt,
                                              &mut backoff_rng),
                    retry,
                    opts.failover,
                    lat1_us[m],
                    &mut pend,
                    &mut front,
                );
                continue;
            }
            let b = route(opts.router, m, now, &boards, &elig,
                          &mut rr)?;
            if let Some(t) = tail.as_mut() {
                if t.is_probe(b) {
                    t.consume_probe(b, now);
                    boards[b].note_probe(now);
                }
            }
            let price = health
                .price_table(b, &lat1_us, &lat1_cpu_us, &lat1_gpu_us)
                [m];
            if now + price > r.deadline_us {
                // Deadline-aware: no survivor can serve it in time —
                // fail it now instead of burning survivor capacity.
                front.record_failed(r.class, r.model);
                continue;
            }
            // A readmit refused by admission control was shed on `b`
            // (and settles there): conserved either way.
            if boards[b].readmit(r, now, retry) {
                touched[b] = true;
                if retry {
                    front.retries += 1;
                }
            }
        }
        // Ingest and route everything that has arrived by `now`.
        while ai < arrivals.len() && arrivals[ai].at_us <= now {
            let a = arrivals[ai];
            ai += 1;
            let m = model_of[a.tenant];
            let class = tenants[a.tenant].class;
            eligible_boards_into(m, now, &replicas, &health,
                                 tail.as_ref(), &mut elig);
            if elig.is_empty() {
                // Every host of the model is down: the front tier
                // owns the request until one returns (or its
                // deadline dooms it).  Offered is counted here, once.
                front.record_offered(class, m);
                let r = QueuedReq {
                    req: a.req,
                    tenant: a.tenant,
                    model: m,
                    class,
                    arrival_us: a.at_us,
                    deadline_us: a.at_us + classes[class].deadline_us,
                };
                // First re-placement try after one backoff (orphans
                // due exactly at `now` were already drained above —
                // a same-instant entry would stall the clock).
                schedule_or_fail(
                    r, 1,
                    now + jittered_backoff_us(0, &mut backoff_rng),
                    false, opts.failover, lat1_us[m], &mut pend,
                    &mut front,
                );
                continue;
            }
            let b = route(
                opts.router, m, now, &boards, &elig, &mut rr,
            )?;
            if let Some(t) = tail.as_mut() {
                if t.is_probe(b) {
                    t.consume_probe(b, now);
                    boards[b].note_probe(now);
                }
            }
            boards[b].offer(a.req, a.tenant, m, class, a.at_us);
            touched[b] = true;
        }
        // BurnPlusSteal: after routing fresh arrivals, re-place work
        // stranded behind long-running batches onto cheaper boards.
        if opts.preempt.steals() {
            steal_pass(now, &mut boards, &replicas, &health,
                       tail.as_ref(), &lat1_us, &mut elig,
                       &mut touched);
        }
        // Hedged dispatch: clone deadline-at-risk interactive requests
        // onto the next-cheapest routable board; the first finish wins
        // (reconciled after the pump phase below).
        if opts.tail.hedge {
            hedge_pass(
                now, &mut boards, &replicas, &health,
                tail.as_ref().expect("tail armed when hedging"),
                &lat1_us, &mut elig, &mut hedges, &mut touched,
            );
        }
        // Autoscaler tick.  The schedule only drives the clock while
        // work is standing (see below), so after an idle gap in the
        // arrival stream `next_tick_us` may lie far in the past: fire
        // one catch-up tick and realign instead of replaying every
        // missed no-op interval.
        if let Some(auto) = &opts.autoscale {
            if now >= auto_state.next_tick_us {
                autoscale_tick(
                    now, auto, &eff_cost_us, &mut boards,
                    &mut replicas, &health, tail.as_ref(),
                    &mut auto_state, &mut scale_events, &mut timeline,
                );
                auto_state.next_tick_us += auto.interval_us;
                while auto_state.next_tick_us <= now {
                    auto_state.next_tick_us += auto.interval_us;
                }
            }
        }
        // Let every board with standing or fresh work dispatch at
        // `now`; push wake-ups into the fleet heap and keep the
        // standing-work count incrementally (skipped boards are empty
        // by construction).
        let mut standing = 0usize;
        for (b, board) in boards.iter_mut().enumerate() {
            if !touched[b] && board.total_queued() == 0 {
                continue;
            }
            touched[b] = false;
            wake_gen[b] += 1;
            if let Some(wake) = board.pump(now)? {
                wakes.push(Reverse((wake.to_bits(), b, wake_gen[b])));
            }
            standing += board.total_queued();
        }
        // Tail bookkeeping: feed the detector from this step's settled
        // batches, then reconcile diverted hedge outcomes — the first
        // finish wins, the loser is cancelled with its lane tail and
        // energy refunded.  A cancellation frees lanes or re-queues
        // batch-mates at `now`, so affected boards re-pump inside this
        // same clock step; the drain loops until no outcome surfaces.
        if let Some(t) = tail.as_mut() {
            while drain_tail(
                now, &mut boards, t, &mut hedges, opts.failover,
                &lat1_us, &mut pend, &mut front, &mut touched,
                &mut backoff_rng,
            ) {
                for b in 0..nb {
                    if touched[b] {
                        touched[b] = false;
                        wake_gen[b] += 1;
                        if let Some(wake) = boards[b].pump(now)? {
                            wakes.push(Reverse((
                                wake.to_bits(), b, wake_gen[b],
                            )));
                        }
                    }
                }
            }
        }
        // Clock advance: earliest live board wake from the heap,
        // merged with the next arrival and (while work is standing)
        // the next autoscaler tick.
        let mut t_next = f64::INFINITY;
        while let Some(&Reverse((bits, b, gen))) = wakes.peek() {
            if gen != wake_gen[b] {
                wakes.pop();
                continue;
            }
            t_next = f64::from_bits(bits);
            break;
        }
        if ai < arrivals.len() {
            t_next = t_next.min(arrivals[ai].at_us);
        }
        // Pending fault transitions and orphan re-deliveries drive
        // the clock too: a rejoin or a backed-off retry must fire
        // even when no board has standing work.
        if ti < transitions.len() {
            t_next = t_next.min(transitions[ti].at_us);
        }
        if let Some(at) = pend.next_at_us() {
            t_next = t_next.min(at);
        }
        // An Open breaker's cooldown expiry must fire even on an
        // otherwise idle fleet, or a recovered board would never
        // re-enter probation.
        if let Some(t) = &tail {
            t_next = t_next.min(t.next_event_us());
        }
        // Ticks drive the clock only while work is standing; across an
        // idle arrival gap the clock jumps straight to the next
        // arrival (ticks resume there via the catch-up above) instead
        // of stepping through thousands of no-op control intervals.
        if opts.autoscale.is_some() && standing > 0 {
            t_next = t_next.min(auto_state.next_tick_us);
        }
        if !t_next.is_finite() {
            break;
        }
        debug_assert!(t_next > now, "fleet clock must advance");
        now = t_next;
    }
    // Tail epilogue: force-settle anything still in flight, run a
    // final reconciliation, then resolve entries stranded by degraded
    // boards — the clone is purged so it can never settle a second
    // copy, and an unserved original either falls to its board's
    // fault backstop (still queued: failed there) or is failed on the
    // front tier here.  Settlement stays exactly-once either way.
    if let Some(t) = tail.as_mut() {
        for board in boards.iter_mut() {
            board.settle_inflight(f64::INFINITY);
        }
        while drain_tail(
            now, &mut boards, t, &mut hedges, opts.failover, &lat1_us,
            &mut pend, &mut front, &mut touched, &mut backoff_rng,
        ) {}
        let leftovers: Vec<usize> = hedges.keys().copied().collect();
        for req in leftovers {
            let e = hedges.remove(&req).expect("hedge entry");
            boards[e.clone_board].hedge_purge_queued(req, e.r.model,
                                                     now);
            boards[e.clone_board].tail_unmark(req);
            boards[e.orig_board].tail_unmark(req);
            if e.winner.is_some() {
                // A copy settled; a still-queued losing original must
                // not also fail in the backstop.
                boards[e.orig_board].hedge_purge_queued(
                    req, e.r.model, now);
            } else {
                let orig_queued = boards[e.orig_board]
                    .queued_of_model(e.r.model)
                    .any(|q| q.req == req);
                if !orig_queued {
                    front.record_failed(e.r.class, e.r.model);
                }
            }
        }
        // Orphans still pending re-delivery when the clock drained
        // are out of chances: fail them on the front tier so the
        // conservation identity closes.
        while let Some((r, _, _)) = pend.pop_due(f64::INFINITY) {
            front.record_failed(r.class, r.model);
        }
    }
    // Seal per-board snapshots and merge the aggregate.
    let board_snaps: Vec<PerfSnapshot> = boards
        .into_iter()
        .map(|b| b.finish(now))
        .collect();
    let mut aggregate = PerfSnapshot::new(
        "fleet",
        opts.shed.name(),
        &class_labels,
        &model_labels,
    );
    for snap in &board_snaps {
        aggregate.merge_from(snap);
    }
    if fault_on || opts.tail.enabled() {
        // Front-tier offered/failed/retry accounting joins the
        // aggregate so conservation closes over the whole fleet.
        // Tail runs need it too: a request whose every hedge copy
        // dies (or whose hosts are all breaker-Open past its
        // deadline) settles as failed on the front tier.
        aggregate.merge_from(&front);
    }
    if opts.autoscale.is_some()
        && timeline
            .last()
            .map_or(false, |s| s.t_us < aggregate.makespan_us)
    {
        // Closing boundary sample at the true end of the run (the
        // last batch finish, not the loop-exit time), so the
        // time-weighted mean covers the whole makespan.
        timeline.push(ReplicaSample {
            t_us: aggregate.makespan_us,
            per_model: count_active(&replicas, nm),
        });
    }
    debug_assert_eq!(aggregate.total_offered() as usize, arrivals.len(),
                     "router lost requests");
    debug_assert_eq!(
        aggregate.total_served() + aggregate.total_shed()
            + aggregate.total_failed(),
        aggregate.total_offered(),
        "fleet conservation drifted"
    );

    // Time-weighted mean replica count per model.
    let mean_replicas: Vec<f64> = if timeline.len() >= 2 {
        let span = timeline.last().unwrap().t_us - timeline[0].t_us;
        let mut mean = vec![0.0; nm];
        for w in timeline.windows(2) {
            let dt = w[1].t_us - w[0].t_us;
            for m in 0..nm {
                mean[m] += w[0].per_model[m] as f64 * dt;
            }
        }
        mean.iter().map(|x| x / span.max(1e-12)).collect()
    } else {
        count_active(&replicas, nm)
            .into_iter()
            .map(|c| c as f64)
            .collect()
    };

    Ok(FleetSnapshot {
        router: opts.router.name().into(),
        governor: opts
            .power
            .as_ref()
            .map(|p| p.governor.name())
            .unwrap_or_default(),
        autoscaled: opts.autoscale.is_some(),
        lanes: opts.lanes,
        boards: board_snaps,
        aggregate,
        scale_events,
        replica_timeline: timeline,
        mean_replicas,
    })
}

/// Non-draining replica count per model (warming included: committed
/// capacity) — the one definition behind the timeline samples and the
/// autoscaler's load signals.
fn count_active(replicas: &[Vec<Replica>], nm: usize) -> Vec<usize> {
    let mut counts = vec![0usize; nm];
    for r in replicas.iter().flat_map(|p| p.iter()) {
        if !r.draining {
            counts[r.model] += 1;
        }
    }
    counts
}

/// The `BurnPlusSteal` work-stealing pass, run once per clock step at
/// wake-up-heap granularity: for every stalled victim board (every
/// schedulable lane busy strictly past `now` — detected through the
/// same lane state the epoch-cached backlog estimates price) with
/// queued work, re-place each queued model's never-dispatched
/// requests onto the cheapest other eligible board.  A move happens
/// only when the thief's priced backlog plus the model's batch-1
/// latency (`lat1_us`, microseconds) undercuts *half* the victim's
/// stall — factor-2 hysteresis, so marginal moves never ping-pong
/// work between boards.  Stolen requests keep their original
/// arrival/deadline and are never re-counted as admitted (see
/// [`BoardSim::steal_queue`] / [`BoardSim::readmit`]); crashed or
/// quarantined boards are excluded as thieves by
/// [`eligible_boards_into`] and never scanned as victims.  The pend
/// heap is untouched: stealing moves only work still owned by a
/// board's admission queues, so a crash-drained request can never be
/// both re-pended and stolen.
fn steal_pass(
    now: f64,
    boards: &mut [BoardSim],
    replicas: &[Vec<Replica>],
    health: &Health,
    tail: Option<&TailState>,
    lat1_us: &[f64],
    elig: &mut Vec<usize>,
    touched: &mut [bool],
) {
    for v in 0..boards.len() {
        if health.down[v] || boards[v].total_queued() == 0 {
            continue;
        }
        let stall = boards[v].stall_us(now);
        if stall <= 0.0 {
            continue; // a lane is free: the victim can dispatch now
        }
        for m in 0..lat1_us.len() {
            if boards[v].queue_len(m) == 0 {
                continue;
            }
            eligible_boards_into(m, now, replicas, health, tail,
                                 elig);
            // Thieves must be breaker-Closed: a Probation board
            // admits only its metered probes, never a bulk steal.
            elig.retain(|&b| {
                b != v
                    && tail.map_or(true, |t| {
                        t.breaker(b) == BreakerState::Closed
                    })
            });
            if elig.is_empty() {
                continue;
            }
            let best = elig
                .iter()
                .map(|&b| boards[b].backlog_residual_us(now))
                .fold(f64::INFINITY, f64::min);
            // Factor-2 hysteresis: move only when the thief is
            // decisively cheaper than waiting out the stall.  (An
            // infinite stall — every lane kind down — always loses,
            // so stranded work on a degraded board escapes.)
            if 2.0 * (best + lat1_us[m]) >= stall {
                continue;
            }
            let stolen = boards[v].steal_queue(m, now);
            touched[v] = true;
            for r in stolen {
                // Re-pick per request: each readmit bumps the thief's
                // epoch, so a large drain re-prices as it spreads.
                let mut tb = elig[0];
                let mut tb_score = f64::INFINITY;
                for &b in elig.iter() {
                    let s = boards[b].backlog_residual_us(now);
                    if s < tb_score {
                        tb = b;
                        tb_score = s;
                    }
                }
                // A refused readmit sheds on the thief: conserved.
                boards[tb].readmit(r, now, false);
                touched[tb] = true;
            }
        }
    }
}

/// The hedged-dispatch pass, run once per clock step after routing
/// and stealing: scan every board's queued class-0 (interactive)
/// requests; when one's projected completion on its current board —
/// standing priced backlog plus the model's batch-1 price — can no
/// longer make its deadline, re-offer a clone to the cheapest other
/// routable board, but only if that board's own projection still
/// beats the deadline (a hopeless clone would just burn capacity).
/// Both copies are hedge-marked so their terminal outcomes divert to
/// the boards' tail outboxes; `resolve_hedge_outcome` settles the
/// first finish and cancels the loser.  The clone enters admission
/// like a failover readmit — never re-counted as offered/admitted —
/// and a request is hedged at most once while its entry stands.
#[allow(clippy::too_many_arguments)]
fn hedge_pass(
    now: f64,
    boards: &mut [BoardSim],
    replicas: &[Vec<Replica>],
    health: &Health,
    tail: &TailState,
    lat1_us: &[f64],
    elig: &mut Vec<usize>,
    hedges: &mut HashMap<usize, HedgeEntry>,
    touched: &mut [bool],
) {
    for v in 0..boards.len() {
        if health.down[v] || boards[v].total_queued() == 0 {
            continue;
        }
        let backlog = boards[v].backlog_residual_us(now);
        for m in 0..lat1_us.len() {
            if boards[v].queue_len(m) == 0 {
                continue;
            }
            // Collect first: marking and re-offering mutate boards,
            // so the queue iterator must not stay borrowed.
            let at_risk: Vec<QueuedReq> = boards[v]
                .queued_of_model(m)
                .filter(|r| {
                    r.class == 0
                        && !hedges.contains_key(&r.req)
                        && now + backlog + lat1_us[m] > r.deadline_us
                })
                .copied()
                .collect();
            if at_risk.is_empty() {
                continue;
            }
            eligible_boards_into(m, now, replicas, health, Some(tail),
                                 elig);
            elig.retain(|&b| b != v);
            if elig.is_empty() {
                continue;
            }
            for r in at_risk {
                // Next-cheapest board: standing work plus price.
                // Re-picked per request — each clone bumps its
                // target's epoch, so a burst spreads.
                let mut tb = elig[0];
                let mut tb_score = f64::INFINITY;
                for &b in elig.iter() {
                    let s = boards[b].backlog_residual_us(now)
                        + lat1_us[m];
                    if s < tb_score {
                        tb = b;
                        tb_score = s;
                    }
                }
                if now + tb_score >= r.deadline_us {
                    continue; // no board projects to save it
                }
                boards[v].tail_mark(r.req);
                boards[tb].tail_mark(r.req);
                hedges.insert(r.req, HedgeEntry {
                    r,
                    orig_board: v,
                    clone_board: tb,
                    copies: 2,
                    winner: None,
                });
                // A refused readmit sheds hedge-marked on `tb`; the
                // diverted death resolves the entry at the next
                // drain.
                if boards[tb].readmit(r, now, false) {
                    boards[tb].note_hedge(now, m, r.class);
                }
                touched[tb] = true;
                touched[v] = true;
            }
        }
    }
}

/// Apply one diverted hedge outcome.  The first `Served` settles the
/// request (exactly once) on its board; the losing copy is eagerly
/// cancelled — retracted mid-flight with lane/energy refunds, or
/// purged from its queue — and if it already finished in the same
/// reconciliation round, its later outcome is billed as duplicate
/// waste instead.  When every copy dies unserved, the request returns
/// to the front tier's deadline-aware retry path (or fails there,
/// counted — conservation never leaks).
#[allow(clippy::too_many_arguments)]
fn resolve_hedge_outcome(
    b: usize,
    o: HedgeOutcome,
    now: f64,
    boards: &mut [BoardSim],
    hedges: &mut HashMap<usize, HedgeEntry>,
    failover: bool,
    lat1_us: &[f64],
    pend: &mut Pend,
    front: &mut PerfSnapshot,
    touched: &mut [bool],
    rng: &mut Rng,
) {
    match o {
        HedgeOutcome::Served {
            r,
            start_us,
            finish_us,
            share_us,
            dma_frac,
        } => {
            let Some(e) = hedges.get_mut(&r.req) else {
                // Defensive: a mark without an entry settles normally.
                boards[b].finalize_hedge_served(
                    &r, start_us, finish_us, share_us, dma_frac,
                    false,
                );
                return;
            };
            if e.winner.is_some() {
                // The twin already settled: this copy's service is a
                // duplicate.  Its lane time was really spent — bill
                // the per-request share as hedge waste and drop it.
                e.copies = e.copies.saturating_sub(1);
                let gone = e.copies == 0;
                boards[b].bill_hedge_waste(share_us, now);
                boards[b].tail_unmark(r.req);
                if gone {
                    hedges.remove(&r.req);
                }
                return;
            }
            // First finish wins.
            e.winner = Some(b);
            e.copies = e.copies.saturating_sub(1);
            let clone_won = b == e.clone_board;
            let loser = if clone_won {
                e.orig_board
            } else {
                e.clone_board
            };
            let loser_pending = e.copies > 0;
            boards[b].finalize_hedge_served(
                &r, start_us, finish_us, share_us, dma_frac,
                clone_won,
            );
            touched[b] = true;
            let mut resolved = !loser_pending;
            if loser_pending
                && (boards[loser].hedge_cancel_inflight(r.req, now)
                    || boards[loser].hedge_purge_queued(
                        r.req, r.model, now))
            {
                // Eager first-wins cancellation; if neither path finds
                // the copy it is racing us (settled this same round or
                // already dead) and its own outcome will resolve it.
                touched[loser] = true;
                resolved = true;
            }
            if resolved {
                // The loser copy was cancelled (unmarked — it will
                // emit no further outcome), so the entry is settled.
                hedges.remove(&r.req);
            }
        }
        HedgeOutcome::Dead { req } => {
            boards[b].tail_unmark(req);
            let Some(e) = hedges.get_mut(&req) else { return };
            e.copies = e.copies.saturating_sub(1);
            if e.copies == 0 {
                let entry = hedges.remove(&req).expect("entry");
                if entry.winner.is_none() {
                    // Both copies died unserved: back to the front
                    // tier's deadline-aware retry (jittered backoff
                    // keeps the clock strictly advancing).
                    schedule_or_fail(
                        entry.r,
                        1,
                        now + jittered_backoff_us(0, rng),
                        true,
                        failover,
                        lat1_us[entry.r.model],
                        pend,
                        front,
                    );
                }
            }
        }
    }
}

/// Drain detector samples and diverted hedge outcomes from every
/// board into the tail state.  Returns true when any hedge outcome
/// was applied — the caller re-pumps the touched boards and drains
/// again until the step quiesces.
#[allow(clippy::too_many_arguments)]
fn drain_tail(
    now: f64,
    boards: &mut [BoardSim],
    t: &mut TailState,
    hedges: &mut HashMap<usize, HedgeEntry>,
    failover: bool,
    lat1_us: &[f64],
    pend: &mut Pend,
    front: &mut PerfSnapshot,
    touched: &mut [bool],
    rng: &mut Rng,
) -> bool {
    for b in 0..boards.len() {
        for s in boards[b].tail_take_samples() {
            let v =
                t.note_sample(b, s.pred_us, s.real_us, s.probe, now);
            if v.suspect {
                boards[b].note_suspect(now);
            }
            if v.opened {
                boards[b].note_breaker_open(now);
            }
            if v.closed {
                boards[b].note_breaker_close(now);
            }
        }
    }
    let mut progressed = false;
    for b in 0..boards.len() {
        for o in boards[b].tail_take_outcomes() {
            progressed = true;
            resolve_hedge_outcome(
                b, o, now, boards, hedges, failover, lat1_us, pend,
                front, touched, rng,
            );
        }
    }
    progressed
}

/// The autoscaler's queue-pressure scale-up trigger: standing backlog
/// per replica, inflated by the control window's preemption waste per
/// replica (capacity burned by cancelled batches re-queues as demand
/// the backlog term alone undercounts), against the pressure fraction
/// of one control interval.
pub(crate) fn pressure_signal(
    backlog_us: f64,
    waste_per_replica_us: f64,
    pressure: f64,
    interval_us: f64,
) -> bool {
    backlog_us + waste_per_replica_us > pressure * interval_us
}

/// Collect the boards eligible for a model-`m` request at `now` into
/// `out` (a scratch buffer reused across arrivals — the routing hot
/// path allocates nothing): available ([`Health::avail`]) boards with
/// an active, non-draining replica; falls back to available boards
/// hosting *any* replica of `m` (warming or draining).  When the tail
/// layer is armed, breaker-Open boards are excluded exactly like
/// unavailable ones and Probation boards admit work only while a
/// probe is due ([`TailState::routable`]).  Empty only when every
/// host of `m` is dark — the caller must then park the request on the
/// front tier, never drop it.
fn eligible_boards_into(
    m: usize,
    now: f64,
    replicas: &[Vec<Replica>],
    health: &Health,
    tail: Option<&TailState>,
    out: &mut Vec<usize>,
) {
    out.clear();
    for (b, p) in replicas.iter().enumerate() {
        if health.avail(b)
            && tail.map_or(true, |t| t.routable(b, now))
            && p.iter().any(|r| {
                r.model == m && !r.draining && r.active_from <= now
            })
        {
            out.push(b);
        }
    }
    if out.is_empty() {
        for (b, p) in replicas.iter().enumerate() {
            if health.avail(b)
                && tail.map_or(true, |t| t.routable(b, now))
                && p.iter().any(|r| r.model == m)
            {
                out.push(b);
            }
        }
    }
}

/// Pick the board for one model-`m` arrival from the eligible set.
/// Cost-aware scores come from each board's epoch-cached backlog
/// estimate: only boards whose queues changed since the last route
/// re-price their queued work (lane residuals are O(lanes) and always
/// fresh — they decay with `now`).
fn route(
    policy: RouterPolicy,
    m: usize,
    now: f64,
    boards: &[BoardSim],
    elig: &[usize],
    rr: &mut [usize],
) -> Result<usize> {
    debug_assert!(!elig.is_empty(),
                  "placement invariant lost: model {m} unhosted");
    anyhow::ensure!(!elig.is_empty(),
                    "no board hosts model index {m}");
    Ok(match policy {
        RouterPolicy::RoundRobin => {
            let b = elig[rr[m] % elig.len()];
            rr[m] += 1;
            b
        }
        RouterPolicy::JoinShortestQueue => *elig
            .iter()
            .min_by_key(|&&b| (boards[b].total_queued(), b))
            .unwrap(),
        RouterPolicy::CostAware => {
            let mut best = elig[0];
            let mut best_score = f64::INFINITY;
            for &b in elig {
                let score = boards[b].backlog_residual_us(now);
                if score < best_score {
                    best = b;
                    best_score = score;
                }
            }
            best
        }
    })
}

/// One autoscaler control step: retire drained replicas, window the
/// per-model signals, and scale up/down with hysteresis.
#[allow(clippy::too_many_arguments)]
fn autoscale_tick(
    now: f64,
    auto: &AutoscalePolicy,
    eff_cost_us: &[f64],
    boards: &mut [BoardSim],
    replicas: &mut [Vec<Replica>],
    health: &Health,
    tail: Option<&TailState>,
    state: &mut AutoState,
    events: &mut Vec<ScaleEvent>,
    timeline: &mut Vec<ReplicaSample>,
) {
    let nm = eff_cost_us.len();
    let nb = boards.len();
    // Retire draining replicas whose queues have emptied.
    for (b, plist) in replicas.iter_mut().enumerate() {
        plist.retain(|r| !(r.draining && boards[b].queue_len(r.model) == 0));
    }
    let counts = count_active(replicas, nm);
    // Preemption waste accrued since the last control tick, per
    // board.  Cancelled-batch work re-queues as demand, so a board
    // bleeding capacity to preemption is under more pressure than its
    // backlog alone shows (ROADMAP follow-up).  Preempt-off runs see
    // an all-zero delta — the signal is byte-inert there.
    let mut dw = vec![0.0; nb];
    for b in 0..nb {
        let w = boards[b].snapshot().preempt_waste_us;
        dw[b] = (w - state.prev_waste[b]).max(0.0);
        state.prev_waste[b] = w;
    }
    let max_per_model = if auto.max_per_model == 0 {
        nb
    } else {
        auto.max_per_model
    };
    for m in 0..nm {
        let offered: u64 = boards
            .iter()
            .map(|b| b.snapshot().per_model[m].offered)
            .sum();
        let met: u64 = boards
            .iter()
            .map(|b| b.snapshot().per_model[m].met)
            .sum();
        let d_off = offered - state.prev_offered[m];
        let d_met = met - state.prev_met[m];
        state.prev_offered[m] = offered;
        state.prev_met[m] = met;
        let attainment = if d_off > 0 {
            d_met as f64 / d_off as f64
        } else {
            1.0
        };
        let eff_cost = eff_cost_us[m];
        // Queue pressure: standing backlog (us of work per replica) —
        // the predictive scale-up signal.
        let queued: usize =
            boards.iter().map(|b| b.queue_len(m)).sum();
        let backlog_us =
            queued as f64 * eff_cost / counts[m].max(1) as f64;
        let waste_us: f64 = (0..nb)
            .filter(|&b| {
                replicas[b]
                    .iter()
                    .any(|r| r.model == m && !r.draining)
            })
            .map(|b| dw[b])
            .sum();
        let pressured = pressure_signal(
            backlog_us,
            waste_us / counts[m].max(1) as f64,
            auto.pressure,
            auto.interval_us,
        );

        // Scale up: unhealthy window or standing pressure.  The streak
        // is not reset after acting — while the signal persists the
        // fleet adds one replica per tick (fast ramp); it resets only
        // when the signal clears.
        if (d_off > 0 && attainment < auto.up_attainment) || pressured {
            state.up_streak[m] += 1;
        } else {
            state.up_streak[m] = 0;
        }
        let total_reps = replicas
            .iter()
            .flat_map(|p| p.iter())
            .filter(|r| r.model == m)
            .count();
        if state.up_streak[m] >= auto.hysteresis {
            // Cheapest capacity first: a still-warm draining replica is
            // reclaimed by cancelling its drain — no warm-up to pay.
            let undrain = (0..nb).find(|&b| {
                health.avail(b)
                    && tail.map_or(true, |t| t.routable(b, now))
                    && replicas[b]
                        .iter()
                        .any(|r| r.model == m && r.draining)
            });
            if let Some(b) = undrain {
                if let Some(r) = replicas[b]
                    .iter_mut()
                    .find(|r| r.model == m && r.draining)
                {
                    r.draining = false;
                }
                boards[b].trace_scale(now, m, true);
                events.push(ScaleEvent {
                    t_us: now,
                    model: m,
                    board: b,
                    up: true,
                });
            } else if total_reps < max_per_model {
                // Otherwise warm a fresh replica on the least-loaded
                // board (by *current* standing work, the same signal
                // the cost-aware router uses) without one.
                // Downtime is lost capacity: a down or fully-degraded
                // board is never a warm-up target (the replica could
                // not serve), so the capacity lands on survivors.
                let mut target: Option<(usize, f64)> = None;
                for b in 0..nb {
                    // Breaker-Open boards are masked from placement
                    // exactly like quarantined ones: warming capacity
                    // onto a gray-failing board would strand it.
                    if !health.avail(b)
                        || !tail.map_or(true, |t| t.routable(b, now))
                        || replicas[b].iter().any(|r| r.model == m)
                    {
                        continue;
                    }
                    let load_b = boards[b].backlog_residual_us(now);
                    if target.map_or(true, |(_, best)| load_b < best) {
                        target = Some((b, load_b));
                    }
                }
                if let Some((b, _)) = target {
                    // The replica serves once its warm-up completes —
                    // which may start late if the board's GPU lanes
                    // are busy.
                    let ready =
                        boards[b].charge_warmup(now, auto.warmup_us);
                    replicas[b].push(Replica {
                        model: m,
                        active_from: ready,
                        draining: false,
                    });
                    boards[b].trace_scale(now, m, true);
                    events.push(ScaleEvent {
                        t_us: now,
                        model: m,
                        board: b,
                        up: true,
                    });
                }
            }
        }

        // Scale down: healthy, lightly loaded AND no standing backlog
        // (`!pressured` keeps the up and down branches mutually
        // exclusive — a backlogged-but-quiet window must not drain)
        // for `hysteresis` consecutive ticks.  Never drains the last
        // replica.
        let load = d_off as f64 * eff_cost
            / (auto.interval_us * counts[m].max(1) as f64);
        if counts[m] > 1
            && attainment >= auto.up_attainment
            && load < auto.down_load
            && !pressured
        {
            state.down_streak[m] += 1;
        } else {
            state.down_streak[m] = 0;
        }
        if state.down_streak[m] >= auto.hysteresis && counts[m] > 1 {
            // Victim preference: a still-warming replica first (no
            // traffic routes to it yet, so no serving capacity is
            // disturbed — its already-charged warm-up lane time is a
            // sunk cost either way); otherwise the *serving* board
            // with the fewest queued requests of m (fastest
            // retirement) — but never the last serving replica.
            let warming = (0..nb).find(|&b| {
                replicas[b].iter().any(|r| {
                    r.model == m && !r.draining && r.active_from > now
                })
            });
            let target = warming.or_else(|| {
                let serving: Vec<usize> = (0..nb)
                    .filter(|&b| {
                        replicas[b].iter().any(|r| {
                            r.model == m
                                && !r.draining
                                && r.active_from <= now
                        })
                    })
                    .collect();
                if serving.len() > 1 {
                    serving
                        .into_iter()
                        .min_by_key(|&b| (boards[b].queue_len(m), b))
                } else {
                    None
                }
            });
            if let Some(b) = target {
                // A board hosts at most one replica per model, so this
                // finds exactly the chosen victim.
                if let Some(r) = replicas[b]
                    .iter_mut()
                    .find(|r| r.model == m && !r.draining)
                {
                    r.draining = true;
                }
                boards[b].trace_scale(now, m, false);
                events.push(ScaleEvent {
                    t_us: now,
                    model: m,
                    board: b,
                    up: false,
                });
            }
        }
    }
    timeline.push(ReplicaSample {
        t_us: now,
        per_model: count_active(replicas, nm),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_policy_parses_and_names() {
        for (s, p) in [
            ("round-robin", RouterPolicy::RoundRobin),
            ("rr", RouterPolicy::RoundRobin),
            ("jsq", RouterPolicy::JoinShortestQueue),
            ("join-shortest-queue", RouterPolicy::JoinShortestQueue),
            ("cost-aware", RouterPolicy::CostAware),
        ] {
            assert_eq!(RouterPolicy::parse(s), Some(p));
        }
        assert_eq!(RouterPolicy::parse("nope"), None);
        for p in [
            RouterPolicy::RoundRobin,
            RouterPolicy::JoinShortestQueue,
            RouterPolicy::CostAware,
        ] {
            assert_eq!(RouterPolicy::parse(p.name()), Some(p));
        }
    }

    #[test]
    fn spread_placement_covers_every_model() {
        let p = spread_placement(4, &[1, 2, 4]);
        assert_eq!(p.len(), 4);
        // model 0 on board 0; model 1 on boards 1,2; model 2 on all.
        assert_eq!(p[0], vec![0, 2]);
        assert_eq!(p[1], vec![1, 2]);
        assert_eq!(p[2], vec![1, 2]);
        assert_eq!(p[3], vec![2]);
        // zero-replica requests still land one replica
        let q = spread_placement(2, &[0]);
        assert_eq!(q.iter().flatten().count(), 1);
        // replica counts above the board count are clamped
        let r = spread_placement(2, &[5]);
        assert_eq!(r.iter().flatten().count(), 2);
    }

    #[test]
    fn fleet_options_defaults_are_well_formed() {
        let o = FleetOptions::new(3, 2);
        assert_eq!(o.placement.len(), 3);
        assert_eq!(o.router, RouterPolicy::CostAware);
        assert!(o.autoscale.is_none());
        assert!(o.power.is_none(), "energy accounting must be opt-in");
        assert!(o.faults.is_none(), "fault injection must be opt-in");
        assert!(o.failover, "failover must default on");
        assert_eq!(o.policy, ClusterPolicy::SparsityAware);
        assert!(!o.tail.enabled(), "tail tolerance must be opt-in");
        assert_eq!(o.tail, TailPolicy::OFF);
        let covered: Vec<usize> =
            o.placement.iter().flatten().copied().collect();
        assert!(covered.contains(&0) && covered.contains(&1));
        let a = AutoscalePolicy::default();
        assert!(a.hysteresis >= 1 && a.interval_us > 0.0);
        assert!(a.down_load < a.up_attainment);
    }

    /// ROADMAP follow-up: preemption waste feeds the scale-up
    /// pressure signal.  A backlog below the threshold on its own
    /// must cross it once the control window's per-replica waste is
    /// added — and a quiet board must stay quiet.
    #[test]
    fn preempt_waste_inflates_scale_up_pressure() {
        // Threshold: 0.6 * 50ms = 30ms of standing work.
        assert!(!pressure_signal(25_000.0, 0.0, 0.6, 50_000.0));
        assert!(pressure_signal(25_000.0, 10_000.0, 0.6, 50_000.0));
        assert!(!pressure_signal(0.0, 0.0, 0.6, 50_000.0));
    }
}
