//! Event-driven virtual-time cluster scheduler: co-schedules CPU/GPU
//! capacity *across* models.
//!
//! This is the dynamic tier of a Sparse-DySta-style two-tier design.
//! The static tier is per-model and offline: each registered model
//! carries its SparOA schedule (GPU-leaning hybrid), a CPU-fallback
//! projection, and Algorithm-2 batch caps for both ([`ModelRegistry`]).
//! The dynamic tier runs at dispatch time: whenever queued work exists,
//! it scores every (model, processor) placement by the deadline-weighted
//! value of the batch it could run — how many queued requests would
//! finish inside their SLO, weighted by class — with the paper's
//! sparsity/intensity signals as placement tie-breaks (sparse models
//! tolerate the CPU, dense-heavy models want the GPU; most of that
//! signal already lives in the calibrated per-placement latencies).
//!
//! Resource model: two lanes (CPU, GPU).  A dispatched batch occupies
//! exactly one lane for its full makespan — the lane its schedule
//! primarily targets — so a hybrid schedule's minority-device time is
//! folded into its lane occupancy.  That keeps the event loop exact and
//! errs conservative (slightly over-serializing each lane).
//!
//! [`ClusterPolicy::StaticSplit`] is the ablation baseline the paper's
//! serving claim is judged against: each model is pinned to one
//! processor up front (every model on the GPU except the one with the
//! cheapest CPU latency), requests drain FIFO with no class ordering and
//! no expiry shedding — i.e. N independent single-queue batchers on a
//! static capacity split.

use crate::device::Proc;
use crate::serve::registry::ModelRegistry;
use crate::serve::report::PerfSnapshot;
use crate::serve::slo::{AdmissionQueues, ShedPolicy, SloClass};
use crate::serve::workload::{Arrival, Tenant};
use anyhow::Result;

/// Cross-model scheduling discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPolicy {
    /// SLO- and sparsity-aware dynamic co-scheduling (the SparOA tier).
    SparsityAware,
    /// Per-model static processor pinning + FIFO (the baseline).
    StaticSplit,
}

impl ClusterPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ClusterPolicy::SparsityAware => "cluster",
            ClusterPolicy::StaticSplit => "static-split",
        }
    }
}

/// Knobs for one cluster run.
#[derive(Debug, Clone, Copy)]
pub struct ClusterOptions {
    pub policy: ClusterPolicy,
    pub shed: ShedPolicy,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            policy: ClusterPolicy::SparsityAware,
            shed: ShedPolicy::ShedLowestClass,
        }
    }
}

fn lane(p: Proc) -> usize {
    match p {
        Proc::Cpu => 0,
        Proc::Gpu => 1,
    }
}

/// Serve a merged multi-tenant arrival stream and report per-class /
/// per-model outcomes.  Everything runs in virtual time through each
/// session's execution backend (the latency oracle is
/// [`crate::api::Session::probe`], cached per (model, placement,
/// batch)).
pub fn run_cluster(
    registry: &ModelRegistry,
    classes: &[SloClass],
    tenants: &[Tenant],
    arrivals: &[Arrival],
    opts: &ClusterOptions,
) -> Result<PerfSnapshot> {
    anyhow::ensure!(!registry.is_empty(), "registry holds no models");
    anyhow::ensure!(!classes.is_empty(), "no SLO classes configured");
    let model_of: Vec<usize> = tenants
        .iter()
        .map(|t| registry.index_of(&t.model))
        .collect::<Result<_>>()?;
    for t in tenants {
        anyhow::ensure!(
            t.class < classes.len(),
            "tenant `{}` references SLO class {} of {}",
            t.name, t.class, classes.len()
        );
    }
    anyhow::ensure!(
        arrivals.windows(2).all(|w| w[0].at_us <= w[1].at_us),
        "arrivals must be time-sorted (use serve::merge_arrivals)"
    );

    let nm = registry.len();
    let class_labels: Vec<String> =
        classes.iter().map(|c| c.name.clone()).collect();
    let model_labels: Vec<String> = registry
        .entries()
        .iter()
        .map(|e| e.name.clone())
        .collect();
    let mut snap = PerfSnapshot::new(
        opts.policy.name(),
        opts.shed.name(),
        &class_labels,
        &model_labels,
    );

    // Latency oracle: memoized per (model, placement, batch) *inside the
    // registry entries* ([`crate::serve::registry::ModelEntry::latency_us`]),
    // so identical configurations are simulated once per registry
    // lifetime — not once per `run_cluster` call.
    let lat_of = |m: usize, p: Proc, b: usize| -> Result<f64> {
        registry.get(m).latency_us(p, b)
    };

    // Static split: pin every model to the GPU except the one that runs
    // cheapest on the CPU (with >= 2 models both processors stay used).
    let static_lane: Vec<Proc> = if opts.policy
        == ClusterPolicy::StaticSplit
    {
        let mut lanes = vec![Proc::Gpu; nm];
        if nm >= 2 {
            let mut best = 0usize;
            let mut best_lat = f64::INFINITY;
            for m in 0..nm {
                let l = lat_of(m, Proc::Cpu, 1)?;
                if l < best_lat {
                    best = m;
                    best_lat = l;
                }
            }
            lanes[best] = Proc::Cpu;
        }
        lanes
    } else {
        Vec::new()
    };

    let sparsity_aware = opts.policy == ClusterPolicy::SparsityAware;
    let mut q = AdmissionQueues::new(classes, opts.shed, nm);
    // Debug builds (and therefore `cargo test`) verify settlement at the
    // request-id level: every request leaves the system exactly once —
    // served or shed, never both, never twice.
    #[cfg(debug_assertions)]
    let mut settled: std::collections::HashSet<usize> =
        std::collections::HashSet::with_capacity(arrivals.len());
    let mut shed_seen = 0usize;
    let mut free = [0.0f64; 2];
    let mut busy = [0.0f64; 2];
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut last_finish = 0.0f64;

    loop {
        // Ingest everything that has arrived by `now`.
        while ai < arrivals.len() && arrivals[ai].at_us <= now {
            let a = arrivals[ai];
            ai += 1;
            let m = model_of[a.tenant];
            snap.record_offered(tenants[a.tenant].class, m);
            q.offer(a.req, a.tenant, m, tenants[a.tenant].class, a.at_us);
        }
        // The dynamic tier refuses to burn capacity on doomed requests.
        if sparsity_aware {
            q.drop_expired(now);
        }
        while shed_seen < q.shed.len() {
            let s = q.shed[shed_seen];
            shed_seen += 1;
            #[cfg(debug_assertions)]
            debug_assert!(settled.insert(s.req),
                          "request {} settled twice (shed)", s.req);
            snap.record_shed(s.class, model_of[s.tenant], s.at_admission);
        }

        if q.total_queued() == 0 {
            if ai >= arrivals.len() {
                break;
            }
            now = arrivals[ai].at_us;
            continue;
        }

        // Score every feasible (model, placement, batch) dispatch
        // option.  Only lanes free *now* are dispatchable — queued work
        // accumulates while a lane is busy, which is what lets the
        // dispatcher re-order by class/deadline and right-size batches
        // (a scheduler that commits arrivals to future slots one by one
        // degenerates into FIFO).  Busy-lane options are still scored:
        // they tell the wait heuristic whether patience would save
        // deadlines that an immediate doomed dispatch would burn.
        struct Candidate {
            m: usize,
            proc: Proc,
            b: usize,
            start: f64,
            finish: f64,
            score: f64,
            met_w: f64,
        }
        let mut best_now: Option<Candidate> = None;
        let mut best_any: Option<Candidate> = None;
        let mut next_free = f64::INFINITY;
        for m in 0..nm {
            let qlen = q.queue_len(m);
            if qlen == 0 {
                continue;
            }
            let entry = registry.get(m);
            let sorted = q.sorted_queue(m);
            let head_arrival = sorted
                .iter()
                .map(|r| r.arrival_us)
                .fold(f64::INFINITY, f64::min);
            let both = [Proc::Cpu, Proc::Gpu];
            let procs: &[Proc] = if sparsity_aware {
                &both
            } else {
                std::slice::from_ref(&static_lane[m])
            };
            for &proc in procs {
                let lane_free = free[lane(proc)];
                if lane_free > now {
                    next_free = next_free.min(lane_free);
                }
                let cap = entry.batch_cap(proc).max(1);
                let start = now.max(lane_free);
                // Candidate batch sizes: powers of two up to the Alg. 2
                // cap, plus "everything queued".  Batch latency grows
                // with size, so right-sizing is what keeps tight
                // deadlines servable under backlog (the static baseline
                // always drains min(queue, cap), like the single-model
                // batcher it stands in for).
                let mut sizes: Vec<usize> = Vec::new();
                if sparsity_aware {
                    let mut b = 1usize;
                    while b < cap.min(qlen) {
                        sizes.push(b);
                        b *= 2;
                    }
                }
                sizes.push(qlen.min(cap));
                for &b in &sizes {
                    let l = lat_of(m, proc, b)?;
                    let finish = start + l;
                    let met_w: f64 = sorted
                        .iter()
                        .take(b)
                        .filter(|r| r.deadline_us >= finish)
                        .map(|r| classes[r.class].weight)
                        .sum();
                    let score = if sparsity_aware {
                        // Primary: deadline-weighted value of the batch
                        // (class weights are >= 1, so one met deadline
                        // outranks every secondary term).  Secondary:
                        // drain rate — when every option is doomed the
                        // scheduler degrades to throughput mode instead
                        // of thrashing on size-1 batches.  The Fig. 2
                        // signals and earlier finishes break ties.
                        let drain =
                            (10.0 * b as f64 / l.max(1.0)).min(0.9);
                        let affinity = match proc {
                            Proc::Cpu => entry.sparsity,
                            Proc::Gpu => entry.intensity,
                        };
                        met_w + drain + 0.01 * affinity - 1e-9 * finish
                    } else {
                        // FIFO across the lane's models: oldest head
                        // wins.
                        -head_arrival - 1e-9 * finish
                    };
                    let cand = || Candidate {
                        m, proc, b, start, finish, score, met_w,
                    };
                    if lane_free <= now
                        && best_now
                            .as_ref()
                            .map_or(true, |c| score > c.score)
                    {
                        best_now = Some(cand());
                    }
                    if best_any
                        .as_ref()
                        .map_or(true, |c| score > c.score)
                    {
                        best_any = Some(cand());
                    }
                }
            }
        }

        // Wait instead of dispatching when nothing is dispatchable now,
        // or when everything dispatchable now is doomed while a busy
        // lane could still meet deadlines once it frees (don't shred
        // requests on an idle-but-hopeless processor).
        let wait = match (&best_now, &best_any) {
            (None, _) => true,
            (Some(bn), Some(ba)) => {
                sparsity_aware
                    && bn.met_w <= 0.0
                    && ba.met_w > 0.0
                    && ba.start > now
            }
            _ => false,
        };
        if wait {
            let mut t = next_free;
            if ai < arrivals.len() {
                t = t.min(arrivals[ai].at_us);
            }
            debug_assert!(t.is_finite() && t > now,
                          "wait must advance virtual time");
            now = t;
            continue;
        }

        let c = best_now.expect("non-wait iterations dispatch");
        let taken = q.take_batch(c.m, c.b, sparsity_aware);
        debug_assert!(!taken.is_empty());
        free[lane(c.proc)] = c.finish;
        busy[lane(c.proc)] += c.finish - c.start;
        last_finish = last_finish.max(c.finish);
        snap.n_batches += 1;
        snap.dispatched += taken.len() as u64;
        for r in &taken {
            let latency = c.finish - r.arrival_us;
            #[cfg(debug_assertions)]
            debug_assert!(settled.insert(r.req),
                          "request {} settled twice (served)", r.req);
            snap.record_served(
                r.class,
                r.model,
                latency,
                c.finish <= r.deadline_us,
            );
        }
    }

    #[cfg(debug_assertions)]
    debug_assert_eq!(
        settled.len() as u64,
        snap.total_served() + snap.total_shed(),
        "settlement accounting drifted"
    );
    snap.makespan_us = last_finish.max(now);
    snap.cpu_busy_us = busy[0];
    snap.gpu_busy_us = busy[1];
    Ok(snap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::graph::ModelGraph;
    use crate::serve::workload::merge_arrivals;
    use crate::serve::workload::ArrivalPattern;

    fn registry() -> ModelRegistry {
        let dev = crate::bench_support::device_profile("agx_orin");
        let mut reg = ModelRegistry::new();
        for (name, blocks, scale, sparsity) in [
            ("heavy", 6, 6.0, 0.1),
            ("light", 4, 0.3, 0.75),
        ] {
            let s = SessionBuilder::new()
                .with_graph(ModelGraph::synthetic(
                    name, blocks, scale, sparsity))
                .with_device(dev.clone())
                .policy("greedy")
                .build()
                .unwrap();
            reg.register(s).unwrap();
        }
        reg
    }

    fn classes() -> Vec<SloClass> {
        vec![
            SloClass::new("interactive", 30_000.0, 64, 4.0),
            SloClass::new("batch", 200_000.0, 256, 1.0),
        ]
    }

    #[test]
    fn light_load_meets_slos_and_conserves_requests() {
        let reg = registry();
        let cls = classes();
        let tenants = vec![
            Tenant {
                name: "t-heavy".into(),
                model: "heavy".into(),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 30.0,
                    n: 150,
                },
            },
            Tenant {
                name: "t-light".into(),
                model: "light".into(),
                class: 1,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 60.0,
                    n: 150,
                },
            },
        ];
        let arrivals = merge_arrivals(&tenants, 11);
        let snap = run_cluster(&reg, &cls, &tenants, &arrivals,
                               &ClusterOptions::default())
            .unwrap();
        assert_eq!(snap.total_offered(), 300);
        assert_eq!(snap.total_served() + snap.total_shed(), 300);
        assert!(snap.aggregate_attainment() > 0.9,
                "light load attainment {}", snap.aggregate_attainment());
        assert!(snap.makespan_us > 0.0);
        assert!(snap.gpu_busy_us > 0.0);
    }

    #[test]
    fn unknown_model_or_class_is_rejected() {
        let reg = registry();
        let cls = classes();
        let bad_model = vec![Tenant {
            name: "x".into(),
            model: "nope".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson { rate_per_s: 1.0, n: 1 },
        }];
        assert!(run_cluster(&reg, &cls, &bad_model, &[],
                            &ClusterOptions::default())
            .is_err());
        let bad_class = vec![Tenant {
            name: "x".into(),
            model: "heavy".into(),
            class: 9,
            pattern: ArrivalPattern::Poisson { rate_per_s: 1.0, n: 1 },
        }];
        assert!(run_cluster(&reg, &cls, &bad_class, &[],
                            &ClusterOptions::default())
            .is_err());
        // Hand-built arrival streams must be time-sorted.
        let ok_tenant = vec![Tenant {
            name: "x".into(),
            model: "heavy".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson { rate_per_s: 1.0, n: 2 },
        }];
        let unsorted = vec![
            Arrival { req: 0, tenant: 0, at_us: 100.0 },
            Arrival { req: 1, tenant: 0, at_us: 50.0 },
        ];
        assert!(run_cluster(&reg, &cls, &ok_tenant, &unsorted,
                            &ClusterOptions::default())
            .is_err());
    }

    #[test]
    fn static_split_pins_one_model_per_processor() {
        let reg = registry();
        let cls = classes();
        let tenants = vec![
            Tenant {
                name: "t-heavy".into(),
                model: "heavy".into(),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 50.0,
                    n: 120,
                },
            },
            Tenant {
                name: "t-light".into(),
                model: "light".into(),
                class: 1,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 200.0,
                    n: 240,
                },
            },
        ];
        let arrivals = merge_arrivals(&tenants, 13);
        let snap = run_cluster(&reg, &cls, &tenants, &arrivals,
            &ClusterOptions {
                policy: ClusterPolicy::StaticSplit,
                shed: ShedPolicy::RejectNew,
            })
            .unwrap();
        // light (cheapest on CPU) pinned to CPU, heavy to GPU: both
        // processors accumulate busy time.
        assert!(snap.cpu_busy_us > 0.0);
        assert!(snap.gpu_busy_us > 0.0);
        assert_eq!(snap.policy, "static-split");
        assert_eq!(snap.total_served() + snap.total_shed(),
                   snap.total_offered());
    }
}
