//! Event-driven virtual-time cluster scheduler: co-schedules CPU/GPU
//! capacity *across* models on one board.
//!
//! This is the dynamic tier of a Sparse-DySta-style two-tier design.
//! The static tier is per-model and offline: each registered model
//! carries its SparOA schedule (GPU-leaning hybrid), a CPU-fallback
//! projection, and Algorithm-2 batch caps for both ([`ModelRegistry`]).
//! The dynamic tier runs at dispatch time: whenever queued work exists,
//! it scores every (model, processor) placement by the deadline-weighted
//! value of the batch it could run — how many queued requests would
//! finish inside their SLO, weighted by class — with the paper's
//! sparsity/intensity signals as placement tie-breaks (sparse models
//! tolerate the CPU, dense-heavy models want the GPU; most of that
//! signal already lives in the calibrated per-placement latencies).
//!
//! Resource model: a [`LaneMatrix`] of independent execution lanes
//! (`run_cluster` uses the classic two-lane CPU+GPU board,
//! [`LaneMatrix::duo`]; the fleet tier gives each board an arbitrary
//! lane mix).  A dispatched batch occupies exactly one lane for its
//! full makespan — the lane its schedule primarily targets — so a
//! hybrid schedule's minority-device time is folded into its lane
//! occupancy.  That keeps the event loop exact and errs conservative
//! (slightly over-serializing each lane).
//!
//! The loop itself lives in `BoardSim` (crate-internal), the
//! single-board scheduling engine: [`run_cluster`] drives one instance
//! over an arrival stream; [`crate::serve::fleet::run_fleet`] drives N
//! of them behind a router.
//!
//! [`ClusterPolicy::StaticSplit`] is the ablation baseline the paper's
//! serving claim is judged against: each model is pinned to one
//! processor up front (every model on the GPU except the one with the
//! cheapest CPU latency), requests drain FIFO with no class ordering and
//! no expiry shedding — i.e. N independent single-queue batchers on a
//! static capacity split.

use crate::device::Proc;
use crate::power::{BoardPower, PowerConfig};
use crate::serve::registry::ModelRegistry;
use crate::serve::report::PerfSnapshot;
use crate::serve::slo::{AdmissionQueues, QueuedReq, ShedPolicy, SloClass};
use crate::serve::workload::{Arrival, Tenant};
use anyhow::Result;
use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Cross-model scheduling discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPolicy {
    /// SLO- and sparsity-aware dynamic co-scheduling (the SparOA tier).
    SparsityAware,
    /// Per-model static processor pinning + FIFO (the baseline).
    StaticSplit,
}

impl ClusterPolicy {
    /// Report label ("cluster" / "static-split").
    pub fn name(self) -> &'static str {
        match self {
            ClusterPolicy::SparsityAware => "cluster",
            ClusterPolicy::StaticSplit => "static-split",
        }
    }
}

/// Knobs for one cluster run.
#[derive(Debug, Clone, Copy)]
pub struct ClusterOptions {
    /// Cross-model scheduling discipline.
    pub policy: ClusterPolicy,
    /// What admission control does when a queue budget fills.
    pub shed: ShedPolicy,
    /// `Some` enables the virtual-time profiler: the board records
    /// [`crate::obs::TraceEvent`]s into a bounded buffer and seals
    /// exact phase accumulators into `PerfSnapshot::phases`.  `None`
    /// (the default) costs one predictable branch per event site.
    pub trace: Option<crate::obs::TraceConfig>,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            policy: ClusterPolicy::SparsityAware,
            shed: ShedPolicy::ShedLowestClass,
            trace: None,
        }
    }
}

/// Preemption / work re-placement policy for the serving tier
/// (installed per run via `FleetOptions::preempt` or the
/// `serve-fleet --preempt=POLICY` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptionPolicy {
    /// Never preempt: dispatched batches always run to completion.
    /// Byte-identical output to the pre-preemption scheduler — the
    /// default.
    #[default]
    Off,
    /// A board may cancel an in-flight strictly-lower-class batch when
    /// a queued higher-class request's deadline would otherwise burn
    /// waiting for a lane: the lane's unexecuted tail and its
    /// committed energy are refunded from the cancel instant
    /// (microseconds of virtual time) and the batch's requests
    /// re-queued with their original arrival/deadline preserved.
    DeadlineBurn,
    /// [`PreemptionPolicy::DeadlineBurn`] plus fleet-level work
    /// stealing: queued (never dispatched) work stalled behind a
    /// long-running batch is re-placed onto idle or cheaper boards,
    /// scored through the router's cost-aware price tables.
    BurnPlusSteal,
}

impl PreemptionPolicy {
    /// Parse a CLI/config spelling: `off`, `deadline-burn`,
    /// `burn-steal` / `burn-plus-steal`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(PreemptionPolicy::Off),
            "deadline-burn" => Some(PreemptionPolicy::DeadlineBurn),
            "burn-steal" | "burn-plus-steal" => {
                Some(PreemptionPolicy::BurnPlusSteal)
            }
            _ => None,
        }
    }

    /// Canonical spelling (accepted back by
    /// [`PreemptionPolicy::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            PreemptionPolicy::Off => "off",
            PreemptionPolicy::DeadlineBurn => "deadline-burn",
            PreemptionPolicy::BurnPlusSteal => "burn-plus-steal",
        }
    }

    /// Whether board-level deadline-burn preemption is armed.
    pub fn preempts(self) -> bool {
        self != PreemptionPolicy::Off
    }

    /// Whether fleet-level work stealing is armed.
    pub fn steals(self) -> bool {
        self == PreemptionPolicy::BurnPlusSteal
    }
}

/// How many independent execution lanes of each processor type a board
/// exposes.  The classic SparOA board is [`LaneMatrix::duo`] (one CPU
/// lane + one GPU lane); multi-accelerator boards widen either side.
/// A lane serves one dispatched batch at a time for its full makespan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneMatrix {
    /// Number of CPU lanes (>= 1).
    pub cpu: usize,
    /// Number of GPU lanes (>= 1).
    pub gpu: usize,
}

impl LaneMatrix {
    /// The single CPU + single GPU board `run_cluster` models.
    pub fn duo() -> Self {
        LaneMatrix { cpu: 1, gpu: 1 }
    }

    /// A board with `cpu` CPU lanes and `gpu` GPU lanes (both clamped
    /// to >= 1 so every placement stays feasible).
    pub fn new(cpu: usize, gpu: usize) -> Self {
        LaneMatrix { cpu: cpu.max(1), gpu: gpu.max(1) }
    }

    /// Total lane count.
    pub fn total(&self) -> usize {
        self.cpu + self.gpu
    }
}

/// Mutable lane occupancy for one board: per-lane free-at time and
/// accumulated busy time, both microseconds of virtual time, plus a
/// min-heap of pending lane-free events so the dispatch loop's "when
/// does the next busy lane free" question is a heap peek, not a scan.
#[derive(Debug, Clone)]
struct LaneState {
    procs: Vec<Proc>,
    free: Vec<f64>,
    busy: Vec<f64>,
    /// Pending lane-free events as (free-at bit pattern, lane), lazily
    /// invalidated: an entry is live iff its time still equals
    /// `free[lane]`.  Free times are non-negative, so the IEEE bit
    /// pattern orders exactly like the float.
    events: BinaryHeap<Reverse<(u64, usize)>>,
}

impl LaneState {
    fn new(m: LaneMatrix) -> Self {
        let mut procs = vec![Proc::Cpu; m.cpu.max(1)];
        procs.extend(vec![Proc::Gpu; m.gpu.max(1)]);
        let n = procs.len();
        LaneState {
            procs,
            free: vec![0.0; n],
            busy: vec![0.0; n],
            events: BinaryHeap::new(),
        }
    }

    /// Earliest-free lane of `proc`: (lane index, free-at time in us).
    fn earliest(&self, proc: Proc) -> (usize, f64) {
        let mut best = usize::MAX;
        let mut best_t = f64::INFINITY;
        for (i, &p) in self.procs.iter().enumerate() {
            if p == proc && self.free[i] < best_t {
                best = i;
                best_t = self.free[i];
            }
        }
        debug_assert!(best != usize::MAX, "no {proc:?} lane configured");
        (best, best_t)
    }

    fn occupy(&mut self, lane: usize, start_us: f64, finish_us: f64) {
        self.free[lane] = finish_us;
        self.busy[lane] += finish_us - start_us;
        self.events.push(Reverse((finish_us.to_bits(), lane)));
        // Lazy invalidation leaves one stale entry per overwrite, and
        // entries only drain on the wait branch — compact by rebuilding
        // from the live lane states once the debris outgrows a small
        // multiple of the lane count (amortized O(log lanes) per
        // occupy, bounded memory over any run length).
        if self.events.len() > 4 * self.free.len().max(1) {
            self.events.clear();
            for (l, &f) in self.free.iter().enumerate() {
                self.events.push(Reverse((f.to_bits(), l)));
            }
        }
    }

    /// Earliest lane-free event strictly after `now_us`, popping stale
    /// (overwritten or already-past) entries on the way.
    fn next_event_after(&mut self, now_us: f64) -> Option<f64> {
        while let Some(&Reverse((bits, lane))) = self.events.peek() {
            let t = f64::from_bits(bits);
            if self.free[lane].to_bits() != bits || t <= now_us {
                self.events.pop();
                continue;
            }
            return Some(t);
        }
        None
    }

    fn busy_us(&self, proc: Proc) -> f64 {
        self.procs
            .iter()
            .zip(&self.busy)
            .filter(|(&p, _)| p == proc)
            .map(|(_, &b)| b)
            .sum()
    }
}

/// One board's event-driven scheduler: admission queues, a lane matrix
/// and the dispatch loop of the dynamic tier, packaged so one instance
/// serves [`run_cluster`] and N instances serve
/// [`crate::serve::fleet::run_fleet`].
///
/// Protocol: the driver owns virtual time.  It calls
/// [`BoardSim::offer`] for every arrival with `at_us <= now`, then
/// [`BoardSim::pump`] to let the board dispatch everything worth
/// dispatching at `now`; `pump` returns the board's next wake-up time
/// (a busy lane freeing) or `None` when the board is idle.  The driver
/// advances `now` to the earliest of all boards' wake-ups and the next
/// arrival, and repeats.  [`BoardSim::finish`] seals the run into a
/// [`PerfSnapshot`].
pub(crate) struct BoardSim<'a> {
    registry: &'a ModelRegistry,
    classes: &'a [SloClass],
    sparsity_aware: bool,
    /// StaticSplit only: the processor each model is pinned to.
    static_lane: Vec<Proc>,
    lanes: LaneState,
    q: AdmissionQueues,
    /// Router price table: per-model cheapest batch-1 latency (us),
    /// installed by the fleet driver (`set_price_table`).  Empty on a
    /// plain `run_cluster` board, which never asks for a backlog score.
    price: Vec<f64>,
    /// Bumped on every queue mutation (offer, expiry shed, dispatch);
    /// the router's cached queued-work score re-prices only when this
    /// moves — the fleet's dirty-flag.
    epoch: u64,
    /// (epoch the cached value was computed at, queued work in us).
    work_cache: Cell<(u64, f64)>,
    snap: PerfSnapshot,
    shed_seen: usize,
    last_finish: f64,
    /// Energy-aware boards carry the DVFS governor's runtime state
    /// (`set_power`); `None` boards dispatch at full frequency with no
    /// energy accounting — bit-identical to the pre-power scheduler.
    power: Option<BoardPower>,
    /// The board's profiler (disabled unless `ClusterOptions::trace`).
    /// Purely observational: records and accumulators only, never an
    /// input to any scheduling decision.
    tracer: crate::obs::Tracer,
    /// Fault runtime state (`arm_faults`); `None` boards take no fault
    /// branches and settle dispatches immediately — bit-identical to
    /// the pre-fault scheduler.
    faults: Option<FaultState>,
    /// Voluntary preemption policy (`arm_preemption`); `Off` boards
    /// skip the burn check entirely — bit-identical to the
    /// pre-preemption scheduler.
    preempt: PreemptionPolicy,
    /// Tail-tolerance hooks (`arm_tail`); `None` boards emit no
    /// detector samples and divert nothing — bit-identical to the
    /// pre-tail scheduler.
    tail: Option<BoardTailHooks>,
    #[cfg(debug_assertions)]
    settled: std::collections::HashSet<usize>,
}

/// One scored dispatch option inside the pump loop.
struct Candidate {
    m: usize,
    lane: usize,
    proc: Proc,
    b: usize,
    start: f64,
    finish: f64,
    score: f64,
    met_w: f64,
}

/// A dispatched batch whose settlement is deferred until its finish
/// time (fault-armed boards only): a crash before `finish_us` retracts
/// it — the lane occupancy is rewound, committed energy refunded, and
/// the requests handed back for deadline-aware retry.
struct InflightBatch {
    lane: usize,
    /// Dispatch start, us (virtual time).
    start_us: f64,
    /// Scheduled finish, us (virtual time).
    finish_us: f64,
    /// Lane draw committed for the interval, watts (0 when the board
    /// is not energy-aware).
    busy_w: f64,
    /// DMA share used for the profiler's phase split (0 untraced).
    dma_frac: f64,
    /// Gray-failure detector inputs (tail-armed boards only, else 0):
    /// the pre-thermal base latency the router's price tables are
    /// built from, and the thermally stretched latency actually
    /// scheduled (pre-governor — a DVFS stretch is chosen, not a
    /// failure).  Their ratio is exactly the inflation the price
    /// tables cannot see.
    pred_us: f64,
    real_us: f64,
    /// This batch is a probation probe (first dispatch after the
    /// fleet admitted a probe to this board).
    probe: bool,
    reqs: Vec<QueuedReq>,
}

/// One realized-vs-predicted latency sample from a settled batch on a
/// tail-armed board, drained each fleet iteration into the
/// gray-failure detector ([`crate::serve::tail::TailState`]).
#[derive(Debug, Clone, Copy)]
pub(crate) struct TailSample {
    /// Pre-thermal base latency of the batch, us.
    pub(crate) pred_us: f64,
    /// Thermally stretched (pre-governor) latency, us.
    pub(crate) real_us: f64,
    /// The batch was a probation probe.
    pub(crate) probe: bool,
}

/// Terminal outcome of a hedge-marked request, diverted from the
/// board's settle paths into the tail outbox: the fleet's first-wins
/// reconciliation (not the board) decides which copy settles.
#[derive(Debug, Clone, Copy)]
pub(crate) enum HedgeOutcome {
    /// The copy finished inside a served batch.
    Served {
        /// The request (original identity: arrival/deadline preserved).
        r: QueuedReq,
        /// Batch dispatch start, us.
        start_us: f64,
        /// Batch finish, us.
        finish_us: f64,
        /// Per-request lane-time share of the batch, us.
        share_us: f64,
        /// DMA fraction for the profiler's phase split.
        dma_frac: f64,
    },
    /// The copy died unserved (shed at re-admission or expired in
    /// queue; crash/lane losses are filtered fleet-side instead).
    Dead {
        /// Global request id.
        req: usize,
    },
}

/// Board-side tail-tolerance hooks (`arm_tail`): detector samples,
/// hedge marks and the hedged-outcome outbox.  `None` boards take no
/// tail branches — the byte-identical legacy path.
#[derive(Debug, Default)]
struct BoardTailHooks {
    /// Samples from settled batches, drained by the fleet.
    samples: Vec<TailSample>,
    /// Request ids whose settlement the fleet's hedge reconciliation
    /// owns (both copies of a hedged request are marked).
    marks: std::collections::HashSet<usize>,
    /// Diverted terminal outcomes of marked requests.
    outbox: Vec<HedgeOutcome>,
    /// The next dispatched batch is a probation probe.
    probe_pending: bool,
}

/// Runtime fault state of one board, present only when the fleet armed
/// the board with a non-empty fault plan (`arm_faults`).  Unarmed
/// boards skip every fault branch and settle dispatches immediately —
/// the pre-fault, bit-identical path.
struct FaultState {
    /// Fail-stop down (crashed, not yet rejoined).
    down: bool,
    /// When the current down interval started, us.
    down_since: f64,
    /// CPU lanes lost to a lane fault.
    cpu_down: bool,
    /// GPU lanes lost to a lane fault.
    gpu_down: bool,
    /// Thermal latency multipliers, `[cpu, gpu]` (1.0 = nominal;
    /// applied to base latency *before* the DVFS governor prices it).
    thermal: [f64; 2],
    /// Dispatched, not-yet-settled batches.
    inflight: Vec<InflightBatch>,
}

/// Index into [`FaultState::thermal`] for a processor kind.
fn thermal_idx(p: Proc) -> usize {
    match p {
        Proc::Cpu => 0,
        Proc::Gpu => 1,
    }
}

impl<'a> BoardSim<'a> {
    /// Build a board over `registry`'s models.  `label` names the
    /// board's [`PerfSnapshot`] (e.g. "cluster" or "fleet/board3").
    /// StaticSplit pins every model to the GPU except the one with the
    /// cheapest CPU latency (probing the registry's latency oracle).
    pub(crate) fn new(
        registry: &'a ModelRegistry,
        classes: &'a [SloClass],
        opts: &ClusterOptions,
        lanes: LaneMatrix,
        label: &str,
    ) -> Result<Self> {
        let nm = registry.len();
        let class_labels: Vec<String> =
            classes.iter().map(|c| c.name.clone()).collect();
        let model_labels: Vec<String> = registry
            .entries()
            .iter()
            .map(|e| e.name.clone())
            .collect();
        // Static split: pin every model to the GPU except the one that
        // runs cheapest on the CPU (with >= 2 models both processors
        // stay used).
        let static_lane: Vec<Proc> = if opts.policy
            == ClusterPolicy::StaticSplit
        {
            let mut pins = vec![Proc::Gpu; nm];
            if nm >= 2 {
                let mut best = 0usize;
                let mut best_lat = f64::INFINITY;
                for m in 0..nm {
                    let l = registry.get(m).latency_us(Proc::Cpu, 1)?;
                    if l < best_lat {
                        best = m;
                        best_lat = l;
                    }
                }
                pins[best] = Proc::Cpu;
            }
            pins
        } else {
            Vec::new()
        };
        Ok(BoardSim {
            registry,
            classes,
            sparsity_aware: opts.policy == ClusterPolicy::SparsityAware,
            static_lane,
            lanes: LaneState::new(lanes),
            q: AdmissionQueues::new(classes, opts.shed, nm),
            price: Vec::new(),
            epoch: 1,
            work_cache: Cell::new((0, 0.0)),
            snap: PerfSnapshot::new(
                label,
                opts.shed.name(),
                &class_labels,
                &model_labels,
            ),
            shed_seen: 0,
            last_finish: 0.0,
            power: None,
            tracer: match opts.trace {
                Some(cfg) => crate::obs::Tracer::new(
                    cfg,
                    nm,
                    classes.len(),
                ),
                None => crate::obs::Tracer::disabled(),
            },
            faults: None,
            preempt: PreemptionPolicy::Off,
            tail: None,
            #[cfg(debug_assertions)]
            settled: std::collections::HashSet::new(),
        })
    }

    /// Make this board energy-aware: install the DVFS governor, ladders
    /// and (optional) power cap.  Fails when the cap cannot admit the
    /// slowest rung on an otherwise-idle board (such a board could
    /// stall forever with queued work).  Call before the first `pump`.
    pub(crate) fn set_power(&mut self, cfg: &PowerConfig) -> Result<()> {
        self.power = Some(BoardPower::new(cfg, &self.lanes.procs)?);
        Ok(())
    }

    /// Offer one arriving request to admission control and record it as
    /// offered in the board's snapshot.  `now_us` is virtual time.
    pub(crate) fn offer(&mut self, req: usize, tenant: usize,
                        model: usize, class: usize, now_us: f64) {
        self.snap.record_offered(class, model);
        let admitted_before = self.q.admitted;
        self.q.offer(req, tenant, model, class, now_us);
        // An admission always changes some queue (plain admit, or
        // evict-then-admit under the shed policies); a rejection
        // provably does not — keep the router's priced-work cache warm
        // under overload, when routing is hottest.
        if self.q.admitted != admitted_before {
            self.epoch += 1;
            self.tracer.record(
                now_us,
                model as u32,
                class as u32,
                crate::obs::TraceEvent::Admit,
            );
        }
    }

    /// Record an autoscaler replica event against this board's trace
    /// (`up`: a replica was added / un-drained vs. drain started).
    pub(crate) fn trace_scale(&mut self, t_us: f64, model: usize,
                              up: bool) {
        self.tracer.record(
            t_us,
            model as u32,
            crate::obs::NONE,
            if up {
                crate::obs::TraceEvent::ScaleUp
            } else {
                crate::obs::TraceEvent::ScaleDown
            },
        );
    }

    /// Install the fleet router's per-model price table (cheapest
    /// batch-1 latency, us) backing the cached backlog score.
    pub(crate) fn set_price_table(&mut self, lat1_us: Vec<f64>) {
        debug_assert_eq!(lat1_us.len(), self.registry.len());
        self.price = lat1_us;
    }

    /// Outstanding queued requests across all models.
    pub(crate) fn total_queued(&self) -> usize {
        self.q.total_queued()
    }

    /// Outstanding queued requests for one model.
    pub(crate) fn queue_len(&self, model: usize) -> usize {
        self.q.queue_len(model)
    }

    /// Read-only view of the board's running snapshot (the fleet
    /// autoscaler's per-window attainment signals).
    pub(crate) fn snapshot(&self) -> &PerfSnapshot {
        &self.snap
    }

    /// Estimated microseconds of work standing between a new arrival
    /// and a free lane: in-flight residual (lane free-at times past
    /// `now`, O(lanes) — it decays with `now`, so it is always priced
    /// fresh) plus queued work priced by the installed table (each
    /// model's cheapest batch-1 latency; see `set_price_table`),
    /// averaged over the lane count.  The queued-work term is cached
    /// against the board's mutation epoch, so the cost-aware router
    /// only re-prices boards whose queues actually changed since the
    /// last route.
    pub(crate) fn backlog_residual_us(&self, now_us: f64) -> f64 {
        debug_assert_eq!(self.price.len(), self.registry.len(),
                         "backlog scored before set_price_table");
        let n = self.lanes.procs.len() as f64;
        let resid: f64 = self
            .lanes
            .free
            .iter()
            .map(|&f| (f - now_us).max(0.0))
            .sum();
        let (cached_epoch, cached_work) = self.work_cache.get();
        let work = if cached_epoch == self.epoch {
            cached_work
        } else {
            let mut w = 0.0;
            for (m, &lat) in self.price.iter().enumerate() {
                let ql = self.q.queue_len(m);
                if ql > 0 {
                    w += ql as f64 * lat;
                }
            }
            self.work_cache.set((self.epoch, w));
            w
        };
        (resid + work) / n
    }

    /// Charge a replica warm-up to this board: occupies the earliest
    /// free GPU lane for `warmup_us` starting no earlier than `now_us`,
    /// so scaling up is never free in virtual time.  Returns the time
    /// the warm-up completes (the replica's earliest serving time).
    pub(crate) fn charge_warmup(&mut self, now_us: f64,
                                warmup_us: f64) -> f64 {
        // A board whose GPU lanes are lost warms up on a CPU lane
        // instead (weights still have to land somewhere it can serve
        // from); with both kinds down the fleet never scales it up.
        let proc = match &self.faults {
            Some(fs) if fs.gpu_down && !fs.cpu_down => Proc::Cpu,
            _ => Proc::Gpu,
        };
        let (lane, free) = self.lanes.earliest(proc);
        let start = now_us.max(free);
        self.lanes.occupy(lane, start, start + warmup_us);
        // Warm-ups burn energy at full frequency and are cap-exempt:
        // weight loading is DMA/alloc-bound, not a governed kernel, and
        // deferring a scale-up decision the autoscaler already committed
        // to would deadlock the replica.
        if let Some(bp) = self.power.as_mut() {
            let w = bp.max_busy_w(lane);
            bp.commit(lane, start, start + warmup_us, w);
        }
        self.tracer.record(
            start + warmup_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::WarmUp {
                lane: lane as u32,
                dur_us: warmup_us,
            },
        );
        self.tracer.acc_warmup(warmup_us);
        start + warmup_us
    }

    /// Arm the fault layer: dispatches settle at their finish times
    /// from here on (so a crash can retract them), and the fault
    /// branches in `pump` become live.  The fleet calls this once per
    /// board before the first pump iff its fault plan is non-empty —
    /// an unarmed board runs the pre-fault, bit-identical path.
    pub(crate) fn arm_faults(&mut self) {
        self.faults = Some(FaultState {
            down: false,
            down_since: 0.0,
            cpu_down: false,
            gpu_down: false,
            thermal: [1.0, 1.0],
            inflight: Vec::new(),
        });
    }

    /// Arm voluntary preemption (`DeadlineBurn` / `BurnPlusSteal`):
    /// the burn check in `pump` becomes live, and the in-flight ledger
    /// is installed (via [`BoardSim::arm_faults`]) if a fault plan
    /// hasn't already done so — settlement defers to batch finish
    /// times so a preemption can retract a running batch.  Deferral is
    /// value-exact (`settle_batch` replays the immediate path's
    /// accounting); `Off` boards are never armed and keep the
    /// byte-identical immediate path.
    pub(crate) fn arm_preemption(&mut self, policy: PreemptionPolicy) {
        self.preempt = policy;
        if policy.preempts() && self.faults.is_none() {
            self.arm_faults();
        }
    }

    /// Arm the tail-tolerance hooks: dispatched batches carry
    /// realized-vs-predicted detector samples, hedge-marked requests
    /// divert their terminal outcomes to the fleet, and the in-flight
    /// ledger is installed (via [`BoardSim::arm_faults`]) if a fault
    /// plan hasn't already done so — a hedge cancellation must be able
    /// to retract a running batch.  Unarmed boards keep the
    /// byte-identical pre-tail path.
    pub(crate) fn arm_tail(&mut self) {
        self.tail = Some(BoardTailHooks::default());
        if self.faults.is_none() {
            self.arm_faults();
        }
    }

    /// Whether a fail-stop fault currently holds this board down.
    pub(crate) fn is_down(&self) -> bool {
        self.faults.as_ref().map_or(false, |f| f.down)
    }

    /// Microseconds until this board could next start *any* dispatch:
    /// the min over schedulable lanes of (free-at − `now_us`), 0 when
    /// a lane is free now, `INFINITY` when every lane kind is down.
    /// The fleet's work-stealing pass compares this stall against
    /// other boards' priced backlogs.
    pub(crate) fn stall_us(&self, now_us: f64) -> f64 {
        let mut best = f64::INFINITY;
        for (l, &p) in self.lanes.procs.iter().enumerate() {
            let up = match &self.faults {
                Some(fs) => match p {
                    Proc::Cpu => !fs.cpu_down,
                    Proc::Gpu => !fs.gpu_down,
                },
                None => true,
            };
            if up {
                best = best.min((self.lanes.free[l] - now_us).max(0.0));
            }
        }
        best
    }

    /// Drain every queued (never dispatched) request of `model` for
    /// re-placement on another board (work stealing): counts them as
    /// `steals`, traces one [`crate::obs::TraceEvent::Steal`] per
    /// drain plus one [`crate::obs::TraceEvent::Requeue`] per moved
    /// request, and bumps the mutation epoch.  `now_us` timestamps the
    /// trace events.  The drained requests keep their original
    /// arrival/deadline and re-enter the destination board via
    /// [`BoardSim::readmit`] without being re-counted as admitted.
    pub(crate) fn steal_queue(&mut self, model: usize, now_us: f64)
        -> Vec<QueuedReq>
    {
        let mut stolen = self.q.drain_model(model);
        // Hedge-marked requests must not change boards: the fleet's
        // first-wins reconciliation keys each copy to the board it was
        // marked on.  Put them straight back and steal only the rest.
        if let Some(h) = &self.tail {
            if stolen.iter().any(|r| h.marks.contains(&r.req)) {
                let (kept, rest): (Vec<_>, Vec<_>) = stolen
                    .into_iter()
                    .partition(|r| h.marks.contains(&r.req));
                for r in kept {
                    let landed = self.q.readmit(r);
                    debug_assert!(
                        landed,
                        "re-queuing a hedge-marked request must not shed"
                    );
                    let _ = landed;
                }
                stolen = rest;
            }
        }
        if stolen.is_empty() {
            return stolen;
        }
        self.epoch += 1;
        self.snap.steals += stolen.len() as u64;
        self.tracer.record(
            now_us,
            model as u32,
            crate::obs::NONE,
            crate::obs::TraceEvent::Steal { n: stolen.len() as u32 },
        );
        for r in &stolen {
            self.tracer.record(
                now_us,
                r.model as u32,
                r.class as u32,
                crate::obs::TraceEvent::Requeue,
            );
        }
        stolen
    }

    /// Mark `req`: its terminal outcome (serve/shed) diverts to the
    /// tail outbox instead of settling — the fleet's hedge
    /// reconciliation owns it.  No-op on unarmed boards.
    pub(crate) fn tail_mark(&mut self, req: usize) {
        if let Some(h) = self.tail.as_mut() {
            h.marks.insert(req);
        }
    }

    /// Drop the hedge mark for `req` (copy resolved or dead).
    pub(crate) fn tail_unmark(&mut self, req: usize) {
        if let Some(h) = self.tail.as_mut() {
            h.marks.remove(&req);
        }
    }

    /// Whether `req` is hedge-marked on this board.
    pub(crate) fn tail_is_marked(&self, req: usize) -> bool {
        self.tail.as_ref().map_or(false, |h| h.marks.contains(&req))
    }

    /// Drain the detector samples accumulated since the last drain.
    pub(crate) fn tail_take_samples(&mut self) -> Vec<TailSample> {
        self.tail
            .as_mut()
            .map(|h| std::mem::take(&mut h.samples))
            .unwrap_or_default()
    }

    /// Drain the diverted hedge outcomes since the last drain.
    pub(crate) fn tail_take_outcomes(&mut self) -> Vec<HedgeOutcome> {
        self.tail
            .as_mut()
            .map(|h| std::mem::take(&mut h.outbox))
            .unwrap_or_default()
    }

    /// Queued (never dispatched) requests of `model` in dispatch
    /// order — the fleet's hedge pass scans these for at-risk
    /// interactive work.
    pub(crate) fn queued_of_model(
        &self,
        model: usize,
    ) -> impl Iterator<Item = &QueuedReq> + '_ {
        self.q.dispatch_view(model)
    }

    /// The detector flagged this board suspect.
    pub(crate) fn note_suspect(&mut self, now_us: f64) {
        self.snap.suspects += 1;
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::Suspect,
        );
    }

    /// The circuit breaker opened on this board.
    pub(crate) fn note_breaker_open(&mut self, now_us: f64) {
        self.snap.breaker_opens += 1;
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::BreakerOpen,
        );
    }

    /// The circuit breaker closed again (probes recovered).
    pub(crate) fn note_breaker_close(&mut self, now_us: f64) {
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::BreakerClose,
        );
    }

    /// A probation probe was admitted to this board: count it and flag
    /// the next dispatched batch as the probe sample.
    pub(crate) fn note_probe(&mut self, now_us: f64) {
        self.snap.probes += 1;
        if let Some(h) = self.tail.as_mut() {
            h.probe_pending = true;
        }
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::Probe,
        );
    }

    /// A hedge clone was re-offered to this board.
    pub(crate) fn note_hedge(
        &mut self,
        now_us: f64,
        model: usize,
        class: usize,
    ) {
        self.snap.hedges += 1;
        self.tracer.record(
            now_us,
            model as u32,
            class as u32,
            crate::obs::TraceEvent::Hedge,
        );
    }

    /// Settle the winning copy of a hedged request as served on this
    /// board — the fleet's first-wins reconciliation picked it.
    /// Replays exactly what the unmarked settle path would have done,
    /// plus the `hedge_wins` counter when the clone (not the original
    /// placement) won.
    pub(crate) fn finalize_hedge_served(
        &mut self,
        r: &QueuedReq,
        start_us: f64,
        finish_us: f64,
        share_us: f64,
        dma_frac: f64,
        clone_won: bool,
    ) {
        #[cfg(debug_assertions)]
        debug_assert!(self.settled.insert(r.req),
                      "request {} settled twice (hedge win)", r.req);
        self.snap.record_served(
            r.class,
            r.model,
            finish_us - r.arrival_us,
            finish_us <= r.deadline_us,
        );
        if clone_won {
            self.snap.hedge_wins += 1;
        }
        if self.tracer.is_enabled() {
            let wait = start_us - r.arrival_us;
            self.tracer.record(
                start_us,
                r.model as u32,
                r.class as u32,
                crate::obs::TraceEvent::QueueWait { wait_us: wait },
            );
            self.tracer.acc_served(
                r.model,
                r.class,
                wait,
                share_us * dma_frac,
                share_us * (1.0 - dma_frac),
            );
        }
        self.tail_unmark(r.req);
    }

    /// Cancel the running batch carrying the losing copy of a hedged
    /// request: refund the unexecuted lane tail and committed energy
    /// exactly like a preemption, bill the executed prefix to
    /// `hedge_waste_us`, re-queue the batch-mates (arrival/deadline
    /// preserved), and drop the loser unsettled — the winner already
    /// served it.  Returns false when no in-flight batch holds `req`.
    pub(crate) fn hedge_cancel_inflight(
        &mut self,
        req: usize,
        now_us: f64,
    ) -> bool {
        let idx = self.faults.as_ref().and_then(|fs| {
            fs.inflight
                .iter()
                .position(|b| b.reqs.iter().any(|r| r.req == req))
        });
        let Some(i) = idx else { return false };
        let b = self
            .faults
            .as_mut()
            .expect("in-flight ledger present")
            .inflight
            .swap_remove(i);
        let cut = now_us.max(b.start_us);
        self.lanes.busy[b.lane] -= b.finish_us - cut;
        self.lanes.free[b.lane] = self.lanes.free[b.lane].min(now_us);
        if let Some(bp) = self.power.as_mut() {
            bp.retract(b.lane, b.start_us, b.finish_us, b.busy_w,
                       now_us);
        }
        self.snap.hedge_waste_us += cut - b.start_us;
        for r in b.reqs {
            if r.req == req {
                self.tracer.record(
                    now_us,
                    r.model as u32,
                    r.class as u32,
                    crate::obs::TraceEvent::HedgeCancel,
                );
                continue;
            }
            // Batch-mates re-enter this board's queues; refusals shed
            // (or divert, if they are themselves hedge-marked) via the
            // settle below.
            self.q.readmit(r);
            self.tracer.record(
                now_us,
                r.model as u32,
                r.class as u32,
                crate::obs::TraceEvent::Requeue,
            );
        }
        self.epoch += 1;
        self.settle_sheds(now_us);
        self.tail_unmark(req);
        true
    }

    /// Remove a still-queued hedge-marked request (the losing copy)
    /// from the admission queues without settling it — the winner
    /// already served it.  Returns false when `req` is not queued here.
    pub(crate) fn hedge_purge_queued(
        &mut self,
        req: usize,
        model: usize,
        now_us: f64,
    ) -> bool {
        if !self.q.dispatch_view(model).any(|r| r.req == req) {
            return false;
        }
        let drained = self.q.drain_model(model);
        let mut purged = None;
        for r in drained {
            if r.req == req {
                purged = Some(r);
                continue;
            }
            let landed = self.q.readmit(r);
            debug_assert!(
                landed,
                "re-queuing around a hedge purge must not shed"
            );
            let _ = landed;
        }
        self.epoch += 1;
        if let Some(r) = purged {
            self.tracer.record(
                now_us,
                r.model as u32,
                r.class as u32,
                crate::obs::TraceEvent::HedgeCancel,
            );
            self.tail_unmark(req);
            true
        } else {
            false
        }
    }

    /// Bill the duplicate executed share of a hedge copy whose batch
    /// finished after the winner settled (both copies completed in the
    /// same reconciliation round): its lane time was really spent, but
    /// the service it produced is a duplicate.
    pub(crate) fn bill_hedge_waste(&mut self, share_us: f64,
                                   now_us: f64) {
        self.snap.hedge_waste_us += share_us;
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::HedgeCancel,
        );
    }

    /// Settle every deferred batch with `finish_us <= up_to_us`:
    /// record its requests served (histograms, attainment, phase
    /// accumulators) exactly as the immediate path would have at
    /// dispatch.  No-op on unarmed boards.  `pub(crate)` so the fleet
    /// can force end-of-run settlement (`INFINITY`) before its final
    /// hedge reconciliation.
    pub(crate) fn settle_inflight(&mut self, up_to_us: f64) {
        let done: Vec<InflightBatch> = match self.faults.as_mut() {
            Some(fs) if !fs.inflight.is_empty() => {
                let mut done = Vec::new();
                let mut i = 0;
                while i < fs.inflight.len() {
                    if fs.inflight[i].finish_us <= up_to_us {
                        done.push(fs.inflight.swap_remove(i));
                    } else {
                        i += 1;
                    }
                }
                done
            }
            _ => return,
        };
        for b in &done {
            self.settle_batch(b);
        }
    }

    /// Settle one finished batch's requests as served.  On tail-armed
    /// boards the batch also emits one realized-vs-predicted detector
    /// sample, and hedge-marked requests are diverted to the outbox
    /// instead of settling — the fleet's first-wins reconciliation
    /// owns their settlement.
    fn settle_batch(&mut self, b: &InflightBatch) {
        if let Some(h) = self.tail.as_mut() {
            if b.pred_us > 0.0 {
                h.samples.push(TailSample {
                    pred_us: b.pred_us,
                    real_us: b.real_us,
                    probe: b.probe,
                });
            }
        }
        let finish = b.finish_us;
        for r in &b.reqs {
            if let Some(h) = self.tail.as_mut() {
                if h.marks.contains(&r.req) {
                    h.outbox.push(HedgeOutcome::Served {
                        r: *r,
                        start_us: b.start_us,
                        finish_us: finish,
                        share_us: (finish - b.start_us)
                            / b.reqs.len() as f64,
                        dma_frac: b.dma_frac,
                    });
                    continue;
                }
            }
            #[cfg(debug_assertions)]
            debug_assert!(self.settled.insert(r.req),
                          "request {} settled twice (served)", r.req);
            self.snap.record_served(
                r.class,
                r.model,
                finish - r.arrival_us,
                finish <= r.deadline_us,
            );
            if self.tracer.is_enabled() {
                let wait = b.start_us - r.arrival_us;
                let share = (finish - b.start_us) / b.reqs.len() as f64;
                self.tracer.record(
                    b.start_us,
                    r.model as u32,
                    r.class as u32,
                    crate::obs::TraceEvent::QueueWait { wait_us: wait },
                );
                self.tracer.acc_served(
                    r.model,
                    r.class,
                    wait,
                    share * b.dma_frac,
                    share * (1.0 - b.dma_frac),
                );
            }
        }
    }

    /// Fail-stop crash at `now_us`: settle everything that finished
    /// first, then retract still-in-flight batches (lane busy time and
    /// committed energy refunded from the crash instant), drain the
    /// admission queues, and mark the board down.  Returns
    /// `(queued, lost)`: requests drained from the queues (for
    /// front-tier re-placement) and requests lost mid-batch (for
    /// deadline-aware retry).  Every one of them left this board
    /// unsettled — it must settle exactly once elsewhere.
    pub(crate) fn crash(&mut self, now_us: f64)
        -> (Vec<QueuedReq>, Vec<QueuedReq>)
    {
        self.settle_inflight(now_us);
        self.settle_sheds(now_us);
        let inflight: Vec<InflightBatch> = self
            .faults
            .as_mut()
            .map(|fs| std::mem::take(&mut fs.inflight))
            .unwrap_or_default();
        let mut lost: Vec<QueuedReq> = Vec::new();
        self.snap.lost_batches += inflight.len() as u64;
        for b in inflight {
            let cut = now_us.max(b.start_us);
            self.lanes.busy[b.lane] -= b.finish_us - cut;
            if let Some(bp) = self.power.as_mut() {
                bp.retract(b.lane, b.start_us, b.finish_us, b.busy_w,
                           now_us);
            }
            lost.extend(b.reqs);
        }
        // Rewind every lane to idle at the crash instant (this also
        // cancels pending warm-ups; stale heap entries self-invalidate
        // once `free` moves).  Warm-up time/energy already spent is
        // not refunded — the weights really were being loaded.
        for f in self.lanes.free.iter_mut() {
            *f = f.min(now_us);
        }
        let queued = self.q.drain_all();
        if let Some(fs) = self.faults.as_mut() {
            fs.down = true;
            fs.down_since = now_us;
        }
        self.epoch += 1;
        self.snap.failovers += 1;
        self.snap.requeued += queued.len() as u64;
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::BoardDown,
        );
        for r in &queued {
            self.tracer.record(
                now_us,
                r.model as u32,
                r.class as u32,
                crate::obs::TraceEvent::Requeue,
            );
        }
        (queued, lost)
    }

    /// Rejoin after a crash: the board serves again from `now_us`; the
    /// down interval is billed to `downtime_us`.
    pub(crate) fn rejoin(&mut self, now_us: f64) {
        if let Some(fs) = self.faults.as_mut() {
            if fs.down {
                fs.down = false;
                self.snap.downtime_us += now_us - fs.down_since;
            }
        }
        self.epoch += 1;
        self.tracer.record(
            now_us,
            crate::obs::NONE,
            crate::obs::NONE,
            crate::obs::TraceEvent::BoardUp,
        );
    }

    /// Lane loss / restore: `down = true` disables every lane of
    /// `proc` (the board degrades to its surviving lanes) and retracts
    /// any batch in flight on them, returning the lost requests for
    /// deadline-aware retry; `down = false` restores the lane kind.
    pub(crate) fn set_lane_down(&mut self, proc: Proc, down: bool,
                                now_us: f64) -> Vec<QueuedReq> {
        self.settle_inflight(now_us);
        let mut lost: Vec<QueuedReq> = Vec::new();
        if down {
            let dead: Vec<InflightBatch> = match self.faults.as_mut() {
                Some(fs) => {
                    let (dead, keep) = std::mem::take(&mut fs.inflight)
                        .into_iter()
                        .partition(|b| self.lanes.procs[b.lane] == proc);
                    fs.inflight = keep;
                    dead
                }
                None => Vec::new(),
            };
            self.snap.lost_batches += dead.len() as u64;
            for b in dead {
                let cut = now_us.max(b.start_us);
                self.lanes.busy[b.lane] -= b.finish_us - cut;
                self.lanes.free[b.lane] =
                    self.lanes.free[b.lane].min(now_us);
                if let Some(bp) = self.power.as_mut() {
                    bp.retract(b.lane, b.start_us, b.finish_us,
                               b.busy_w, now_us);
                }
                lost.extend(b.reqs);
            }
            for l in 0..self.lanes.procs.len() {
                if self.lanes.procs[l] == proc {
                    self.lanes.free[l] = self.lanes.free[l].min(now_us);
                    self.tracer.record(
                        now_us,
                        crate::obs::NONE,
                        crate::obs::NONE,
                        crate::obs::TraceEvent::LaneDown {
                            lane: l as u32,
                        },
                    );
                }
            }
        }
        if let Some(fs) = self.faults.as_mut() {
            match proc {
                Proc::Cpu => fs.cpu_down = down,
                Proc::Gpu => fs.gpu_down = down,
            }
        }
        self.epoch += 1;
        lost
    }

    /// Set the thermal latency multiplier for lanes of `proc`
    /// (`scale >= 1.0`; 1.0 restores nominal speed).  Applied to base
    /// latency before the DVFS governor prices a dispatch, so a
    /// throttled rung stacks multiplicatively on top.
    pub(crate) fn set_thermal(&mut self, proc: Proc, scale: f64) {
        if let Some(fs) = self.faults.as_mut() {
            fs.thermal[thermal_idx(proc)] = scale;
        }
        self.epoch += 1;
    }

    /// Re-admit a request failed over from another board, preserving
    /// its original arrival/deadline and *not* re-counting it as
    /// admitted (see [`AdmissionQueues::readmit`]).  `retry` marks a
    /// request lost mid-batch (traced as a `Retry` on this board);
    /// requeued-from-queue deliveries pass `false`.  Returns whether
    /// it landed (on `false` it was shed here, which settles it).
    pub(crate) fn readmit(&mut self, r: QueuedReq, now_us: f64,
                          retry: bool) -> bool {
        let landed = self.q.readmit(r);
        self.epoch += 1;
        if landed && retry {
            self.tracer.record(
                now_us,
                r.model as u32,
                r.class as u32,
                crate::obs::TraceEvent::Retry,
            );
        }
        landed
    }

    /// `DeadlineBurn` core: cancel one in-flight strictly-lower-class
    /// batch when that rescues a queued higher-class request whose
    /// deadline (µs of virtual time) would burn waiting for a lane,
    /// and the rescued class weight exceeds the deadline weight the
    /// victim would still meet by finishing.  The victim's unexecuted
    /// lane tail and committed energy are refunded from `now_us`
    /// exactly like a crash retract; the already-executed prefix stays
    /// billed as lane busy time and is accumulated into
    /// `preempt_waste_us`.  The victim's requests re-enter this
    /// board's queues with arrival/deadline preserved.  Returns
    /// whether a batch was preempted — callers loop until quiescent,
    /// so one pump can free several lanes.
    fn preempt_for_deadlines(&mut self, now_us: f64) -> Result<bool> {
        match &self.faults {
            Some(fs) if !fs.down && !fs.inflight.is_empty() => {}
            _ => return Ok(false),
        }
        if self.q.total_queued() == 0 {
            return Ok(false);
        }
        // (inflight index, still-meetable weight, start µs) of the
        // cheapest victim found across every burning queue head.
        let mut victim: Option<(usize, f64, f64)> = None;
        for m in 0..self.registry.len() {
            if self.q.queue_len(m) == 0 {
                continue;
            }
            let head = match self.q.dispatch_view(m).next() {
                Some(r) => *r,
                None => continue,
            };
            let rescue_w = self.classes[head.class].weight;
            let entry = self.registry.get(m);
            // The head is "burning" only when no alive lane kind can
            // meet its deadline by dispatching now or by waiting for
            // its earliest lane — but freeing a lane now still could.
            let mut patient = false;
            let mut burn = [false; 2];
            for proc in [Proc::Cpu, Proc::Gpu] {
                let fs = self.faults.as_ref().expect("armed above");
                let up = match proc {
                    Proc::Cpu => !fs.cpu_down,
                    Proc::Gpu => !fs.gpu_down,
                };
                if !up {
                    continue;
                }
                let lat1 = entry.latency_us(proc, 1)?
                    * fs.thermal[thermal_idx(proc)];
                if now_us + lat1 > head.deadline_us {
                    continue; // unservable even on a free lane
                }
                let (_, free) = self.lanes.earliest(proc);
                if free <= now_us || free + lat1 <= head.deadline_us {
                    patient = true; // the dispatcher handles it unaided
                    break;
                }
                burn[thermal_idx(proc)] = true;
            }
            if patient {
                continue;
            }
            let fs = self.faults.as_ref().expect("armed above");
            for (i, b) in fs.inflight.iter().enumerate() {
                if !burn[thermal_idx(self.lanes.procs[b.lane])] {
                    continue;
                }
                let bclass = b.reqs.iter().map(|r| r.class).min()
                    .expect("dispatched batches are never empty");
                // Only strictly lower-priority batches are fair game.
                if bclass <= head.class {
                    continue;
                }
                // Deadline weight the victim still delivers by running
                // to completion; preempting must beat it.
                let remaining_w: f64 = b.reqs.iter()
                    .filter(|r| r.deadline_us >= b.finish_us)
                    .map(|r| self.classes[r.class].weight)
                    .sum();
                if remaining_w >= rescue_w {
                    continue;
                }
                // Cheapest victim first: least still-meetable weight,
                // then least already-executed (wasted) lane time.
                let better = match victim {
                    None => true,
                    Some((_, w, s)) => remaining_w < w
                        || (remaining_w == w && b.start_us > s),
                };
                if better {
                    victim = Some((i, remaining_w, b.start_us));
                }
            }
        }
        let Some((i, _, _)) = victim else {
            return Ok(false);
        };
        let b = self.faults.as_mut().expect("armed above")
            .inflight.swap_remove(i);
        // Refund the unexecuted tail exactly like a crash retract; the
        // executed prefix stays billed as busy lane time.
        let cut = now_us.max(b.start_us);
        self.lanes.busy[b.lane] -= b.finish_us - cut;
        self.lanes.free[b.lane] = self.lanes.free[b.lane].min(now_us);
        if let Some(bp) = self.power.as_mut() {
            bp.retract(b.lane, b.start_us, b.finish_us, b.busy_w,
                       now_us);
        }
        self.snap.preemptions += 1;
        self.snap.preempt_waste_us += cut - b.start_us;
        self.tracer.record(
            now_us,
            b.reqs.first()
                .map_or(crate::obs::NONE, |r| r.model as u32),
            b.reqs.iter().map(|r| r.class as u32).min()
                .unwrap_or(crate::obs::NONE),
            crate::obs::TraceEvent::Preempt { lane: b.lane as u32 },
        );
        for r in b.reqs {
            // Original arrival/deadline preserved, not re-counted as
            // admitted; a refused readmission sheds here and settles
            // through `settle_sheds` right after.
            self.q.readmit(r);
        }
        self.epoch += 1;
        self.settle_sheds(now_us);
        Ok(true)
    }

    /// Dispatch everything worth dispatching at `now_us`: sheds expired
    /// work (dynamic tier), settles shed accounting, then repeatedly
    /// scores every feasible (model, placement, batch) option and
    /// dispatches the best until the board prefers to wait.  Returns
    /// the board's next wake-up time (earliest busy lane freeing), or
    /// `None` when nothing is queued.
    pub(crate) fn pump(&mut self, now_us: f64) -> Result<Option<f64>> {
        let now = now_us;
        // Armed boards settle dispatches at their finish times so a
        // crash can retract what hadn't completed; catch up first so
        // retraction never claws back genuinely finished work.  A
        // downed board serves nothing (arrivals keep queueing; the
        // fleet drains them on the crash transition).
        self.settle_inflight(now);
        if self.is_down() {
            return Ok(None);
        }
        // The dynamic tier refuses to burn capacity on doomed requests.
        // Expiry is an O(1) head-deadline check when nothing is due,
        // head pops otherwise (see `AdmissionQueues::drop_expired`).
        if self.sparsity_aware {
            let shed_before = self.q.shed.len();
            self.q.drop_expired(now);
            if self.q.shed.len() != shed_before {
                self.epoch += 1;
            }
        }
        self.settle_sheds(now);
        // Voluntary preemption (DeadlineBurn / BurnPlusSteal): rescue
        // burning higher-class deadlines before scoring dispatches, so
        // a freed lane is visible to this pump's candidates.
        if self.preempt.preempts() {
            while self.preempt_for_deadlines(now)? {}
        }
        loop {
            if self.q.total_queued() == 0 {
                return Ok(None);
            }

            // Score every feasible (model, placement, batch) dispatch
            // option.  Only lanes free *now* are dispatchable — queued
            // work accumulates while a lane is busy, which is what lets
            // the dispatcher re-order by class/deadline and right-size
            // batches (a scheduler that commits arrivals to future
            // slots one by one degenerates into FIFO).  Busy-lane
            // options are still scored: they tell the wait heuristic
            // whether patience would save deadlines that an immediate
            // doomed dispatch would burn.  Scoring reads the queues
            // through the borrowing `dispatch_view` — no clones, no
            // sorts — and the per-model head/length aggregates the
            // indexed queues keep in O(1)/O(classes).
            let mut best_now: Option<Candidate> = None;
            let mut best_any: Option<Candidate> = None;
            for m in 0..self.registry.len() {
                let qlen = self.q.queue_len(m);
                if qlen == 0 {
                    continue;
                }
                let entry = self.registry.get(m);
                let head_arrival = self.q.head_arrival_us(m);
                let both = [Proc::Cpu, Proc::Gpu];
                let procs: &[Proc] = if self.sparsity_aware {
                    &both
                } else {
                    std::slice::from_ref(&self.static_lane[m])
                };
                for &proc in procs {
                    // Lost lane kinds are unschedulable until restored.
                    let proc_up = match &self.faults {
                        Some(fs) => match proc {
                            Proc::Cpu => !fs.cpu_down,
                            Proc::Gpu => !fs.gpu_down,
                        },
                        None => true,
                    };
                    if !proc_up {
                        continue;
                    }
                    let (lane, lane_free) = self.lanes.earliest(proc);
                    let cap = entry.batch_cap(proc).max(1);
                    let start = now.max(lane_free);
                    // Candidate batch sizes: powers of two up to the
                    // Alg. 2 cap, plus "everything queued".  Batch
                    // latency grows with size, so right-sizing is what
                    // keeps tight deadlines servable under backlog (the
                    // static baseline always drains min(queue, cap),
                    // like the single-model batcher it stands in for).
                    let mut sizes: Vec<usize> = Vec::new();
                    if self.sparsity_aware {
                        let mut b = 1usize;
                        while b < cap.min(qlen) {
                            sizes.push(b);
                            b *= 2;
                        }
                    }
                    sizes.push(qlen.min(cap));
                    for &b in &sizes {
                        let mut l = entry.latency_us(proc, b)?;
                        // Thermal slow-down stretches base latency
                        // before the governor prices the dispatch.
                        // Unarmed boards never take this branch, so
                        // the fault-free path stays bit-identical.
                        if let Some(fs) = &self.faults {
                            l *= fs.thermal[thermal_idx(proc)];
                        }
                        let finish = start + l;
                        let met_w: f64 = self
                            .q
                            .dispatch_view(m)
                            .take(b)
                            .filter(|r| r.deadline_us >= finish)
                            .map(|r| self.classes[r.class].weight)
                            .sum();
                        let score = if self.sparsity_aware {
                            // Primary: deadline-weighted value of the
                            // batch (class weights are >= 1, so one met
                            // deadline outranks every secondary term).
                            // Secondary: drain rate — when every option
                            // is doomed the scheduler degrades to
                            // throughput mode instead of thrashing on
                            // size-1 batches.  The Fig. 2 signals and
                            // earlier finishes break ties.
                            let drain =
                                (10.0 * b as f64 / l.max(1.0)).min(0.9);
                            let affinity = match proc {
                                Proc::Cpu => entry.sparsity,
                                Proc::Gpu => entry.intensity,
                            };
                            met_w + drain + 0.01 * affinity
                                - 1e-9 * finish
                        } else {
                            // FIFO across the lane's models: oldest
                            // head wins.
                            -head_arrival - 1e-9 * finish
                        };
                        let cand = || Candidate {
                            m, lane, proc, b, start, finish, score,
                            met_w,
                        };
                        if lane_free <= now
                            && best_now
                                .as_ref()
                                .map_or(true, |c| score > c.score)
                        {
                            best_now = Some(cand());
                        }
                        if best_any
                            .as_ref()
                            .map_or(true, |c| score > c.score)
                        {
                            best_any = Some(cand());
                        }
                    }
                }
            }

            // No candidate at all: every schedulable lane kind is
            // down (unreachable fault-free — queued work always has
            // at least one placement).  The work stays queued; if no
            // lane is ever restored, `finish` force-fails it.
            if best_any.is_none() {
                return Ok(None);
            }

            // Wait instead of dispatching when nothing is dispatchable
            // now, or when everything dispatchable now is doomed while
            // a busy lane could still meet deadlines once it frees
            // (don't shred requests on an idle-but-hopeless processor).
            let wait = match (&best_now, &best_any) {
                (None, _) => true,
                (Some(bn), Some(ba)) => {
                    self.sparsity_aware
                        && bn.met_w <= 0.0
                        && ba.met_w > 0.0
                        && ba.start > now
                }
                _ => false,
            };
            if wait {
                // Wake at the next lane-free event — a heap peek over
                // the pending occupancies, not a lane scan.
                let next_free = self.lanes.next_event_after(now);
                debug_assert!(
                    matches!(next_free, Some(t) if t > now),
                    "wait must have a busy lane to wake on"
                );
                anyhow::ensure!(
                    next_free.is_some(),
                    "board waited with no pending lane event"
                );
                return Ok(next_free);
            }

            let c = best_now.expect("non-wait iterations dispatch");
            // Governor decision point (energy-aware boards): placement
            // and batch size are already fixed by the score above at
            // full-frequency prices; the governor only chooses how fast
            // to run the chosen batch.  StretchToDeadline slows it to
            // the cheapest rung that still meets the worst deadline it
            // would meet at full speed; a binding power cap clamps
            // further (throttle event) or defers the dispatch to the
            // next lane-free event.
            let mut finish = c.finish;
            let mut freq_state = crate::obs::NONE;
            let mut busy_w = 0.0;
            if let Some(bp) = self.power.as_mut() {
                let worst = self
                    .q
                    .dispatch_view(c.m)
                    .take(c.b)
                    .filter(|r| r.deadline_us >= c.finish)
                    .map(|r| r.deadline_us)
                    .fold(f64::INFINITY, f64::min);
                let worst = worst.is_finite().then_some(worst);
                match bp.admit(c.lane, &self.lanes.free, c.start,
                               c.finish - c.start, worst) {
                    Some(adm) => {
                        finish = c.start + adm.scaled_lat_us;
                        busy_w = adm.busy_w;
                        bp.commit(c.lane, c.start, finish, adm.busy_w);
                        freq_state = adm.state as u32;
                        if adm.clamped {
                            self.tracer.record(
                                c.start,
                                c.m as u32,
                                crate::obs::NONE,
                                crate::obs::TraceEvent::Throttle,
                            );
                            self.tracer.acc_throttle();
                        }
                    }
                    None => {
                        self.tracer.record(
                            now,
                            c.m as u32,
                            crate::obs::NONE,
                            crate::obs::TraceEvent::Throttle,
                        );
                        self.tracer.acc_throttle();
                        // Cap-bound: every admissible rung would push
                        // board draw over the cap while other lanes are
                        // busy.  A busy lane must exist (the cap was
                        // validated feasible on an idle board), so wake
                        // when it frees and headroom returns.
                        let next_free = self.lanes.next_event_after(now);
                        anyhow::ensure!(
                            next_free.is_some(),
                            "cap-deferred dispatch with no pending \
                             lane event"
                        );
                        return Ok(next_free);
                    }
                }
            }
            let taken =
                self.q.take_batch(c.m, c.b, self.sparsity_aware);
            debug_assert!(!taken.is_empty());
            self.epoch += 1;
            self.lanes.occupy(c.lane, c.start, finish);
            self.last_finish = self.last_finish.max(finish);
            self.snap.n_batches += 1;
            self.snap.dispatched += taken.len() as u64;
            // Profiler: split the batch's lane occupancy into a DMA
            // span followed by a compute span using the model's probed
            // transfer share, and attribute per-request shares to the
            // phase accumulators.  All derived work (the fraction
            // probe, the share math) sits behind `is_enabled`.
            let dma_frac = if self.tracer.is_enabled() {
                use crate::obs::TraceEvent;
                let f = self
                    .registry
                    .get(c.m)
                    .dma_fraction(c.proc, taken.len())?;
                let span = finish - c.start;
                let lane = c.lane as u32;
                let batch = taken.len() as u32;
                let m = c.m as u32;
                let none = crate::obs::NONE;
                self.tracer.record(
                    now, m, none, TraceEvent::BatchForm { batch });
                self.tracer.record(
                    c.start, m, none,
                    TraceEvent::Dispatch { lane, batch, freq_state });
                self.tracer.record(
                    c.start + span * f, m, none,
                    TraceEvent::Dma { lane, dur_us: span * f });
                self.tracer.record(
                    finish, m, none,
                    TraceEvent::Compute {
                        lane,
                        dur_us: span * (1.0 - f),
                    });
                f
            } else {
                0.0
            };
            // Tail detector sample for this dispatch: predicted is the
            // pre-thermal base latency the router's price tables see,
            // realized is the thermally stretched candidate latency
            // (pre-governor: a DVFS stretch is chosen, not a gray
            // failure).  Unarmed boards compute nothing here.
            let (pred_us, real_us, probe) = match self.tail.as_mut() {
                Some(h) => {
                    let p = std::mem::take(&mut h.probe_pending);
                    (
                        self.registry.get(c.m).latency_us(c.proc, c.b)?,
                        c.finish - c.start,
                        p,
                    )
                }
                None => (0.0, 0.0, false),
            };
            if let Some(fs) = self.faults.as_mut() {
                // Armed: settlement is deferred to the batch's finish
                // time so a fault landing before then can retract it
                // (crash / lane loss).  `settle_batch` replays exactly
                // the accounting below, so fault-free armed runs are
                // still exact — only *when* the counters move differs.
                fs.inflight.push(InflightBatch {
                    lane: c.lane,
                    start_us: c.start,
                    finish_us: finish,
                    busy_w,
                    dma_frac,
                    pred_us,
                    real_us,
                    probe,
                    reqs: taken,
                });
            } else {
                for r in &taken {
                    let latency = finish - r.arrival_us;
                    #[cfg(debug_assertions)]
                    debug_assert!(self.settled.insert(r.req),
                                  "request {} settled twice (served)",
                                  r.req);
                    self.snap.record_served(
                        r.class,
                        r.model,
                        latency,
                        finish <= r.deadline_us,
                    );
                    if self.tracer.is_enabled() {
                        let wait = c.start - r.arrival_us;
                        let share =
                            (finish - c.start) / taken.len() as f64;
                        self.tracer.record(
                            c.start,
                            r.model as u32,
                            r.class as u32,
                            crate::obs::TraceEvent::QueueWait {
                                wait_us: wait,
                            },
                        );
                        self.tracer.acc_served(
                            r.model,
                            r.class,
                            wait,
                            share * dma_frac,
                            share * (1.0 - dma_frac),
                        );
                    }
                }
            }
        }
    }

    /// Record any newly shed requests (admission rejections + expiries)
    /// into the snapshot, exactly once each.  `now_us` timestamps the
    /// trace events (sheds surface at the pump that settles them).
    fn settle_sheds(&mut self, now_us: f64) {
        for &s in self.q.shed_since(self.shed_seen) {
            if let Some(h) = self.tail.as_mut() {
                // A hedge-marked copy that sheds (re-admission refusal
                // or queue expiry) is a copy death, not a shed: the
                // request may still be served by its twin.  Divert to
                // the fleet's reconciliation.
                if h.marks.contains(&s.req) {
                    h.outbox.push(HedgeOutcome::Dead { req: s.req });
                    continue;
                }
            }
            #[cfg(debug_assertions)]
            debug_assert!(self.settled.insert(s.req),
                          "request {} settled twice (shed)", s.req);
            self.snap.record_shed(s.class, s.model, s.at_admission);
            self.tracer.record(
                now_us,
                s.model as u32,
                s.class as u32,
                if s.at_admission {
                    crate::obs::TraceEvent::Shed
                } else {
                    crate::obs::TraceEvent::Expire
                },
            );
            self.tracer.acc_shed(s.model, s.class, !s.at_admission);
        }
        self.shed_seen = self.q.shed.len();
    }

    /// Seal the run: `now_us` is the driver's final virtual time.
    /// Verifies (debug builds) that every request settled exactly once.
    pub(crate) fn finish(mut self, now_us: f64) -> PerfSnapshot {
        // Everything still in flight on an armed board completes by
        // the horizon (the driver only seals after the last finish).
        self.settle_inflight(f64::INFINITY);
        self.settle_sheds(now_us);
        if self.faults.is_some() {
            // Fault backstop: work stranded in the queues of a downed
            // or fully-degraded board is *failed*, never silently
            // dropped — conservation stays exact under any plan.
            for r in self.q.drain_all() {
                #[cfg(debug_assertions)]
                debug_assert!(self.settled.insert(r.req),
                              "request {} settled twice (failed)",
                              r.req);
                self.snap.record_failed(r.class, r.model);
            }
            if let Some(fs) = &self.faults {
                if fs.down {
                    // Crash with no rejoin before the horizon: bill
                    // the open-ended down interval to the seal time.
                    self.snap.downtime_us +=
                        (now_us - fs.down_since).max(0.0);
                }
            }
        }
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            self.settled.len() as u64,
            self.snap.total_served() + self.snap.total_shed()
                + self.snap.total_failed(),
            "settlement accounting drifted"
        );
        self.snap.makespan_us = self.last_finish.max(now_us);
        self.snap.cpu_busy_us = self.lanes.busy_us(Proc::Cpu);
        self.snap.gpu_busy_us = self.lanes.busy_us(Proc::Gpu);
        // Horizon: warm-up occupancies extend lane free times past the
        // last *dispatch* finish without touching last_finish, so take
        // the max over both — otherwise a lane could log more busy
        // time than the window it idles (and the profiler's capacity
        // identity) is judged against.
        let horizon = self
            .lanes
            .free
            .iter()
            .fold(self.snap.makespan_us, |h, &f| h.max(f));
        if self.tracer.is_enabled() {
            let capacity = self.lanes.procs.len() as f64 * horizon;
            let busy: f64 = self.lanes.busy.iter().sum();
            let idle = (capacity - busy).max(0.0);
            let (events, dropped) = self.tracer.take();
            self.snap.trace_events = events;
            self.snap.trace_dropped = dropped;
            self.snap.phases = self.tracer.seal(idle, capacity);
        }
        if let Some(mut bp) = self.power.take() {
            let mut e_mj =
                bp.busy_energy_mj + bp.soc_w() * horizon / 1e3;
            for (lane, &busy) in self.lanes.busy.iter().enumerate() {
                e_mj +=
                    (horizon - busy).max(0.0) * bp.idle_w_of(lane) / 1e3;
            }
            self.snap.energy_mj = e_mj;
            self.snap.busy_energy_mj = bp.busy_energy_mj;
            self.snap.power_horizon_us = horizon;
            self.snap.idle_floor_w = bp.idle_floor_w();
            self.snap.soc_w = bp.soc_w();
            self.snap.governor = bp.governor_name();
            self.snap.throttle_events = bp.throttles;
            self.snap.power_trace = std::mem::take(&mut bp.trace);
            self.snap.power_trace_dropped = bp.trace_dropped;
        }
        self.snap
    }
}

/// Serve a merged multi-tenant arrival stream on one two-lane board and
/// report per-class / per-model outcomes.  Everything runs in virtual
/// time through each session's execution backend (the latency oracle is
/// [`crate::api::Session::probe`], cached per (model, placement,
/// batch)).
pub fn run_cluster(
    registry: &ModelRegistry,
    classes: &[SloClass],
    tenants: &[Tenant],
    arrivals: &[Arrival],
    opts: &ClusterOptions,
) -> Result<PerfSnapshot> {
    anyhow::ensure!(!registry.is_empty(), "registry holds no models");
    anyhow::ensure!(!classes.is_empty(), "no SLO classes configured");
    let model_of: Vec<usize> = tenants
        .iter()
        .map(|t| registry.index_of(&t.model))
        .collect::<Result<_>>()?;
    for t in tenants {
        anyhow::ensure!(
            t.class < classes.len(),
            "tenant `{}` references SLO class {} of {}",
            t.name, t.class, classes.len()
        );
    }
    anyhow::ensure!(
        arrivals.windows(2).all(|w| w[0].at_us <= w[1].at_us),
        "arrivals must be time-sorted (use serve::merge_arrivals)"
    );

    let mut board = BoardSim::new(
        registry,
        classes,
        opts,
        LaneMatrix::duo(),
        opts.policy.name(),
    )?;
    let mut now = 0.0f64;
    let mut ai = 0usize;
    loop {
        // Ingest everything that has arrived by `now`.
        while ai < arrivals.len() && arrivals[ai].at_us <= now {
            let a = arrivals[ai];
            ai += 1;
            board.offer(
                a.req,
                a.tenant,
                model_of[a.tenant],
                tenants[a.tenant].class,
                a.at_us,
            );
        }
        match board.pump(now)? {
            None => {
                if ai >= arrivals.len() {
                    break;
                }
                now = arrivals[ai].at_us;
            }
            Some(wake) => {
                let mut t = wake;
                if ai < arrivals.len() {
                    t = t.min(arrivals[ai].at_us);
                }
                debug_assert!(t.is_finite() && t > now,
                              "wait must advance virtual time");
                now = t;
            }
        }
    }
    Ok(board.finish(now))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::graph::ModelGraph;
    use crate::serve::workload::merge_arrivals;
    use crate::serve::workload::ArrivalPattern;

    fn registry() -> ModelRegistry {
        let dev = crate::bench_support::device_profile("agx_orin");
        let mut reg = ModelRegistry::new();
        for (name, blocks, scale, sparsity) in [
            ("heavy", 6, 6.0, 0.1),
            ("light", 4, 0.3, 0.75),
        ] {
            let s = SessionBuilder::new()
                .with_graph(ModelGraph::synthetic(
                    name, blocks, scale, sparsity))
                .with_device(dev.clone())
                .policy("greedy")
                .build()
                .unwrap();
            reg.register(s).unwrap();
        }
        reg
    }

    fn classes() -> Vec<SloClass> {
        vec![
            SloClass::new("interactive", 30_000.0, 64, 4.0),
            SloClass::new("batch", 200_000.0, 256, 1.0),
        ]
    }

    #[test]
    fn light_load_meets_slos_and_conserves_requests() {
        let reg = registry();
        let cls = classes();
        let tenants = vec![
            Tenant {
                name: "t-heavy".into(),
                model: "heavy".into(),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 30.0,
                    n: 150,
                },
            },
            Tenant {
                name: "t-light".into(),
                model: "light".into(),
                class: 1,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 60.0,
                    n: 150,
                },
            },
        ];
        let arrivals = merge_arrivals(&tenants, 11);
        let snap = run_cluster(&reg, &cls, &tenants, &arrivals,
                               &ClusterOptions::default())
            .unwrap();
        assert_eq!(snap.total_offered(), 300);
        assert_eq!(snap.total_served() + snap.total_shed(), 300);
        assert!(snap.aggregate_attainment() > 0.9,
                "light load attainment {}", snap.aggregate_attainment());
        assert!(snap.makespan_us > 0.0);
        assert!(snap.gpu_busy_us > 0.0);
    }

    #[test]
    fn unknown_model_or_class_is_rejected() {
        let reg = registry();
        let cls = classes();
        let bad_model = vec![Tenant {
            name: "x".into(),
            model: "nope".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson { rate_per_s: 1.0, n: 1 },
        }];
        assert!(run_cluster(&reg, &cls, &bad_model, &[],
                            &ClusterOptions::default())
            .is_err());
        let bad_class = vec![Tenant {
            name: "x".into(),
            model: "heavy".into(),
            class: 9,
            pattern: ArrivalPattern::Poisson { rate_per_s: 1.0, n: 1 },
        }];
        assert!(run_cluster(&reg, &cls, &bad_class, &[],
                            &ClusterOptions::default())
            .is_err());
        // Hand-built arrival streams must be time-sorted.
        let ok_tenant = vec![Tenant {
            name: "x".into(),
            model: "heavy".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson { rate_per_s: 1.0, n: 2 },
        }];
        let unsorted = vec![
            Arrival { req: 0, tenant: 0, at_us: 100.0 },
            Arrival { req: 1, tenant: 0, at_us: 50.0 },
        ];
        assert!(run_cluster(&reg, &cls, &ok_tenant, &unsorted,
                            &ClusterOptions::default())
            .is_err());
    }

    #[test]
    fn static_split_pins_one_model_per_processor() {
        let reg = registry();
        let cls = classes();
        let tenants = vec![
            Tenant {
                name: "t-heavy".into(),
                model: "heavy".into(),
                class: 0,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 50.0,
                    n: 120,
                },
            },
            Tenant {
                name: "t-light".into(),
                model: "light".into(),
                class: 1,
                pattern: ArrivalPattern::Poisson {
                    rate_per_s: 200.0,
                    n: 240,
                },
            },
        ];
        let arrivals = merge_arrivals(&tenants, 13);
        let snap = run_cluster(&reg, &cls, &tenants, &arrivals,
            &ClusterOptions {
                policy: ClusterPolicy::StaticSplit,
                shed: ShedPolicy::RejectNew,
                trace: None,
            })
            .unwrap();
        // light (cheapest on CPU) pinned to CPU, heavy to GPU: both
        // processors accumulate busy time.
        assert!(snap.cpu_busy_us > 0.0);
        assert!(snap.gpu_busy_us > 0.0);
        assert_eq!(snap.policy, "static-split");
        assert_eq!(snap.total_served() + snap.total_shed(),
                   snap.total_offered());
    }

    #[test]
    fn energy_aware_board_accounts_power_and_keeps_conservation() {
        use crate::power::{Governor, PowerConfig, PowerProfile};
        let reg = registry();
        let cls = classes();
        let tenants = vec![Tenant {
            name: "t".into(),
            model: "light".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson { rate_per_s: 40.0, n: 120 },
        }];
        let arrivals = merge_arrivals(&tenants, 29);
        let dev = crate::bench_support::device_profile("agx_orin");
        let profile = PowerProfile::from_device(&dev).unwrap();
        let mut cfg =
            PowerConfig::new(profile, Governor::StretchToDeadline);
        cfg.trace = true;
        let mut board = BoardSim::new(
            &reg, &cls, &ClusterOptions::default(), LaneMatrix::duo(),
            "t")
            .unwrap();
        board.set_power(&cfg).unwrap();
        let mut now = 0.0;
        let mut ai = 0;
        loop {
            while ai < arrivals.len() && arrivals[ai].at_us <= now {
                let a = arrivals[ai];
                ai += 1;
                board.offer(a.req, a.tenant, 1, 1, a.at_us);
            }
            match board.pump(now).unwrap() {
                None => {
                    if ai >= arrivals.len() {
                        break;
                    }
                    now = arrivals[ai].at_us;
                }
                Some(w) => {
                    now = if ai < arrivals.len() {
                        w.min(arrivals[ai].at_us)
                    } else {
                        w
                    };
                }
            }
        }
        let snap = board.finish(now);
        assert_eq!(snap.total_served() + snap.total_shed(),
                   snap.total_offered());
        assert_eq!(snap.governor, "stretch-to-deadline");
        assert!(snap.energy_mj > 0.0);
        assert!(snap.busy_energy_mj > 0.0);
        assert!(snap.busy_energy_mj < snap.energy_mj,
                "idle + SoC floors must add energy on a lightly loaded \
                 board");
        assert!(snap.power_horizon_us >= snap.makespan_us);
        assert_eq!(snap.throttle_events, 0, "uncapped run throttled");
        assert!(!snap.power_trace.is_empty());
        assert!(snap.energy_per_inference_mj() > 0.0);
        assert!(snap.mean_power_w() > snap.soc_w + snap.idle_floor_w,
                "mean power must sit above the all-idle floor");
    }

    #[test]
    fn lane_matrix_widens_a_board() {
        // Same overloaded single-model stream on a 1+1 vs a 1+3 board:
        // more GPU lanes must not lose requests, and must not serve
        // materially fewer deadlines (the greedy dispatcher doesn't
        // guarantee strict monotonicity — extra free lanes can trade
        // batch amortization for immediacy — so allow 10% slack).
        let reg = registry();
        let cls = classes();
        let tenants = vec![Tenant {
            name: "t".into(),
            model: "heavy".into(),
            class: 0,
            pattern: ArrivalPattern::Poisson { rate_per_s: 600.0, n: 400 },
        }];
        let arrivals = merge_arrivals(&tenants, 19);
        let model_of = vec![0usize];
        let mut met = Vec::new();
        for lanes in [LaneMatrix::duo(), LaneMatrix::new(1, 3)] {
            let mut board = BoardSim::new(
                &reg, &cls, &ClusterOptions::default(), lanes, "t")
                .unwrap();
            let mut now = 0.0;
            let mut ai = 0;
            loop {
                while ai < arrivals.len() && arrivals[ai].at_us <= now {
                    let a = arrivals[ai];
                    ai += 1;
                    board.offer(a.req, a.tenant, model_of[a.tenant], 0,
                                a.at_us);
                }
                match board.pump(now).unwrap() {
                    None => {
                        if ai >= arrivals.len() {
                            break;
                        }
                        now = arrivals[ai].at_us;
                    }
                    Some(w) => {
                        now = if ai < arrivals.len() {
                            w.min(arrivals[ai].at_us)
                        } else {
                            w
                        };
                    }
                }
            }
            let snap = board.finish(now);
            assert_eq!(snap.total_served() + snap.total_shed(),
                       snap.total_offered());
            met.push(snap.total_met());
        }
        assert!(met[1] as f64 >= met[0] as f64 * 0.9,
                "wider board met {} << duo {}", met[1], met[0]);
    }

    #[test]
    fn deadline_burn_preempts_to_rescue_high_class() {
        let reg = registry();
        let mk_cls = |d_hi: f64| vec![
            SloClass::new("hi", d_hi, 64, 100.0),
            SloClass::new("lo", 10_000_000.0, 256, 1.0),
        ];
        // Probe run (no preemption, same dispatch decisions): measure
        // how long the heavy batches pin both lanes so the rescue
        // deadline can be sized to provably burn without a preemption.
        let probe_cls = mk_cls(30_000.0);
        let mut probe = BoardSim::new(
            &reg, &probe_cls, &ClusterOptions::default(),
            LaneMatrix::duo(), "t")
            .unwrap();
        let mut t = 0.0;
        let mut next_id = 0;
        for _ in 0..3 {
            for _ in 0..8 {
                probe.offer(next_id, 0, 0, 1, t);
                next_id += 1;
            }
            probe.pump(t).unwrap();
            t += 1.0;
        }
        let t1 = t;
        let min_free = probe.lanes.free.iter().cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(min_free > t1 + 1_000.0,
                "24 heavy requests should pin both lanes well past \
                 t1 = {} (min_free {})", t1, min_free);
        let lat1_min = [Proc::Cpu, Proc::Gpu]
            .into_iter()
            .map(|p| reg.get(1).latency_us(p, 1).unwrap())
            .fold(f64::INFINITY, f64::min);
        // Feasible on a free lane now (lat1_min <= d_hi) but not on
        // any lane busy until min_free — the burn window.
        let d_hi = lat1_min + 0.5 * (min_free - t1);

        let cls = mk_cls(d_hi);
        let mut board = BoardSim::new(
            &reg, &cls, &ClusterOptions::default(), LaneMatrix::duo(),
            "t")
            .unwrap();
        board.arm_preemption(PreemptionPolicy::DeadlineBurn);
        let mut t = 0.0;
        let mut next_id = 0;
        for _ in 0..3 {
            for _ in 0..8 {
                board.offer(next_id, 0, 0, 1, t);
                next_id += 1;
            }
            board.pump(t).unwrap();
            t += 1.0;
        }
        // One interactive request on the cheap model: both lanes are
        // pinned by weight-1 batches, so DeadlineBurn must cancel one.
        board.offer(next_id, 0, 1, 0, t1);
        board.pump(t1).unwrap();
        assert_eq!(board.snap.preemptions, 1,
                   "exactly one batch preempted");
        assert!(board.snap.preempt_waste_us > 0.0,
                "the cancelled batch had executed a prefix");
        let mut now = t1;
        loop {
            match board.pump(now).unwrap() {
                None => break,
                Some(w) => now = w,
            }
        }
        let snap = board.finish(now);
        assert_eq!(snap.total_served() + snap.total_shed(),
                   snap.total_offered());
        assert_eq!(snap.total_offered(), 25);
        assert_eq!(snap.per_class[0].met, 1,
                   "the rescued interactive deadline must be met");
        assert_eq!(snap.per_class[1].offered, 24);
        assert_eq!(snap.preemptions, 1);
        assert!(snap.preempt_waste_us > 0.0);
    }

    #[test]
    fn dormant_deadline_burn_is_byte_identical_to_off() {
        // Arming preemption defers settlement through the in-flight
        // ledger but must stay value-exact when no preemption fires:
        // a single-class stream (no higher class to rescue) produces a
        // byte-identical snapshot JSON.
        let reg = registry();
        let cls = classes();
        let tenants = vec![Tenant {
            name: "t".into(),
            model: "light".into(),
            class: 1,
            pattern: ArrivalPattern::Poisson { rate_per_s: 40.0, n: 120 },
        }];
        let arrivals = merge_arrivals(&tenants, 7);
        let run = |arm: bool| {
            let mut board = BoardSim::new(
                &reg, &cls, &ClusterOptions::default(),
                LaneMatrix::duo(), "t")
                .unwrap();
            if arm {
                board.arm_preemption(PreemptionPolicy::DeadlineBurn);
            }
            let mut now = 0.0;
            let mut ai = 0;
            loop {
                while ai < arrivals.len() && arrivals[ai].at_us <= now {
                    let a = arrivals[ai];
                    ai += 1;
                    board.offer(a.req, a.tenant, 1, 1, a.at_us);
                }
                match board.pump(now).unwrap() {
                    None => {
                        if ai >= arrivals.len() {
                            break;
                        }
                        now = arrivals[ai].at_us;
                    }
                    Some(w) => {
                        now = if ai < arrivals.len() {
                            w.min(arrivals[ai].at_us)
                        } else {
                            w
                        };
                    }
                }
            }
            board.finish(now).to_json_string()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn steal_queue_drains_counts_and_preserves_identity() {
        let reg = registry();
        let cls = classes();
        let mut a = BoardSim::new(
            &reg, &cls, &ClusterOptions::default(), LaneMatrix::duo(),
            "a")
            .unwrap();
        let mut b = BoardSim::new(
            &reg, &cls, &ClusterOptions::default(), LaneMatrix::duo(),
            "b")
            .unwrap();
        // Queue work on A without pumping — never dispatched.
        for i in 0..5 {
            a.offer(i, 0, 0, 1, 10.0 * i as f64);
        }
        for i in 5..8 {
            a.offer(i, 0, 1, 1, 5.0);
        }
        let stolen = a.steal_queue(0, 60.0);
        assert_eq!(stolen.len(), 5);
        assert_eq!(a.snap.steals, 5);
        assert_eq!(a.q.queue_len(0), 0, "stolen model fully drained");
        assert_eq!(a.q.queue_len(1), 3, "other model untouched");
        // Draining the other model again is a no-op steal-wise.
        assert!(a.steal_queue(0, 61.0).is_empty());
        assert_eq!(a.snap.steals, 5);
        for (i, r) in stolen.iter().enumerate() {
            assert_eq!(r.req, i);
            assert_eq!(r.arrival_us, 10.0 * i as f64,
                       "original arrival preserved");
            assert_eq!(r.deadline_us,
                       r.arrival_us + cls[1].deadline_us,
                       "original deadline preserved");
            assert!(b.readmit(*r, 60.0, false));
        }
        assert_eq!(b.q.queue_len(0), 5);
        // Stolen requests land on the thief without an offered bump —
        // conservation stays anchored to the victim's ledger.
        assert_eq!(b.snap.total_offered(), 0);
    }
}
