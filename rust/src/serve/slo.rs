//! SLO classes, bounded per-class queues, admission control and load
//! shedding for the multi-tenant serving tier.
//!
//! Every request carries an SLO class (0 = highest priority) with a
//! per-class latency deadline and a bounded outstanding-request budget.
//! When a class budget is full, the [`ShedPolicy`] decides who pays:
//! reject the newcomer, shed the oldest queued request of that class, or
//! shed from the lowest-priority class that has work queued.  The
//! accounting is conservation-exact: every offered request is either
//! admitted (and later served or shed-expired) or shed at admission —
//! nothing is lost, nothing is served twice (property-tested in
//! `rust/tests/serve_multitenant.rs`).

/// One service class.
#[derive(Debug, Clone)]
pub struct SloClass {
    pub name: String,
    /// End-to-end latency deadline, microseconds after arrival.
    pub deadline_us: f64,
    /// Bound on outstanding (queued, unserved) requests of this class.
    pub queue_cap: usize,
    /// Scheduling weight (higher = more valuable to meet).  Keep >= 1.0:
    /// the cluster scheduler treats one met deadline as outranking all
    /// of its sub-unit tie-break terms.
    pub weight: f64,
}

impl SloClass {
    /// Build a class: `deadline_us` is the end-to-end budget in
    /// microseconds, `queue_cap` the outstanding-request bound,
    /// `weight` the (>= 1.0) scheduling weight.
    pub fn new(name: &str, deadline_us: f64, queue_cap: usize,
               weight: f64) -> Self {
        SloClass { name: name.into(), deadline_us, queue_cap, weight }
    }
}

/// What to do when the queue budget is exhausted.
///
/// `RejectNew` and `ShedOldest` enforce each class's `queue_cap`
/// independently.  `ShedLowestClass` treats the sum of all caps as one
/// shared pool: when the pool is full, the oldest request of the
/// lowest-priority class with queued work is displaced — but never a
/// class of strictly higher priority than the newcomer (a batch arrival
/// cannot push out interactive work; it is rejected instead).  Either
/// way the total outstanding count never exceeds the configured budget,
/// so queue memory is bounded regardless of offered load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Drop the arriving request.
    RejectNew,
    /// Drop the oldest queued request of the same class, admit the new.
    ShedOldest,
    /// Shared pool; displace the lowest-priority queued work.
    ShedLowestClass,
}

impl ShedPolicy {
    /// Parse a CLI/config spelling (`reject-new` | `shed-oldest` |
    /// `shed-lowest-class`).
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        Some(match s {
            "reject-new" => ShedPolicy::RejectNew,
            "shed-oldest" => ShedPolicy::ShedOldest,
            "shed-lowest-class" => ShedPolicy::ShedLowestClass,
            _ => return None,
        })
    }
    /// Canonical spelling, the inverse of [`ShedPolicy::parse`].
    pub fn name(self) -> &'static str {
        match self {
            ShedPolicy::RejectNew => "reject-new",
            ShedPolicy::ShedOldest => "shed-oldest",
            ShedPolicy::ShedLowestClass => "shed-lowest-class",
        }
    }
}

/// One admitted, not-yet-served request.
#[derive(Debug, Clone, Copy)]
pub struct QueuedReq {
    /// Global request id (index into the merged arrival stream).
    pub req: usize,
    /// Index into the tenant set.
    pub tenant: usize,
    /// Registry index of the target model.
    pub model: usize,
    /// SLO class index (0 = highest priority).
    pub class: usize,
    /// Admission time, microseconds of virtual time.
    pub arrival_us: f64,
    /// Absolute deadline, microseconds (`arrival_us` + class budget).
    pub deadline_us: f64,
}

/// A request shed before service, and why.
#[derive(Debug, Clone, Copy)]
pub struct ShedReq {
    /// Global request id (index into the merged arrival stream).
    pub req: usize,
    /// Index into the tenant set.
    pub tenant: usize,
    /// Registry index of the model the request targeted.
    pub model: usize,
    /// SLO class index (0 = highest priority).
    pub class: usize,
    /// true when shed at admission, false when expired in queue.
    pub at_admission: bool,
}

/// Dispatch order: class priority first, FIFO within a class — the one
/// comparator both the scoring snapshot and the dispatch drain use.
fn class_then_arrival(a: &QueuedReq, b: &QueuedReq) -> std::cmp::Ordering {
    a.class
        .cmp(&b.class)
        .then(a.arrival_us.partial_cmp(&b.arrival_us).unwrap())
}

/// Bounded multi-model queues with per-class admission budgets.
#[derive(Debug, Clone)]
pub struct AdmissionQueues {
    classes: Vec<SloClass>,
    policy: ShedPolicy,
    /// Per-model FIFO queues (arrival order within a model).
    queues: Vec<Vec<QueuedReq>>,
    /// Outstanding queued requests per class (across models).
    outstanding: Vec<usize>,
    /// Requests admitted so far (count).
    pub admitted: u64,
    /// Everything shed so far (admission rejections + queue expiries).
    pub shed: Vec<ShedReq>,
}

impl AdmissionQueues {
    /// Empty queues for `n_models` models under `classes` budgets.
    pub fn new(classes: &[SloClass], policy: ShedPolicy,
               n_models: usize) -> Self {
        AdmissionQueues {
            classes: classes.to_vec(),
            policy,
            queues: vec![Vec::new(); n_models],
            outstanding: vec![0; classes.len()],
            admitted: 0,
            shed: Vec::new(),
        }
    }

    /// The configured SLO class table.
    pub fn classes(&self) -> &[SloClass] {
        &self.classes
    }

    /// Outstanding (queued, unserved) requests across all models.
    pub fn total_queued(&self) -> usize {
        self.outstanding.iter().sum()
    }

    /// Outstanding requests queued for one model.
    pub fn queue_len(&self, model: usize) -> usize {
        self.queues[model].len()
    }

    /// Sorted dispatch view of one model's queue: class-priority first,
    /// FIFO within a class.
    pub fn sorted_queue(&self, model: usize) -> Vec<QueuedReq> {
        let mut q = self.queues[model].clone();
        q.sort_by(class_then_arrival);
        q
    }

    /// Offer one arriving request; admits it or sheds per policy.
    pub fn offer(&mut self, req: usize, tenant: usize, model: usize,
                 class: usize, now_us: f64) {
        let full = match self.policy {
            ShedPolicy::RejectNew | ShedPolicy::ShedOldest => {
                self.outstanding[class] >= self.classes[class].queue_cap
            }
            ShedPolicy::ShedLowestClass => {
                let pool: usize =
                    self.classes.iter().map(|c| c.queue_cap).sum();
                self.total_queued() >= pool
            }
        };
        if full {
            match self.policy {
                ShedPolicy::RejectNew => {
                    self.shed.push(ShedReq {
                        req, tenant, model, class, at_admission: true });
                    return;
                }
                ShedPolicy::ShedOldest => {
                    if !self.evict_oldest_of_class(class) {
                        self.shed.push(ShedReq {
                            req, tenant, model, class,
                            at_admission: true });
                        return;
                    }
                }
                ShedPolicy::ShedLowestClass => {
                    // Victim class: lowest priority (highest index) with
                    // queued work, but never a class above the newcomer.
                    let victim = (class..self.classes.len())
                        .rev()
                        .find(|&c| self.outstanding[c] > 0);
                    match victim {
                        Some(vc) if self.evict_oldest_of_class(vc) => {}
                        _ => {
                            self.shed.push(ShedReq {
                                req, tenant, model, class,
                                at_admission: true });
                            return;
                        }
                    }
                }
            }
        }
        self.outstanding[class] += 1;
        self.admitted += 1;
        self.queues[model].push(QueuedReq {
            req,
            tenant,
            model,
            class,
            arrival_us: now_us,
            deadline_us: now_us + self.classes[class].deadline_us,
        });
    }

    fn evict_oldest_of_class(&mut self, class: usize) -> bool {
        let mut best: Option<(usize, usize, f64)> = None; // (model, idx, t)
        for (m, q) in self.queues.iter().enumerate() {
            for (i, r) in q.iter().enumerate() {
                if r.class == class
                    && best.map_or(true, |(_, _, t)| r.arrival_us < t)
                {
                    best = Some((m, i, r.arrival_us));
                }
            }
        }
        let Some((m, i, _)) = best else { return false };
        let victim = self.queues[m].remove(i);
        self.outstanding[victim.class] -= 1;
        self.shed.push(ShedReq {
            req: victim.req,
            tenant: victim.tenant,
            model: victim.model,
            class: victim.class,
            at_admission: true,
        });
        true
    }

    /// Shed every queued request whose deadline has already passed (the
    /// dynamic tier's "don't burn capacity on doomed work" rule).
    pub fn drop_expired(&mut self, now_us: f64) {
        for q in &mut self.queues {
            let mut i = 0;
            while i < q.len() {
                if q[i].deadline_us <= now_us {
                    let victim = q.remove(i);
                    self.outstanding[victim.class] -= 1;
                    self.shed.push(ShedReq {
                        req: victim.req,
                        tenant: victim.tenant,
                        model: victim.model,
                        class: victim.class,
                        at_admission: false,
                    });
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Remove up to `max` requests of one model for dispatch.  With
    /// `class_order`, higher-priority classes leave the queue first
    /// (FIFO within a class); otherwise strict FIFO.
    pub fn take_batch(&mut self, model: usize, max: usize,
                      class_order: bool) -> Vec<QueuedReq> {
        let q = &mut self.queues[model];
        if class_order {
            q.sort_by(class_then_arrival);
        } else {
            q.sort_by(|a, b| {
                a.arrival_us.partial_cmp(&b.arrival_us).unwrap()
            });
        }
        let take = max.min(q.len());
        let taken: Vec<QueuedReq> = q.drain(..take).collect();
        for r in &taken {
            self.outstanding[r.class] -= 1;
        }
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<SloClass> {
        vec![
            SloClass::new("interactive", 20_000.0, 2, 4.0),
            SloClass::new("batch", 100_000.0, 3, 1.0),
        ]
    }

    #[test]
    fn reject_new_bounds_the_queue() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        for i in 0..5 {
            q.offer(i, 0, 0, 0, i as f64);
        }
        assert_eq!(q.admitted, 2);
        assert_eq!(q.shed.len(), 3);
        assert!(q.shed.iter().all(|s| s.at_admission));
        assert_eq!(q.total_queued(), 2);
        // the admitted ones are the first two
        let taken = q.take_batch(0, 10, true);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![0, 1]);
        assert_eq!(q.total_queued(), 0);
    }

    #[test]
    fn shed_oldest_keeps_the_newest() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::ShedOldest, 1);
        for i in 0..5 {
            q.offer(i, 0, 0, 0, i as f64);
        }
        assert_eq!(q.admitted, 5);
        assert_eq!(q.shed.len(), 3); // 0, 1, 2 displaced
        let taken = q.take_batch(0, 10, true);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![3, 4]);
    }

    #[test]
    fn shed_lowest_class_protects_high_priority() {
        let cls = classes();
        let mut q =
            AdmissionQueues::new(&cls, ShedPolicy::ShedLowestClass, 1);
        // Fill the batch class.
        for i in 0..3 {
            q.offer(i, 1, 0, 1, i as f64);
        }
        // Fill interactive, then overflow it: the victim must come from
        // the batch class (lower priority), not from interactive.
        q.offer(10, 0, 0, 0, 10.0);
        q.offer(11, 0, 0, 0, 11.0);
        q.offer(12, 0, 0, 0, 12.0);
        let shed_classes: Vec<usize> =
            q.shed.iter().map(|s| s.class).collect();
        assert_eq!(shed_classes, vec![1]);
        assert_eq!(q.shed[0].req, 0); // oldest batch request paid
        // A batch overflow can never displace interactive work.
        q.offer(13, 1, 0, 1, 13.0);
        q.offer(14, 1, 0, 1, 14.0);
        let shed_after: Vec<usize> =
            q.shed.iter().map(|s| s.class).collect();
        assert!(shed_after.iter().all(|&c| c == 1));
    }

    #[test]
    fn expiry_sheds_with_accounting() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 2);
        q.offer(0, 0, 0, 0, 0.0); // deadline 20ms
        q.offer(1, 0, 1, 1, 0.0); // deadline 100ms
        q.drop_expired(50_000.0);
        assert_eq!(q.shed.len(), 1);
        assert_eq!(q.shed[0].req, 0);
        assert!(!q.shed[0].at_admission);
        assert_eq!(q.total_queued(), 1);
        assert_eq!(q.queue_len(0), 0);
        assert_eq!(q.queue_len(1), 1);
    }

    #[test]
    fn take_batch_orders_by_class_then_fifo() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        q.offer(0, 0, 0, 1, 0.0);
        q.offer(1, 0, 0, 0, 1.0);
        q.offer(2, 0, 0, 1, 2.0);
        q.offer(3, 0, 0, 0, 3.0);
        let taken = q.take_batch(0, 3, true);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![1, 3, 0]);
        assert_eq!(q.total_queued(), 1);
    }
}
