//! SLO classes, bounded per-class queues, admission control and load
//! shedding for the multi-tenant serving tier.
//!
//! Every request carries an SLO class (0 = highest priority) with a
//! per-class latency deadline and a bounded outstanding-request budget.
//! When a class budget is full, the [`ShedPolicy`] decides who pays:
//! reject the newcomer, shed the oldest queued request of that class, or
//! shed from the lowest-priority class that has work queued.  The
//! accounting is conservation-exact: every offered request is either
//! admitted (and later served or shed-expired) or shed at admission —
//! nothing is lost, nothing is served twice (property-tested in
//! `rust/tests/serve_multitenant.rs`).
//!
//! # The indexed core
//!
//! [`AdmissionQueues`] stores each model's backlog as per-(model, class)
//! `VecDeque` rings that are *sorted by construction* under the dispatch
//! comparator (class-priority ladder, FIFO within a class):
//!
//! * [`AdmissionQueues::dispatch_view`] is a borrowing iterator in
//!   dispatch order — zero clones, zero sorts (the board scheduler's
//!   scoring loop reads it directly);
//! * [`AdmissionQueues::take_batch`] drains ring heads in order, no sort;
//! * shed-policy evictions and expiry sweeps are head-pops (plus an O(1)
//!   head-deadline early-out for the no-expiry common case), not scans.
//!
//! The original flat-vec clone+sort implementation survives verbatim as
//! [`ReferenceQueues`] — the readable spec.  `rust/tests/slo_indexed.rs`
//! drives both through randomized offer/take/shed/expire interleavings
//! and pins the indexed path bit-identical: same admissions, same
//! sorted queues, same take-batch drains, same shed victims.  Two
//! reference behaviors are permutation artifacts of its in-place sorts
//! rather than specified semantics, and the indexed path canonicalizes
//! them to admission order: the emission order of shed records *within
//! one expiry sweep* (every downstream consumer is a counter, so the
//! pin compares shed logs as multisets plus exact admission-shed
//! order), and the strict-FIFO tie-break between requests with exactly
//! equal arrival times (the indexed drain uses admission order).  The
//! `fig_fleet` bench times the two implementations against each other
//! (dispatch ns/req at Q = 10^2..10^4).

use std::collections::VecDeque;

/// One service class.
#[derive(Debug, Clone)]
pub struct SloClass {
    pub name: String,
    /// End-to-end latency deadline, microseconds after arrival.
    pub deadline_us: f64,
    /// Bound on outstanding (queued, unserved) requests of this class.
    pub queue_cap: usize,
    /// Scheduling weight (higher = more valuable to meet).  Keep >= 1.0:
    /// the cluster scheduler treats one met deadline as outranking all
    /// of its sub-unit tie-break terms.
    pub weight: f64,
}

impl SloClass {
    /// Build a class: `deadline_us` is the end-to-end budget in
    /// microseconds, `queue_cap` the outstanding-request bound,
    /// `weight` the (>= 1.0) scheduling weight.
    pub fn new(name: &str, deadline_us: f64, queue_cap: usize,
               weight: f64) -> Self {
        SloClass { name: name.into(), deadline_us, queue_cap, weight }
    }
}

/// An energy service-level objective: a budget on mean energy per
/// served inference, in millijoules.
///
/// Latency SLOs ([`SloClass::deadline_us`]) bound *when* a request
/// finishes; an `EnergySlo` bounds *what it costs* to finish it.  The
/// fleet reports both so a governor can be judged on the full trade:
/// attainment (latency side) and joules per inference (energy side).
/// Checked against [`crate::serve::PerfSnapshot::energy_per_inference_mj`]
/// after a run — it is an observability target, not an admission gate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergySlo {
    /// Mean-energy budget per served inference, millijoules.
    pub budget_mj_per_inference: f64,
}

impl EnergySlo {
    /// Build an energy SLO with the given per-inference budget
    /// (millijoules; must be finite and positive to be meaningful).
    pub fn new(budget_mj_per_inference: f64) -> Self {
        EnergySlo { budget_mj_per_inference }
    }

    /// Whether a measured mean energy per inference (millijoules, e.g.
    /// from `PerfSnapshot::energy_per_inference_mj()`) meets the budget.
    pub fn met(&self, energy_per_inference_mj: f64) -> bool {
        energy_per_inference_mj <= self.budget_mj_per_inference
    }
}

/// What to do when the queue budget is exhausted.
///
/// `RejectNew` and `ShedOldest` enforce each class's `queue_cap`
/// independently.  `ShedLowestClass` treats the sum of all caps as one
/// shared pool: when the pool is full, the oldest request of the
/// lowest-priority class with queued work is displaced — but never a
/// class of strictly higher priority than the newcomer (a batch arrival
/// cannot push out interactive work; it is rejected instead).  Either
/// way the total outstanding count never exceeds the configured budget,
/// so queue memory is bounded regardless of offered load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Drop the arriving request.
    RejectNew,
    /// Drop the oldest queued request of the same class, admit the new.
    ShedOldest,
    /// Shared pool; displace the lowest-priority queued work.
    ShedLowestClass,
}

impl ShedPolicy {
    /// Parse a CLI/config spelling (`reject-new` | `shed-oldest` |
    /// `shed-lowest-class`).
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        Some(match s {
            "reject-new" => ShedPolicy::RejectNew,
            "shed-oldest" => ShedPolicy::ShedOldest,
            "shed-lowest-class" => ShedPolicy::ShedLowestClass,
            _ => return None,
        })
    }
    /// Canonical spelling, the inverse of [`ShedPolicy::parse`].
    pub fn name(self) -> &'static str {
        match self {
            ShedPolicy::RejectNew => "reject-new",
            ShedPolicy::ShedOldest => "shed-oldest",
            ShedPolicy::ShedLowestClass => "shed-lowest-class",
        }
    }
}

/// One admitted, not-yet-served request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedReq {
    /// Global request id (index into the merged arrival stream).
    pub req: usize,
    /// Index into the tenant set.
    pub tenant: usize,
    /// Registry index of the target model.
    pub model: usize,
    /// SLO class index (0 = highest priority).
    pub class: usize,
    /// Admission time, microseconds of virtual time.
    pub arrival_us: f64,
    /// Absolute deadline, microseconds (`arrival_us` + class budget).
    pub deadline_us: f64,
}

/// A request shed before service, and why.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedReq {
    /// Global request id (index into the merged arrival stream).
    pub req: usize,
    /// Index into the tenant set.
    pub tenant: usize,
    /// Registry index of the model the request targeted.
    pub model: usize,
    /// SLO class index (0 = highest priority).
    pub class: usize,
    /// true when shed at admission, false when expired in queue.
    pub at_admission: bool,
}

/// Dispatch order: class priority first, FIFO within a class — the one
/// comparator both the scoring view and the dispatch drain realize.
fn class_then_arrival(a: &QueuedReq, b: &QueuedReq) -> std::cmp::Ordering {
    a.class
        .cmp(&b.class)
        .then(a.arrival_us.partial_cmp(&b.arrival_us).unwrap())
}

/// One ring entry: the request plus its global admission sequence
/// number.  The sequence number reproduces the reference flat-vec
/// insertion order exactly wherever the dispatch comparator ties
/// (equal arrivals within a class, FIFO merges across classes).
#[derive(Debug, Clone, Copy)]
struct Slot {
    req: QueuedReq,
    seq: u64,
}

/// Bounded multi-model queues with per-class admission budgets, indexed
/// for O(1)/O(log Q) dispatch (see the module docs).  Pin spec:
/// [`ReferenceQueues`].
#[derive(Debug, Clone)]
pub struct AdmissionQueues {
    classes: Vec<SloClass>,
    policy: ShedPolicy,
    /// `rings[model][class]`: sorted by (arrival, admission seq) by
    /// construction, so chaining rings in class order yields the
    /// dispatch order with no sort.
    rings: Vec<Vec<VecDeque<Slot>>>,
    /// Outstanding queued requests per class (across models).
    outstanding: Vec<usize>,
    /// Outstanding queued requests per model (across classes).
    model_len: Vec<usize>,
    /// Outstanding queued requests in total.
    total: usize,
    /// `ShedLowestClass` shared-pool bound: sum of all class caps,
    /// precomputed once at construction.
    pool_cap: usize,
    /// Earliest absolute deadline over all queued requests; `None` when
    /// unknown (recomputed lazily by the expiry sweep).  Lets
    /// [`AdmissionQueues::drop_expired`] return in O(1) when nothing
    /// has expired — the common case on every board pump.
    earliest_deadline: Option<f64>,
    /// Monotonic admission counter backing the `Slot` sequence numbers.
    next_seq: u64,
    /// Requests admitted so far (count).
    pub admitted: u64,
    /// Everything shed so far (admission rejections + queue expiries).
    pub shed: Vec<ShedReq>,
}

impl AdmissionQueues {
    /// Empty queues for `n_models` models under `classes` budgets.
    pub fn new(classes: &[SloClass], policy: ShedPolicy,
               n_models: usize) -> Self {
        AdmissionQueues {
            pool_cap: classes.iter().map(|c| c.queue_cap).sum(),
            rings: (0..n_models)
                .map(|_| vec![VecDeque::new(); classes.len()])
                .collect(),
            outstanding: vec![0; classes.len()],
            model_len: vec![0; n_models],
            total: 0,
            earliest_deadline: Some(f64::INFINITY),
            next_seq: 0,
            classes: classes.to_vec(),
            policy,
            admitted: 0,
            shed: Vec::new(),
        }
    }

    /// The configured SLO class table.
    pub fn classes(&self) -> &[SloClass] {
        &self.classes
    }

    /// Outstanding (queued, unserved) requests across all models, O(1).
    pub fn total_queued(&self) -> usize {
        self.total
    }

    /// The shed log's suffix starting at `from` — the entries appended
    /// since a caller last settled them.  The board pump uses this to
    /// account (and trace) each shed/expiry exactly once.
    pub fn shed_since(&self, from: usize) -> &[ShedReq] {
        &self.shed[from.min(self.shed.len())..]
    }

    /// Outstanding requests queued for one model, O(1).
    pub fn queue_len(&self, model: usize) -> usize {
        self.model_len[model]
    }

    /// Borrowing dispatch view of one model's queue: class-priority
    /// first, FIFO within a class — the exact order
    /// [`AdmissionQueues::take_batch`] drains in.  Zero clones, zero
    /// sorts; the rings are sorted by construction.
    pub fn dispatch_view(&self, model: usize)
        -> impl Iterator<Item = &QueuedReq> + '_
    {
        self.rings[model]
            .iter()
            .flat_map(|ring| ring.iter().map(|s| &s.req))
    }

    /// Oldest arrival time queued for one model (the FIFO head), or
    /// `INFINITY` when the model's queue is empty.  O(classes): the min
    /// over the ring heads.
    pub fn head_arrival_us(&self, model: usize) -> f64 {
        self.rings[model]
            .iter()
            .filter_map(|ring| ring.front())
            .map(|s| s.req.arrival_us)
            .fold(f64::INFINITY, f64::min)
    }

    /// The dispatch view materialized through the reference clone+sort
    /// path (the old `sorted_queue`): equal to
    /// [`AdmissionQueues::dispatch_view`] by the ring invariant — the
    /// pin tests assert exactly that.
    pub fn sorted_queue_reference(&self, model: usize) -> Vec<QueuedReq> {
        let mut slots: Vec<Slot> = self.rings[model]
            .iter()
            .flat_map(|ring| ring.iter().copied())
            .collect();
        slots.sort_by(|a, b| {
            class_then_arrival(&a.req, &b.req).then(a.seq.cmp(&b.seq))
        });
        slots.into_iter().map(|s| s.req).collect()
    }

    /// Offer one arriving request; admits it or sheds per policy.  O(1)
    /// plus, under a full budget, one O(models) head-peek eviction.
    pub fn offer(&mut self, req: usize, tenant: usize, model: usize,
                 class: usize, now_us: f64) {
        let full = match self.policy {
            ShedPolicy::RejectNew | ShedPolicy::ShedOldest => {
                self.outstanding[class] >= self.classes[class].queue_cap
            }
            // Shared pool bound precomputed at construction.
            ShedPolicy::ShedLowestClass => self.total >= self.pool_cap,
        };
        if full {
            match self.policy {
                ShedPolicy::RejectNew => {
                    self.shed.push(ShedReq {
                        req, tenant, model, class, at_admission: true });
                    return;
                }
                ShedPolicy::ShedOldest => {
                    if !self.evict_oldest_of_class(class) {
                        self.shed.push(ShedReq {
                            req, tenant, model, class,
                            at_admission: true });
                        return;
                    }
                }
                ShedPolicy::ShedLowestClass => {
                    // Victim class: lowest priority (highest index) with
                    // queued work, but never a class above the newcomer.
                    let victim = (class..self.classes.len())
                        .rev()
                        .find(|&c| self.outstanding[c] > 0);
                    match victim {
                        Some(vc) if self.evict_oldest_of_class(vc) => {}
                        _ => {
                            self.shed.push(ShedReq {
                                req, tenant, model, class,
                                at_admission: true });
                            return;
                        }
                    }
                }
            }
        }
        self.outstanding[class] += 1;
        self.model_len[model] += 1;
        self.total += 1;
        self.admitted += 1;
        let r = QueuedReq {
            req,
            tenant,
            model,
            class,
            arrival_us: now_us,
            deadline_us: now_us + self.classes[class].deadline_us,
        };
        if let Some(d) = self.earliest_deadline {
            self.earliest_deadline = Some(d.min(r.deadline_us));
        }
        let slot = Slot { req: r, seq: self.next_seq };
        self.next_seq += 1;
        let ring = &mut self.rings[model][class];
        match ring.back() {
            // Out-of-order admission: keep the ring sorted by
            // (arrival, seq) — binary-search insert, O(1) for the
            // in-order protocol every driver follows.
            Some(b) if b.req.arrival_us > now_us => {
                let i = ring
                    .partition_point(|s| s.req.arrival_us <= now_us);
                ring.insert(i, slot);
            }
            _ => ring.push_back(slot),
        }
    }

    /// Remove a queued request from the aggregate accounting (the ring
    /// pop itself happens at the call site).
    fn account_removed(&mut self, r: &QueuedReq) {
        self.outstanding[r.class] -= 1;
        self.model_len[r.model] -= 1;
        self.total -= 1;
        // Removal can only raise the earliest deadline; recompute lazily.
        self.earliest_deadline = None;
    }

    /// Shed the oldest queued request of `class`: O(models) head peeks
    /// (each ring head is its (model, class) minimum by construction),
    /// one head pop.
    fn evict_oldest_of_class(&mut self, class: usize) -> bool {
        let mut best: Option<(usize, f64)> = None; // (model, arrival)
        for (m, rings) in self.rings.iter().enumerate() {
            if let Some(s) = rings[class].front() {
                if best.map_or(true, |(_, t)| s.req.arrival_us < t) {
                    best = Some((m, s.req.arrival_us));
                }
            }
        }
        let Some((m, _)) = best else { return false };
        let victim = self.rings[m][class].pop_front().unwrap().req;
        self.account_removed(&victim);
        self.shed.push(ShedReq {
            req: victim.req,
            tenant: victim.tenant,
            model: victim.model,
            class: victim.class,
            at_admission: true,
        });
        true
    }

    /// Shed every queued request whose deadline has already passed (the
    /// dynamic tier's "don't burn capacity on doomed work" rule).  O(1)
    /// when nothing has expired (head-deadline early-out); otherwise
    /// head pops only — expired requests form a prefix of every ring
    /// (deadline = arrival + class constant, rings sorted by arrival).
    pub fn drop_expired(&mut self, now_us: f64) {
        if let Some(d) = self.earliest_deadline {
            if d > now_us {
                return;
            }
        }
        let mut victims: Vec<Slot> = Vec::new();
        for m in 0..self.rings.len() {
            // Pop each ring's expired prefix, then shed in admission
            // (seq) order — deterministic and content-defined, unlike
            // the reference's within-sweep emission order, which is an
            // artifact of its in-place sorts (the pin compares shed
            // logs as multisets for exactly this reason; every counter
            // downstream is order-insensitive).
            victims.clear();
            for ring in self.rings[m].iter_mut() {
                while ring
                    .front()
                    .map_or(false, |s| s.req.deadline_us <= now_us)
                {
                    victims.push(ring.pop_front().unwrap());
                }
            }
            victims.sort_by_key(|s| s.seq);
            for s in &victims {
                let victim = s.req;
                self.account_removed(&victim);
                self.shed.push(ShedReq {
                    req: victim.req,
                    tenant: victim.tenant,
                    model: victim.model,
                    class: victim.class,
                    at_admission: false,
                });
            }
        }
        // Refresh the head-deadline aggregate from the surviving ring
        // heads (each head is its ring's minimum deadline).
        let mut d = f64::INFINITY;
        for rings in &self.rings {
            for ring in rings {
                if let Some(s) = ring.front() {
                    d = d.min(s.req.deadline_us);
                }
            }
        }
        self.earliest_deadline = Some(d);
    }

    /// Drain every queued request, in admission (seq) order, leaving
    /// the queues empty but the admission/shed logs intact.  The fleet
    /// failover path uses this when a board crashes (queued work moves
    /// back to the front tier) — the drained requests keep their
    /// original `arrival_us`/`deadline_us` and are *not* re-counted as
    /// admitted when they land on a survivor via
    /// [`AdmissionQueues::readmit`].
    pub fn drain_all(&mut self) -> Vec<QueuedReq> {
        let mut slots: Vec<Slot> = Vec::with_capacity(self.total);
        for rings in &mut self.rings {
            for ring in rings {
                slots.extend(ring.drain(..));
            }
        }
        slots.sort_by_key(|s| s.seq);
        self.outstanding.iter_mut().for_each(|o| *o = 0);
        self.model_len.iter_mut().for_each(|l| *l = 0);
        self.total = 0;
        self.earliest_deadline = Some(f64::INFINITY);
        slots.into_iter().map(|s| s.req).collect()
    }

    /// Drain every queued request of one model, in admission (seq)
    /// order — the work-stealing analogue of
    /// [`AdmissionQueues::drain_all`].  The drained requests keep
    /// their original `arrival_us`/`deadline_us` (microseconds of
    /// virtual time) and re-enter another board via
    /// [`AdmissionQueues::readmit`] without being re-counted as
    /// admitted.  Ownership stays exclusive: a request lives in
    /// exactly one board's rings *or* the fleet's pend-heap, so work
    /// drained by a crash (and re-pended for retry) can never also be
    /// stolen from here — stealing only ever sees requests a board
    /// currently holds.
    pub fn drain_model(&mut self, model: usize) -> Vec<QueuedReq> {
        let mut slots: Vec<Slot> =
            Vec::with_capacity(self.model_len[model]);
        for ring in &mut self.rings[model] {
            slots.extend(ring.drain(..));
        }
        slots.sort_by_key(|s| s.seq);
        for s in &slots {
            self.outstanding[s.req.class] -= 1;
        }
        self.total -= slots.len();
        self.model_len[model] = 0;
        if !slots.is_empty() {
            // Removal can only raise the earliest deadline; recompute
            // lazily like `account_removed`.
            self.earliest_deadline = None;
        }
        slots.into_iter().map(|s| s.req).collect()
    }

    /// Re-admit a request drained from another board's queues (its
    /// original `arrival_us`/`deadline_us` preserved).  Enforces the
    /// same cap/shed policy as [`AdmissionQueues::offer`] but does NOT
    /// bump `admitted` — the request was already counted once at its
    /// first admission, and conservation demands it be counted exactly
    /// once.  Returns `true` when the request landed in a queue; on
    /// `false` it was shed at (re-)admission and logged in `shed`.
    pub fn readmit(&mut self, r: QueuedReq) -> bool {
        let full = match self.policy {
            ShedPolicy::RejectNew | ShedPolicy::ShedOldest => {
                self.outstanding[r.class] >= self.classes[r.class].queue_cap
            }
            ShedPolicy::ShedLowestClass => self.total >= self.pool_cap,
        };
        if full {
            let rejected = match self.policy {
                ShedPolicy::RejectNew => true,
                ShedPolicy::ShedOldest => {
                    !self.evict_oldest_of_class(r.class)
                }
                ShedPolicy::ShedLowestClass => {
                    let victim = (r.class..self.classes.len())
                        .rev()
                        .find(|&c| self.outstanding[c] > 0);
                    !matches!(victim,
                              Some(vc) if self.evict_oldest_of_class(vc))
                }
            };
            if rejected {
                self.shed.push(ShedReq {
                    req: r.req,
                    tenant: r.tenant,
                    model: r.model,
                    class: r.class,
                    at_admission: true,
                });
                return false;
            }
        }
        self.outstanding[r.class] += 1;
        self.model_len[r.model] += 1;
        self.total += 1;
        if let Some(d) = self.earliest_deadline {
            self.earliest_deadline = Some(d.min(r.deadline_us));
        }
        let slot = Slot { req: r, seq: self.next_seq };
        self.next_seq += 1;
        let ring = &mut self.rings[r.model][r.class];
        // A failed-over request usually arrived before everything the
        // survivor has queued since — binary-insert keeps the ring
        // sorted by (arrival, seq).
        let i = ring
            .partition_point(|s| s.req.arrival_us <= r.arrival_us);
        if i == ring.len() {
            ring.push_back(slot);
        } else {
            ring.insert(i, slot);
        }
        true
    }

    /// Remove up to `max` requests of one model for dispatch.  With
    /// `class_order`, higher-priority classes leave the queue first
    /// (FIFO within a class); otherwise strict FIFO.  Head pops in both
    /// cases — the FIFO path is a k-way merge over the class rings by
    /// (arrival, admission seq).
    pub fn take_batch(&mut self, model: usize, max: usize,
                      class_order: bool) -> Vec<QueuedReq> {
        let mut taken: Vec<QueuedReq> = Vec::new();
        if class_order {
            for c in 0..self.classes.len() {
                while taken.len() < max {
                    let Some(s) = self.rings[model][c].pop_front() else {
                        break;
                    };
                    taken.push(s.req);
                }
                if taken.len() >= max {
                    break;
                }
            }
        } else {
            while taken.len() < max {
                let mut best: Option<(usize, f64, u64)> = None;
                for (c, ring) in self.rings[model].iter().enumerate() {
                    if let Some(s) = ring.front() {
                        let better = best.map_or(true, |(_, a, q)| {
                            (s.req.arrival_us, s.seq) < (a, q)
                        });
                        if better {
                            best = Some((c, s.req.arrival_us, s.seq));
                        }
                    }
                }
                let Some((c, _, _)) = best else { break };
                taken.push(self.rings[model][c].pop_front().unwrap().req);
            }
        }
        for r in &taken {
            self.outstanding[r.class] -= 1;
        }
        self.model_len[model] -= taken.len();
        self.total -= taken.len();
        if !taken.is_empty() {
            self.earliest_deadline = None;
        }
        taken
    }
}

/// The original flat-vec admission queues — the readable spec the
/// indexed [`AdmissionQueues`] is pinned against (dispatch/take/evict/
/// expiry order and shed accounting bit-identical; see
/// `rust/tests/slo_indexed.rs`).  Also the reference side of the
/// `fig_fleet` dispatch bench: its `sorted_queue` clones and sorts the
/// whole backlog per call and `take_batch` sorts again, the O(Q log Q)
/// cost the indexed core removes.  Semantics are documented on the
/// indexed struct; this one exists to stay unchanged.
#[derive(Debug, Clone)]
pub struct ReferenceQueues {
    classes: Vec<SloClass>,
    policy: ShedPolicy,
    /// Per-model FIFO queues (arrival order within a model).
    queues: Vec<Vec<QueuedReq>>,
    /// Outstanding queued requests per class (across models).
    outstanding: Vec<usize>,
    /// Requests admitted so far (count).
    pub admitted: u64,
    /// Everything shed so far (admission rejections + queue expiries).
    pub shed: Vec<ShedReq>,
}

impl ReferenceQueues {
    /// Empty queues for `n_models` models under `classes` budgets.
    pub fn new(classes: &[SloClass], policy: ShedPolicy,
               n_models: usize) -> Self {
        ReferenceQueues {
            classes: classes.to_vec(),
            policy,
            queues: vec![Vec::new(); n_models],
            outstanding: vec![0; classes.len()],
            admitted: 0,
            shed: Vec::new(),
        }
    }

    /// Outstanding (queued, unserved) requests across all models.
    pub fn total_queued(&self) -> usize {
        self.outstanding.iter().sum()
    }

    /// Outstanding requests queued for one model.
    pub fn queue_len(&self, model: usize) -> usize {
        self.queues[model].len()
    }

    /// Sorted dispatch view of one model's queue: class-priority first,
    /// FIFO within a class.  Clones and sorts per call — the cost the
    /// indexed `dispatch_view` removes.
    pub fn sorted_queue(&self, model: usize) -> Vec<QueuedReq> {
        let mut q = self.queues[model].clone();
        q.sort_by(class_then_arrival);
        q
    }

    /// Offer one arriving request; admits it or sheds per policy.
    pub fn offer(&mut self, req: usize, tenant: usize, model: usize,
                 class: usize, now_us: f64) {
        let full = match self.policy {
            ShedPolicy::RejectNew | ShedPolicy::ShedOldest => {
                self.outstanding[class] >= self.classes[class].queue_cap
            }
            ShedPolicy::ShedLowestClass => {
                let pool: usize =
                    self.classes.iter().map(|c| c.queue_cap).sum();
                self.total_queued() >= pool
            }
        };
        if full {
            match self.policy {
                ShedPolicy::RejectNew => {
                    self.shed.push(ShedReq {
                        req, tenant, model, class, at_admission: true });
                    return;
                }
                ShedPolicy::ShedOldest => {
                    if !self.evict_oldest_of_class(class) {
                        self.shed.push(ShedReq {
                            req, tenant, model, class,
                            at_admission: true });
                        return;
                    }
                }
                ShedPolicy::ShedLowestClass => {
                    let victim = (class..self.classes.len())
                        .rev()
                        .find(|&c| self.outstanding[c] > 0);
                    match victim {
                        Some(vc) if self.evict_oldest_of_class(vc) => {}
                        _ => {
                            self.shed.push(ShedReq {
                                req, tenant, model, class,
                                at_admission: true });
                            return;
                        }
                    }
                }
            }
        }
        self.outstanding[class] += 1;
        self.admitted += 1;
        self.queues[model].push(QueuedReq {
            req,
            tenant,
            model,
            class,
            arrival_us: now_us,
            deadline_us: now_us + self.classes[class].deadline_us,
        });
    }

    fn evict_oldest_of_class(&mut self, class: usize) -> bool {
        let mut best: Option<(usize, usize, f64)> = None; // (model, idx, t)
        for (m, q) in self.queues.iter().enumerate() {
            for (i, r) in q.iter().enumerate() {
                if r.class == class
                    && best.map_or(true, |(_, _, t)| r.arrival_us < t)
                {
                    best = Some((m, i, r.arrival_us));
                }
            }
        }
        let Some((m, i, _)) = best else { return false };
        let victim = self.queues[m].remove(i);
        self.outstanding[victim.class] -= 1;
        self.shed.push(ShedReq {
            req: victim.req,
            tenant: victim.tenant,
            model: victim.model,
            class: victim.class,
            at_admission: true,
        });
        true
    }

    /// Shed every queued request whose deadline has already passed.
    pub fn drop_expired(&mut self, now_us: f64) {
        for q in &mut self.queues {
            let mut i = 0;
            while i < q.len() {
                if q[i].deadline_us <= now_us {
                    let victim = q.remove(i);
                    self.outstanding[victim.class] -= 1;
                    self.shed.push(ShedReq {
                        req: victim.req,
                        tenant: victim.tenant,
                        model: victim.model,
                        class: victim.class,
                        at_admission: false,
                    });
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Remove up to `max` requests of one model for dispatch (sorts the
    /// model's whole queue per call).
    pub fn take_batch(&mut self, model: usize, max: usize,
                      class_order: bool) -> Vec<QueuedReq> {
        let q = &mut self.queues[model];
        if class_order {
            q.sort_by(class_then_arrival);
        } else {
            q.sort_by(|a, b| {
                a.arrival_us.partial_cmp(&b.arrival_us).unwrap()
            });
        }
        let take = max.min(q.len());
        let taken: Vec<QueuedReq> = q.drain(..take).collect();
        for r in &taken {
            self.outstanding[r.class] -= 1;
        }
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<SloClass> {
        vec![
            SloClass::new("interactive", 20_000.0, 2, 4.0),
            SloClass::new("batch", 100_000.0, 3, 1.0),
        ]
    }

    #[test]
    fn energy_slo_gates_on_the_mj_budget() {
        let slo = EnergySlo::new(50.0);
        assert!(slo.met(49.9));
        assert!(slo.met(50.0), "budget boundary is inclusive");
        assert!(!slo.met(50.1));
        // Zero measured energy (e.g. a run with no served requests)
        // trivially meets any positive budget.
        assert!(slo.met(0.0));
    }

    #[test]
    fn reject_new_bounds_the_queue() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        for i in 0..5 {
            q.offer(i, 0, 0, 0, i as f64);
        }
        assert_eq!(q.admitted, 2);
        assert_eq!(q.shed.len(), 3);
        assert!(q.shed.iter().all(|s| s.at_admission));
        assert_eq!(q.total_queued(), 2);
        // the admitted ones are the first two
        let taken = q.take_batch(0, 10, true);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![0, 1]);
        assert_eq!(q.total_queued(), 0);
    }

    #[test]
    fn shed_oldest_keeps_the_newest() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::ShedOldest, 1);
        for i in 0..5 {
            q.offer(i, 0, 0, 0, i as f64);
        }
        assert_eq!(q.admitted, 5);
        assert_eq!(q.shed.len(), 3); // 0, 1, 2 displaced
        let taken = q.take_batch(0, 10, true);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![3, 4]);
    }

    #[test]
    fn shed_lowest_class_protects_high_priority() {
        let cls = classes();
        let mut q =
            AdmissionQueues::new(&cls, ShedPolicy::ShedLowestClass, 1);
        // Fill the batch class.
        for i in 0..3 {
            q.offer(i, 1, 0, 1, i as f64);
        }
        // Fill interactive, then overflow it: the victim must come from
        // the batch class (lower priority), not from interactive.
        q.offer(10, 0, 0, 0, 10.0);
        q.offer(11, 0, 0, 0, 11.0);
        q.offer(12, 0, 0, 0, 12.0);
        let shed_classes: Vec<usize> =
            q.shed.iter().map(|s| s.class).collect();
        assert_eq!(shed_classes, vec![1]);
        assert_eq!(q.shed[0].req, 0); // oldest batch request paid
        // A batch overflow can never displace interactive work.
        q.offer(13, 1, 0, 1, 13.0);
        q.offer(14, 1, 0, 1, 14.0);
        let shed_after: Vec<usize> =
            q.shed.iter().map(|s| s.class).collect();
        assert!(shed_after.iter().all(|&c| c == 1));
    }

    #[test]
    fn expiry_sheds_with_accounting() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 2);
        q.offer(0, 0, 0, 0, 0.0); // deadline 20ms
        q.offer(1, 0, 1, 1, 0.0); // deadline 100ms
        q.drop_expired(50_000.0);
        assert_eq!(q.shed.len(), 1);
        assert_eq!(q.shed[0].req, 0);
        assert!(!q.shed[0].at_admission);
        assert_eq!(q.total_queued(), 1);
        assert_eq!(q.queue_len(0), 0);
        assert_eq!(q.queue_len(1), 1);
        // The head-deadline early-out: nothing more expires below the
        // surviving deadline, and the sweep stays accounting-exact.
        q.drop_expired(60_000.0);
        assert_eq!(q.shed.len(), 1);
        q.drop_expired(100_000.0);
        assert_eq!(q.shed.len(), 2);
        assert_eq!(q.total_queued(), 0);
    }

    #[test]
    fn take_batch_orders_by_class_then_fifo() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        q.offer(0, 0, 0, 1, 0.0);
        q.offer(1, 0, 0, 0, 1.0);
        q.offer(2, 0, 0, 1, 2.0);
        q.offer(3, 0, 0, 0, 3.0);
        let taken = q.take_batch(0, 3, true);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![1, 3, 0]);
        assert_eq!(q.total_queued(), 1);
    }

    #[test]
    fn dispatch_view_is_the_sorted_order_without_clones() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 2);
        q.offer(0, 0, 0, 1, 0.0);
        q.offer(1, 0, 0, 0, 1.0);
        q.offer(2, 0, 1, 0, 1.5);
        q.offer(3, 0, 0, 0, 2.0);
        let view: Vec<QueuedReq> = q.dispatch_view(0).copied().collect();
        assert_eq!(view, q.sorted_queue_reference(0));
        assert_eq!(view.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![1, 3, 0]);
        assert_eq!(q.head_arrival_us(0), 0.0);
        assert_eq!(q.head_arrival_us(1), 1.5);
    }

    #[test]
    fn out_of_order_offers_keep_rings_sorted() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        q.offer(0, 0, 0, 1, 5.0);
        q.offer(1, 0, 0, 1, 2.0); // behind the back of the ring
        q.offer(2, 0, 0, 1, 2.0); // tie: admission order breaks it
        let view: Vec<usize> =
            q.dispatch_view(0).map(|r| r.req).collect();
        assert_eq!(view, vec![1, 2, 0]);
        assert_eq!(q.head_arrival_us(0), 2.0);
        // FIFO take follows (arrival, admission) order too.
        let taken = q.take_batch(0, 2, false);
        assert_eq!(taken.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![1, 2]);
    }

    #[test]
    fn shared_pool_cap_is_precomputed_and_enforced() {
        let cls = classes(); // caps 2 + 3 = 5
        let mut q =
            AdmissionQueues::new(&cls, ShedPolicy::ShedLowestClass, 1);
        for i in 0..7 {
            q.offer(i, 0, 0, 1, i as f64);
        }
        // Pool bound (5) held: two oldest batch requests displaced.
        assert_eq!(q.total_queued(), 5);
        assert_eq!(q.shed.len(), 2);
        assert_eq!(q.shed[0].req, 0);
        assert_eq!(q.shed[1].req, 1);
    }

    #[test]
    fn drain_all_empties_queues_without_touching_the_logs() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 2);
        q.offer(0, 0, 0, 1, 0.0);
        q.offer(1, 0, 1, 0, 1.0);
        q.offer(2, 0, 0, 0, 2.0);
        let drained = q.drain_all();
        // Admission (seq) order, original timestamps preserved.
        assert_eq!(drained.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![0, 1, 2]);
        assert_eq!(drained[0].arrival_us, 0.0);
        assert_eq!(q.total_queued(), 0);
        assert_eq!(q.queue_len(0), 0);
        assert_eq!(q.queue_len(1), 0);
        assert_eq!(q.admitted, 3, "drain does not un-admit");
        assert!(q.shed.is_empty(), "drain sheds nothing");
        // Queues stay usable afterwards.
        q.offer(3, 0, 0, 0, 3.0);
        assert_eq!(q.total_queued(), 1);
        q.drop_expired(1.0);
        assert!(q.shed.is_empty());
    }

    #[test]
    fn drain_model_scopes_the_drain_and_keeps_accounting_exact() {
        let cls = classes();
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 2);
        q.offer(0, 0, 0, 1, 0.0);
        q.offer(1, 0, 1, 0, 1.0);
        q.offer(2, 0, 0, 0, 2.0);
        let stolen = q.drain_model(0);
        // Admission (seq) order, original timestamps preserved; the
        // other model's queue is untouched.
        assert_eq!(stolen.iter().map(|r| r.req).collect::<Vec<_>>(),
                   vec![0, 2]);
        assert_eq!(stolen[0].arrival_us, 0.0);
        assert_eq!(q.queue_len(0), 0);
        assert_eq!(q.queue_len(1), 1);
        assert_eq!(q.total_queued(), 1);
        assert_eq!(q.admitted, 3, "stealing does not un-admit");
        assert!(q.shed.is_empty(), "stealing sheds nothing");
        // Expiry accounting survives the lazy earliest-deadline reset.
        q.drop_expired(20_001.0);
        assert_eq!(q.shed.len(), 1);
        assert_eq!(q.shed[0].req, 1);
        assert_eq!(q.total_queued(), 0);
        // Draining an already-empty model is a no-op.
        assert!(q.drain_model(0).is_empty());
    }

    #[test]
    fn readmit_preserves_deadlines_and_skips_the_admitted_count() {
        let cls = classes();
        let mut src = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        src.offer(0, 0, 0, 0, 5.0); // deadline 20_005
        let mut dst = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        dst.offer(7, 0, 0, 0, 100.0);
        let moved = src.drain_all();
        assert!(dst.readmit(moved[0]));
        assert_eq!(dst.admitted, 1, "readmit is not a second admission");
        assert_eq!(dst.total_queued(), 2);
        // The failed-over request keeps its original arrival, so it
        // sorts ahead of the survivor's newer work.
        let view: Vec<QueuedReq> = dst.dispatch_view(0).copied().collect();
        assert_eq!(view[0].req, 0);
        assert_eq!(view[0].arrival_us, 5.0);
        assert_eq!(view[0].deadline_us, 20_005.0);
        // And expiry still sees the (older) deadline.
        dst.drop_expired(20_005.0);
        assert_eq!(dst.shed.len(), 1);
        assert_eq!(dst.shed[0].req, 0);
        assert!(!dst.shed[0].at_admission);
    }

    #[test]
    fn readmit_enforces_the_shed_policy() {
        let cls = classes(); // interactive cap 2
        let mut q = AdmissionQueues::new(&cls, ShedPolicy::RejectNew, 1);
        q.offer(0, 0, 0, 0, 0.0);
        q.offer(1, 0, 0, 0, 1.0);
        let refugee = QueuedReq {
            req: 9, tenant: 0, model: 0, class: 0,
            arrival_us: 0.5, deadline_us: 20_000.5,
        };
        assert!(!q.readmit(refugee), "full class rejects under RejectNew");
        assert_eq!(q.shed.len(), 1);
        assert_eq!(q.shed[0].req, 9);
        assert!(q.shed[0].at_admission);
        assert_eq!(q.total_queued(), 2);
        // Under ShedOldest the refugee displaces the oldest instead.
        let mut q2 = AdmissionQueues::new(&cls, ShedPolicy::ShedOldest, 1);
        q2.offer(0, 0, 0, 0, 0.0);
        q2.offer(1, 0, 0, 0, 1.0);
        assert!(q2.readmit(refugee));
        assert_eq!(q2.shed.len(), 1);
        assert_eq!(q2.shed[0].req, 0);
        assert_eq!(q2.total_queued(), 2);
    }

    #[test]
    fn reference_queues_mirror_the_indexed_semantics() {
        // A quick inline pin (the full randomized pin lives in
        // rust/tests/slo_indexed.rs): same op sequence, same outcomes.
        let cls = classes();
        for policy in [
            ShedPolicy::RejectNew,
            ShedPolicy::ShedOldest,
            ShedPolicy::ShedLowestClass,
        ] {
            let mut a = AdmissionQueues::new(&cls, policy, 2);
            let mut b = ReferenceQueues::new(&cls, policy, 2);
            for i in 0..12 {
                let (m, c, t) = (i % 2, (i / 2) % 2, i as f64 * 3.0);
                a.offer(i, 0, m, c, t);
                b.offer(i, 0, m, c, t);
            }
            a.drop_expired(25_000.0);
            b.drop_expired(25_000.0);
            assert_eq!(a.admitted, b.admitted);
            assert_eq!(a.shed, b.shed);
            assert_eq!(a.total_queued(), b.total_queued());
            for m in 0..2 {
                assert_eq!(a.sorted_queue_reference(m),
                           b.sorted_queue(m));
                assert_eq!(a.take_batch(m, 3, true),
                           b.take_batch(m, 3, true));
            }
        }
    }
}
