//! DVFS governor subsystem for the serving tier (the SparseDVFS sequel
//! to SparOA's scheduler — PAPERS.md).
//!
//! Each lane of a board's [`LaneMatrix`](crate::serve::LaneMatrix) owns a
//! small ladder of frequency states ([`FreqState`]: a latency-scale /
//! static-W / dyn-W point, loaded from `config/devices.json` or
//! synthesized from the calibrated profile).  A per-board [`Governor`]
//! picks one state per dispatched batch:
//!
//! * [`Governor::RaceToIdle`] — always run at max frequency and let the
//!   lane fall back to its idle floor as early as possible;
//! * [`Governor::StretchToDeadline`] — the slowest (cheapest-energy)
//!   state whose projected finish still meets the batch's worst SLO
//!   deadline, priced through the same `latency_us` probes the
//!   dispatcher scores with;
//! * [`Governor::FixedState`] — pin one ladder rung (the control arm).
//!
//! An optional per-board power cap (watts) bounds instantaneous draw:
//! when the governor's pick would exceed the cap at dispatch time the
//! state is clamped toward slower rungs (surfaced as *throttle events*),
//! and when even the slowest rung does not fit the dispatch is deferred
//! to the next lane-finish event.  Board power only steps up at dispatch
//! starts, so enforcing the cap there bounds it at every instant.
//!
//! Accounting: busy intervals cost `busy_power_w` × duration; idle gaps
//! cost the lane's idle floor (the slowest state's static draw); the SoC
//! floor accrues over the whole horizon.  Totals land in
//! [`PerfSnapshot`](crate::serve::PerfSnapshot) as mJ / mean W /
//! J-per-inference.  All energies are millijoules, powers watts, times
//! microseconds.
//!
//! # Faults vs. throttles
//!
//! The fault layer ([`crate::faults`]) composes with DVFS
//! multiplicatively: a thermal slow-down scales a lane's *base* latency
//! before the governor sees it, so `pick_state` and the cap check price
//! the already-slowed batch, and a throttled rung stacks on top
//! (`latency = base × thermal_scale × rung_scale`).  Fail-stop crashes
//! retract in-flight busy intervals through [`BoardPower::retract`] —
//! energy a batch never finished drawing is refunded, so the mJ ledger
//! stays exact under any fault plan — while the board's idle/SoC floors
//! keep accruing over its downtime (a crashed board still draws its
//! floor until operators power it off; we model it as floor-only).

use crate::device::{DeviceModel, Proc, ProcModel};
use anyhow::Result;

pub use crate::device::FreqState;

/// Relative tolerance for cap comparisons (watts).
const CAP_EPS_W: f64 = 1e-9;

/// Latency-scale factors of the ladder synthesized for profiles without
/// `freq_states` (fastest first; rung 0 is the calibrated point).
const DEFAULT_SCALES: [f64; 3] = [1.0, 1.35, 1.8];
/// Static-power factors of the synthesized ladder (× calibrated W).
const DEFAULT_STATIC: [f64; 3] = [1.0, 0.7, 0.5];
/// Dynamic-power factors of the synthesized ladder (× calibrated W).
const DEFAULT_DYN: [f64; 3] = [1.0, 0.62, 0.39];
const DEFAULT_NAMES: [&str; 3] = ["max", "mid", "low"];

/// The DVFS ladder of one lane plus its idle floor.
#[derive(Debug, Clone)]
pub struct LanePowerModel {
    /// Frequency states, fastest first (`states[0].latency_scale == 1.0`).
    pub states: Vec<FreqState>,
    /// Draw while the lane is idle, watts (the slowest state's static
    /// power — an idle lane parks at its lowest frequency).
    pub idle_w: f64,
}

impl LanePowerModel {
    /// Build the ladder for one processor: the profile's `freq_states`
    /// when present, else a default 3-rung ladder synthesized from the
    /// calibrated (static, dyn) draw.  Validates DVFS physics: scales
    /// strictly increasing from 1.0, busy power strictly decreasing,
    /// and energy-per-op (scale × busy power) strictly decreasing —
    /// otherwise a slower rung would never be worth picking.
    pub fn from_proc(p: &ProcModel) -> Result<Self> {
        let states: Vec<FreqState> = if p.freq_states.is_empty() {
            (0..3)
                .map(|i| FreqState {
                    name: DEFAULT_NAMES[i].to_string(),
                    latency_scale: DEFAULT_SCALES[i],
                    static_w: p.power_static_w * DEFAULT_STATIC[i],
                    dyn_w: p.power_dyn_w * DEFAULT_DYN[i],
                })
                .collect()
        } else {
            p.freq_states.clone()
        };
        anyhow::ensure!(!states.is_empty(), "empty frequency ladder");
        anyhow::ensure!(
            (states[0].latency_scale - 1.0).abs() < 1e-9,
            "ladder rung 0 must be the full-frequency point \
             (latency_scale 1.0), got {}",
            states[0].latency_scale
        );
        for s in &states {
            anyhow::ensure!(
                s.latency_scale.is_finite()
                    && s.static_w.is_finite()
                    && s.dyn_w.is_finite()
                    && s.latency_scale >= 1.0
                    && s.static_w >= 0.0
                    && s.dyn_w >= 0.0,
                "frequency state `{}` has non-physical parameters",
                s.name
            );
        }
        for w in states.windows(2) {
            anyhow::ensure!(
                w[1].latency_scale > w[0].latency_scale,
                "latency_scale must strictly increase down the ladder \
                 ({} -> {})",
                w[0].name,
                w[1].name
            );
            anyhow::ensure!(
                w[1].busy_power_w() < w[0].busy_power_w(),
                "busy power must strictly decrease down the ladder \
                 ({} -> {})",
                w[0].name,
                w[1].name
            );
            anyhow::ensure!(
                w[1].latency_scale * w[1].busy_power_w()
                    < w[0].latency_scale * w[0].busy_power_w(),
                "energy per op (scale x busy W) must strictly decrease \
                 down the ladder ({} -> {}), or the slow rung is never \
                 worth picking",
                w[0].name,
                w[1].name
            );
        }
        let idle_w = states.last().expect("non-empty").static_w;
        Ok(LanePowerModel { states, idle_w })
    }

    /// Busy draw of rung `state`, watts.
    pub fn busy_w(&self, state: usize) -> f64 {
        self.states[state].busy_power_w()
    }

    /// Latency multiplier of rung `state` (dimensionless, >= 1.0).
    pub fn scale(&self, state: usize) -> f64 {
        self.states[state].latency_scale
    }
}

/// Per-board power model: one ladder per processor kind plus the SoC
/// floor (DRAM + carrier board, watts) that accrues regardless of lane
/// activity.
#[derive(Debug, Clone)]
pub struct PowerProfile {
    /// CPU-lane ladder.
    pub cpu: LanePowerModel,
    /// GPU-lane ladder.
    pub gpu: LanePowerModel,
    /// Always-on SoC draw, watts.
    pub soc_static_w: f64,
}

impl PowerProfile {
    /// Derive the board power model from a calibrated device profile.
    pub fn from_device(dev: &DeviceModel) -> Result<Self> {
        Ok(PowerProfile {
            cpu: LanePowerModel::from_proc(&dev.cpu)?,
            gpu: LanePowerModel::from_proc(&dev.gpu)?,
            soc_static_w: dev.soc_static_w,
        })
    }

    /// The ladder for lanes of processor kind `p`.
    pub fn lane(&self, p: Proc) -> &LanePowerModel {
        match p {
            Proc::Cpu => &self.cpu,
            Proc::Gpu => &self.gpu,
        }
    }
}

/// Frequency-selection policy applied per dispatched batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Governor {
    /// Max frequency always; the lane idles (at its floor) as early as
    /// possible.
    RaceToIdle,
    /// Slowest rung whose projected finish still meets the batch's
    /// worst met-at-full-speed SLO deadline; falls back to max
    /// frequency when nothing would be met anyway.
    StretchToDeadline,
    /// Pin rung `i` (clamped to the ladder length) — the control arm.
    FixedState(usize),
}

impl Governor {
    /// Parse a CLI/config spelling: `race-to-idle` (or `race`),
    /// `stretch-to-deadline` (or `stretch`), `fixed:<rung>`.
    pub fn parse(s: &str) -> Result<Governor> {
        match s {
            "race-to-idle" | "race" => Ok(Governor::RaceToIdle),
            "stretch-to-deadline" | "stretch" => {
                Ok(Governor::StretchToDeadline)
            }
            _ => {
                if let Some(n) = s.strip_prefix("fixed:") {
                    let i: usize = n.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "bad fixed-state governor `{s}` (want \
                             fixed:<rung index>)"
                        )
                    })?;
                    return Ok(Governor::FixedState(i));
                }
                anyhow::bail!(
                    "unknown governor `{s}` (race-to-idle | \
                     stretch-to-deadline | fixed:<rung>)"
                )
            }
        }
    }

    /// Canonical spelling (round-trips through [`Governor::parse`]).
    pub fn name(&self) -> String {
        match self {
            Governor::RaceToIdle => "race-to-idle".to_string(),
            Governor::StretchToDeadline => "stretch-to-deadline".to_string(),
            Governor::FixedState(i) => format!("fixed:{i}"),
        }
    }
}

/// Everything the serving tier needs to run a board energy-aware.
#[derive(Debug, Clone)]
pub struct PowerConfig {
    /// Ladders + SoC floor.
    pub profile: PowerProfile,
    /// Per-batch frequency policy.
    pub governor: Governor,
    /// Optional instantaneous board power cap, watts (`None` =
    /// uncapped).  Must admit the slowest rung on an otherwise-idle
    /// board or `BoardSim` rejects it up front.
    pub cap_w: Option<f64>,
    /// Record a [`PowerEvent`] per dispatched batch (test/debug aid;
    /// off by default — traces grow with request count).
    pub trace: bool,
    /// Upper bound on recorded [`PowerEvent`]s when `trace` is on.
    /// Past it, events are dropped (newest-first) and counted in
    /// `PerfSnapshot::power_trace_dropped` — the energy ledger stays
    /// exact; only the reconstruction timeline is truncated.  Keeps
    /// million-request scale runs from ballooning memory.
    pub trace_cap: usize,
}

impl PowerConfig {
    /// Uncapped, untraced config.
    pub fn new(profile: PowerProfile, governor: Governor) -> Self {
        PowerConfig {
            profile,
            governor,
            cap_w: None,
            trace: false,
            trace_cap: 65_536,
        }
    }
}

/// One busy interval on one lane, for power-timeline reconstruction.
/// While `[start_us, finish_us)` is in flight the lane draws `busy_w`
/// watts instead of its `idle_w`-watt floor.
#[derive(Debug, Clone)]
pub struct PowerEvent {
    /// Flat lane index within the board.
    pub lane: usize,
    /// Processor kind of the lane.
    pub proc: Proc,
    /// Dispatch start, us (virtual time).
    pub start_us: f64,
    /// Scaled finish, us (virtual time).
    pub finish_us: f64,
    /// Draw while busy at the chosen rung, watts.
    pub busy_w: f64,
    /// The lane's idle floor, watts.
    pub idle_w: f64,
}

/// One admitted dispatch's power decision, returned by
/// `BoardPower::admit`.
#[derive(Debug, Clone, Copy)]
pub struct PowerAdmit {
    /// Batch latency at the chosen rung, µs.
    pub scaled_lat_us: f64,
    /// Lane draw while busy at the chosen rung, watts.
    pub busy_w: f64,
    /// Chosen ladder rung (0 = fastest).
    pub state: usize,
    /// True when the power cap forced a slower rung than the governor
    /// wanted (already counted as a throttle event).
    pub clamped: bool,
}

/// Governor decision: the slowest admissible rung for a batch whose
/// full-speed latency is `base_latency_us` starting at `start_us`, given
/// the worst (earliest) deadline among requests that would be met at
/// full speed (`None` when nothing meets even then).
pub fn pick_state(
    model: &LanePowerModel,
    governor: Governor,
    start_us: f64,
    base_latency_us: f64,
    worst_deadline_us: Option<f64>,
) -> usize {
    match governor {
        Governor::RaceToIdle => 0,
        Governor::FixedState(i) => i.min(model.states.len() - 1),
        Governor::StretchToDeadline => {
            let Some(deadline) = worst_deadline_us else {
                return 0;
            };
            let mut pick = 0;
            for (i, s) in model.states.iter().enumerate() {
                if start_us + base_latency_us * s.latency_scale <= deadline {
                    pick = i;
                } else {
                    break;
                }
            }
            pick
        }
    }
}

/// Per-board runtime power state: lane draws, the energy accumulator,
/// throttle counter, and (optionally) the busy-interval trace.  Owned by
/// `serve::cluster::BoardSim`.
pub(crate) struct BoardPower {
    profile: PowerProfile,
    governor: Governor,
    cap_w: Option<f64>,
    trace_on: bool,
    trace_cap: usize,
    lane_proc: Vec<Proc>,
    /// Busy draw of each lane's most recent dispatch, watts (meaningful
    /// while that lane's `free` time is in the future).
    lane_w: Vec<f64>,
    /// Per-lane idle floor, watts.
    lane_idle_w: Vec<f64>,
    /// Σ busy-interval energy so far, mJ.
    pub(crate) busy_energy_mj: f64,
    /// Cap-binding events (state clamped or dispatch deferred).
    pub(crate) throttles: u64,
    /// Busy-interval trace (empty unless `PowerConfig::trace`; bounded
    /// at `PowerConfig::trace_cap` events).
    pub(crate) trace: Vec<PowerEvent>,
    /// Events dropped after `trace` hit `trace_cap`.
    pub(crate) trace_dropped: u64,
}

impl BoardPower {
    /// Build the runtime state for a board whose flat lane `i` runs on
    /// `lane_proc[i]`.  Rejects a cap too tight to ever dispatch: an
    /// otherwise-idle board must fit the *slowest* rung of every lane
    /// kind, or a capped board with queued work could stall forever.
    pub(crate) fn new(cfg: &PowerConfig, lane_proc: &[Proc]) -> Result<Self> {
        let lane_idle_w: Vec<f64> = lane_proc
            .iter()
            .map(|&p| cfg.profile.lane(p).idle_w)
            .collect();
        if let Some(cap) = cfg.cap_w {
            anyhow::ensure!(
                cap.is_finite() && cap > 0.0,
                "power cap must be a positive wattage, got {cap}"
            );
            let floor: f64 = lane_idle_w.iter().sum();
            for (i, &p) in lane_proc.iter().enumerate() {
                let lm = cfg.profile.lane(p);
                let slowest = lm
                    .states
                    .last()
                    .expect("validated non-empty")
                    .busy_power_w();
                let need = cfg.profile.soc_static_w + floor
                    - lane_idle_w[i]
                    + slowest;
                anyhow::ensure!(
                    need <= cap + CAP_EPS_W,
                    "power cap {cap} W is infeasible: an idle board \
                     needs {need:.3} W to run one {} lane at its \
                     slowest rung",
                    p.name()
                );
            }
        }
        Ok(BoardPower {
            profile: cfg.profile.clone(),
            governor: cfg.governor,
            cap_w: cfg.cap_w,
            trace_on: cfg.trace,
            trace_cap: cfg.trace_cap,
            lane_proc: lane_proc.to_vec(),
            lane_w: vec![0.0; lane_proc.len()],
            lane_idle_w,
            busy_energy_mj: 0.0,
            throttles: 0,
            trace: Vec::new(),
            trace_dropped: 0,
        })
    }

    /// Canonical governor spelling, for reports.
    pub(crate) fn governor_name(&self) -> String {
        self.governor.name()
    }

    /// SoC floor, watts.
    pub(crate) fn soc_w(&self) -> f64 {
        self.profile.soc_static_w
    }

    /// Σ per-lane idle floors, watts — the board's all-idle draw minus
    /// the SoC term.
    pub(crate) fn idle_floor_w(&self) -> f64 {
        self.lane_idle_w.iter().sum()
    }

    /// Idle floor of one lane, watts.
    pub(crate) fn idle_w_of(&self, lane: usize) -> f64 {
        self.lane_idle_w[lane]
    }

    /// Instantaneous board draw at time `t` if `lane` were running at
    /// `busy_w`, watts.  `free` is the per-lane busy-until timeline.
    fn power_if(&self, free: &[f64], t: f64, lane: usize, busy_w: f64)
        -> f64
    {
        let mut w = self.profile.soc_static_w;
        for j in 0..self.lane_proc.len() {
            w += if j == lane {
                busy_w
            } else if free[j] > t {
                self.lane_w[j]
            } else {
                self.lane_idle_w[j]
            };
        }
        w
    }

    /// Governor + cap decision for a dispatch on `lane` starting at
    /// `start_us` with full-speed latency `base_latency_us`.  Returns
    /// the chosen rung's [`PowerAdmit`], or `None` when the cap does
    /// not admit even the slowest rung right now (caller defers to the
    /// next lane-finish event).  Counts a throttle event whenever the
    /// cap changes the outcome.
    pub(crate) fn admit(
        &mut self,
        lane: usize,
        free: &[f64],
        start_us: f64,
        base_latency_us: f64,
        worst_deadline_us: Option<f64>,
    ) -> Option<PowerAdmit> {
        let lm = self.profile.lane(self.lane_proc[lane]);
        let desired = pick_state(
            lm,
            self.governor,
            start_us,
            base_latency_us,
            worst_deadline_us,
        );
        let chosen = match self.cap_w {
            None => Some(desired),
            Some(cap) => (desired..lm.states.len()).find(|&s| {
                let w = lm.states[s].busy_power_w();
                self.power_if(free, start_us, lane, w) <= cap + CAP_EPS_W
            }),
        };
        match chosen {
            Some(s) => {
                if s != desired {
                    self.throttles += 1;
                }
                let lm = self.profile.lane(self.lane_proc[lane]);
                Some(PowerAdmit {
                    scaled_lat_us: base_latency_us
                        * lm.states[s].latency_scale,
                    busy_w: lm.states[s].busy_power_w(),
                    state: s,
                    clamped: s != desired,
                })
            }
            None => {
                self.throttles += 1;
                None
            }
        }
    }

    /// Account a dispatched busy interval: adds `busy_w` × duration to
    /// the energy ledger, marks the lane's in-flight draw, and records
    /// the trace event when tracing is on.
    pub(crate) fn commit(&mut self, lane: usize, start_us: f64,
                         finish_us: f64, busy_w: f64) {
        self.busy_energy_mj += busy_w * (finish_us - start_us) / 1e3;
        self.lane_w[lane] = busy_w;
        if self.trace_on {
            if self.trace.len() < self.trace_cap {
                self.trace.push(PowerEvent {
                    lane,
                    proc: self.lane_proc[lane],
                    start_us,
                    finish_us,
                    busy_w,
                    idle_w: self.lane_idle_w[lane],
                });
            } else {
                self.trace_dropped += 1;
            }
        }
    }

    /// Busy draw of the full-frequency rung on `lane`, watts — what a
    /// cap-exempt warmup charge runs at.
    pub(crate) fn max_busy_w(&self, lane: usize) -> f64 {
        self.profile.lane(self.lane_proc[lane]).busy_w(0)
    }

    /// Un-account the tail of a committed busy interval: a crash at
    /// `cut_us` retracts the batch occupying `lane` until `finish_us`,
    /// refunding `busy_w` × (finish − max(start, cut)) from the energy
    /// ledger (the board stopped computing at the crash).  When tracing
    /// is on, the matching [`PowerEvent`] is truncated to the cut (or
    /// removed if the batch never started).  The caller rewinds the
    /// lane's `free` timeline itself.
    pub(crate) fn retract(&mut self, lane: usize, start_us: f64,
                          finish_us: f64, busy_w: f64, cut_us: f64) {
        let cut = cut_us.max(start_us);
        if finish_us > cut {
            self.busy_energy_mj -= busy_w * (finish_us - cut) / 1e3;
        }
        if self.trace_on {
            // The retracted dispatch is almost always the lane's most
            // recent trace entry; search from the back.
            if let Some(i) = self.trace.iter().rposition(|e| {
                e.lane == lane
                    && e.finish_us == finish_us
                    && e.start_us == start_us
            }) {
                if cut > start_us {
                    self.trace[i].finish_us = cut;
                } else {
                    self.trace.remove(i);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::device_profile;

    fn agx_profile() -> PowerProfile {
        PowerProfile::from_device(&device_profile("agx_orin")).unwrap()
    }

    #[test]
    fn governor_spellings_round_trip() {
        for s in ["race-to-idle", "stretch-to-deadline", "fixed:2"] {
            assert_eq!(Governor::parse(s).unwrap().name(), s);
        }
        assert_eq!(Governor::parse("race").unwrap(), Governor::RaceToIdle);
        assert_eq!(
            Governor::parse("stretch").unwrap(),
            Governor::StretchToDeadline
        );
        assert!(Governor::parse("turbo").is_err());
        assert!(Governor::parse("fixed:x").is_err());
    }

    #[test]
    fn ladder_loads_from_config_and_synthesizes_without_one() {
        let dev = device_profile("agx_orin");
        let from_json = LanePowerModel::from_proc(&dev.gpu).unwrap();
        assert_eq!(from_json.states.len(), 3);
        assert_eq!(from_json.idle_w, dev.gpu.freq_states[2].static_w);
        // Strip the ladder: from_proc synthesizes a valid default.
        let mut bare = dev.cpu.clone();
        bare.freq_states.clear();
        let synth = LanePowerModel::from_proc(&bare).unwrap();
        assert_eq!(synth.states.len(), 3);
        assert_eq!(synth.states[0].latency_scale, 1.0);
        assert_eq!(synth.states[0].busy_power_w(),
                   bare.power_static_w + bare.power_dyn_w);
    }

    #[test]
    fn ladder_validation_rejects_non_physical_rungs() {
        let dev = device_profile("agx_orin");
        // Rung 0 must be the full-frequency point.
        let mut p = dev.cpu.clone();
        p.freq_states[0].latency_scale = 1.2;
        assert!(LanePowerModel::from_proc(&p).is_err());
        // Busy power must strictly decrease.
        let mut p = dev.cpu.clone();
        p.freq_states[1].dyn_w = p.freq_states[0].dyn_w + 5.0;
        assert!(LanePowerModel::from_proc(&p).is_err());
        // Energy per op must strictly decrease (slow rung that saves
        // almost no power is not worth a ladder slot).
        let mut p = dev.cpu.clone();
        p.freq_states[1].static_w = p.freq_states[0].static_w;
        p.freq_states[1].dyn_w = p.freq_states[0].dyn_w - 1e-6;
        assert!(LanePowerModel::from_proc(&p).is_err());
    }

    #[test]
    fn pick_state_per_governor() {
        let lm = agx_profile().gpu;
        // Race: always rung 0.
        assert_eq!(
            pick_state(&lm, Governor::RaceToIdle, 0.0, 100.0, Some(1e9)),
            0
        );
        // Fixed: clamped to the ladder.
        assert_eq!(
            pick_state(&lm, Governor::FixedState(7), 0.0, 100.0, None),
            lm.states.len() - 1
        );
        // Stretch with ample slack: slowest rung.
        let g = Governor::StretchToDeadline;
        assert_eq!(pick_state(&lm, g, 0.0, 100.0, Some(1e9)),
                   lm.states.len() - 1);
        // Stretch with slack for the mid rung only (scales 1.0/1.4/2.0).
        assert_eq!(pick_state(&lm, g, 0.0, 100.0, Some(150.0)), 1);
        // No slack, or nothing met even at full speed: full frequency.
        assert_eq!(pick_state(&lm, g, 0.0, 100.0, Some(50.0)), 0);
        assert_eq!(pick_state(&lm, g, 0.0, 100.0, None), 0);
    }

    #[test]
    fn infeasible_cap_is_rejected_up_front() {
        let prof = agx_profile();
        let lanes = [Proc::Cpu, Proc::Gpu];
        let mut cfg = PowerConfig::new(prof.clone(), Governor::RaceToIdle);
        // All-idle board + slowest GPU rung is the binding need.
        let need = prof.soc_static_w
            + prof.cpu.idle_w
            + prof.gpu.states.last().unwrap().busy_power_w();
        cfg.cap_w = Some(need - 0.1);
        assert!(BoardPower::new(&cfg, &lanes).is_err());
        cfg.cap_w = Some(need + 0.1);
        assert!(BoardPower::new(&cfg, &lanes).is_ok());
        cfg.cap_w = Some(-3.0);
        assert!(BoardPower::new(&cfg, &lanes).is_err());
    }

    #[test]
    fn cap_clamps_then_defers_and_counts_throttles() {
        let prof = agx_profile();
        let lanes = [Proc::Gpu, Proc::Gpu];
        let mid_w = prof.gpu.states[1].busy_power_w();
        let low_w = prof.gpu.states[2].busy_power_w();
        // Cap fits {one busy mid rung + one idle lane} but not
        // {busy max + idle} — RaceToIdle's pick gets clamped to mid.
        let mut cfg = PowerConfig::new(prof.clone(), Governor::RaceToIdle);
        cfg.cap_w =
            Some(prof.soc_static_w + prof.gpu.idle_w + mid_w + 0.01);
        let mut bp = BoardPower::new(&cfg, &lanes).unwrap();
        let free = [0.0, 0.0];
        let adm = bp.admit(0, &free, 0.0, 100.0, None).unwrap();
        let (lat, w) = (adm.scaled_lat_us, adm.busy_w);
        assert_eq!(w, mid_w);
        assert_eq!(lat, 100.0 * prof.gpu.states[1].latency_scale);
        assert_eq!(adm.state, 1);
        assert!(adm.clamped);
        assert_eq!(bp.throttles, 1);
        bp.commit(0, 0.0, lat, w);
        // With lane 0 busy at mid, lane 1 cannot fit even the slowest
        // rung (mid + low > mid + idle + 0.01) — deferral.
        assert!(mid_w + low_w > mid_w + prof.gpu.idle_w + 0.01);
        let free = [lat, 0.0];
        assert!(bp.admit(1, &free, 10.0, 100.0, None).is_none());
        assert_eq!(bp.throttles, 2);
        // After lane 0 finishes, the same dispatch is admitted again
        // (still clamped to mid under this cap, so one more throttle).
        let again = bp.admit(1, &free, lat + 1.0, 100.0, None).unwrap();
        assert_eq!(again.busy_w, mid_w);
        assert!(again.clamped);
        assert_eq!(bp.throttles, 3);
    }

    #[test]
    fn commit_accumulates_busy_energy_and_traces() {
        let prof = agx_profile();
        let mut cfg = PowerConfig::new(prof.clone(), Governor::RaceToIdle);
        cfg.trace = true;
        let mut bp = BoardPower::new(&cfg, &[Proc::Gpu]).unwrap();
        let w = prof.gpu.states[0].busy_power_w();
        bp.commit(0, 100.0, 600.0, w);
        bp.commit(0, 700.0, 1200.0, w);
        assert!((bp.busy_energy_mj - 2.0 * w * 500.0 / 1e3).abs() < 1e-12);
        assert_eq!(bp.trace.len(), 2);
        assert_eq!(bp.trace[0].idle_w, prof.gpu.idle_w);
        assert_eq!(bp.trace[1].start_us, 700.0);
        assert_eq!(bp.trace_dropped, 0);
    }

    #[test]
    fn retract_refunds_the_unfinished_tail() {
        let prof = agx_profile();
        let mut cfg = PowerConfig::new(prof.clone(), Governor::RaceToIdle);
        cfg.trace = true;
        let mut bp = BoardPower::new(&cfg, &[Proc::Gpu]).unwrap();
        let w = prof.gpu.states[0].busy_power_w();
        bp.commit(0, 100.0, 600.0, w);
        bp.commit(0, 700.0, 1200.0, w);
        let full = bp.busy_energy_mj;
        // Crash at 900: the second batch ran 200 of its 500 us.
        bp.retract(0, 700.0, 1200.0, w, 900.0);
        assert!((bp.busy_energy_mj - (full - w * 300.0 / 1e3)).abs()
                < 1e-12);
        assert_eq!(bp.trace.len(), 2);
        assert_eq!(bp.trace[1].finish_us, 900.0);
        // Crash before the first batch started: fully refunded,
        // trace entry removed.
        bp.retract(0, 100.0, 600.0, w, 50.0);
        assert!((bp.busy_energy_mj - w * 200.0 / 1e3).abs() < 1e-12);
        assert_eq!(bp.trace.len(), 1);
        assert_eq!(bp.trace[0].start_us, 700.0);
    }

    #[test]
    fn trace_is_bounded_and_overflow_is_counted() {
        let prof = agx_profile();
        let mut cfg = PowerConfig::new(prof.clone(), Governor::RaceToIdle);
        cfg.trace = true;
        cfg.trace_cap = 4;
        let mut bp = BoardPower::new(&cfg, &[Proc::Gpu]).unwrap();
        let w = prof.gpu.states[0].busy_power_w();
        for i in 0..6 {
            let t = 1000.0 * i as f64;
            bp.commit(0, t, t + 500.0, w);
        }
        // The cap bounds the trace; the energy ledger stays exact.
        assert_eq!(bp.trace.len(), 4);
        assert_eq!(bp.trace_dropped, 2);
        assert!((bp.busy_energy_mj - 6.0 * w * 500.0 / 1e3).abs() < 1e-12);
    }
}
