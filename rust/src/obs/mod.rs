//! `sparoa::obs` — the built-in virtual-time profiler.
//!
//! A zero-cost-when-disabled tracing layer threaded through the
//! serving stack (`serve::cluster`, `serve::fleet`, `power`): each
//! board owns a [`Tracer`] that records typed [`TraceEvent`]s in
//! *virtual* microseconds into a bounded buffer and accumulates exact
//! per-(model, class) phase totals, sealed into a [`PhaseBreakdown`]
//! on the board's [`crate::serve::PerfSnapshot`] at finish time.  Two
//! exporters turn a run into standard profiler inputs:
//!
//! * [`folded`] — flamegraph.pl / inferno folded-stack text
//!   (`board;model;class;phase count_us`), built from the exact phase
//!   accumulators, so event-buffer drops never skew it;
//! * [`chrome_trace`] — Chrome trace-event JSON (Perfetto-loadable),
//!   one `pid` per board, one `tid` per lane, timestamps in
//!   virtual-time microseconds.
//!
//! `sparoa serve-fleet --trace_out=FILE --trace_format=folded|chrome`
//! wires both into the CLI, the `fig_scale` bench measures tracer
//! throughput/overhead at 10^6 requests, and
//! `rust/tests/obs_trace.rs` pins trace totals to the
//! [`crate::serve::PerfSnapshot`] aggregates (every admitted request
//! appears exactly once as served/shed/expired; phase sums equal the
//! lane capacity to 1e-6 relative; `Throttle` events equal
//! `throttle_events`).

use std::fmt::Write as _;

/// Sentinel index for "no model / class / lane attribution" on a
/// [`TraceRecord`] (exporters drop the corresponding stack frame).
pub const NONE: u32 = u32::MAX;

/// Tracer configuration, carried by
/// [`crate::serve::ClusterOptions`] / [`crate::serve::FleetOptions`].
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Per-board event-buffer capacity, in records.  Once full,
    /// further records are dropped (newest-first) and counted in the
    /// snapshot's `trace_dropped`; the [`PhaseBreakdown`] accumulators
    /// keep exact totals regardless of drops.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { capacity: 262_144 }
    }
}

/// One typed profiler event.  All durations/waits are virtual-time
/// microseconds; `lane` indexes the board's
/// [`crate::serve::LaneMatrix`] lanes; `freq_state` is the DVFS
/// ladder rung chosen at dispatch ([`NONE`] when the board runs
/// without a governor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// A request passed admission control.
    Admit,
    /// A served request's arrival→dispatch wait, µs (recorded at
    /// dispatch, so it doubles as the served-exactly-once marker).
    QueueWait {
        /// Arrival→dispatch wait, µs.
        wait_us: f64,
    },
    /// A batch of `batch` requests was drained together.
    BatchForm {
        /// Requests in the batch.
        batch: u32,
    },
    /// A batch started executing.
    Dispatch {
        /// Lane index the batch occupies.
        lane: u32,
        /// Requests in the batch.
        batch: u32,
        /// DVFS ladder rung (0 = fastest), [`NONE`] without a governor.
        freq_state: u32,
    },
    /// Host↔device transfer share of a batch's lane occupancy
    /// (span; recorded at its end time).
    Dma {
        /// Lane index.
        lane: u32,
        /// Span length, µs.
        dur_us: f64,
    },
    /// Compute share of a batch's lane occupancy (span; recorded at
    /// its end time).
    Compute {
        /// Lane index.
        lane: u32,
        /// Span length, µs.
        dur_us: f64,
    },
    /// A request was shed at admission time (rejection or policy
    /// eviction).
    Shed,
    /// A request was shed because its deadline expired in queue.
    Expire,
    /// The power cap clamped a dispatch to a slower rung or deferred
    /// it (reconciles 1:1 with the snapshot's `throttle_events`).
    Throttle,
    /// The autoscaler added (or reclaimed) a replica of the record's
    /// model on this board.
    ScaleUp,
    /// The autoscaler started draining a replica of the record's
    /// model on this board.
    ScaleDown,
    /// A replica warm-up occupied a lane (span; recorded at its end
    /// time).
    WarmUp {
        /// Lane index.
        lane: u32,
        /// Span length, µs.
        dur_us: f64,
    },
    /// A fail-stop fault took this board down (fault layer; in-flight
    /// batches were retracted, queued work drained to the front tier).
    BoardDown,
    /// A crashed board rejoined the fleet and resumed serving.
    BoardUp,
    /// A lane-loss fault disabled one of this board's lanes (the board
    /// degrades to its surviving lanes).
    LaneDown {
        /// Index of the lost lane in the board's
        /// [`crate::serve::LaneMatrix`].
        lane: u32,
    },
    /// A queued request was drained off a crashed board for
    /// re-placement on a survivor (recorded on the crashed board).
    Requeue,
    /// A request lost in a retracted in-flight batch re-entered a
    /// survivor's queue after the deadline-aware retry check (recorded
    /// on the destination board).
    Retry,
    /// An in-flight batch was voluntarily cancelled to rescue a
    /// higher-class deadline (preemption): the lane and its committed
    /// energy were refunded from the cancel point and the batch's
    /// requests re-queued with arrival/deadline preserved.  Recorded
    /// once per cancelled batch on the preempting board, so the event
    /// count reconciles 1:1 with the snapshot's `preemptions`.
    Preempt {
        /// Lane index the cancelled batch occupied.
        lane: u32,
    },
    /// The work-stealing pass re-placed one model's queued (never
    /// dispatched) requests onto another board (recorded once per
    /// drain on the victim board; each moved request additionally
    /// records a [`TraceEvent::Requeue`] there).  Σ `n` reconciles
    /// 1:1 with the snapshot's `steals`.
    Steal {
        /// Requests moved by this drain.
        n: u32,
    },
    /// The gray-failure detector flagged this board suspect: its EWMA
    /// of realized/predicted dispatch latency stayed inflated for K
    /// consecutive batches.  Recorded once per episode on the suspect
    /// board; reconciles 1:1 with the snapshot's `suspects`.
    Suspect,
    /// The circuit breaker opened (first trip or a failed probe
    /// re-opening it): the board leaves routing/steal/autoscale
    /// placement until probation.  Reconciles 1:1 with `breaker_opens`.
    BreakerOpen,
    /// Probation completed: the breaker closed and the board is fully
    /// routable again.
    BreakerClose,
    /// A probation probe dispatch was admitted to this board (the
    /// routed request itself is the probe).  Reconciles 1:1 with the
    /// snapshot's `probes`.
    Probe,
    /// An at-risk request was hedged: a clone was offered to another
    /// board (recorded on the board receiving the clone).  Reconciles
    /// 1:1 with the snapshot's `hedges`.
    Hedge,
    /// The losing copy of a hedged request was cancelled after the
    /// winner finished: in-flight lane time and committed energy were
    /// refunded (or the queued clone purged), with any duplicate
    /// executed work billed to `hedge_waste_us`.
    HedgeCancel,
}

/// One buffered event: virtual time, (model, class) attribution
/// ([`NONE`] = unattributed), payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    /// Virtual time the event was recorded, µs.  Span payloads
    /// (`Dma`, `Compute`, `WarmUp`) are recorded at their *end*;
    /// exporters recover the start as `t_us - dur_us`.
    pub t_us: f64,
    /// Registry index of the model, or [`NONE`].
    pub model: u32,
    /// SLO class index, or [`NONE`].
    pub class: u32,
    /// The event payload.
    pub event: TraceEvent,
}

/// Exact phase totals for one (model, class) pair.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseRow {
    /// Registry index of the model.
    pub model: u32,
    /// SLO class index.
    pub class: u32,
    /// Summed arrival→dispatch wait over served requests, µs.
    /// Request-time, not lane-time: excluded from the capacity
    /// identity below.
    pub queue_wait_us: f64,
    /// Summed per-request DMA share of lane occupancy, µs.
    pub dma_us: f64,
    /// Summed per-request compute share of lane occupancy, µs.
    pub compute_us: f64,
    /// Requests served.
    pub served: u64,
    /// Requests shed at admission.
    pub shed: u64,
    /// Requests expired in queue.
    pub expired: u64,
}

impl PhaseRow {
    /// Lane-time attributed to this row, µs (`dma_us + compute_us`).
    pub fn service_us(&self) -> f64 {
        self.dma_us + self.compute_us
    }
}

/// A board's (after `merge_from`: a fleet's) sealed phase breakdown.
///
/// Capacity identity, pinned by `rust/tests/obs_trace.rs`:
/// Σ rows [`PhaseRow::service_us`] + `warmup_us` + `idle_us` ==
/// `capacity_us` to 1e-6 relative.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseBreakdown {
    /// One row per (model, class) pair with any activity.
    pub rows: Vec<PhaseRow>,
    /// Lane-µs no lane spent busy (capacity minus total busy time).
    pub idle_us: f64,
    /// Lane-µs spent on autoscaler replica warm-ups.
    pub warmup_us: f64,
    /// Total lane capacity, lane-µs: lanes × horizon, where horizon is
    /// the later of the makespan and the last lane-free event.  Sums
    /// across boards on merge.
    pub capacity_us: f64,
    /// Power-cap clamp/defer events (equals the snapshot's
    /// `throttle_events` when sealed from the same run).
    pub throttles: u64,
}

impl PhaseBreakdown {
    /// True when no enabled tracer sealed into this breakdown.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty() && self.capacity_us == 0.0
    }

    /// Total lane-time attributed to request service, µs.
    pub fn service_us(&self) -> f64 {
        self.rows.iter().map(|r| r.service_us()).sum()
    }

    /// Fold `other` into `self`: rows summed by (model, class), the
    /// idle/warmup/capacity/throttle totals added — the
    /// fleet-aggregate path used by `PerfSnapshot::merge_from`.
    pub fn merge_from(&mut self, other: &PhaseBreakdown) {
        for o in &other.rows {
            match self
                .rows
                .iter_mut()
                .find(|r| r.model == o.model && r.class == o.class)
            {
                Some(r) => {
                    r.queue_wait_us += o.queue_wait_us;
                    r.dma_us += o.dma_us;
                    r.compute_us += o.compute_us;
                    r.served += o.served;
                    r.shed += o.shed;
                    r.expired += o.expired;
                }
                None => self.rows.push(*o),
            }
        }
        self.idle_us += other.idle_us;
        self.warmup_us += other.warmup_us;
        self.capacity_us += other.capacity_us;
        self.throttles += other.throttles;
    }
}

/// Per-board event recorder + phase accumulator.
///
/// A disabled tracer costs one predictable branch per call site —
/// every method early-returns on `enabled`, and callers gate derived
/// work (e.g. the DMA-fraction probe) behind [`Tracer::is_enabled`].
/// The claim is measured, not asserted: `hotpath` prints
/// `tracer_disabled_overhead` and `fig_scale --ci` gates it at 1.05x.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    cap: usize,
    buf: Vec<TraceRecord>,
    dropped: u64,
    /// nm × nc accumulators, class-major within model.
    rows: Vec<PhaseRow>,
    nc: usize,
    warmup_us: f64,
    throttles: u64,
}

impl Tracer {
    /// The no-op tracer every board starts with.
    pub fn disabled() -> Self {
        Tracer {
            enabled: false,
            cap: 0,
            buf: Vec::new(),
            dropped: 0,
            rows: Vec::new(),
            nc: 0,
            warmup_us: 0.0,
            throttles: 0,
        }
    }

    /// An enabled tracer for a board serving `nm` models × `nc` SLO
    /// classes.
    pub fn new(cfg: TraceConfig, nm: usize, nc: usize) -> Self {
        let mut rows = Vec::with_capacity(nm * nc);
        for m in 0..nm {
            for c in 0..nc {
                rows.push(PhaseRow {
                    model: m as u32,
                    class: c as u32,
                    ..PhaseRow::default()
                });
            }
        }
        Tracer {
            enabled: true,
            cap: cfg.capacity.max(1),
            buf: Vec::new(),
            dropped: 0,
            rows,
            nc: nc.max(1),
            warmup_us: 0.0,
            throttles: 0,
        }
    }

    /// True when recording.  Callers compute non-trivial derived
    /// values (probe calls, per-request shares) only behind this.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event at virtual time `t_us` (pass [`NONE`] for
    /// unattributed model/class).  Past capacity the record is
    /// dropped and counted; on a disabled tracer this is a single
    /// branch.
    #[inline]
    pub fn record(
        &mut self,
        t_us: f64,
        model: u32,
        class: u32,
        event: TraceEvent,
    ) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(TraceRecord { t_us, model, class, event });
        } else {
            self.dropped += 1;
        }
    }

    /// Accumulate one served request's phase shares, µs.
    #[inline]
    pub fn acc_served(
        &mut self,
        model: usize,
        class: usize,
        wait_us: f64,
        dma_us: f64,
        compute_us: f64,
    ) {
        if !self.enabled {
            return;
        }
        let r = &mut self.rows[model * self.nc + class];
        r.queue_wait_us += wait_us;
        r.dma_us += dma_us;
        r.compute_us += compute_us;
        r.served += 1;
    }

    /// Accumulate one shed request (`expired = false`: admission-time
    /// shed; `true`: deadline expiry in queue).
    #[inline]
    pub fn acc_shed(&mut self, model: usize, class: usize, expired: bool) {
        if !self.enabled {
            return;
        }
        let r = &mut self.rows[model * self.nc + class];
        if expired {
            r.expired += 1;
        } else {
            r.shed += 1;
        }
    }

    /// Accumulate a replica warm-up's lane occupancy, µs.
    #[inline]
    pub fn acc_warmup(&mut self, dur_us: f64) {
        if !self.enabled {
            return;
        }
        self.warmup_us += dur_us;
    }

    /// Count one power-cap clamp/defer (the `Throttle` event itself is
    /// recorded separately via [`Tracer::record`]).
    #[inline]
    pub fn acc_throttle(&mut self) {
        if !self.enabled {
            return;
        }
        self.throttles += 1;
    }

    /// Drain the event buffer: `(records, dropped_count)`.
    pub fn take(&mut self) -> (Vec<TraceRecord>, u64) {
        (std::mem::take(&mut self.buf), self.dropped)
    }

    /// Seal the phase accumulators into a [`PhaseBreakdown`].  The
    /// board computes `idle_us` / `capacity_us` (both lane-µs,
    /// capacity = lanes × horizon) at finish time; rows with no
    /// activity are dropped.  A disabled tracer seals to the empty
    /// breakdown.
    pub fn seal(&mut self, idle_us: f64, capacity_us: f64) -> PhaseBreakdown {
        if !self.enabled {
            return PhaseBreakdown::default();
        }
        PhaseBreakdown {
            rows: std::mem::take(&mut self.rows)
                .into_iter()
                .filter(|r| {
                    r.served + r.shed + r.expired > 0
                        || r.service_us() > 0.0
                })
                .collect(),
            idle_us,
            warmup_us: self.warmup_us,
            capacity_us,
            throttles: self.throttles,
        }
    }
}

/// Strip the folded-stack separator from a frame label.
fn frame(label: &str) -> String {
    label.replace(';', ":")
}

/// Render one board's [`PhaseBreakdown`] as flamegraph.pl / inferno
/// folded-stack lines: `board;model;class;phase count` where count is
/// rounded virtual-time µs (zero-count lines are skipped), plus
/// `board;warmup` and `board;idle` frames.  Built from the exact
/// phase accumulators, so event-buffer drops never skew the graph.
pub fn folded(
    board: &str,
    phases: &PhaseBreakdown,
    model_labels: &[String],
    class_labels: &[String],
) -> String {
    let name = |labels: &[String], i: u32| -> String {
        labels
            .get(i as usize)
            .map(|l| frame(l))
            .unwrap_or_else(|| format!("#{i}"))
    };
    let board = frame(board);
    let mut out = String::new();
    for r in &phases.rows {
        let stem = format!(
            "{board};{};{}",
            name(model_labels, r.model),
            name(class_labels, r.class)
        );
        for (phase, us) in [
            ("queue_wait", r.queue_wait_us),
            ("dma", r.dma_us),
            ("compute", r.compute_us),
        ] {
            let n = us.max(0.0).round() as u64;
            if n > 0 {
                let _ = writeln!(out, "{stem};{phase} {n}");
            }
        }
    }
    for (phase, us) in
        [("warmup", phases.warmup_us), ("idle", phases.idle_us)]
    {
        let n = us.max(0.0).round() as u64;
        if n > 0 {
            let _ = writeln!(out, "{board};{phase} {n}");
        }
    }
    out
}

/// Append one board's records as Chrome trace-event objects onto
/// `out` (comma-separated; `first` tracks whether a separator is
/// pending).  `pid` = board index; `tid` = lane for lane-carrying
/// events, else the SLO class (0 when unattributed); `ts` =
/// virtual-time µs.  Span payloads are buffered at their end time, so
/// `ts = t_us - dur_us` and `dur = dur_us`.
pub fn chrome_events_into(
    out: &mut String,
    first: &mut bool,
    pid: usize,
    records: &[TraceRecord],
    model_labels: &[String],
    class_labels: &[String],
) {
    use crate::util::json::{self, Value};
    let label = |labels: &[String], i: u32| -> Option<String> {
        if i == NONE {
            None
        } else {
            Some(
                labels
                    .get(i as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("#{i}")),
            )
        }
    };
    let num = |x: f64| json::to_string(&Value::Num(x));
    for r in records {
        let (kind, lane, dur_us, extra): (
            &str,
            Option<u32>,
            Option<f64>,
            Vec<(&str, f64)>,
        ) = match r.event {
            TraceEvent::Admit => ("admit", None, None, vec![]),
            TraceEvent::QueueWait { wait_us } => {
                ("queue_wait", None, None, vec![("wait_us", wait_us)])
            }
            TraceEvent::BatchForm { batch } => {
                ("batch_form", None, None, vec![("batch", batch as f64)])
            }
            TraceEvent::Dispatch { lane, batch, freq_state } => (
                "dispatch",
                Some(lane),
                None,
                vec![
                    ("batch", batch as f64),
                    (
                        "freq_state",
                        if freq_state == NONE {
                            -1.0
                        } else {
                            freq_state as f64
                        },
                    ),
                ],
            ),
            TraceEvent::Dma { lane, dur_us } => {
                ("dma", Some(lane), Some(dur_us), vec![])
            }
            TraceEvent::Compute { lane, dur_us } => {
                ("compute", Some(lane), Some(dur_us), vec![])
            }
            TraceEvent::Shed => ("shed", None, None, vec![]),
            TraceEvent::Expire => ("expire", None, None, vec![]),
            TraceEvent::Throttle => ("throttle", None, None, vec![]),
            TraceEvent::ScaleUp => ("scale_up", None, None, vec![]),
            TraceEvent::ScaleDown => ("scale_down", None, None, vec![]),
            TraceEvent::WarmUp { lane, dur_us } => {
                ("warmup", Some(lane), Some(dur_us), vec![])
            }
            TraceEvent::BoardDown => ("board_down", None, None, vec![]),
            TraceEvent::BoardUp => ("board_up", None, None, vec![]),
            TraceEvent::LaneDown { lane } => {
                ("lane_down", Some(lane), None, vec![])
            }
            TraceEvent::Requeue => ("requeue", None, None, vec![]),
            TraceEvent::Retry => ("retry", None, None, vec![]),
            TraceEvent::Preempt { lane } => {
                ("preempt", Some(lane), None, vec![])
            }
            TraceEvent::Steal { n } => {
                ("steal", None, None, vec![("n", n as f64)])
            }
            TraceEvent::Suspect => ("suspect", None, None, vec![]),
            TraceEvent::BreakerOpen => {
                ("breaker_open", None, None, vec![])
            }
            TraceEvent::BreakerClose => {
                ("breaker_close", None, None, vec![])
            }
            TraceEvent::Probe => ("probe", None, None, vec![]),
            TraceEvent::Hedge => ("hedge", None, None, vec![]),
            TraceEvent::HedgeCancel => {
                ("hedge_cancel", None, None, vec![])
            }
        };
        let name = match label(model_labels, r.model) {
            Some(m) => format!("{kind}:{m}"),
            None => kind.to_string(),
        };
        let tid =
            lane.unwrap_or(if r.class == NONE { 0 } else { r.class });
        let ts = r.t_us - dur_us.unwrap_or(0.0);
        if !*first {
            out.push(',');
        }
        *first = false;
        let _ = write!(
            out,
            "{{\"name\":{},\"cat\":\"sparoa\",\"ph\":\"{}\",\"ts\":{},\
             \"pid\":{},\"tid\":{}",
            json::to_string(&Value::Str(name)),
            if dur_us.is_some() { 'X' } else { 'i' },
            num(ts),
            pid,
            tid
        );
        match dur_us {
            Some(d) => {
                let _ = write!(out, ",\"dur\":{}", num(d));
            }
            // Instant events: thread scope keeps Perfetto's marker
            // rendering local to the tid.
            None => out.push_str(",\"s\":\"t\""),
        }
        out.push_str(",\"args\":{");
        let mut sep = false;
        if let Some(c) = label(class_labels, r.class) {
            let _ = write!(
                out,
                "\"class\":{}",
                json::to_string(&Value::Str(c))
            );
            sep = true;
        }
        for (k, v) in extra {
            if sep {
                out.push(',');
            }
            sep = true;
            let _ = write!(out, "\"{k}\":{}", num(v));
        }
        out.push_str("}}");
    }
}

/// Wrap per-board record slices into one Perfetto-loadable Chrome
/// trace document: `{"traceEvents":[...]}`, `pid` = slice index.
pub fn chrome_trace(
    boards: &[&[TraceRecord]],
    model_labels: &[String],
    class_labels: &[String],
) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for (pid, records) in boards.iter().enumerate() {
        chrome_events_into(
            &mut out,
            &mut first,
            pid,
            records,
            model_labels,
            class_labels,
        );
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_and_seals_nothing() {
        let mut t = Tracer::disabled();
        assert!(!t.is_enabled());
        t.record(1.0, 0, 0, TraceEvent::Admit);
        t.acc_served(0, 0, 1.0, 2.0, 3.0);
        t.acc_shed(0, 0, false);
        t.acc_warmup(5.0);
        t.acc_throttle();
        let (events, dropped) = t.take();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
        let p = t.seal(10.0, 20.0);
        assert!(p.is_empty());
    }

    #[test]
    fn buffer_caps_and_counts_drops() {
        let mut t = Tracer::new(TraceConfig { capacity: 3 }, 1, 1);
        for i in 0..5 {
            t.record(i as f64, 0, 0, TraceEvent::Admit);
        }
        let (events, dropped) = t.take();
        assert_eq!(events.len(), 3);
        assert_eq!(dropped, 2);
        // Drop-newest: the earliest records survive.
        assert_eq!(events[0].t_us, 0.0);
        assert_eq!(events[2].t_us, 2.0);
    }

    #[test]
    fn seal_keeps_the_capacity_identity() {
        let mut t = Tracer::new(TraceConfig::default(), 2, 2);
        t.acc_served(0, 1, 4.0, 1.0, 9.0);
        t.acc_served(1, 0, 2.0, 0.5, 4.5);
        t.acc_shed(1, 1, true);
        t.acc_warmup(5.0);
        t.acc_throttle();
        // busy = 15 service + 5 warmup; capacity 100 -> idle 80.
        let p = t.seal(80.0, 100.0);
        assert_eq!(p.rows.len(), 3, "inactive rows dropped");
        assert!(
            (p.service_us() + p.warmup_us + p.idle_us - p.capacity_us)
                .abs()
                < 1e-9
        );
        assert_eq!(p.throttles, 1);
        let expired: u64 = p.rows.iter().map(|r| r.expired).sum();
        assert_eq!(expired, 1);
    }

    #[test]
    fn merge_sums_rows_and_totals() {
        let mut a = PhaseBreakdown::default();
        let mut t = Tracer::new(TraceConfig::default(), 1, 2);
        t.acc_served(0, 0, 1.0, 2.0, 3.0);
        a.merge_from(&t.seal(5.0, 10.0));
        let mut u = Tracer::new(TraceConfig::default(), 1, 2);
        u.acc_served(0, 0, 1.0, 2.0, 3.0);
        u.acc_served(0, 1, 4.0, 1.0, 1.0);
        u.acc_throttle();
        a.merge_from(&u.seal(3.0, 10.0));
        assert_eq!(a.rows.len(), 2);
        let r00 = a
            .rows
            .iter()
            .find(|r| r.model == 0 && r.class == 0)
            .unwrap();
        assert_eq!(r00.served, 2);
        assert!((r00.compute_us - 6.0).abs() < 1e-12);
        assert!((a.capacity_us - 20.0).abs() < 1e-12);
        assert!((a.idle_us - 8.0).abs() < 1e-12);
        assert_eq!(a.throttles, 1);
    }

    #[test]
    fn folded_lines_are_flamegraph_shaped() {
        let mut t = Tracer::new(TraceConfig::default(), 1, 1);
        t.acc_served(0, 0, 10.4, 3.6, 6.4);
        let p = t.seal(90.0, 100.0);
        let models = vec!["mnet;v3".to_string()];
        let classes = vec!["interactive".to_string()];
        let text = folded("board0", &p, &models, &classes);
        for line in text.lines() {
            let (stack, count) = line.rsplit_once(' ').unwrap();
            assert!(count.parse::<u64>().unwrap() > 0);
            assert!(stack.starts_with("board0;"));
        }
        // Separator in a label is sanitized, not a new frame.
        assert!(text.contains("board0;mnet:v3;interactive;compute 6"));
        assert!(text.contains("board0;idle 90"));
    }

    #[test]
    fn chrome_trace_is_valid_json_with_required_keys() {
        use crate::util::json::{parse, Value};
        let records = vec![
            TraceRecord {
                t_us: 10.0,
                model: 0,
                class: 0,
                event: TraceEvent::Dispatch {
                    lane: 1,
                    batch: 4,
                    freq_state: NONE,
                },
            },
            TraceRecord {
                t_us: 30.0,
                model: 0,
                class: NONE,
                event: TraceEvent::Compute { lane: 1, dur_us: 20.0 },
            },
            TraceRecord {
                t_us: 5.0,
                model: NONE,
                class: NONE,
                event: TraceEvent::Throttle,
            },
        ];
        let models = vec!["m\"quote".to_string()];
        let classes = vec!["interactive".to_string()];
        let text = chrome_trace(&[&records], &models, &classes);
        let doc = parse(&text).expect("chrome export must parse");
        let Value::Obj(o) = &doc else { panic!("not an object") };
        let Some(Value::Arr(events)) = o.get("traceEvents") else {
            panic!("no traceEvents array")
        };
        assert_eq!(events.len(), 3);
        for e in events {
            let Value::Obj(e) = e else { panic!("event not object") };
            for key in ["name", "ph", "ts", "pid", "tid"] {
                assert!(e.contains_key(key), "missing {key}");
            }
        }
        // The span event carries dur and ts = end - dur.
        let Value::Obj(span) = &events[1] else { unreachable!() };
        assert_eq!(span.get("ph"), Some(&Value::Str("X".into())));
        assert_eq!(span.get("dur"), Some(&Value::Num(20.0)));
        assert_eq!(span.get("ts"), Some(&Value::Num(10.0)));
        // Instants carry the scope key.
        let Value::Obj(inst) = &events[0] else { unreachable!() };
        assert_eq!(inst.get("ph"), Some(&Value::Str("i".into())));
        assert_eq!(inst.get("s"), Some(&Value::Str("t".into())));
    }
}
