//! [`Session`] — the owned, thread-safe entry point to the SparOA engine.
//!
//! A session bundles everything one model needs to run — graph, device
//! profile, schedule, engine options and an execution backend — behind a
//! builder, so CLI subcommands, the server, examples and tests stop
//! hand-assembling graph + device + predictor + scheduler + options.
//!
//! ```text
//! SessionBuilder::new()
//!     .model("mobilenet_v3_small")
//!     .device("agx_orin")
//!     .policy("sac")
//!     .backend(BackendChoice::Sim)
//!     .build()?
//!     .infer()?
//! ```

use crate::api::backend::{
    BackendChoice, ExecuteRequest, ExecutionBackend,
};
use crate::api::report::InferenceReport;
use crate::baselines::Baseline;
use crate::config::Config;
use crate::device::{DeviceModel, DeviceRegistry};
use crate::engine::sim::SimOptions;
use crate::graph::{ModelGraph, ModelZoo};
use crate::predictor::ThresholdPredictor;
use crate::runtime::HostTensor;
use crate::scheduler::Schedule;
use crate::server::batcher::{
    run_batching, BatchPolicy, BatchingReport, Request,
};
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Builder for [`Session`]: model + device + policy + batch + backend.
///
/// Defaults mirror [`Config::default`]; every knob is optional.
pub struct SessionBuilder {
    artifacts: PathBuf,
    devices_json: Option<PathBuf>,
    model: String,
    device: String,
    policy: String,
    batch: usize,
    episodes: usize,
    seed: u64,
    use_predictor: bool,
    warm: bool,
    schedule: Option<Schedule>,
    options: Option<SimOptions>,
    backend: BackendChoice,
    graph_override: Option<ModelGraph>,
    device_override: Option<DeviceModel>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        let cfg = Config::default();
        SessionBuilder {
            artifacts: cfg.artifacts,
            devices_json: None,
            model: cfg.model,
            device: cfg.device,
            policy: cfg.policy,
            batch: cfg.batch.max(1),
            episodes: cfg.episodes,
            seed: cfg.seed,
            use_predictor: false,
            warm: true,
            schedule: None,
            options: None,
            backend: BackendChoice::Sim,
            graph_override: None,
            device_override: None,
        }
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed every field from a [`Config`] (the CLI path).  The config's
    /// `backend` string selects the execution substrate; `"both"` maps to
    /// the simulator (the CLI layers its own real pass on top).
    pub fn from_config(cfg: &Config) -> Self {
        let backend = match cfg.backend.as_str() {
            "pjrt" => BackendChoice::Pjrt,
            _ => BackendChoice::Sim,
        };
        SessionBuilder {
            artifacts: cfg.artifacts.clone(),
            devices_json: None,
            model: cfg.model.clone(),
            device: cfg.device.clone(),
            policy: cfg.policy.clone(),
            batch: cfg.batch.max(1),
            episodes: cfg.episodes,
            seed: cfg.seed,
            use_predictor: false,
            warm: true,
            schedule: None,
            options: None,
            backend,
            graph_override: None,
            device_override: None,
        }
    }

    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }
    pub fn devices_json(mut self, path: impl Into<PathBuf>) -> Self {
        self.devices_json = Some(path.into());
        self
    }
    pub fn model(mut self, name: &str) -> Self {
        self.model = name.into();
        self
    }
    pub fn device(mut self, id: &str) -> Self {
        self.device = id.into();
        self
    }
    /// Scheduling policy name (see [`Baseline::from_name`]).
    pub fn policy(mut self, name: &str) -> Self {
        self.policy = name.into();
        self
    }
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }
    /// SAC training episodes (policies that learn).
    pub fn episodes(mut self, episodes: usize) -> Self {
        self.episodes = episodes;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    /// Query the threshold predictor during build (PJRT backends only)
    /// and hand its per-op thresholds to the scheduling policy.
    pub fn use_predictor(mut self, yes: bool) -> Self {
        self.use_predictor = yes;
        self
    }
    /// Warm the backend up at build (compile all artifacts, cache
    /// weights).  On by default; disable for sessions that only need
    /// metadata (e.g. predictor queries) — execution still works, paying
    /// lazy compilation on first use instead.
    pub fn warm(mut self, yes: bool) -> Self {
        self.warm = yes;
        self
    }
    /// Use this exact schedule instead of running the policy.
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }
    /// Override the engine options (baseline knobs, noise, batch...).
    pub fn options(mut self, options: SimOptions) -> Self {
        self.options = Some(options);
        self
    }
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.backend = backend;
        self
    }
    /// Use this graph directly instead of loading it from `artifacts/`.
    /// Lets synthetic models ([`ModelGraph::synthetic`]) and in-memory
    /// graphs run through the full session machinery without `make
    /// artifacts` — the substrate for always-on tests and the
    /// multi-tenant serving demos.
    pub fn with_graph(mut self, graph: ModelGraph) -> Self {
        self.graph_override = Some(graph);
        self
    }
    /// Use this device profile directly instead of resolving
    /// `devices.json`.
    pub fn with_device(mut self, device: DeviceModel) -> Self {
        self.device_override = Some(device);
        self
    }

    /// Load the model + device, resolve the backend, run the scheduling
    /// policy and warm everything up.
    pub fn build(self) -> Result<Session> {
        let graph = match self.graph_override {
            Some(g) => {
                g.validate()?;
                g
            }
            None => {
                let zoo = ModelZoo::load(&self.artifacts)?;
                zoo.get(&self.model)?.clone()
            }
        };
        let device = match self.device_override {
            Some(d) => d,
            None => load_device(
                &self.artifacts,
                self.devices_json.as_deref(),
                &self.device,
            )?,
        };

        // Resolve the backend first: the predictor runs through it.
        anyhow::ensure!(
            !self.use_predictor
                || matches!(self.backend, BackendChoice::Pjrt),
            "use_predictor requires the PJRT backend (the threshold \
             predictor is an HLO artifact queried through the runtime)"
        );
        let (backend, thresholds): (Box<dyn ExecutionBackend>, _) =
            match self.backend {
                BackendChoice::Sim => {
                    (Box::new(crate::api::backend::SimBackend), None)
                }
                BackendChoice::Pjrt => {
                    let be = crate::api::backend::PjrtBackend::new(
                        &self.artifacts)?;
                    let th = if self.use_predictor {
                        let pred = ThresholdPredictor::new(be.runtime());
                        Some(pred.predict_graph(&graph)?)
                    } else {
                        None
                    };
                    (Box::new(be), th)
                }
                BackendChoice::Custom(be) => (be, None),
            };

        let baseline = Baseline::from_name(&self.policy)
            .with_context(|| format!("unknown policy `{}`", self.policy))?;
        let schedule = match self.schedule {
            Some(s) => {
                anyhow::ensure!(
                    s.xi.len() == graph.ops.len(),
                    "schedule has {} entries for a {}-op graph",
                    s.xi.len(),
                    graph.ops.len()
                );
                s
            }
            None => baseline.schedule(
                &graph,
                &device,
                thresholds.as_deref(),
                self.batch,
                self.episodes,
            ),
        };
        let options = self
            .options
            .unwrap_or_else(|| baseline.options(self.batch, self.seed));

        let compiled =
            if self.warm { backend.warm_up(&graph)? } else { 0 };
        Ok(Session {
            graph,
            device,
            schedule,
            options,
            thresholds,
            backend,
            compiled,
        })
    }
}

/// Device registry lookup with the conventional fallbacks: an explicit
/// path, then `artifacts/devices.json` (copied there by `make artifacts`),
/// then `config/devices.json` at the repo root.
fn load_device(
    artifacts: &std::path::Path,
    explicit: Option<&std::path::Path>,
    id: &str,
) -> Result<DeviceModel> {
    let path = match explicit {
        Some(p) => p.to_path_buf(),
        None => {
            let in_artifacts = artifacts.join("devices.json");
            if in_artifacts.exists() {
                in_artifacts
            } else {
                crate::repo_root().join("config/devices.json")
            }
        }
    };
    let reg = DeviceRegistry::load(&path)?;
    Ok(reg.get(id)?.clone())
}

/// An owned inference session: one model, one device profile, one
/// schedule, one execution backend.  `Send`, no borrowed lifetimes —
/// a server can move it onto its worker thread.
pub struct Session {
    graph: ModelGraph,
    device: DeviceModel,
    schedule: Schedule,
    options: SimOptions,
    thresholds: Option<Vec<(f64, f64)>>,
    backend: Box<dyn ExecutionBackend>,
    compiled: usize,
}

impl Session {
    pub fn graph(&self) -> &ModelGraph {
        &self.graph
    }
    pub fn device(&self) -> &DeviceModel {
        &self.device
    }
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }
    pub fn options(&self) -> &SimOptions {
        &self.options
    }
    /// Predicted per-op thresholds, when built with `use_predictor`.
    pub fn thresholds(&self) -> Option<&[(f64, f64)]> {
        self.thresholds.as_deref()
    }
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
    /// Executables compiled at warm-up (0 for simulate-only backends).
    pub fn compiled(&self) -> usize {
        self.compiled
    }
    /// Swap in a new schedule (e.g. after re-training the policy online).
    pub fn set_schedule(&mut self, schedule: Schedule) {
        self.schedule = schedule;
    }
    pub fn set_options(&mut self, options: SimOptions) {
        self.options = options;
    }

    /// A seeded standard-normal input of the model's exec shape.
    pub fn random_input(&self, seed: u64) -> HostTensor {
        HostTensor::random_normal(&self.graph.input_shape_exec, seed)
    }

    /// One inference at the session's batch size.  Numerics backends
    /// synthesize a seeded input; use [`Session::infer_input`] for real
    /// data.
    pub fn infer(&self) -> Result<InferenceReport> {
        self.execute(&[], &self.options)
    }

    /// One inference on a caller-provided input tensor.
    pub fn infer_input(&self, input: &HostTensor) -> Result<InferenceReport> {
        self.execute(std::slice::from_ref(input), &self.options)
    }

    /// One batched inference over `inputs` (batch = `inputs.len()`).
    pub fn infer_batch(
        &self,
        inputs: &[HostTensor],
    ) -> Result<InferenceReport> {
        anyhow::ensure!(!inputs.is_empty(), "infer_batch needs >= 1 input");
        let mut opts = self.options.clone();
        opts.batch = inputs.len();
        self.execute(inputs, &opts)
    }

    fn execute(
        &self,
        inputs: &[HostTensor],
        options: &SimOptions,
    ) -> Result<InferenceReport> {
        self.backend.execute(&ExecuteRequest {
            graph: &self.graph,
            device: &self.device,
            schedule: &self.schedule,
            options,
            inputs,
        })
    }

    /// Serve a virtual-time request stream under a batching policy
    /// (Fig. 8 path).  Per-batch latency comes from the calibrated
    /// simulator timeline regardless of this session's backend — serving
    /// accounting is virtual time; use [`Session::infer_input`] per
    /// request for real numerics (see examples/serve_requests.rs).
    /// Pass a backend explicitly via
    /// [`crate::server::batcher::run_batching`] to time batches on a
    /// different substrate.
    pub fn serve(
        &self,
        requests: &[Request],
        policy: &BatchPolicy,
    ) -> Result<BatchingReport> {
        run_batching(
            &crate::api::backend::SimBackend,
            &self.graph,
            &self.device,
            &self.schedule,
            &self.options,
            requests,
            policy,
        )
    }

    /// Probe one `batch`-sized inference under an alternate `schedule`
    /// through this session's backend, without mutating the session.
    /// The multi-tenant cluster scheduler uses this as its latency
    /// oracle (e.g. "what would this model's batch cost on the CPU
    /// fallback plan?") — cached per (placement, batch) in
    /// `serve::registry::ModelEntry::latency_us`.  Probes skip per-op
    /// timing recording: callers consume the aggregates only, and the
    /// serve tier issues thousands of probes per run.
    pub fn probe(
        &self,
        schedule: &Schedule,
        batch: usize,
    ) -> Result<InferenceReport> {
        anyhow::ensure!(
            schedule.xi.len() == self.graph.ops.len(),
            "probe schedule has {} entries for a {}-op graph",
            schedule.xi.len(),
            self.graph.ops.len()
        );
        let mut opts = self.options.clone();
        opts.batch = batch.max(1);
        opts.record_timings = false;
        self.backend.execute(&ExecuteRequest {
            graph: &self.graph,
            device: &self.device,
            schedule,
            options: &opts,
            inputs: &[],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_session_builds_and_infers() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let session = SessionBuilder::new()
            .model("mobilenet_v3_small")
            .device("agx_orin")
            .policy("greedy")
            .backend(BackendChoice::Sim)
            .build()
            .unwrap();
        let rep = session.infer().unwrap();
        assert_eq!(rep.backend, "sim");
        assert!(rep.makespan_us > 0.0);
        let batched = session
            .infer_batch(&[
                session.random_input(1),
                session.random_input(2),
            ])
            .unwrap();
        assert_eq!(batched.batch, 2);
        assert!(batched.makespan_us > rep.makespan_us);
    }

    #[test]
    fn synthetic_session_runs_without_artifacts() {
        // No `make artifacts`, no gating: with_graph + with_device make
        // the full session machinery self-contained.
        let g = ModelGraph::synthetic("syn_session", 4, 1.0, 0.5);
        let dev = crate::bench_support::device_profile("agx_orin");
        let session = SessionBuilder::new()
            .with_graph(g)
            .with_device(dev)
            .policy("greedy")
            .build()
            .unwrap();
        let rep = session.infer().unwrap();
        assert_eq!(rep.backend, "sim");
        assert!(rep.makespan_us > 0.0);
        // probe: CPU projection is slower than the hybrid plan on this
        // compute-heavy chain, and leaves the GPU idle.
        let cpu = session.schedule().project(
            crate::device::Proc::Cpu, "cpu-probe");
        let probed = session.probe(&cpu, 2).unwrap();
        assert_eq!(probed.batch, 2);
        assert!(probed.gpu_busy_us == 0.0);
        assert!(probed.makespan_us > rep.makespan_us);
        // wrong-length schedules are rejected
        let bad = Schedule { xi: vec![0.0; 3], policy: "bad".into() };
        assert!(session.probe(&bad, 1).is_err());
    }

    #[test]
    fn schedule_override_skips_policy() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let g = zoo.get("resnet18").unwrap();
        let session = SessionBuilder::new()
            .model("resnet18")
            .schedule(Schedule::uniform(g, 0.0, "cpu-pin"))
            .build()
            .unwrap();
        assert_eq!(session.schedule().policy, "cpu-pin");
        let rep = session.infer().unwrap();
        assert_eq!(rep.policy, "cpu-pin");
        assert!(rep.gpu_busy_us == 0.0);
    }
}
