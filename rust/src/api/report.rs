//! The unified [`InferenceReport`] every execution backend returns.
//!
//! Simulated and real runs produce the *same* type: the virtual-time
//! latency/energy/memory breakdown is always present (the real path shares
//! the calibrated timeline, DESIGN.md §5), while numerics-only fields
//! (`output`, `measured_sparsity`, `host_us`) are `Some` only for backends
//! that actually execute the model.  This is what lets a single parity
//! test diff a `SimBackend` run against a `PjrtBackend` run.

use crate::device::Proc;
use crate::energy::EnergyLedger;
use crate::engine::sim::{OpTiming, SimReport};
use crate::runtime::HostTensor;
use crate::scheduler::Schedule;

/// Unified result of one inference, regardless of execution substrate.
#[derive(Debug, Clone, Default)]
pub struct InferenceReport {
    /// Backend that produced the report ("sim", "pjrt", ...).
    pub backend: String,
    /// Schedule provenance: the policy that produced the placement.
    pub policy: String,
    /// Batch size the report accounts for.
    pub batch: usize,
    // --- virtual-time latency breakdown (calibrated device timeline) ---
    pub makespan_us: f64,
    pub cpu_busy_us: f64,
    pub gpu_busy_us: f64,
    pub transfer_us: f64,
    pub launch_us: f64,
    pub aggregation_us: f64,
    pub switches: u32,
    pub timings: Vec<OpTiming>,
    // --- memory accounting ---
    pub peak_gpu_mem_mb: f64,
    pub cpu_mem_mb: f64,
    // --- real-execution extras (None on simulate-only backends) ---
    /// Host wall-clock of the real execution path, microseconds.
    pub host_us: Option<f64>,
    /// Model output tensor.
    pub output: Option<HostTensor>,
    /// Measured per-op output sparsity (paper Eq. 1) from real numerics.
    pub measured_sparsity: Option<Vec<f64>>,
}

impl InferenceReport {
    /// Lift a simulator report into the unified shape.
    pub fn from_sim(
        backend: &str,
        schedule: &Schedule,
        batch: usize,
        rep: SimReport,
    ) -> Self {
        InferenceReport {
            backend: backend.into(),
            policy: schedule.policy.clone(),
            batch,
            makespan_us: rep.makespan_us,
            cpu_busy_us: rep.cpu_busy_us,
            gpu_busy_us: rep.gpu_busy_us,
            transfer_us: rep.transfer_us,
            launch_us: rep.launch_us,
            aggregation_us: rep.aggregation_us,
            switches: rep.switches,
            timings: rep.timings,
            peak_gpu_mem_mb: rep.peak_gpu_mem_mb,
            cpu_mem_mb: rep.cpu_mem_mb,
            host_us: None,
            output: None,
            measured_sparsity: None,
        }
    }

    /// Energy ledger over the virtual-time breakdown (Fig. 11 accounting).
    pub fn ledger(&self) -> EnergyLedger {
        EnergyLedger {
            cpu_busy_us: self.cpu_busy_us,
            gpu_busy_us: self.gpu_busy_us,
            xfer_us: self.transfer_us,
            makespan_us: self.makespan_us,
        }
    }

    /// Total memory footprint (weights on each device + peak activations).
    pub fn total_mem_mb(&self) -> f64 {
        self.peak_gpu_mem_mb + self.cpu_mem_mb
    }

    /// Busy time of one processor timeline.
    pub fn busy_us(&self, proc: Proc) -> f64 {
        match proc {
            Proc::Cpu => self.cpu_busy_us,
            Proc::Gpu => self.gpu_busy_us,
        }
    }

    /// One-line human summary for CLI/examples.
    pub fn summary(&self) -> String {
        let real = match self.host_us {
            Some(us) => format!(" host={us:.0}us"),
            None => String::new(),
        };
        format!(
            "[{}] policy={} batch={} makespan={:.1}us cpu={:.1}us \
             gpu={:.1}us transfer={:.1}us switches={} peak_gpu_mem={:.1}MB{}",
            self.backend, self.policy, self.batch, self.makespan_us,
            self.cpu_busy_us, self.gpu_busy_us, self.transfer_us,
            self.switches, self.peak_gpu_mem_mb, real
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_lift_preserves_breakdown_and_provenance() {
        let rep = SimReport {
            makespan_us: 100.0,
            cpu_busy_us: 40.0,
            gpu_busy_us: 55.0,
            transfer_us: 5.0,
            ..Default::default()
        };
        let sched = Schedule {
            xi: vec![1.0; 3],
            policy: "unit-test".into(),
        };
        let r = InferenceReport::from_sim("sim", &sched, 2, rep);
        assert_eq!(r.backend, "sim");
        assert_eq!(r.policy, "unit-test");
        assert_eq!(r.batch, 2);
        assert!((r.makespan_us - 100.0).abs() < 1e-12);
        assert!(r.output.is_none() && r.host_us.is_none());
        let ledger = r.ledger();
        assert!((ledger.cpu_busy_us - 40.0).abs() < 1e-12);
        assert!((ledger.xfer_us - 5.0).abs() < 1e-12);
    }
}
