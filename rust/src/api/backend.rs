//! [`ExecutionBackend`] — the pluggable execution substrate behind a
//! [`crate::api::Session`].
//!
//! Two first-class implementations ship with the crate:
//! * [`SimBackend`] — the virtual-time simulator over the calibrated
//!   device models (`engine::sim`); every figure/baseline runs here.
//!   Each `execute` is one `simulate` call — a thin wrapper over the
//!   `engine::costs` table walk; search loops that re-simulate one
//!   (graph, device, options) many times should hold a
//!   `engine::costs::CostTable` directly instead of going through a
//!   backend (the serve tier additionally memoizes probe results per
//!   (model, placement, batch) in its registry).
//! * [`PjrtBackend`] — real numerics through the PJRT runtime
//!   (`engine::exec`), owned and `Send`, with per-model executable and
//!   weight-parameter caches so the request hot path neither compiles nor
//!   re-slices `weights.bin`.
//!
//! Both return the unified [`InferenceReport`]; the real backend also
//! replays the schedule on the simulated timeline so its latency/energy
//! breakdown is directly comparable to a simulated run (the parity test in
//! `tests/api_parity.rs` diffs the two).

use crate::api::report::InferenceReport;
use crate::device::DeviceModel;
use crate::engine::exec::{execute_graph, OpParams};
use crate::engine::sim::{simulate, SimOptions};
use crate::graph::ModelGraph;
use crate::runtime::{HostTensor, Runtime, WeightStore};
use crate::scheduler::Schedule;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// One execution request: everything a backend needs to run (or replay)
/// a scheduled inference.
pub struct ExecuteRequest<'a> {
    pub graph: &'a ModelGraph,
    pub device: &'a DeviceModel,
    pub schedule: &'a Schedule,
    pub options: &'a SimOptions,
    /// Input tensors, one per batch item.  Backends that only account time
    /// ignore these; numerics backends synthesize a seeded random input
    /// when the slice is empty (`options.seed`).
    pub inputs: &'a [HostTensor],
}

/// Which execution substrate a [`crate::api::SessionBuilder`] should
/// construct.
pub enum BackendChoice {
    /// Virtual-time simulator ([`SimBackend`]).
    Sim,
    /// Real numerics through PJRT ([`PjrtBackend`]).
    Pjrt,
    /// Bring your own backend (sharding, remote executors, ...).
    Custom(Box<dyn ExecutionBackend>),
}

/// An interchangeable execution substrate for the hybrid engine (§5).
///
/// `Send` so a `Session` (or a serving thread pool) can own a boxed
/// backend and move it across threads.
pub trait ExecutionBackend: Send {
    /// Short stable identifier ("sim", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Prepare per-model state (compile artifacts, cache weights).
    /// Returns the number of compiled executables, 0 when nothing to do.
    fn warm_up(&self, _graph: &ModelGraph) -> Result<usize> {
        Ok(0)
    }

    /// Run one (possibly batched) inference and report it.
    fn execute(&self, req: &ExecuteRequest) -> Result<InferenceReport>;
}

/// Virtual-time simulation backend (wraps [`crate::engine::sim`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SimBackend;

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn execute(&self, req: &ExecuteRequest) -> Result<InferenceReport> {
        let rep = simulate(req.graph, req.device, req.schedule, req.options);
        Ok(InferenceReport::from_sim(
            self.name(),
            req.schedule,
            req.options.batch.max(1),
            rep,
        ))
    }
}

/// Real-numerics backend over the PJRT runtime (wraps
/// [`crate::engine::exec`]).
///
/// Owns its [`Runtime`] outright (no borrowed lifetimes): the executable
/// cache already lives behind a mutex inside the runtime, and the per-op
/// parameter tensors are resolved once per model into an [`OpParams`]
/// table shared via `Arc` — repeated `execute` calls clone neither
/// executables nor weights.
pub struct PjrtBackend {
    runtime: Runtime,
    params: Mutex<HashMap<String, Arc<OpParams>>>,
}

impl PjrtBackend {
    pub fn new(artifacts_root: &Path) -> Result<Self> {
        Ok(PjrtBackend {
            runtime: Runtime::new(artifacts_root)?,
            params: Mutex::new(HashMap::new()),
        })
    }

    /// The underlying PJRT runtime (e.g. for the threshold predictor).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Per-model parameter cache: built on first use (or at warm-up).
    fn params_for(&self, graph: &ModelGraph) -> Result<Arc<OpParams>> {
        let mut cache = self.params.lock().unwrap();
        if let Some(p) = cache.get(&graph.model) {
            return Ok(p.clone());
        }
        let weights = WeightStore::load(&graph.weights_path)?;
        let params = Arc::new(OpParams::build(graph, &weights)?);
        cache.insert(graph.model.clone(), params.clone());
        Ok(params)
    }

    fn synth_input(graph: &ModelGraph, seed: u64) -> HostTensor {
        HostTensor::random_normal(&graph.input_shape_exec, seed)
    }
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn warm_up(&self, graph: &ModelGraph) -> Result<usize> {
        self.params_for(graph)?;
        self.runtime.warm_up(graph)
    }

    fn execute(&self, req: &ExecuteRequest) -> Result<InferenceReport> {
        let params = self.params_for(req.graph)?;
        // No inputs supplied: synthesize one per batch item so the real
        // host_us covers the same work the simulated timeline accounts.
        let synthesized: Vec<HostTensor>;
        let inputs: &[HostTensor] = if req.inputs.is_empty() {
            synthesized = (0..req.options.batch.max(1) as u64)
                .map(|i| Self::synth_input(req.graph, req.options.seed + i))
                .collect();
            &synthesized
        } else {
            req.inputs
        };

        let mut host_us = 0.0;
        let mut last = None;
        for input in inputs {
            let res = execute_graph(
                &self.runtime, req.graph, &params, input, req.schedule,
            )?;
            host_us += res.host_us;
            last = Some(res);
        }
        let last = last.context("no inputs executed")?;

        // Shared calibrated timeline: the real path reports the same
        // virtual-time breakdown a simulated run would (DESIGN.md §5).
        let sim =
            simulate(req.graph, req.device, req.schedule, req.options);
        let mut rep = InferenceReport::from_sim(
            self.name(),
            req.schedule,
            req.options.batch.max(1).max(inputs.len()),
            sim,
        );
        rep.host_us = Some(host_us);
        rep.output = Some(last.output);
        rep.measured_sparsity = Some(last.sparsity_out);
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    fn setup() -> Option<(ModelZoo, DeviceRegistry)> {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return None;
        }
        Some((
            ModelZoo::load(&art).unwrap(),
            DeviceRegistry::load(
                &crate::repo_root().join("config/devices.json")).unwrap(),
        ))
    }

    #[test]
    fn sim_backend_reports_unified_shape() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("mobilenet_v2").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let sched = Schedule::uniform(g, 1.0, "gpu");
        let opts = SimOptions::default();
        let rep = SimBackend
            .execute(&ExecuteRequest {
                graph: g,
                device: dev,
                schedule: &sched,
                options: &opts,
                inputs: &[],
            })
            .unwrap();
        assert_eq!(rep.backend, "sim");
        assert_eq!(rep.policy, "gpu");
        assert!(rep.makespan_us > 0.0);
        assert!(rep.output.is_none());
    }
}
