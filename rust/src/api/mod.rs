//! The crate's primary public surface: an owned [`Session`] over a
//! pluggable [`ExecutionBackend`].
//!
//! The paper's hybrid inference engine (§5) is *one* engine with
//! interchangeable execution substrates.  This module is that seam:
//!
//! * [`ExecutionBackend`] — trait over `execute(graph, schedule, input)
//!   -> InferenceReport`.
//! * [`SimBackend`] — the virtual-time simulator (figures, baselines,
//!   serving studies).
//! * [`PjrtBackend`] — real numerics through the PJRT runtime, owned and
//!   `Send`, with executable + weight-parameter caches.
//! * [`Session`] / [`SessionBuilder`] — owns model, device, schedule,
//!   options and backend; exposes `infer()`, `infer_batch()` and
//!   `serve()`.
//! * [`InferenceReport`] — one report type for simulated and real runs,
//!   so the two can be diffed in a single parity test.
//!
//! # Quickstart
//!
//! ```no_run
//! use sparoa::api::{BackendChoice, SessionBuilder};
//!
//! # fn main() -> anyhow::Result<()> {
//! // Simulated timeline (no artifacts executed):
//! let session = SessionBuilder::new()
//!     .model("mobilenet_v3_small")
//!     .device("agx_orin")
//!     .policy("sac")
//!     .episodes(30)
//!     .backend(BackendChoice::Sim)
//!     .build()?;
//! let report = session.infer()?;
//! println!("{}", report.summary());
//!
//! // Real numerics through PJRT on the same configuration:
//! let real = SessionBuilder::new()
//!     .model("mobilenet_v3_small")
//!     .schedule(session.schedule().clone())
//!     .backend(BackendChoice::Pjrt)
//!     .build()?;
//! let rep = real.infer_input(&real.random_input(0))?;
//! println!("output {:?}", rep.output.unwrap().shape);
//! # Ok(()) }
//! ```
//!
//! Serving goes through the same session:
//!
//! ```no_run
//! use sparoa::api::SessionBuilder;
//! use sparoa::server::{batcher::poisson_stream, BatchPolicy};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = SessionBuilder::new().build()?;
//! let stream = poisson_stream(200, 150.0, 42);
//! let rep = session.serve(&stream, &BatchPolicy::Dynamic {
//!     max: 64, optimizer_cost_us: 30.0 })?;
//! println!("p99 {:.0}us at {:.0} rps", rep.p99_latency_us,
//!          rep.throughput_rps);
//! # Ok(()) }
//! ```

pub mod backend;
pub mod report;
pub mod session;

pub use backend::{
    BackendChoice, ExecuteRequest, ExecutionBackend, PjrtBackend,
    SimBackend,
};
pub use report::InferenceReport;
pub use session::{Session, SessionBuilder};
