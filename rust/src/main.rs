//! `sparoa` — the SparOA coordinator CLI / launcher.
//!
//! Subcommands:
//!   profile     — Fig. 2 quadrant profile of a model
//!   infer       — one scheduled inference (simulated timeline + real PJRT)
//!   serve       — serve a Poisson request stream with dynamic batching
//!   serve-multi — multi-tenant SLO-aware serving across models
//!   serve-fleet — distributed multi-board serving: router + autoscaler
//!                 + DVFS governor (energy/J-per-inference reporting)
//!   train       — train the SAC scheduler, print the convergence trace
//!   compare     — run all baselines on one model/device (Fig. 5 row)
//!   predict     — query the threshold predictor for a model
//!
//! Flags are `--key=value` overrides of the config (see config/mod.rs),
//! `--key` alone for booleans (e.g. `--verbose`), plus
//! `--config=<file.json>`.  `sparoa help <cmd>` prints per-subcommand
//! usage.
//!
//! Every subcommand that runs the engine goes through
//! [`sparoa::api::SessionBuilder`] — the CLI owns no engine wiring.

use anyhow::{bail, Context, Result};
use sparoa::api::{BackendChoice, SessionBuilder};
use sparoa::baselines::{Baseline, ALL};
use sparoa::bench_support::Table;
use sparoa::config::Config;
use sparoa::faults::FaultPlan;
use sparoa::graph::ModelZoo;
use sparoa::power::{Governor, PowerConfig, PowerProfile};
use sparoa::profiler;
use sparoa::scheduler::sac_sched::{SacScheduler, SacSchedulerConfig};
use sparoa::scheduler::{ScheduleCtx, Scheduler};
use sparoa::serve::{
    self, merge_arrivals, run_cluster, run_fleet, trace_from_json,
    AutoscalePolicy, ClusterOptions, ClusterPolicy, FleetOptions,
    RouterPolicy,
};
use sparoa::server::{batcher::poisson_stream, BatchPolicy};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const SUBCOMMANDS: [&str; 8] = [
    "profile", "infer", "serve", "serve-multi", "serve-fleet", "train",
    "compare", "predict",
];

fn usage(cmd: &str) -> String {
    let common = "--model=NAME --device=ID --artifacts=DIR --seed=N";
    match cmd {
        "profile" => format!(
            "sparoa profile [{common}]\n  \
             Print the Fig. 2 sparsity/intensity quadrant profile."
        ),
        "infer" => format!(
            "sparoa infer [{common}] [--policy=sac|greedy|dp|threshold|...] \
             [--batch=N] [--episodes=N] [--backend=sim|pjrt|both] \
             [--verbose]\n  \
             One scheduled inference: simulated timeline, energy, and \
             (backend!=sim) real PJRT numerics."
        ),
        "serve" => format!(
            "sparoa serve [{common}] [--policy=..] [--request_rate=R] \
             [--num_requests=N]\n  \
             Serve a Poisson stream under fixed vs dynamic batching."
        ),
        "serve-multi" => format!(
            "sparoa serve-multi [{common}] [--load=X] [--num_requests=N] \
             [--trace=FILE.json] [--json]\n  \
             Multi-tenant SLO-aware serving: 3 models x 3 SLO classes x \
             4 arrival patterns\n  \
             (poisson, bursty MMPP, diurnal, trace replay) on shared \
             CPU/GPU capacity,\n  \
             cross-model cluster scheduling vs a static split baseline."
        ),
        "serve-fleet" => format!(
            "sparoa serve-fleet [{common}] [--boards=N] \
             [--router=round-robin|jsq|cost-aware] [--autoscale] \
             [--governor=race-to-idle|stretch-to-deadline|fixed:N|off] \
             [--power_cap_w=W] \
             [--load=X] [--num_requests=N] [--trace=FILE.json] \
             [--faults=PLAN.json] [--mttf_s=S --mttr_s=S] \
             [--preempt=off|deadline-burn|burn-plus-steal] \
             [--hedge=on|off] [--breaker=on|off] \
             [--trace_out=FILE] [--trace_format=folded|chrome] \
             [--json]\n  \
             Distributed multi-board serving: the serve-multi tenant \
             mix routed across N\n  \
             simulated boards by a front-tier router, with optional \
             replica autoscaling\n  \
             from per-board attainment/queue-pressure signals.  \
             Compares all three routers.\n  \
             Boards run under a DVFS governor (energy columns in every \
             table; --governor=off\n  \
             disables accounting); --power_cap_w bounds per-board \
             instantaneous draw.\n  \
             --faults injects a deterministic fault plan (board \
             crashes, lane loss, thermal\n  \
             slow-downs); --mttf_s/--mttr_s sample seeded crash/rejoin \
             schedules instead.\n  \
             Every router arm runs under the same plan, so rows stay \
             comparable.\n  \
             --preempt arms deadline-burn batch preemption (and, with \
             burn-plus-steal,\n  \
             cross-board work stealing); off is bit-identical to \
             run-to-completion.\n  \
             --breaker arms gray-failure detection with a per-board \
             circuit breaker\n  \
             (Closed/Open/Probation); --hedge re-offers \
             deadline-at-risk interactive\n  \
             requests to a second board, first finish wins.  Both \
             default off\n  \
             (bit-identical to single-copy dispatch).\n  \
             --trace_out writes a virtual-time execution trace of the \
             configured router's run\n  \
             (folded = flamegraph.pl/inferno stacks, chrome = Perfetto \
             JSON)."
        ),
        "train" => format!(
            "sparoa train [{common}] [--episodes=N] [--noise=X] \
             [--batch=N]\n  \
             Train the SAC scheduler and print the convergence trace."
        ),
        "compare" => format!(
            "sparoa compare [{common}] [--batch=N] [--episodes=N]\n  \
             Run all eleven baselines + SparOA on one model/device."
        ),
        "predict" => format!(
            "sparoa predict [{common}]\n  \
             Query the threshold predictor (requires PJRT artifacts)."
        ),
        _ => format!(
            "sparoa <{}> [--key=value ...] [--key] [--config=file.json]\n\
             Run `sparoa help <cmd>` for per-subcommand usage.",
            SUBCOMMANDS.join("|")
        ),
    }
}

/// Parse CLI args: one subcommand (plus an optional help topic),
/// `--key=value` config overrides, and bare `--flag` booleans.
fn parse_args() -> Result<(String, Option<String>, Config)> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut positional = Vec::new();
    let mut cfg = Config::default();
    // Flags that may appear bare (`--flag` == `--flag=true`).
    const BOOL_FLAGS: [&str; 3] = ["verbose", "json", "autoscale"];
    for a in &args {
        if let Some(rest) = a.strip_prefix("--") {
            // `--key=value`, or a bare boolean `--flag` (=true).
            let (k, v) = match rest.split_once('=') {
                Some((k, v)) => (k, v),
                None if rest == "help" || rest == "h" => {
                    positional.insert(0, "help".to_string());
                    continue;
                }
                None if BOOL_FLAGS.contains(&rest) => (rest, "true"),
                None => bail!("flag `{a}` needs =value"),
            };
            if k == "config" {
                anyhow::ensure!(v != "true",
                                "flag `--config` needs =file.json");
                cfg = Config::from_file(std::path::Path::new(v))?;
            } else {
                cfg.apply_override(k, v)
                    .with_context(|| format!("bad flag `{a}`"))?;
            }
        } else {
            positional.push(a.clone());
        }
    }
    let cmd = positional
        .first()
        .cloned()
        .unwrap_or_else(|| "help".to_string());
    let topic = positional.get(1).cloned();
    if cmd != "help" && topic.is_some() {
        bail!(
            "unexpected argument `{}`\n{}",
            topic.unwrap(),
            usage(&cmd)
        );
    }
    Ok((cmd, topic, cfg))
}

fn run() -> Result<()> {
    let (cmd, topic, cfg) = parse_args()?;
    match cmd.as_str() {
        "profile" => profile(&cfg),
        "infer" => infer(&cfg),
        "serve" => serve(&cfg),
        "serve-multi" => serve_multi(&cfg),
        "serve-fleet" => serve_fleet(&cfg),
        "train" => train(&cfg),
        "compare" => compare(&cfg),
        "predict" => predict(&cfg),
        "help" | "-h" => {
            match topic {
                Some(t) if SUBCOMMANDS.contains(&t.as_str()) => {
                    println!("{}", usage(&t));
                }
                Some(t) => {
                    bail!("unknown command `{t}`\n{}", usage(""));
                }
                None => println!("{}", usage("")),
            }
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `sparoa help`)"),
    }
}

fn profile(cfg: &Config) -> Result<()> {
    let zoo = ModelZoo::load(&cfg.artifacts)?;
    let g = zoo.get(&cfg.model)?;
    let profiles = profiler::quadrant_profile(g);
    let counts = profiler::quadrant_counts(&profiles);
    let mut t = Table::new(
        &format!("Fig.2 quadrant profile — {}", cfg.model),
        &["quadrant", "ops", "meaning"],
    );
    for (q, n) in counts {
        let meaning = match q {
            profiler::Quadrant::DenseHeavy => "dense+heavy -> GPU",
            profiler::Quadrant::SparseHeavy => "sparse+heavy (QII!)",
            profiler::Quadrant::DenseLight => "dense+light (QIII)",
            profiler::Quadrant::SparseLight => "sparse+light -> CPU",
        };
        t.row(vec![format!("{q:?}"), n.to_string(), meaning.into()]);
    }
    t.print();
    println!("\n  op-level scatter (sparsity, FLOPs):");
    for p in profiles.iter().take(20) {
        println!("    {:28} rho={:.2} I={:.2e} {:?}",
                 p.name, p.sparsity, p.flops, p.quadrant);
    }
    if profiles.len() > 20 {
        println!("    ... {} more ops", profiles.len() - 20);
    }
    Ok(())
}

fn infer(cfg: &Config) -> Result<()> {
    // Simulated timeline first (also trains/derives the schedule).
    let sim = SessionBuilder::from_config(cfg)
        .backend(BackendChoice::Sim)
        .build()?;
    let rep = sim.infer()?;
    println!(
        "model={} device={} policy={} batch={}",
        cfg.model, cfg.device, rep.policy, rep.batch
    );
    println!(
        "  simulated: makespan={:.1}us cpu_busy={:.1}us gpu_busy={:.1}us \
         transfer={:.1}us switches={} peak_gpu_mem={:.1}MB",
        rep.makespan_us, rep.cpu_busy_us, rep.gpu_busy_us, rep.transfer_us,
        rep.switches, rep.peak_gpu_mem_mb
    );
    let ledger = rep.ledger();
    println!(
        "  power={:.2}W energy={:.2}mJ/inference",
        ledger.mean_power_w(sim.device()),
        ledger.energy_mj(sim.device())
    );
    if cfg.verbose {
        println!("  per-op timeline (first 32):");
        for t in rep.timings.iter().take(32) {
            println!(
                "    op {:4} {:?}  start {:9.1}us  finish {:9.1}us",
                t.op, t.proc, t.start_us, t.finish_us
            );
        }
    }
    if cfg.backend != "sim" {
        // Real numerics through PJRT, reusing the schedule just computed.
        let real = SessionBuilder::from_config(cfg)
            .schedule(sim.schedule().clone())
            .backend(BackendChoice::Pjrt)
            .build()?;
        let rrep = real.infer_input(&real.random_input(cfg.seed))?;
        println!(
            "  real exec: {} artifacts, output shape {:?}, host time {:.0}us",
            real.compiled(),
            rrep.output.map(|o| o.shape).unwrap_or_default(),
            rrep.host_us.unwrap_or(0.0)
        );
    }
    Ok(())
}

fn serve(cfg: &Config) -> Result<()> {
    let session = SessionBuilder::from_config(cfg)
        .backend(BackendChoice::Sim)
        .build()?;
    let reqs = poisson_stream(cfg.num_requests, cfg.request_rate, cfg.seed);
    let mut t = Table::new(
        &format!("serving — {} on {} ({} req @ {:.0}/s)",
                 cfg.model, cfg.device, cfg.num_requests, cfg.request_rate),
        &["policy", "mean lat", "p99 lat", "throughput", "overhead%"],
    );
    for (name, policy) in [
        ("fixed-32",
         BatchPolicy::Fixed { size: 32, timeout_us: 20_000.0 }),
        ("sparoa-dynamic",
         BatchPolicy::Dynamic { max: 64, optimizer_cost_us: 30.0 }),
    ] {
        let rep = session.serve(&reqs, &policy)?;
        t.row(vec![
            name.into(),
            format!("{:.1}us", rep.mean_latency_us),
            format!("{:.1}us", rep.p99_latency_us),
            format!("{:.1} rps", rep.throughput_rps),
            format!("{:.1}%", rep.overhead_pct()),
        ]);
    }
    t.print();
    Ok(())
}

/// Shared serve-multi / serve-fleet preamble: the demo registry,
/// classes, tenants (honoring `--trace`) and the merged arrival stream.
fn demo_workload(
    cfg: &Config,
) -> Result<(
    sparoa::serve::ModelRegistry,
    Vec<sparoa::serve::SloClass>,
    Vec<sparoa::serve::Tenant>,
    Vec<sparoa::serve::Arrival>,
)> {
    let registry = serve::demo::registry(&cfg.artifacts, &cfg.device)?;
    let classes = serve::demo::classes();
    let trace = if cfg.trace.is_empty() {
        None
    } else {
        let text = std::fs::read_to_string(&cfg.trace)
            .with_context(|| format!("reading trace `{}`", cfg.trace))?;
        Some(trace_from_json(&text)?)
    };
    let tenants = serve::demo::tenants(
        &registry, cfg.load, cfg.num_requests, cfg.seed, trace)?;
    let arrivals = merge_arrivals(&tenants, cfg.seed);
    Ok((registry, classes, tenants, arrivals))
}

fn serve_multi(cfg: &Config) -> Result<()> {
    let (registry, classes, tenants, arrivals) = demo_workload(cfg)?;

    if !cfg.json {
        let mut t = Table::new(
            &format!(
                "multi-tenant fleet — {} models on {} (load x{:.1}, {} \
                 requests)",
                registry.len(), cfg.device, cfg.load, arrivals.len()),
            &["tenant", "model", "class", "pattern", "requests"],
        );
        for tn in &tenants {
            t.row(vec![
                tn.name.clone(),
                tn.model.clone(),
                classes[tn.class].name.clone(),
                tn.pattern.kind().into(),
                tn.pattern.len().to_string(),
            ]);
        }
        t.print();
    }

    let mut snapshots = Vec::new();
    for policy in [ClusterPolicy::SparsityAware, ClusterPolicy::StaticSplit]
    {
        let snap = run_cluster(&registry, &classes, &tenants, &arrivals,
            &ClusterOptions { policy, ..Default::default() })?;
        if !cfg.json {
            snap.class_table(&format!(
                "per-class outcomes — {}", snap.policy)).print();
            println!("{}", snap.summary());
        }
        snapshots.push(snap);
    }

    if cfg.json {
        let obj = sparoa::util::json::Value::Arr(
            snapshots.iter().map(|s| s.to_json()).collect());
        println!("{}", sparoa::util::json::to_string(&obj));
    } else {
        let (dyn_a, stat_a) = (
            snapshots[0].aggregate_attainment(),
            snapshots[1].aggregate_attainment(),
        );
        println!(
            "\ncross-model cluster scheduling: {:.1}% aggregate SLO \
             attainment vs {:.1}% on a static CPU/GPU split ({:+.1} pts)",
            100.0 * dyn_a,
            100.0 * stat_a,
            100.0 * (dyn_a - stat_a)
        );
    }
    Ok(())
}

fn serve_fleet(cfg: &Config) -> Result<()> {
    let (registry, classes, tenants, arrivals) = demo_workload(cfg)?;
    let n_boards = cfg.boards.max(1);
    let chosen = RouterPolicy::parse(&cfg.router).with_context(|| {
        format!("router must be round-robin|jsq|cost-aware, got `{}`",
                cfg.router)
    })?;
    let preempt = sparoa::serve::PreemptionPolicy::parse(&cfg.preempt)
        .with_context(|| {
            format!(
                "preempt must be off|deadline-burn|burn-plus-steal, \
                 got `{}`",
                cfg.preempt
            )
        })?;
    // Tail-tolerance switches (validated on|off by config).
    let tail = sparoa::serve::TailPolicy {
        hedge: cfg.hedge == "on",
        breaker: cfg.breaker == "on",
    };

    // Energy accounting is on unless --governor=off: the boards' DVFS
    // ladders come from the same calibrated device profile the demo
    // registry was built on.
    let power = if cfg.governor == "off" {
        None
    } else {
        let governor = Governor::parse(&cfg.governor)?;
        let profile =
            PowerProfile::from_device(registry.get(0).session.device())?;
        let mut pc = PowerConfig::new(profile, governor);
        if cfg.power_cap_w > 0.0 {
            pc.cap_w = Some(cfg.power_cap_w);
        }
        Some(pc)
    };

    // Fault plan: an explicit JSON schedule (--faults=FILE) and/or a
    // seeded MTTF/MTTR crash/rejoin sample appended on top.  The same
    // plan is installed into every router arm so rows stay comparable.
    let mut fault_plan = if cfg.faults.is_empty() {
        FaultPlan::none()
    } else {
        let text = std::fs::read_to_string(&cfg.faults).with_context(
            || format!("reading fault plan `{}`", cfg.faults))?;
        FaultPlan::from_json(&text).with_context(
            || format!("parsing fault plan `{}`", cfg.faults))?
    };
    if cfg.mttf_s > 0.0 {
        anyhow::ensure!(
            cfg.mttr_s > 0.0,
            "--mttf_s needs --mttr_s > 0 (mean repair time, seconds)"
        );
        let horizon_us = arrivals.last().map_or(0.0, |a| a.at_us);
        anyhow::ensure!(
            horizon_us > 0.0,
            "--mttf_s needs a non-empty arrival stream to size the \
             sampling horizon"
        );
        let sampled = FaultPlan::sample_mttf_mttr(
            n_boards, cfg.mttf_s, cfg.mttr_s, horizon_us, cfg.seed)?;
        fault_plan.faults.extend(sampled.faults);
    }

    if !cfg.json {
        println!(
            "fleet — {} boards (1 cpu + 1 gpu lane each), {} models, \
             load x{:.1}, {} requests, autoscale {}, governor {}{}, \
             preempt {}, tail {}",
            n_boards, registry.len(), cfg.load, arrivals.len(),
            if cfg.autoscale { "on" } else { "off" },
            if cfg.governor == "off" { "off" } else { &cfg.governor },
            match cfg.power_cap_w {
                w if w > 0.0 && power.is_some() =>
                    format!(", cap {w:.1} W/board"),
                _ => String::new(),
            },
            preempt.name(),
            tail.name(),
        );
        if !fault_plan.is_none() {
            println!(
                "fault plan: {} faults armed ({}{})",
                fault_plan.faults.len(),
                if cfg.faults.is_empty() {
                    "sampled"
                } else {
                    cfg.faults.as_str()
                },
                if cfg.mttf_s > 0.0 && !cfg.faults.is_empty() {
                    " + sampled"
                } else {
                    ""
                },
            );
        }
    }

    // Run all three routers over the same stream for the comparison
    // table; the configured one is detailed last.
    let routers = [
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::CostAware,
    ];
    let mut snapshots = Vec::new();
    for router in routers {
        let mut opts = FleetOptions::new(n_boards, registry.len());
        opts.router = router;
        opts.power = power.clone();
        opts.faults = fault_plan.clone();
        opts.preempt = preempt;
        opts.tail = tail;
        if cfg.autoscale {
            opts.autoscale = Some(AutoscalePolicy::default());
        }
        // Only the configured router's run pays for tracing; the two
        // comparison runs stay on the disabled (zero-cost) tracer.
        if !cfg.trace_out.is_empty() && router == chosen {
            opts.trace = Some(sparoa::obs::TraceConfig::default());
        }
        snapshots.push(run_fleet(
            &registry, &classes, &tenants, &arrivals, &opts)?);
    }

    if !cfg.trace_out.is_empty() {
        let traced = snapshots
            .iter()
            .find(|s| s.router == chosen.name())
            .expect("configured router was run");
        let text = match cfg.trace_format.as_str() {
            "chrome" => traced.chrome_trace(),
            _ => traced.folded_trace(),
        };
        std::fs::write(&cfg.trace_out, text).with_context(|| {
            format!("writing trace `{}`", cfg.trace_out)
        })?;
        if !cfg.json {
            println!("trace ({}) -> {}", cfg.trace_format, cfg.trace_out);
        }
    }

    if cfg.json {
        let obj = sparoa::util::json::Value::Arr(
            snapshots.iter().map(|s| s.to_json()).collect());
        println!("{}", sparoa::util::json::to_string(&obj));
        return Ok(());
    }

    let energy_on = power.is_some();
    let mut headers = vec![
        "router", "attainment", "shed", "mean batch", "cpu util",
        "gpu util", "scale events",
    ];
    if energy_on {
        headers.extend(["mJ/inf", "mean W", "throttles"]);
    }
    let mut t = Table::new("front-tier router comparison", &headers);
    for s in &snapshots {
        let mut row = vec![
            s.router.clone(),
            format!("{:.1}%", 100.0 * s.aggregate_attainment()),
            s.total_shed().to_string(),
            format!("{:.1}", s.aggregate.mean_batch()),
            format!("{:.0}%", 100.0 * s.mean_cpu_util()),
            format!("{:.0}%", 100.0 * s.mean_gpu_util()),
            s.scale_events.len().to_string(),
        ];
        if energy_on {
            row.extend([
                format!("{:.2}", s.energy_per_inference_mj()),
                format!("{:.1}", s.mean_power_w()),
                s.total_throttles().to_string(),
            ]);
        }
        t.row(row);
    }
    t.print();

    let detail = snapshots
        .iter()
        .find(|s| s.router == chosen.name())
        .expect("configured router was run");
    let mut headers = vec![
        "board", "offered", "served", "met", "shed", "cpu util",
        "gpu util",
    ];
    if energy_on {
        headers.extend(["mJ/inf", "mean W", "throttles"]);
    }
    let mut bt = Table::new(
        &format!("per-board outcomes — {}", detail.router),
        &headers,
    );
    for (b, snap) in detail.boards.iter().enumerate() {
        let mut row = vec![
            b.to_string(),
            snap.total_offered().to_string(),
            snap.total_served().to_string(),
            snap.total_met().to_string(),
            snap.total_shed().to_string(),
            format!("{:.0}%", 100.0 * snap.cpu_util()),
            format!("{:.0}%", 100.0 * snap.gpu_util()),
        ];
        if energy_on {
            row.extend([
                format!("{:.2}", snap.energy_per_inference_mj()),
                format!("{:.1}", snap.mean_power_w()),
                snap.throttle_events.to_string(),
            ]);
        }
        bt.row(row);
    }
    bt.print();
    detail
        .aggregate
        .class_table("fleet per-class outcomes")
        .print();
    if cfg.autoscale {
        let reps: Vec<String> = detail
            .mean_replicas
            .iter()
            .map(|x| format!("{x:.2}"))
            .collect();
        println!(
            "autoscaler: {} scale events, mean replicas per model \
             [{}]",
            detail.scale_events.len(),
            reps.join(", "),
        );
    }
    println!("{}", detail.summary());
    Ok(())
}

fn train(cfg: &Config) -> Result<()> {
    // A cheap static session provides the owned graph/device pair; the
    // trained plan is then swapped in and evaluated through the same API.
    let mut session = SessionBuilder::from_config(cfg)
        .policy("threshold")
        .backend(BackendChoice::Sim)
        .build()?;
    let mut s = SacScheduler::new(SacSchedulerConfig {
        episodes: cfg.episodes,
        noise: cfg.noise,
        ..Default::default()
    });
    let plan = s.schedule(&ScheduleCtx {
        graph: session.graph(),
        device: session.device(),
        thresholds: session.thresholds(),
        batch: cfg.batch.max(1),
    });
    println!("SAC convergence on {} / {}:", cfg.model, cfg.device);
    for p in &s.trace {
        println!("  ep {:3}  makespan {:9.1} us  t={:6.2}s",
                 p.episode, p.makespan_us, p.wall_s);
    }
    let gpu_share = plan.gpu_share(session.graph());
    let switches = plan.switch_count(session.graph());
    session.set_schedule(plan);
    let rep = session.infer()?;
    println!("converged after {:.2}s; gpu share {:.1}%; switches {}; \
              eval makespan {:.1}us",
             s.converged_after_s, 100.0 * gpu_share, switches,
             rep.makespan_us);
    Ok(())
}

fn compare(cfg: &Config) -> Result<()> {
    let session = SessionBuilder::from_config(cfg)
        .policy("threshold")
        .backend(BackendChoice::Sim)
        .build()?;
    let (g, dev) = (session.graph(), session.device());
    let mut t = Table::new(
        &format!("Fig.5 latency — {} on {}", cfg.model, cfg.device),
        &["baseline", "latency (us)", "speedup vs SparOA", "gpu share"],
    );
    let mut results = Vec::new();
    for b in ALL {
        let episodes = if b == Baseline::Sparoa { cfg.episodes } else { 0 };
        let (sched, rep) = b.run(g, dev, None, cfg.batch.max(1), episodes);
        results.push((b, sched, rep));
    }
    let sparoa_lat = results
        .iter()
        .find(|(b, _, _)| *b == Baseline::Sparoa)
        .unwrap()
        .2
        .makespan_us;
    for (b, sched, rep) in &results {
        t.row(vec![
            b.name().into(),
            format!("{:.1}", rep.makespan_us),
            format!("{:.2}x", rep.makespan_us / sparoa_lat),
            format!("{:.0}%", 100.0 * sched.gpu_share(g)),
        ]);
    }
    t.print();
    Ok(())
}

fn predict(cfg: &Config) -> Result<()> {
    let session = SessionBuilder::from_config(cfg)
        .policy("threshold")
        .backend(BackendChoice::Pjrt)
        .use_predictor(true)
        .warm(false) // thresholds only; skip compiling every artifact
        .build()?;
    let th = session
        .thresholds()
        .context("predictor returned no thresholds")?;
    println!("threshold predictions for {} (first 24 ops):", cfg.model);
    for (op, (s, c)) in session.graph().ops.iter().zip(th).take(24) {
        println!("  {:28} rho={:.2} -> s*={:.2} c*={:.2}",
                 op.name, op.sparsity_in, s, c);
    }
    Ok(())
}
