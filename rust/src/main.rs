//! `sparoa` — the SparOA coordinator CLI / launcher.
//!
//! Subcommands:
//!   profile    — Fig. 2 quadrant profile of a model
//!   infer      — one scheduled inference (simulated timeline + real PJRT)
//!   serve      — serve a Poisson request stream with dynamic batching
//!   train      — train the SAC scheduler, print the convergence trace
//!   compare    — run all baselines on one model/device (Fig. 5 row)
//!   predict    — query the threshold predictor for a model
//!
//! Flags are `--key=value` overrides of the config (see config/mod.rs),
//! plus `--config=<file.json>`.

use anyhow::{bail, Context, Result};
use sparoa::baselines::{Baseline, ALL};
use sparoa::bench_support::Table;
use sparoa::config::Config;
use sparoa::device::DeviceRegistry;
use sparoa::engine::sim::{simulate, SimOptions};
use sparoa::engine::HybridEngine;
use sparoa::graph::ModelZoo;
use sparoa::predictor::ThresholdPredictor;
use sparoa::profiler;
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::sac_sched::{SacScheduler, SacSchedulerConfig};
use sparoa::scheduler::{Schedule, ScheduleCtx, Scheduler};
use sparoa::server::{run_batching_sim, BatchPolicy};
use sparoa::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_args() -> Result<(String, Config)> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = String::new();
    let mut cfg = Config::default();
    for a in &args {
        if let Some(rest) = a.strip_prefix("--") {
            let (k, v) = rest
                .split_once('=')
                .with_context(|| format!("flag `{a}` needs =value"))?;
            if k == "config" {
                cfg = Config::from_file(std::path::Path::new(v))?;
            } else {
                cfg.apply_override(k, v)?;
            }
        } else if cmd.is_empty() {
            cmd = a.clone();
        } else {
            bail!("unexpected argument `{a}`");
        }
    }
    if cmd.is_empty() {
        cmd = "help".into();
    }
    Ok((cmd, cfg))
}

fn run() -> Result<()> {
    let (cmd, cfg) = parse_args()?;
    match cmd.as_str() {
        "profile" => profile(&cfg),
        "infer" => infer(&cfg),
        "serve" => serve(&cfg),
        "train" => train(&cfg),
        "compare" => compare(&cfg),
        "predict" => predict(&cfg),
        "help" | "-h" | "--help" => {
            println!(
                "sparoa <profile|infer|serve|train|compare|predict> \
                 [--model=..] [--device=..] [--policy=..] [--batch=N] \
                 [--episodes=N] [--request_rate=R] [--num_requests=N] \
                 [--config=file.json]"
            );
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `sparoa help`)"),
    }
}

fn load(cfg: &Config) -> Result<(ModelZoo, DeviceRegistry)> {
    let zoo = ModelZoo::load(&cfg.artifacts)?;
    let reg = DeviceRegistry::load(&cfg.devices_json())?;
    Ok((zoo, reg))
}

fn profile(cfg: &Config) -> Result<()> {
    let (zoo, _) = load(cfg)?;
    let g = zoo.get(&cfg.model)?;
    let profiles = profiler::quadrant_profile(g);
    let counts = profiler::quadrant_counts(&profiles);
    let mut t = Table::new(
        &format!("Fig.2 quadrant profile — {}", cfg.model),
        &["quadrant", "ops", "meaning"],
    );
    for (q, n) in counts {
        let meaning = match q {
            profiler::Quadrant::DenseHeavy => "dense+heavy -> GPU",
            profiler::Quadrant::SparseHeavy => "sparse+heavy (QII!)",
            profiler::Quadrant::DenseLight => "dense+light (QIII)",
            profiler::Quadrant::SparseLight => "sparse+light -> CPU",
        };
        t.row(vec![format!("{q:?}"), n.to_string(), meaning.into()]);
    }
    t.print();
    println!("\n  op-level scatter (sparsity, FLOPs):");
    for p in profiles.iter().take(20) {
        println!("    {:28} rho={:.2} I={:.2e} {:?}",
                 p.name, p.sparsity, p.flops, p.quadrant);
    }
    if profiles.len() > 20 {
        println!("    ... {} more ops", profiles.len() - 20);
    }
    Ok(())
}

fn make_schedule(cfg: &Config, zoo: &ModelZoo, reg: &DeviceRegistry)
    -> Result<(Schedule, SimOptions)>
{
    let g = zoo.get(&cfg.model)?;
    let dev = reg.get(&cfg.device)?;
    let b = match cfg.policy.as_str() {
        "sac" | "sparoa" => Baseline::Sparoa,
        "greedy" => Baseline::SparoaGreedy,
        "dp" => Baseline::SparoaDp,
        "threshold" | "static" => Baseline::SparoaNoRl,
        "cpu" => Baseline::CpuOnly,
        "gpu" | "pytorch" => Baseline::GpuOnlyPyTorch,
        "tensorrt" => Baseline::TensorRt,
        "tvm" => Baseline::Tvm,
        "ios" => Baseline::Ios,
        "pos" => Baseline::Pos,
        "codl" => Baseline::CoDl,
        "tensorflow" => Baseline::TensorFlow,
        other => bail!("unknown policy `{other}`"),
    };
    let sched = b.schedule(g, dev, None, cfg.batch.max(1), cfg.episodes);
    Ok((sched, b.options(cfg.batch.max(1), cfg.seed)))
}

fn infer(cfg: &Config) -> Result<()> {
    let (zoo, reg) = load(cfg)?;
    let g = zoo.get(&cfg.model)?;
    let dev = reg.get(&cfg.device)?;
    let (sched, opts) = make_schedule(cfg, &zoo, &reg)?;
    let rep = simulate(g, dev, &sched, &opts);
    println!(
        "model={} device={} policy={} batch={}",
        cfg.model, cfg.device, sched.policy, opts.batch
    );
    println!(
        "  simulated: makespan={:.1}us cpu_busy={:.1}us gpu_busy={:.1}us \
         transfer={:.1}us switches={} peak_gpu_mem={:.1}MB",
        rep.makespan_us, rep.cpu_busy_us, rep.gpu_busy_us, rep.transfer_us,
        rep.switches, rep.peak_gpu_mem_mb
    );
    let ledger = rep.ledger();
    println!(
        "  power={:.2}W energy={:.2}mJ/inference",
        ledger.mean_power_w(dev),
        ledger.energy_mj(dev)
    );
    // Real numerics through PJRT.
    let rt = Runtime::new(&cfg.artifacts)?;
    let engine = HybridEngine::new(&rt, g)?;
    let n = engine.warm_up()?;
    let mut rng = Rng::new(cfg.seed);
    let numel: usize = g.input_shape_exec.iter().product();
    let input = HostTensor::new(
        g.input_shape_exec.clone(),
        (0..numel).map(|_| rng.normal() as f32).collect(),
    );
    let out = engine.infer(&input, &sched)?;
    println!(
        "  real exec: {} artifacts, output shape {:?}, host time {:.0}us",
        n, out.output.shape, out.host_us
    );
    Ok(())
}

fn serve(cfg: &Config) -> Result<()> {
    let (zoo, reg) = load(cfg)?;
    let g = zoo.get(&cfg.model)?;
    let dev = reg.get(&cfg.device)?;
    let (sched, opts) = make_schedule(cfg, &zoo, &reg)?;
    let reqs = sparoa::server::batcher::poisson_stream(
        cfg.num_requests, cfg.request_rate, cfg.seed);
    let mut t = Table::new(
        &format!("serving — {} on {} ({} req @ {:.0}/s)",
                 cfg.model, cfg.device, cfg.num_requests, cfg.request_rate),
        &["policy", "mean lat", "p99 lat", "throughput", "overhead%"],
    );
    for (name, policy) in [
        ("fixed-32",
         BatchPolicy::Fixed { size: 32, timeout_us: 20_000.0 }),
        ("sparoa-dynamic",
         BatchPolicy::Dynamic { max: 64, optimizer_cost_us: 30.0 }),
    ] {
        let rep = run_batching_sim(g, dev, &sched, &opts, &reqs, &policy);
        t.row(vec![
            name.into(),
            format!("{:.1}us", rep.mean_latency_us),
            format!("{:.1}us", rep.p99_latency_us),
            format!("{:.1} rps", rep.throughput_rps),
            format!("{:.1}%", rep.overhead_pct()),
        ]);
    }
    t.print();
    Ok(())
}

fn train(cfg: &Config) -> Result<()> {
    let (zoo, reg) = load(cfg)?;
    let g = zoo.get(&cfg.model)?;
    let dev = reg.get(&cfg.device)?;
    let mut s = SacScheduler::new(SacSchedulerConfig {
        episodes: cfg.episodes,
        noise: cfg.noise,
        ..Default::default()
    });
    let plan = s.schedule(&ScheduleCtx {
        graph: g, device: dev, thresholds: None, batch: cfg.batch.max(1),
    });
    println!("SAC convergence on {} / {}:", cfg.model, cfg.device);
    for p in &s.trace {
        println!("  ep {:3}  makespan {:9.1} us  t={:6.2}s",
                 p.episode, p.makespan_us, p.wall_s);
    }
    println!("converged after {:.2}s; gpu share {:.1}%; switches {}",
             s.converged_after_s, 100.0 * plan.gpu_share(g),
             plan.switch_count(g));
    Ok(())
}

fn compare(cfg: &Config) -> Result<()> {
    let (zoo, reg) = load(cfg)?;
    let g = zoo.get(&cfg.model)?;
    let dev = reg.get(&cfg.device)?;
    let mut t = Table::new(
        &format!("Fig.5 latency — {} on {}", cfg.model, cfg.device),
        &["baseline", "latency (us)", "speedup vs SparOA", "gpu share"],
    );
    let mut results = Vec::new();
    for b in ALL {
        let episodes = if b == Baseline::Sparoa { cfg.episodes } else { 0 };
        let (sched, rep) = b.run(g, dev, None, cfg.batch.max(1), episodes);
        results.push((b, sched, rep));
    }
    let sparoa_lat = results
        .iter()
        .find(|(b, _, _)| *b == Baseline::Sparoa)
        .unwrap()
        .2
        .makespan_us;
    for (b, sched, rep) in &results {
        t.row(vec![
            b.name().into(),
            format!("{:.1}", rep.makespan_us),
            format!("{:.2}x", rep.makespan_us / sparoa_lat),
            format!("{:.0}%", 100.0 * sched.gpu_share(g)),
        ]);
    }
    t.print();
    Ok(())
}

fn predict(cfg: &Config) -> Result<()> {
    let (zoo, _) = load(cfg)?;
    let g = zoo.get(&cfg.model)?;
    let rt = Runtime::new(&cfg.artifacts)?;
    let pred = ThresholdPredictor::new(&rt);
    let th = pred.predict_graph(g)?;
    println!("threshold predictions for {} (first 24 ops):", cfg.model);
    for (op, (s, c)) in g.ops.iter().zip(&th).take(24) {
        println!("  {:28} rho={:.2} -> s*={:.2} c*={:.2}",
                 op.name, op.sparsity_in, s, c);
    }
    Ok(())
}
