//! Precomputed cost tables + allocation-free / incremental simulation —
//! the fast inner loop behind every schedule search.
//!
//! [`crate::engine::sim::simulate_reference`] is the readable spec
//! timeline: it re-derives every per-op roofline cost on every call and
//! allocates fresh buffers per inference.  Schedule search (threshold
//! calibration, Alg. 2 batch right-sizing, DP/greedy/SAC, the serve
//! tier's latency oracle) invokes the simulator O(candidates x ops) per
//! decision, so this module hoists everything that is invariant across
//! candidates:
//!
//! * [`CostTable`] — built once per (graph, device, options, batch):
//!   each op's (latency, launch) on CPU and GPU plus its cross-device
//!   transfer cost, so [`CostTable::simulate_into`] is a pure timeline
//!   walk over table lookups.
//! * [`SimScratch`] — reusable finish/placed/timing buffers; repeated
//!   simulations allocate nothing after the first call.  With
//!   `SimOptions::record_timings = false` the per-op [`OpTiming`] vec is
//!   skipped entirely (search loops never read it).
//! * [`IncrementalSim`] — per-op timeline checkpoints so a single-op
//!   placement flip re-times only the affected suffix
//!   ([`IncrementalSim::eval_flip`]); [`refine_flips`] builds a
//!   hill-climbing local search on top.
//!
//! Which entry point to use when: search loops build one `CostTable` and
//! call `simulate_into` (scratch reuse) or `IncrementalSim` (flip
//! neighborhoods); report/figure paths keep calling
//! [`crate::engine::sim::simulate`], a thin wrapper over the same walk.
//! `rust/tests/sim_fastpath.rs` pins every fast entry point to
//! bit-identical aggregates against the reference simulator.

use crate::device::{
    DeviceModel, HardwareState, Proc, GPU_BW_RAMP_BYTES,
    GPU_BW_RAMP_FLOOR,
};
use crate::engine::sim::{
    OpTiming, SimOptions, SimReport, AGGREGATION_US, MEM_FLOOR_MB,
};
use crate::graph::{ModelGraph, OpClass};
use crate::scheduler::{mode_of, Mode, Schedule};

/// Per-op costs precomputed under one engine configuration.  All values
/// mirror exactly what the reference simulator would derive inline.
#[derive(Debug, Clone, Copy)]
struct OpCostEntry {
    schedulable: bool,
    cpu_lat: f64,
    cpu_launch: f64,
    gpu_lat: f64,
    gpu_launch: f64,
    /// Cross-device transfer cost of this op's output (always computed:
    /// the DMA latency floor applies even to empty payloads, which is
    /// what the co-run aggregation path pays).
    xfer_out: f64,
    /// Whether the ready-time path charges a transfer at all (the
    /// reference simulator skips zero-byte producer edges).
    has_out_bytes: bool,
    out_bytes_batch: f64,
    params_bytes: f64,
    out_mb: f64,
    params_mb: f64,
}

/// Precomputed per-op cost table for one (graph, device, options, batch).
///
/// Self-contained (owns copies of the op dependency lists and the device
/// bits the timeline needs), so it can be cached and shared without
/// holding graph/device borrows.
#[derive(Debug, Clone)]
pub struct CostTable {
    batch: usize,
    seed: u64,
    noise: f64,
    gpu_cap_mb: f64,
    replicate_weights: bool,
    record_timings: bool,
    entries: Vec<OpCostEntry>,
    inputs: Vec<Vec<usize>>,
}

impl CostTable {
    /// Precompute every op's placement costs under `opts`, batched
    /// (the ROADMAP "SIMD/batched CostTable build" item): all
    /// (processor, class) roofline constants are resolved once — the
    /// scalar path paid four BTreeMap string probes *per op* — and the
    /// per-op math runs in structure-of-arrays passes over the whole
    /// graph, with the log/pow terms isolated in their own tight
    /// loops.  Every f64 expression keeps the scalar path's exact
    /// operation order, so the table stays bit-identical to
    /// [`crate::engine::sim::simulate_reference`] (pinned by
    /// `rust/tests/sim_fastpath.rs` and the in-module tests below).
    pub fn build(
        graph: &ModelGraph,
        dev: &DeviceModel,
        opts: &SimOptions,
    ) -> CostTable {
        let batch = opts.batch.max(1) as f64;
        let n = graph.ops.len();

        const ALL_CLASSES: [OpClass; 9] = [
            OpClass::MatMul,
            OpClass::Conv,
            OpClass::DwConv,
            OpClass::Attention,
            OpClass::Norm,
            OpClass::Elementwise,
            OpClass::Pool,
            OpClass::Softmax,
            OpClass::Other,
        ];
        // Per-class (flop-rate denominator, sparsity elasticity) for
        // one processor.  The denominator is the exact product the
        // scalar roofline forms per op (`peak * util * 1e9`), computed
        // once per class so per-op compute time is a single divide.
        let class_consts = |proc: Proc| -> ([f64; 9], [f64; 9]) {
            let p = dev.proc(proc);
            let mut denom = [0.0f64; 9];
            let mut elast = [0.0f64; 9];
            for c in ALL_CLASSES {
                let key = c.key();
                let util = p
                    .util
                    .get(key)
                    .or_else(|| p.util.get("other"))
                    .copied()
                    .unwrap_or(0.3)
                    .max(dev.min_util_floor);
                denom[c as usize] = p.peak_gflops * util * 1e9;
                elast[c as usize] = p
                    .sparsity_elasticity
                    .get(key)
                    .copied()
                    .unwrap_or(0.0);
            }
            (denom, elast)
        };
        let (cpu_denom, cpu_elast) = class_consts(Proc::Cpu);
        let (gpu_denom, gpu_elast) = class_consts(Proc::Gpu);
        // The residual launch component is an engine-level constant per
        // processor (same fusion/stream/dispatch chain as the scalar
        // path, evaluated once instead of per op).
        let launch_const = |proc: Proc| -> f64 {
            let mut l = dev.proc(proc).launch_overhead_us
                * (1.0 - opts.fusion_factor);
            if opts.inter_op_parallel {
                l *= opts.stream_pipeline_factor;
            }
            l + opts.dispatch_overhead_us
        };
        let cpu_launch = launch_const(Proc::Cpu);
        let gpu_launch = launch_const(Proc::Gpu);
        let cpu_bw9 = dev.cpu.mem_bw_gbps * 1e9;
        let dma_bw9 = dev.transfer.dma_bw_gbps * 1e9;

        // Structure-of-arrays over op dims.
        let mut flops_b = Vec::with_capacity(n);
        let mut bytes_b = Vec::with_capacity(n);
        let mut sp = Vec::with_capacity(n);
        let mut ci = Vec::with_capacity(n);
        for op in &graph.ops {
            flops_b.push(op.flops_paper * batch);
            bytes_b.push(op.bytes_moved_paper() * batch);
            sp.push(if opts.sparsity_aware { op.sparsity_in } else { 0.0 });
            ci.push(op.class as usize);
        }

        // Compute-side pass per processor:
        // eff = flops * (1 - sp * elast); t = eff / denom * 1e6.
        let compute_pass = |denom: &[f64; 9], elast: &[f64; 9]| {
            (0..n)
                .map(|i| {
                    let eff = flops_b[i]
                        * (1.0 - sp[i].clamp(0.0, 1.0) * elast[ci[i]]);
                    eff / denom[ci[i]] * 1e6
                })
                .collect::<Vec<f64>>()
        };
        let mut cpu_tc = compute_pass(&cpu_denom, &cpu_elast);
        let gpu_tc = compute_pass(&gpu_denom, &gpu_elast);
        // Framework CPU kernel quality (the log10 term) gets its own
        // pass and is skipped entirely on the optimized-kernel default.
        if opts.cpu_kernel_quality < 1.0 {
            let q = opts.cpu_kernel_quality.max(0.01);
            for i in 0..n {
                let scale = ((flops_b[i].max(1.0).log10() - 7.5) / 2.0)
                    .clamp(0.0, 1.0);
                let q_eff = q + (0.8 - q).max(0.0) * scale;
                cpu_tc[i] /= q_eff;
            }
        }
        // Memory-side passes: CPU at flat bandwidth; the GPU pays the
        // small-transfer pow-ramp (isolated here so the powf calls sit
        // in one tight loop).
        let cpu_tm: Vec<f64> =
            bytes_b.iter().map(|&b| b / cpu_bw9 * 1e6).collect();
        let gpu_tm: Vec<f64> = bytes_b
            .iter()
            .map(|&b| {
                let ramp = (b / GPU_BW_RAMP_BYTES)
                    .powf(0.5)
                    .clamp(GPU_BW_RAMP_FLOOR, 1.0);
                let bw_eff = dev.gpu.mem_bw_gbps * ramp;
                b / (bw_eff * 1e9) * 1e6
            })
            .collect();

        // Assembly: roofline max, kernel speedup, launch constants and
        // the DMA transfer chain (`DeviceModel::transfer_us` unrolled
        // with its bandwidth product hoisted).
        let mut entries = Vec::with_capacity(n);
        let mut inputs = Vec::with_capacity(n);
        for (i, op) in graph.ops.iter().enumerate() {
            let cpu_lat =
                cpu_tc[i].max(cpu_tm[i]) / opts.kernel_speedup + cpu_launch;
            let gpu_lat =
                gpu_tc[i].max(gpu_tm[i]) / opts.kernel_speedup + gpu_launch;
            let out_bytes_batch = op.bytes_out_paper * batch;
            let mut xfer = dev.transfer.dma_latency_us
                + out_bytes_batch / dma_bw9 * 1e6;
            if !opts.pinned_memory {
                xfer *= dev.transfer.pageable_penalty;
            }
            if opts.async_streams {
                xfer *= 1.0 - dev.transfer.async_overlap;
            }
            entries.push(OpCostEntry {
                schedulable: op.class.schedulable(),
                cpu_lat,
                cpu_launch,
                gpu_lat,
                gpu_launch,
                xfer_out: xfer,
                has_out_bytes: op.bytes_out_paper > 0.0,
                out_bytes_batch,
                params_bytes: op.params_bytes_paper,
                out_mb: out_bytes_batch / 1e6,
                params_mb: op.params_bytes_paper / 1e6,
            });
            inputs.push(op.inputs.clone());
        }
        CostTable {
            batch: opts.batch.max(1),
            seed: opts.seed,
            noise: opts.noise,
            gpu_cap_mb: dev.gpu_mem_capacity_mb,
            replicate_weights: opts.replicate_weights,
            record_timings: opts.record_timings,
            entries,
            inputs,
        }
    }

    /// Number of ops in the table (== the graph's op count).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True for an empty graph's table.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Batch size the table was built for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Whether op `id` participates in CPU/GPU placement (non-schedulable
    /// ops are fixed by their kind).
    pub fn schedulable(&self, id: usize) -> bool {
        self.entries[id].schedulable
    }

    /// Dependency list of op `id` (copy of the graph's).
    pub fn inputs(&self, id: usize) -> &[usize] {
        &self.inputs[id]
    }

    /// Contention-free latency of op `id` on `proc` (compute + residual
    /// launch), exactly [`crate::engine::sim::op_cost_us`]'s first
    /// component.
    pub fn lat(&self, id: usize, proc: Proc) -> f64 {
        match proc {
            Proc::Cpu => self.entries[id].cpu_lat,
            Proc::Gpu => self.entries[id].gpu_lat,
        }
    }

    /// Residual launch component of op `id` on `proc`.
    pub fn launch(&self, id: usize, proc: Proc) -> f64 {
        match proc {
            Proc::Cpu => self.entries[id].cpu_launch,
            Proc::Gpu => self.entries[id].gpu_launch,
        }
    }

    /// Cross-device transfer cost of op `id`'s output.
    pub fn xfer_out(&self, id: usize) -> f64 {
        self.entries[id].xfer_out
    }

    /// Per-op phase split for trace attribution, microseconds:
    /// `(compute, launch, transfer)`.  Compute is the pure kernel time
    /// (`lat` minus the residual launch); transfer is the worst-case
    /// cross-device cost of this op's output (only paid when a consumer
    /// sits on the other processor).  Sums over a schedule reconcile
    /// with [`crate::engine::sim::SimReport::phase_totals`].
    pub fn op_phase_us(&self, id: usize, proc: Proc) -> (f64, f64, f64) {
        let e = &self.entries[id];
        let (lat, launch) = match proc {
            Proc::Cpu => (e.cpu_lat, e.cpu_launch),
            Proc::Gpu => (e.gpu_lat, e.gpu_launch),
        };
        ((lat - launch).max(0.0), launch, e.xfer_out)
    }

    /// Whether op `id` emits bytes that a cross-device consumer must pay
    /// a transfer for.
    pub fn has_out_bytes(&self, id: usize) -> bool {
        self.entries[id].has_out_bytes
    }

    /// Batched output bytes of op `id` (hardware-state working set).
    pub fn out_bytes_batch(&self, id: usize) -> f64 {
        self.entries[id].out_bytes_batch
    }

    /// Parameter bytes of op `id`.
    pub fn params_bytes(&self, id: usize) -> f64 {
        self.entries[id].params_bytes
    }

    /// Derive the table at a slower DVFS rung: every compute-side cost
    /// (CPU/GPU latency and launch) is multiplied by `latency_scale`
    /// (the frequency state's dimensionless slowdown, >= 1.0; see
    /// [`crate::device::FreqState::latency_scale`]), while cross-device
    /// transfer costs are left untouched — DMA bandwidth is independent
    /// of the compute clocks in this model.  `scaled(1.0)` reproduces
    /// the original table bit-for-bit.
    pub fn scaled(&self, latency_scale: f64) -> CostTable {
        assert!(
            latency_scale.is_finite() && latency_scale > 0.0,
            "latency_scale must be finite and positive, got {latency_scale}"
        );
        let mut t = self.clone();
        for e in &mut t.entries {
            e.cpu_lat *= latency_scale;
            e.cpu_launch *= latency_scale;
            e.gpu_lat *= latency_scale;
            e.gpu_launch *= latency_scale;
        }
        t
    }

    /// Simulate one inference under `schedule` into reusable buffers.
    /// Identical timeline to the reference simulator — same hardware
    /// state, same RNG draw order, same accounting — minus all per-call
    /// allocation and roofline recomputation.  The result lands in
    /// `scratch.report`.
    pub fn simulate_into(
        &self,
        schedule: &Schedule,
        scratch: &mut SimScratch,
    ) {
        let n = self.entries.len();
        debug_assert_eq!(schedule.xi.len(), n);
        scratch.reset(n);
        let SimScratch { finish, placed, report } = scratch;
        let mut hw = HardwareState::with_capacity(
            self.gpu_cap_mb, self.seed, self.noise);
        let mut cpu_free = 0.0f64;
        let mut gpu_free = 0.0f64;
        let mut gpu_weights_mb = 0.0;
        let mut cpu_weights_mb = 0.0;
        let mut gpu_act_mb: f64 = 0.0;
        let mut staging_mb = 0.0;
        let mut peak_gpu: f64 = 0.0;

        for id in 0..n {
            let e = self.entries[id];
            let ins = &self.inputs[id];
            let mode = if !e.schedulable {
                let p = ins.first().map(|&i| placed[i]).unwrap_or(Proc::Cpu);
                Mode::Single(p)
            } else {
                mode_of(schedule.xi[id])
            };
            match mode {
                Mode::Single(proc) => {
                    let (base, launch) = match proc {
                        Proc::Cpu => (e.cpu_lat, e.cpu_launch),
                        Proc::Gpu => (e.gpu_lat, e.gpu_launch),
                    };
                    let lat = base * hw.contention_factor(proc);
                    let mut r: f64 = 0.0;
                    for &i in ins {
                        let mut t = finish[i];
                        if placed[i] != proc && self.entries[i].has_out_bytes
                        {
                            let x = self.entries[i].xfer_out;
                            report.transfer_us += x;
                            t += x;
                        }
                        r = r.max(t);
                    }
                    let free = match proc {
                        Proc::Cpu => cpu_free,
                        Proc::Gpu => gpu_free,
                    };
                    let start = r.max(free);
                    let end = start + lat;
                    match proc {
                        Proc::Cpu => {
                            cpu_free = end;
                            report.cpu_busy_us += lat;
                        }
                        Proc::Gpu => {
                            gpu_free = end;
                            report.gpu_busy_us += lat;
                        }
                    }
                    report.launch_us += launch;
                    finish[id] = end;
                    placed[id] = proc;
                    hw.dispatch(proc, e.out_bytes_batch, e.params_bytes);
                    if proc == Proc::Gpu {
                        gpu_weights_mb += e.params_mb;
                        gpu_act_mb = (gpu_act_mb * 0.92) + e.out_mb;
                        if self.replicate_weights {
                            cpu_weights_mb += e.params_mb;
                        }
                    } else {
                        cpu_weights_mb += e.params_mb;
                        if self.replicate_weights {
                            gpu_weights_mb += e.params_mb;
                        }
                    }
                    for &i in ins {
                        if placed[i] != proc {
                            staging_mb += 2.0 * self.entries[i].out_mb;
                        }
                    }
                    if self.record_timings {
                        report.timings.push(OpTiming {
                            op: id,
                            proc,
                            start_us: start,
                            finish_us: end,
                            compute_us: lat,
                            transfer_us: 0.0,
                        });
                    }
                }
                Mode::CoRun(_w) => {
                    let lat_c = e.cpu_lat * hw.contention_factor(Proc::Cpu);
                    let lat_g = e.gpu_lat * hw.contention_factor(Proc::Gpu);
                    let mut rc: f64 = 0.0;
                    for &i in ins {
                        let mut t = finish[i];
                        if placed[i] != Proc::Cpu
                            && self.entries[i].has_out_bytes
                        {
                            let x = self.entries[i].xfer_out;
                            report.transfer_us += x;
                            t += x;
                        }
                        rc = rc.max(t);
                    }
                    let mut rg: f64 = 0.0;
                    for &i in ins {
                        let mut t = finish[i];
                        if placed[i] != Proc::Gpu
                            && self.entries[i].has_out_bytes
                        {
                            let x = self.entries[i].xfer_out;
                            report.transfer_us += x;
                            t += x;
                        }
                        rg = rg.max(t);
                    }
                    let sc = rc.max(cpu_free);
                    let sg = rg.max(gpu_free);
                    let ec = sc + lat_c;
                    let eg = sg + lat_g;
                    cpu_free = ec;
                    gpu_free = eg;
                    report.cpu_busy_us += lat_c;
                    report.gpu_busy_us += lat_g;
                    report.launch_us += e.cpu_launch + e.gpu_launch;
                    let xcpu = e.xfer_out;
                    report.transfer_us += xcpu;
                    report.aggregation_us += AGGREGATION_US;
                    let end = ec.max(eg) + xcpu + AGGREGATION_US;
                    finish[id] = end;
                    placed[id] = Proc::Gpu;
                    hw.dispatch(Proc::Gpu, e.out_bytes_batch, e.params_bytes);
                    gpu_weights_mb += e.params_mb;
                    cpu_weights_mb += e.params_mb; // replicated
                    gpu_act_mb = (gpu_act_mb * 0.92) + e.out_mb;
                    if self.record_timings {
                        report.timings.push(OpTiming {
                            op: id,
                            proc: Proc::Gpu,
                            start_us: sc.min(sg),
                            finish_us: end,
                            compute_us: lat_c.max(lat_g),
                            transfer_us: xcpu,
                        });
                    }
                }
            }
            peak_gpu = peak_gpu.max(gpu_weights_mb + gpu_act_mb + staging_mb);
        }

        report.switches = hw.switches;
        let last_finish = finish.iter().cloned().fold(0.0, f64::max);
        report.makespan_us = cpu_free.max(gpu_free).max(last_finish);
        report.peak_gpu_mem_mb = peak_gpu + MEM_FLOOR_MB;
        report.cpu_mem_mb = cpu_weights_mb;
    }

    /// Start an incremental evaluator from schedule `xi` (full replay
    /// once, then [`IncrementalSim::eval_flip`] is O(suffix)).
    pub fn incremental(&self, xi: &[f64]) -> IncrementalSim<'_> {
        assert_eq!(
            xi.len(),
            self.entries.len(),
            "schedule has {} entries for a {}-op table",
            xi.len(),
            self.entries.len()
        );
        let n = self.entries.len();
        let mut inc = IncrementalSim {
            table: self,
            xi: xi.to_vec(),
            ckpt: Vec::with_capacity(n),
            finish: vec![0.0; n],
            placed: vec![Proc::Cpu; n],
            makespan: 0.0,
            tmp_finish: vec![0.0; n],
            tmp_placed: vec![Proc::Cpu; n],
        };
        inc.replay_commit(0);
        inc
    }
}

/// Reusable simulation buffers: feed to [`CostTable::simulate_into`]
/// repeatedly; nothing is allocated after the first call (timings keep
/// their capacity across runs and stay empty when the table was built
/// with `record_timings: false`).
#[derive(Debug, Default)]
pub struct SimScratch {
    finish: Vec<f64>,
    placed: Vec<Proc>,
    /// Result of the most recent `simulate_into` call.
    pub report: SimReport,
}

impl SimScratch {
    /// Empty buffers; sized lazily on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, n: usize) {
        self.finish.clear();
        self.finish.resize(n, 0.0);
        self.placed.clear();
        self.placed.resize(n, Proc::Cpu);
        let mut timings = std::mem::take(&mut self.report.timings);
        timings.clear();
        self.report = SimReport { timings, ..SimReport::default() };
    }

    /// Move the last report out (the one-shot `simulate` wrapper path).
    pub fn take_report(&mut self) -> SimReport {
        std::mem::take(&mut self.report)
    }
}

/// Timeline state immediately before an op executes.
#[derive(Debug, Clone)]
struct Checkpoint {
    cpu_free: f64,
    gpu_free: f64,
    hw: HardwareState,
}

/// Incremental delta-evaluator over one [`CostTable`]: holds the
/// committed schedule's per-op timeline checkpoints so a single-op
/// placement flip replays only ops `k..n` instead of the whole model.
/// Makespans are exactly those of the reference simulator (same state
/// evolution, same RNG order), so a local search driven by `eval_flip`
/// optimizes the true objective, not an approximation.
pub struct IncrementalSim<'a> {
    table: &'a CostTable,
    xi: Vec<f64>,
    /// ckpt[i] = state just before op i ran under the committed xi.
    ckpt: Vec<Checkpoint>,
    finish: Vec<f64>,
    placed: Vec<Proc>,
    makespan: f64,
    tmp_finish: Vec<f64>,
    tmp_placed: Vec<Proc>,
}

impl IncrementalSim<'_> {
    /// Advance one op on a timeline state.  Mirrors the `simulate_into`
    /// walk (same f64 operation order, same RNG draws) minus the report
    /// accounting that makespan evaluation never reads.
    fn step_op(
        table: &CostTable,
        xi: f64,
        id: usize,
        finish: &mut [f64],
        placed: &mut [Proc],
        cpu_free: &mut f64,
        gpu_free: &mut f64,
        hw: &mut HardwareState,
    ) {
        let e = table.entries[id];
        let ins = &table.inputs[id];
        let mode = if !e.schedulable {
            let p = ins.first().map(|&i| placed[i]).unwrap_or(Proc::Cpu);
            Mode::Single(p)
        } else {
            mode_of(xi)
        };
        match mode {
            Mode::Single(proc) => {
                let base = match proc {
                    Proc::Cpu => e.cpu_lat,
                    Proc::Gpu => e.gpu_lat,
                };
                let lat = base * hw.contention_factor(proc);
                let mut r: f64 = 0.0;
                for &i in ins {
                    let mut t = finish[i];
                    if placed[i] != proc && table.entries[i].has_out_bytes {
                        t += table.entries[i].xfer_out;
                    }
                    r = r.max(t);
                }
                let free = match proc {
                    Proc::Cpu => *cpu_free,
                    Proc::Gpu => *gpu_free,
                };
                let start = r.max(free);
                let end = start + lat;
                match proc {
                    Proc::Cpu => *cpu_free = end,
                    Proc::Gpu => *gpu_free = end,
                }
                finish[id] = end;
                placed[id] = proc;
                hw.dispatch(proc, e.out_bytes_batch, e.params_bytes);
            }
            Mode::CoRun(_w) => {
                let lat_c = e.cpu_lat * hw.contention_factor(Proc::Cpu);
                let lat_g = e.gpu_lat * hw.contention_factor(Proc::Gpu);
                let mut rc: f64 = 0.0;
                for &i in ins {
                    let mut t = finish[i];
                    if placed[i] != Proc::Cpu
                        && table.entries[i].has_out_bytes
                    {
                        t += table.entries[i].xfer_out;
                    }
                    rc = rc.max(t);
                }
                let mut rg: f64 = 0.0;
                for &i in ins {
                    let mut t = finish[i];
                    if placed[i] != Proc::Gpu
                        && table.entries[i].has_out_bytes
                    {
                        t += table.entries[i].xfer_out;
                    }
                    rg = rg.max(t);
                }
                let sc = rc.max(*cpu_free);
                let sg = rg.max(*gpu_free);
                let ec = sc + lat_c;
                let eg = sg + lat_g;
                *cpu_free = ec;
                *gpu_free = eg;
                finish[id] = ec.max(eg) + e.xfer_out + AGGREGATION_US;
                placed[id] = Proc::Gpu;
                hw.dispatch(Proc::Gpu, e.out_bytes_batch, e.params_bytes);
            }
        }
    }

    /// Replay ops `k..n` into the committed state, refreshing
    /// checkpoints; updates and returns the makespan.
    fn replay_commit(&mut self, k: usize) -> f64 {
        let n = self.table.entries.len();
        let (mut cpu_free, mut gpu_free, mut hw) = if k == 0 {
            (
                0.0,
                0.0,
                HardwareState::with_capacity(
                    self.table.gpu_cap_mb,
                    self.table.seed,
                    self.table.noise,
                ),
            )
        } else {
            let c = self.ckpt[k].clone();
            (c.cpu_free, c.gpu_free, c.hw)
        };
        self.ckpt.truncate(k);
        for id in k..n {
            self.ckpt.push(Checkpoint {
                cpu_free,
                gpu_free,
                hw: hw.clone(),
            });
            Self::step_op(
                self.table,
                self.xi[id],
                id,
                &mut self.finish,
                &mut self.placed,
                &mut cpu_free,
                &mut gpu_free,
                &mut hw,
            );
        }
        let last = self.finish.iter().cloned().fold(0.0, f64::max);
        self.makespan = cpu_free.max(gpu_free).max(last);
        self.makespan
    }

    /// Makespan if op `op` were flipped to `new_xi`, leaving the
    /// committed schedule untouched.  Replays only ops `op..n`
    /// (allocation-free: scratch buffers are reused).
    pub fn eval_flip(&mut self, op: usize, new_xi: f64) -> f64 {
        let n = self.table.entries.len();
        assert!(op < n, "op {op} out of range for {n}-op table");
        let (mut cpu_free, mut gpu_free, mut hw) = {
            let c = &self.ckpt[op];
            (c.cpu_free, c.gpu_free, c.hw.clone())
        };
        self.tmp_finish.copy_from_slice(&self.finish);
        self.tmp_placed.copy_from_slice(&self.placed);
        for id in op..n {
            let xi = if id == op { new_xi } else { self.xi[id] };
            Self::step_op(
                self.table,
                xi,
                id,
                &mut self.tmp_finish,
                &mut self.tmp_placed,
                &mut cpu_free,
                &mut gpu_free,
                &mut hw,
            );
        }
        let last = self.tmp_finish.iter().cloned().fold(0.0, f64::max);
        cpu_free.max(gpu_free).max(last)
    }

    /// Commit a flip: re-times the suffix, refreshes checkpoints and
    /// returns the new makespan (exactly what `eval_flip` predicted).
    pub fn apply_flip(&mut self, op: usize, new_xi: f64) -> f64 {
        assert!(
            op < self.table.entries.len(),
            "op {op} out of range for {}-op table",
            self.table.entries.len()
        );
        self.xi[op] = new_xi;
        self.replay_commit(op)
    }

    /// Makespan of the committed schedule, us.
    pub fn makespan_us(&self) -> f64 {
        self.makespan
    }

    /// The committed schedule.
    pub fn xi(&self) -> &[f64] {
        &self.xi
    }

    /// Consume the evaluator, keeping the committed schedule.
    pub fn into_xi(self) -> Vec<f64> {
        self.xi
    }
}

/// Hill-climb over single-op placement flips with the incremental
/// evaluator: each schedulable op's primary device is tentatively
/// flipped and the flip is kept when the exact simulated makespan
/// improves.  Updates `schedule.xi` in place and returns the refined
/// makespan.  Cost: O(passes x n x suffix) table lookups — hundreds of
/// times cheaper than the same search over full re-simulations.
pub fn refine_flips(
    table: &CostTable,
    schedule: &mut Schedule,
    max_passes: usize,
) -> f64 {
    let mut inc = table.incremental(&schedule.xi);
    let mut best = inc.makespan_us();
    for _ in 0..max_passes {
        let mut improved = false;
        for id in 0..table.len() {
            if !table.schedulable(id) {
                continue;
            }
            let cur = inc.xi()[id];
            let flipped = if cur >= 0.5 { 0.0 } else { 1.0 };
            let m = inc.eval_flip(id, flipped);
            if m < best * (1.0 - 1e-12) {
                best = inc.apply_flip(id, flipped);
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    schedule.xi = inc.into_xi();
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim::simulate_reference;

    fn fixture() -> (ModelGraph, DeviceModel, SimOptions) {
        let g = ModelGraph::synthetic("costs_fixture", 5, 1.5, 0.5);
        let dev = crate::bench_support::device_profile("agx_orin");
        let opts = SimOptions { batch: 2, ..Default::default() };
        (g, dev, opts)
    }

    fn mixed_schedule(n: usize) -> Schedule {
        let xi = (0..n)
            .map(|i| match i % 4 {
                0 => 0.0,
                1 => 1.0,
                2 => 0.5, // co-run band
                _ => 0.8,
            })
            .collect();
        Schedule { xi, policy: "mixed".into() }
    }

    #[test]
    fn table_walk_matches_reference_bitwise() {
        let (g, dev, opts) = fixture();
        let sched = mixed_schedule(g.ops.len());
        let r = simulate_reference(&g, &dev, &sched, &opts);
        let table = CostTable::build(&g, &dev, &opts);
        let mut scratch = SimScratch::new();
        // Twice: scratch reuse must not leak state between runs.
        for _ in 0..2 {
            table.simulate_into(&sched, &mut scratch);
            let f = &scratch.report;
            assert_eq!(f.makespan_us, r.makespan_us);
            assert_eq!(f.cpu_busy_us, r.cpu_busy_us);
            assert_eq!(f.gpu_busy_us, r.gpu_busy_us);
            assert_eq!(f.transfer_us, r.transfer_us);
            assert_eq!(f.launch_us, r.launch_us);
            assert_eq!(f.aggregation_us, r.aggregation_us);
            assert_eq!(f.switches, r.switches);
            assert_eq!(f.peak_gpu_mem_mb, r.peak_gpu_mem_mb);
            assert_eq!(f.cpu_mem_mb, r.cpu_mem_mb);
            assert_eq!(f.timings.len(), r.timings.len());
        }
    }

    #[test]
    fn op_phase_split_reconciles_with_latency() {
        let (g, dev, opts) = fixture();
        let table = CostTable::build(&g, &dev, &opts);
        for id in 0..table.len() {
            for proc in [Proc::Cpu, Proc::Gpu] {
                let (compute, launch, xfer) = table.op_phase_us(id, proc);
                assert!(compute >= 0.0 && launch >= 0.0 && xfer >= 0.0);
                // compute + launch recomposes the contention-free
                // latency exactly; xfer matches the table's column.
                assert!(
                    (compute + launch - table.lat(id, proc)).abs() < 1e-9,
                    "op {id} {proc:?} phase split drifted"
                );
                assert_eq!(launch, table.launch(id, proc));
                assert_eq!(xfer, table.xfer_out(id));
            }
        }
    }

    #[test]
    fn record_timings_off_skips_vec_but_keeps_aggregates() {
        let (g, dev, opts) = fixture();
        let sched = mixed_schedule(g.ops.len());
        let r = simulate_reference(&g, &dev, &sched, &opts);
        let fast_opts = SimOptions { record_timings: false, ..opts };
        let table = CostTable::build(&g, &dev, &fast_opts);
        let mut scratch = SimScratch::new();
        table.simulate_into(&sched, &mut scratch);
        assert!(scratch.report.timings.is_empty());
        assert_eq!(scratch.report.makespan_us, r.makespan_us);
        assert_eq!(scratch.report.transfer_us, r.transfer_us);
    }

    #[test]
    fn eval_flip_is_tentative_and_apply_matches_reference() {
        let (g, dev, opts) = fixture();
        let sched = mixed_schedule(g.ops.len());
        let table = CostTable::build(&g, &dev, &opts);
        let mut inc = table.incremental(&sched.xi);
        let base = inc.makespan_us();
        assert_eq!(
            base,
            simulate_reference(&g, &dev, &sched, &opts).makespan_us
        );
        // Tentative evaluation leaves the committed state untouched.
        let mid = g.ops.len() / 2;
        let probed = inc.eval_flip(mid, 1.0 - sched.xi[mid].round());
        assert_eq!(inc.makespan_us(), base);
        assert_eq!(probed, inc.eval_flip(mid, 1.0 - sched.xi[mid].round()));
        // Committing reproduces exactly the tentative value and the
        // reference simulation of the flipped schedule.
        let committed = inc.apply_flip(mid, 1.0 - sched.xi[mid].round());
        assert_eq!(probed, committed);
        let mut xi = sched.xi.clone();
        xi[mid] = 1.0 - sched.xi[mid].round();
        let flipped = Schedule { xi, policy: "flipped".into() };
        assert_eq!(
            committed,
            simulate_reference(&g, &dev, &flipped, &opts).makespan_us
        );
    }

    #[test]
    fn batched_build_matches_scalar_rooflines_bitwise() {
        use crate::engine::sim::op_cost_us;
        // The batched SoA build must reproduce the scalar per-op
        // roofline exactly — across engine-option variants that hit
        // every hoisted constant (quality log-term, sparsity toggle,
        // transfer multipliers, fusion/stream chain).
        let g = ModelGraph::synthetic("costs_batched", 6, 2.0, 0.45);
        let dev = crate::bench_support::device_profile("orin_nano");
        let variants = [
            SimOptions { batch: 3, ..Default::default() },
            SimOptions {
                batch: 1,
                cpu_kernel_quality: 0.12,
                sparsity_aware: false,
                pinned_memory: false,
                async_streams: false,
                inter_op_parallel: false,
                fusion_factor: 0.0,
                ..Default::default()
            },
        ];
        for opts in &variants {
            let table = CostTable::build(&g, &dev, opts);
            let batch = opts.batch.max(1) as f64;
            for (i, op) in g.ops.iter().enumerate() {
                let flops = op.flops_paper * batch;
                let bytes = op.bytes_moved_paper() * batch;
                for proc in [Proc::Cpu, Proc::Gpu] {
                    let (lat, launch) = op_cost_us(
                        &dev, proc, op.class, flops, bytes,
                        op.sparsity_in, opts);
                    assert_eq!(table.lat(i, proc).to_bits(),
                               lat.to_bits(),
                               "op {i} {proc:?} latency drifted");
                    assert_eq!(table.launch(i, proc).to_bits(),
                               launch.to_bits(),
                               "op {i} {proc:?} launch drifted");
                }
                let xfer = dev.transfer_us(
                    op.bytes_out_paper * batch,
                    opts.pinned_memory,
                    opts.async_streams,
                );
                assert_eq!(table.xfer_out(i).to_bits(), xfer.to_bits(),
                           "op {i} transfer drifted");
            }
        }
    }

    #[test]
    fn scaled_table_slows_compute_but_not_dma() {
        let (g, dev, opts) = fixture();
        let table = CostTable::build(&g, &dev, &opts);
        // Identity scale is bit-exact.
        let same = table.scaled(1.0);
        for i in 0..table.len() {
            for proc in [Proc::Cpu, Proc::Gpu] {
                assert_eq!(same.lat(i, proc).to_bits(),
                           table.lat(i, proc).to_bits());
            }
            assert_eq!(same.xfer_out(i).to_bits(),
                       table.xfer_out(i).to_bits());
        }
        // A slower rung scales every compute cost and leaves DMA alone.
        let slow = table.scaled(1.8);
        for i in 0..table.len() {
            for proc in [Proc::Cpu, Proc::Gpu] {
                assert_eq!(slow.lat(i, proc), table.lat(i, proc) * 1.8);
                assert_eq!(slow.launch(i, proc),
                           table.launch(i, proc) * 1.8);
            }
            assert_eq!(slow.xfer_out(i).to_bits(),
                       table.xfer_out(i).to_bits(),
                       "DMA cost must be frequency-independent");
        }
        // And the simulated makespan strictly grows on a real graph.
        let sched = mixed_schedule(g.ops.len());
        let mut scratch = SimScratch::new();
        table.simulate_into(&sched, &mut scratch);
        let fast = scratch.report.makespan_us;
        slow.simulate_into(&sched, &mut scratch);
        assert!(scratch.report.makespan_us > fast);
    }

    #[test]
    fn refine_never_worsens_the_plan() {
        let (g, dev, opts) = fixture();
        let table = CostTable::build(&g, &dev, &opts);
        // Deliberately bad plan: everything on the CPU.
        let mut plan = Schedule::uniform(&g, 0.0, "cpu-pin");
        let before =
            simulate_reference(&g, &dev, &plan, &opts).makespan_us;
        let after = refine_flips(&table, &mut plan, 3);
        assert!(after <= before);
        assert_eq!(
            after,
            simulate_reference(&g, &dev, &plan, &opts).makespan_us
        );
    }
}
