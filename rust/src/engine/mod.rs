//! The hybrid inference engine (paper §5): executes a scheduled model with
//! real numerics through PJRT while accounting time/energy/memory on the
//! calibrated device timeline.
//!
//! * `sim` — the virtual-time simulator (every figure runs through it).
//! * `costs` — the fast inner loop: precomputed per-op [`CostTable`]s,
//!   the allocation-free `simulate_into` walk, and the incremental
//!   `eval_flip` suffix re-timer that schedule search runs on.
//! * `exec` — real execution of the exec-scale artifacts (native handling
//!   of data-movement ops, weighted-average aggregation of co-run ops).
//! * `batching` — the gradient-based dynamic batching of Alg. 2, with
//!   memoized + parallel candidate evaluation.
//!
//! These are implementation details of the public [`crate::api`] layer:
//! `api::SimBackend` wraps `sim::simulate` and `api::PjrtBackend` wraps
//! `exec::execute_graph`; new code should construct an `api::Session`
//! rather than calling either path directly.

pub mod batching;
pub mod costs;
pub mod exec;
pub mod sim;

pub use costs::{refine_flips, CostTable, IncrementalSim, SimScratch};
pub use exec::{execute_graph, HybridEngine, OpParams};
pub use sim::{simulate, simulate_reference, SimOptions, SimReport};
