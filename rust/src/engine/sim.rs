//! Virtual-time execution simulator over the calibrated device models.
//!
//! Given a model graph, a device profile and a [`Schedule`], replays the
//! inference on two processor timelines (CPU, GPU) with DMA transfers,
//! async-stream overlap, co-run aggregation (Eq. 14), contention dynamics
//! and memory tracking.  Every figure reproduction and the SAC reward run
//! through this function; the real-numerics path (engine::HybridEngine)
//! shares the same timeline so measured breakdowns match simulated ones.

use crate::device::{DeviceModel, HardwareState, Proc};
use crate::energy::EnergyLedger;
use crate::graph::ModelGraph;
use crate::scheduler::{mode_of, Mode, Schedule};

/// Simulator options: which engine features are enabled (baselines toggle
/// these to model their frameworks — see baselines/).
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// pinned host memory for transfers (SparOA §5.1); pageable otherwise.
    pub pinned_memory: bool,
    /// CUDA-stream style async overlap of transfer with compute.
    pub async_streams: bool,
    /// multiplicative kernel-efficiency bonus (tuned kernels: TensorRT/TVM).
    pub kernel_speedup: f64,
    /// operator-fusion factor: fraction of launch overheads eliminated.
    pub fusion_factor: f64,
    /// inter-operator parallelism: independent ops on the same device may
    /// overlap (IOS/POS multi-stream); modeled as launch-overhead hiding.
    pub inter_op_parallel: bool,
    /// residual launch fraction when inter-op streams are on.  SparOA's
    /// engine double-buffers launches on dedicated CUDA streams (§5.1,
    /// 78% transfer/compute overlap, 89% GPU util) => 0.25; generic
    /// multi-stream engines (TensorRT/IOS/POS) => 0.45.
    pub stream_pipeline_factor: f64,
    /// whether sparse-aware kernels are used (CPU sparsity elasticity).
    pub sparsity_aware: bool,
    /// host-side framework dispatch cost per op, us (eager frameworks pay
    /// 10-20us of python/op-dispatch per operator; compiled engines ~0;
    /// the rust coordinator ~0.5, measured by the hotpath bench).
    pub dispatch_overhead_us: f64,
    /// CPU kernel quality: multiplier on the CPU's compute utilization.
    /// 1.0 = optimized sparse kernels (SparOA's path); eager frameworks on
    /// ARM achieve ~10-15% of that for dense conv/matmul.
    pub cpu_kernel_quality: f64,
    /// contention/jitter noise amplitude (0 = deterministic).
    pub noise: f64,
    /// dual-layout weight replication (CoDL keeps CPU+GPU copies of every
    /// operator's weights for its hybrid-type-friendly data sharing).
    pub replicate_weights: bool,
    /// record the per-op [`OpTiming`] vec in the report.  On by default
    /// (figure/report paths read it); search loops that only consume the
    /// aggregates turn it off so the fast path allocates nothing.
    pub record_timings: bool,
    /// batch size.
    pub batch: usize,
    /// rng seed for the hardware-dynamics jitter.
    pub seed: u64,
}

impl Default for SimOptions {
    /// The default is the SparOA engine itself: pinned DMA, CUDA-stream
    /// async execution, sparse-aware kernels, the engine's own fusion
    /// pass, and the measured rust-coordinator dispatch cost.
    fn default() -> Self {
        SimOptions {
            pinned_memory: true,
            async_streams: true,
            kernel_speedup: 1.05,
            fusion_factor: 0.55,
            inter_op_parallel: true,
            stream_pipeline_factor: 0.25,
            sparsity_aware: true,
            dispatch_overhead_us: SPAROA_DISPATCH_US,
            cpu_kernel_quality: 1.0,
            replicate_weights: false,
            record_timings: true,
            noise: 0.0,
            batch: 1,
            seed: 1,
        }
    }
}

/// Per-op device cost under engine options, *without* contention:
/// returns (latency_us, launch_component_us).  Shared by the simulator
/// and the RL environment so their timelines agree exactly.
pub fn op_cost_us(
    dev: &DeviceModel,
    proc: Proc,
    class: crate::graph::OpClass,
    flops: f64,
    bytes: f64,
    sparsity: f64,
    opts: &SimOptions,
) -> (f64, f64) {
    let sp = if opts.sparsity_aware { sparsity } else { 0.0 };
    let (mut t_compute, t_mem, launch) =
        dev.op_cost_parts_us(proc, class, flops, bytes, sp);
    if proc == Proc::Cpu && opts.cpu_kernel_quality < 1.0 {
        // Framework kernel quality hits the flop-bound part only, and is
        // worst for small ops (per-op overheads, poor blocking); large
        // GEMMs approach library efficiency.  Interpolate toward 0.8 of
        // optimized quality above ~3e7 FLOPs.
        let q = opts.cpu_kernel_quality.max(0.01);
        let scale = ((flops.max(1.0).log10() - 7.5) / 2.0).clamp(0.0, 1.0);
        let q_eff = q + (0.8 - q).max(0.0) * scale;
        t_compute /= q_eff;
    }
    let compute = t_compute.max(t_mem) / opts.kernel_speedup;
    let mut eff_launch = launch * (1.0 - opts.fusion_factor);
    if opts.inter_op_parallel {
        eff_launch *= opts.stream_pipeline_factor; // launch pipelining
    }
    eff_launch += opts.dispatch_overhead_us;
    (compute + eff_launch, eff_launch)
}

/// Per-op dispatch cost of the rust coordinator itself (measured by the
/// hotpath bench; also baked into the RL environment's timeline).
pub const SPAROA_DISPATCH_US: f64 = 0.5;

/// Per-op record in the simulation report.
#[derive(Debug, Clone)]
pub struct OpTiming {
    pub op: usize,
    pub proc: Proc,
    pub start_us: f64,
    pub finish_us: f64,
    pub compute_us: f64,
    pub transfer_us: f64,
}

/// Aggregate simulation result for one inference.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub makespan_us: f64,
    pub cpu_busy_us: f64,
    pub gpu_busy_us: f64,
    pub transfer_us: f64,
    pub launch_us: f64,
    pub aggregation_us: f64,
    pub switches: u32,
    pub peak_gpu_mem_mb: f64,
    pub cpu_mem_mb: f64,
    pub timings: Vec<OpTiming>,
}

impl SimReport {
    pub fn ledger(&self) -> EnergyLedger {
        EnergyLedger {
            cpu_busy_us: self.cpu_busy_us,
            gpu_busy_us: self.gpu_busy_us,
            xfer_us: self.transfer_us,
            makespan_us: self.makespan_us,
        }
    }
    /// Total memory footprint (weights on each device + peak activations).
    pub fn total_mem_mb(&self) -> f64 {
        self.peak_gpu_mem_mb + self.cpu_mem_mb
    }
    /// Phase totals for trace attribution, microseconds:
    /// `(compute, transfer, launch, aggregation)`.  Compute is the sum
    /// of both lanes' busy time; the components may overlap in wall
    /// time, so their sum can exceed `makespan_us` — these are
    /// attribution buckets (the serving tracer's per-op phase hook),
    /// not a wall-clock decomposition.
    pub fn phase_totals(&self) -> (f64, f64, f64, f64) {
        (
            self.cpu_busy_us + self.gpu_busy_us,
            self.transfer_us,
            self.launch_us,
            self.aggregation_us,
        )
    }
}

/// Fixed cost of the weighted-average aggregation step (Eq. 14), us.
pub const AGGREGATION_US: f64 = 4.0;

/// Framework/runtime baseline of the reported GPU footprint, MB (the
/// contention model's allocator baseline in `HardwareState` is *not*
/// part of the model's reported footprint).
pub(crate) const MEM_FLOOR_MB: f64 = 280.0;

/// Simulate one inference under `schedule`.
///
/// Thin wrapper over the fast path (`engine::costs`): builds a
/// [`crate::engine::costs::CostTable`] and walks it through a fresh
/// [`crate::engine::costs::SimScratch`].  One-shot report/figure callers
/// should use this; search loops evaluating many candidates on one
/// (graph, device, options, batch) should build the `CostTable` once and
/// call `simulate_into` (scratch reuse, `record_timings: false`) or
/// `IncrementalSim::eval_flip` (single-op flips) directly — that is
/// where the ~10x win over per-call table builds lives.
pub fn simulate(
    graph: &ModelGraph,
    dev: &DeviceModel,
    schedule: &Schedule,
    opts: &SimOptions,
) -> SimReport {
    let table = crate::engine::costs::CostTable::build(graph, dev, opts);
    let mut scratch = crate::engine::costs::SimScratch::new();
    table.simulate_into(schedule, &mut scratch);
    scratch.take_report()
}

/// Reference implementation of the simulated timeline: re-derives every
/// per-op roofline cost inline and allocates per call.  This is the
/// readable spec the fast path is pinned against (see
/// `rust/tests/sim_fastpath.rs`, which asserts bit-identical aggregates);
/// production code should call [`simulate`] or the `engine::costs` entry
/// points instead.  Always records per-op timings regardless of
/// `SimOptions::record_timings`.
pub fn simulate_reference(
    graph: &ModelGraph,
    dev: &DeviceModel,
    schedule: &Schedule,
    opts: &SimOptions,
) -> SimReport {
    let n = graph.ops.len();
    debug_assert_eq!(schedule.xi.len(), n);
    let batch = opts.batch.max(1) as f64;

    let mut hw = HardwareState::new(dev, opts.seed, opts.noise);
    let mut report = SimReport::default();
    let mut finish = vec![0.0f64; n];
    let mut placed = vec![Proc::Cpu; n];
    let mut cpu_free = 0.0f64;
    let mut gpu_free = 0.0f64;
    // Weights resident per device (Fig. 12 sharded-storage accounting).
    let mut gpu_weights_mb = 0.0;
    let mut cpu_weights_mb = 0.0;
    let mut gpu_act_mb: f64 = 0.0;
    // pinned staging buffers for every cross-device edge (both sides)
    let mut staging_mb = 0.0;
    let mut peak_gpu: f64 = 0.0;

    for op in &graph.ops {
        let xi = schedule.xi[op.id];
        // Data-movement ops run where their (first) producer placed data.
        let mode = if !op.class.schedulable() {
            let p = op
                .inputs
                .first()
                .map(|&i| placed[i])
                .unwrap_or(Proc::Cpu);
            Mode::Single(p)
        } else {
            mode_of(xi)
        };

        let flops = op.flops_paper * batch;
        let bytes = op.bytes_moved_paper() * batch;

        let lat_on = |proc: Proc, hw: &mut HardwareState| -> (f64, f64) {
            let (lat, eff_launch) = op_cost_us(
                dev, proc, op.class, flops, bytes, op.sparsity_in, opts);
            let contention = hw.contention_factor(proc);
            (lat * contention, eff_launch)
        };

        // Ready time per target proc: producers' finish + cross-device DMA.
        let ready = |proc: Proc,
                     report: &mut SimReport,
                     placed: &[Proc],
                     finish: &[f64]|
         -> f64 {
            let mut r: f64 = 0.0;
            for &i in &op.inputs {
                let mut t = finish[i];
                if placed[i] != proc && graph.ops[i].bytes_out_paper > 0.0 {
                    let x = dev.transfer_us(
                        graph.ops[i].bytes_out_paper * batch,
                        opts.pinned_memory,
                        opts.async_streams,
                    );
                    report.transfer_us += x;
                    t += x;
                }
                r = r.max(t);
            }
            r
        };

        match mode {
            Mode::Single(proc) => {
                let (lat, launch) = lat_on(proc, &mut hw);
                let r = ready(proc, &mut report, &placed, &finish);
                let free = match proc {
                    Proc::Cpu => cpu_free,
                    Proc::Gpu => gpu_free,
                };
                let start = r.max(free);
                let end = start + lat;
                match proc {
                    Proc::Cpu => {
                        cpu_free = end;
                        report.cpu_busy_us += lat;
                    }
                    Proc::Gpu => {
                        gpu_free = end;
                        report.gpu_busy_us += lat;
                    }
                }
                report.launch_us += launch;
                finish[op.id] = end;
                placed[op.id] = proc;
                hw.dispatch(proc, op.bytes_out_paper * batch,
                            op.params_bytes_paper);
                if proc == Proc::Gpu {
                    gpu_weights_mb += op.params_bytes_paper / 1e6;
                    gpu_act_mb = (gpu_act_mb * 0.92)
                        + op.bytes_out_paper * batch / 1e6;
                    if opts.replicate_weights {
                        cpu_weights_mb += op.params_bytes_paper / 1e6;
                    }
                } else {
                    cpu_weights_mb += op.params_bytes_paper / 1e6;
                    if opts.replicate_weights {
                        gpu_weights_mb += op.params_bytes_paper / 1e6;
                    }
                }
                // pinned staging for cross-device input edges (two copies)
                for &i in &op.inputs {
                    if placed[i] != proc {
                        staging_mb += 2.0
                            * (graph.ops[i].bytes_out_paper * batch / 1e6);
                    }
                }
                report.timings.push(OpTiming {
                    op: op.id,
                    proc,
                    start_us: start,
                    finish_us: end,
                    compute_us: lat,
                    transfer_us: 0.0,
                });
            }
            Mode::CoRun(_w) => {
                // Paper Alg. 1 lines 10-13: run on both, aggregate Eq. 14.
                let (lat_c, launch_c) = lat_on(Proc::Cpu, &mut hw);
                let (lat_g, launch_g) = lat_on(Proc::Gpu, &mut hw);
                let rc = ready(Proc::Cpu, &mut report, &placed, &finish);
                let rg = ready(Proc::Gpu, &mut report, &placed, &finish);
                let sc = rc.max(cpu_free);
                let sg = rg.max(gpu_free);
                let ec = sc + lat_c;
                let eg = sg + lat_g;
                cpu_free = ec;
                gpu_free = eg;
                report.cpu_busy_us += lat_c;
                report.gpu_busy_us += lat_g;
                report.launch_us += launch_c + launch_g;
                // CPU result ships to GPU for aggregation (§5.1).
                let xcpu = dev.transfer_us(
                    op.bytes_out_paper * batch,
                    opts.pinned_memory,
                    opts.async_streams,
                );
                report.transfer_us += xcpu;
                report.aggregation_us += AGGREGATION_US;
                let end = ec.max(eg) + xcpu + AGGREGATION_US;
                finish[op.id] = end;
                placed[op.id] = Proc::Gpu;
                hw.dispatch(Proc::Gpu, op.bytes_out_paper * batch,
                            op.params_bytes_paper);
                gpu_weights_mb += op.params_bytes_paper / 1e6;
                cpu_weights_mb += op.params_bytes_paper / 1e6; // replicated
                gpu_act_mb =
                    (gpu_act_mb * 0.92) + op.bytes_out_paper * batch / 1e6;
                report.timings.push(OpTiming {
                    op: op.id,
                    proc: Proc::Gpu,
                    start_us: sc.min(sg),
                    finish_us: end,
                    compute_us: lat_c.max(lat_g),
                    transfer_us: xcpu,
                });
            }
        }
        peak_gpu = peak_gpu.max(gpu_weights_mb + gpu_act_mb + staging_mb);
    }

    report.switches = hw.switches;
    // Co-run aggregation (transfer + Eq. 14) extends past the processor
    // timelines, so the makespan is the max over all completion events.
    let last_finish = finish.iter().cloned().fold(0.0, f64::max);
    report.makespan_us = cpu_free.max(gpu_free).max(last_finish);
    report.peak_gpu_mem_mb = peak_gpu + MEM_FLOOR_MB;
    report.cpu_mem_mb = cpu_weights_mb;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;
    use std::path::Path;

    fn setup() -> Option<(ModelZoo, DeviceRegistry)> {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return None;
        }
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        Some((
            ModelZoo::load(&art).unwrap(),
            DeviceRegistry::load(&root.join("config/devices.json")).unwrap(),
        ))
    }

    #[test]
    fn cpu_only_much_slower_than_gpu_only_on_heavy_model() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("vit_b16").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let cpu = simulate(g, dev, &Schedule::uniform(g, 0.0, "cpu"),
                           &SimOptions::default());
        let gpu = simulate(g, dev, &Schedule::uniform(g, 1.0, "gpu"),
                           &SimOptions::default());
        assert!(cpu.makespan_us > 3.0 * gpu.makespan_us,
                "cpu {} vs gpu {}", cpu.makespan_us, gpu.makespan_us);
    }

    #[test]
    fn makespan_bounded_by_busy_sum() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("mobilenet_v2").unwrap();
        let dev = reg.get("orin_nano").unwrap();
        let r = simulate(g, dev, &Schedule::uniform(g, 1.0, "gpu"),
                         &SimOptions::default());
        assert!(r.makespan_us > 0.0);
        assert!(r.makespan_us <= r.cpu_busy_us + r.gpu_busy_us
                + r.transfer_us + r.aggregation_us + 1e-6);
    }

    #[test]
    fn pinned_and_async_reduce_transfer() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("resnet18").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        // Alternate ops CPU/GPU to force transfers.
        let mut xi = vec![0.0; g.ops.len()];
        for (i, x) in xi.iter_mut().enumerate() {
            *x = if i % 2 == 0 { 0.0 } else { 1.0 };
        }
        let sched = Schedule { xi, policy: "alt".into() };
        let fast = simulate(g, dev, &sched, &SimOptions::default());
        let slow = simulate(g, dev, &sched, &SimOptions {
            pinned_memory: false,
            async_streams: false,
            ..SimOptions::default()
        });
        assert!(slow.transfer_us > 2.0 * fast.transfer_us);
        assert!(slow.makespan_us > fast.makespan_us);
    }

    #[test]
    fn batch_scales_latency_sublinearly_on_gpu() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("mobilenet_v3_small").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let b1 = simulate(g, dev, &Schedule::uniform(g, 1.0, "gpu"),
                          &SimOptions { batch: 1, ..Default::default() });
        let b8 = simulate(g, dev, &Schedule::uniform(g, 1.0, "gpu"),
                          &SimOptions { batch: 8, ..Default::default() });
        let ratio = b8.makespan_us / b1.makespan_us;
        assert!(ratio < 8.0, "batching should amortize launches: {ratio}");
        assert!(ratio > 1.0);
    }

    #[test]
    fn corun_aggregates_on_gpu() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("resnet18").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let r = simulate(g, dev, &Schedule::uniform(g, 0.5, "co"),
                         &SimOptions::default());
        assert!(r.aggregation_us > 0.0);
        assert!(r.cpu_busy_us > 0.0 && r.gpu_busy_us > 0.0);
    }
}
