//! Dynamic batching optimization (paper §5.2, Algorithm 2).
//!
//! Gradient descent on per-item latency L(B)/B with the paper's three
//! constraint rules: halve on memory overflow + real-time violation,
//! double (capped) for highly sparse inputs, halve for high-intensity
//! inputs.  The latency/memory oracle is the device simulator, so the
//! optimizer is hardware-aware by construction.  Candidate batch sizes
//! are memoized across iterations (the search revisits the same sizes
//! constantly) and the independent gradient-neighbor probes run in
//! parallel ([`crate::util::par::par_map`]).

use crate::device::DeviceModel;
use crate::engine::sim::{simulate, SimOptions, SimReport};
use crate::graph::ModelGraph;
use crate::scheduler::Schedule;
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct BatchConstraints {
    /// available memory budget, MB (M_max)
    pub mem_limit_mb: f64,
    /// real-time bound per item, us (T_real-time)
    pub realtime_us: f64,
    /// sparsity threshold triggering batch growth
    pub sparsity_threshold: f64,
    /// intensity threshold (normalized) triggering batch shrink
    pub intensity_threshold: f64,
    pub min_batch: usize,
    pub max_batch: usize,
}

impl Default for BatchConstraints {
    fn default() -> Self {
        BatchConstraints {
            mem_limit_mb: 4096.0,
            realtime_us: 50_000.0,
            sparsity_threshold: 0.5,
            intensity_threshold: 0.6,
            min_batch: 1,
            max_batch: 512,
        }
    }
}

impl BatchConstraints {
    /// Constraints derived from a device profile: the memory budget is the
    /// device's GPU capacity (what the Fig. 8 bench and the multi-tenant
    /// registry both want).
    pub fn for_device(dev: &DeviceModel) -> Self {
        BatchConstraints {
            mem_limit_mb: dev.gpu_mem_capacity_mb,
            ..Default::default()
        }
    }
}

#[derive(Debug, Clone)]
pub struct BatchStep {
    pub batch: usize,
    pub per_item_us: f64,
    pub mem_mb: f64,
}

#[derive(Debug, Clone)]
pub struct BatchPlan {
    pub batch: usize,
    pub per_item_us: f64,
    pub trace: Vec<BatchStep>,
}

fn eval(graph: &ModelGraph, dev: &DeviceModel, sched: &Schedule,
        opts: &SimOptions, b: usize) -> (SimReport, f64) {
    let mut o = opts.clone();
    o.batch = b;
    // The optimizer only reads aggregates; skip the per-op timing vec.
    o.record_timings = false;
    let r = simulate(graph, dev, sched, &o);
    let per_item = r.makespan_us / b as f64;
    (r, per_item)
}

/// Memoized probe: (per-item latency us, total memory MB) for one batch
/// size, computed at most once per `optimize_batch` call.
fn cached<F: Fn(usize) -> (f64, f64)>(
    cache: &mut HashMap<usize, (f64, f64)>,
    probe: &F,
    b: usize,
) -> (f64, f64) {
    if let Some(&v) = cache.get(&b) {
        return v;
    }
    let v = probe(b);
    cache.insert(b, v);
    v
}

/// Mean input sparsity / normalized intensity of the model's schedulable
/// ops (drives Alg. 2 lines 10-14; the multi-tenant cluster scheduler
/// reuses the same signals for cross-model placement tie-breaks).
pub fn model_profile(graph: &ModelGraph) -> (f64, f64) {
    let mut sp = 0.0;
    let mut it = 0.0;
    let mut n = 0.0f64;
    for op in graph.schedulable_ops() {
        sp += op.sparsity_in;
        let lf = op.flops_paper.max(1.0).log10();
        it += ((lf - 3.0) / 9.0).clamp(0.0, 1.0);
        n += 1.0;
    }
    (sp / n.max(1.0), it / n.max(1.0))
}

/// Algorithm 2: returns the optimized batch size and the search trace.
pub fn optimize_batch(
    graph: &ModelGraph,
    dev: &DeviceModel,
    sched: &Schedule,
    opts: &SimOptions,
    b0: usize,
    c: &BatchConstraints,
) -> BatchPlan {
    let eta = 0.35; // learning rate on log2(B)
    let eps = 0.01; // convergence threshold on per-item latency (relative)
    let (sparsity, intensity) = model_profile(graph);

    let clamp = |b: f64| -> usize {
        (b.round() as i64).clamp(c.min_batch as i64, c.max_batch as i64)
            as usize
    };
    let mut b = clamp(b0 as f64);
    let mut trace = Vec::new();
    // Probe oracle (one full simulation per *distinct* batch size) and
    // its memo: the descent revisits the same sizes on most iterations.
    let mut cache: HashMap<usize, (f64, f64)> = HashMap::new();
    let probe = |bb: usize| -> (f64, f64) {
        let (r, l) = eval(graph, dev, sched, opts, bb);
        (l, r.total_mem_mb())
    };
    let (mut per_item, mut mem_mb) = cached(&mut cache, &probe, b);
    let mut prev = f64::INFINITY;

    for _ in 0..24 {
        trace.push(BatchStep { batch: b, per_item_us: per_item, mem_mb });
        if prev.is_finite() && (per_item - prev).abs() <= eps * prev {
            break;
        }
        prev = per_item;

        // line 5-6: numeric gradient on log-batch, step downhill.  The
        // two neighbor probes are independent simulations — evaluate the
        // uncached ones in parallel.
        let b_hi = clamp(b as f64 * 2.0);
        let b_lo = clamp(b as f64 / 2.0);
        let mut misses: Vec<usize> = Vec::new();
        for cand in [b_hi, b_lo] {
            if !cache.contains_key(&cand) && !misses.contains(&cand) {
                misses.push(cand);
            }
        }
        let fresh = crate::util::par::par_map(&misses, |&x| probe(x));
        for (&x, v) in misses.iter().zip(fresh) {
            cache.insert(x, v);
        }
        let l_hi = cache[&b_hi].0;
        let l_lo = cache[&b_lo].0;
        let grad = (l_hi - l_lo)
            / ((b_hi as f64).log2() - (b_lo as f64).log2()).max(1e-9);
        let mut nb = (b as f64).log2() - eta * grad.signum()
            * (1.0 + grad.abs().log10().max(0.0));
        nb = nb.clamp(0.0, (c.max_batch as f64).log2());
        let mut next = clamp(nb.exp2());

        // lines 7-9: memory guard (halve while over budget), with the
        // real-time bound as a secondary shrink trigger.  The real-time
        // check deliberately tests the *pre-halving* candidate's
        // latency, matching the original formulation (the memoization
        // refactor must not shift Alg. 2's trajectory).
        let (l_next, mut m_next) = cached(&mut cache, &probe, next);
        while m_next > c.mem_limit_mb && next > c.min_batch {
            next = clamp(next as f64 / 2.0);
            m_next = cached(&mut cache, &probe, next).1;
        }
        if l_next > c.realtime_us && next > c.min_batch {
            next = clamp(next as f64 / 2.0);
        }
        // lines 10-13: data-driven partitioning.
        if sparsity > c.sparsity_threshold {
            next = clamp((2 * next) as f64);
        } else if intensity > c.intensity_threshold {
            next = clamp(next as f64 / 2.0);
        }
        if next == b {
            break;
        }
        b = next;
        let v = cached(&mut cache, &probe, b);
        per_item = v.0;
        mem_mb = v.1;
    }
    // Keep the best *memory-feasible* point seen, not just the last.
    let feasible: Vec<&BatchStep> = trace
        .iter()
        .filter(|s| s.mem_mb <= c.mem_limit_mb)
        .collect();
    let pool: Vec<&BatchStep> = if feasible.is_empty() {
        trace.iter().collect()
    } else {
        feasible
    };
    let best = pool
        .iter()
        .min_by(|a, x| a.per_item_us.partial_cmp(&x.per_item_us).unwrap())
        .map(|s| (*s).clone())
        .unwrap_or(BatchStep { batch: b, per_item_us: per_item, mem_mb });
    BatchPlan { batch: best.batch, per_item_us: best.per_item_us, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    fn setup() -> Option<(ModelZoo, DeviceRegistry)> {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return None;
        }
        Some((
            ModelZoo::load(&art).unwrap(),
            DeviceRegistry::load(
                &crate::repo_root().join("config/devices.json")).unwrap(),
        ))
    }

    #[test]
    fn optimized_batch_beats_batch_one_throughput() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("mobilenet_v3_small").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let sched = Schedule::uniform(g, 1.0, "gpu");
        let opts = SimOptions::default();
        let plan = optimize_batch(g, dev, &sched, &opts, 1,
                                  &BatchConstraints::default());
        let (_, l1) = eval(g, dev, &sched, &opts, 1);
        assert!(plan.batch >= 1);
        assert!(plan.per_item_us <= l1 * 1.001,
                "optimized {} vs b1 {}", plan.per_item_us, l1);
    }

    #[test]
    fn respects_memory_limit() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("vit_b16").unwrap();
        let dev = reg.get("orin_nano").unwrap();
        let sched = Schedule::uniform(g, 1.0, "gpu");
        let opts = SimOptions::default();
        let (r64, _) = eval(g, dev, &sched, &opts, 64);
        let (r1, _) = eval(g, dev, &sched, &opts, 1);
        assert!(r64.total_mem_mb() > r1.total_mem_mb());
        let c = BatchConstraints {
            // a budget batch-64 violates but small batches satisfy
            mem_limit_mb: 0.5 * (r1.total_mem_mb() + r64.total_mem_mb()),
            realtime_us: 1.0, // force the shrink trigger too
            ..Default::default()
        };
        let plan = optimize_batch(g, dev, &sched, &opts, 64, &c);
        let (rep, _) = eval(g, dev, &sched, &opts, plan.batch);
        assert!(plan.batch < 64, "batch {}", plan.batch);
        assert!(rep.total_mem_mb() <= c.mem_limit_mb * 1.01,
                "batch {} mem {}", plan.batch, rep.total_mem_mb());
    }

    #[test]
    fn batch_stays_within_bounds() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("mobilenet_v2").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let sched = Schedule::uniform(g, 1.0, "gpu");
        let c = BatchConstraints::default();
        let plan = optimize_batch(g, dev, &sched, &SimOptions::default(),
                                  8, &c);
        assert!(plan.batch >= c.min_batch && plan.batch <= c.max_batch);
        for s in &plan.trace {
            assert!(s.batch >= c.min_batch && s.batch <= c.max_batch);
        }
    }
}
