//! Real-numerics execution of a scheduled model: walks the graph in
//! topological order, runs artifact-backed ops through the PJRT runtime,
//! applies data-movement ops natively, and performs the weighted-average
//! aggregation (Eq. 14) for co-run ops.
//!
//! Co-run note: both processors compute the *same* operator, so the
//! engine executes the artifact once and aggregates ξ·P + (1−ξ)·P — which
//! Eq. 14 makes numerically the identity.  A debug assertion verifies
//! this, protecting against schedule/aggregation drift.

use crate::graph::{ModelGraph, OpKind};
use crate::runtime::{HostTensor, Runtime, WeightStore};
use crate::scheduler::{mode_of, Mode, Schedule};
use anyhow::{Context, Result};

pub struct HybridEngine<'a> {
    pub runtime: &'a Runtime,
    pub graph: &'a ModelGraph,
    pub weights: WeightStore,
}

/// Outcome of one real inference.
pub struct ExecResult {
    pub output: HostTensor,
    /// Measured output sparsity per op (compare with topology profile).
    pub sparsity_out: Vec<f64>,
    /// Host wall-clock of the PJRT execution path, microseconds.
    pub host_us: f64,
}

impl<'a> HybridEngine<'a> {
    pub fn new(runtime: &'a Runtime, graph: &'a ModelGraph) -> Result<Self> {
        let weights = WeightStore::load(&graph.weights_path)?;
        Ok(HybridEngine { runtime, graph, weights })
    }

    /// Pre-compile all artifacts so the request path never compiles.
    pub fn warm_up(&self) -> Result<usize> {
        self.runtime.warm_up(self.graph)
    }

    /// Execute the model on `input` under `schedule`.
    pub fn infer(&self, input: &HostTensor, schedule: &Schedule)
        -> Result<ExecResult>
    {
        let t0 = std::time::Instant::now();
        let n = self.graph.ops.len();
        let mut vals: Vec<Option<HostTensor>> = vec![None; n];
        let mut sparsity = vec![0.0f64; n];
        // Remaining-consumer counts for activation freeing.
        let mut pending: Vec<usize> =
            self.graph.consumers.iter().map(|c| c.len()).collect();

        for op in &self.graph.ops {
            let out = match op.kind {
                OpKind::Input => {
                    anyhow::ensure!(
                        input.shape == op.exec_out_shape,
                        "input shape {:?} != expected {:?}",
                        input.shape,
                        op.exec_out_shape
                    );
                    input.clone()
                }
                OpKind::Reshape => {
                    let src = vals[op.inputs[0]]
                        .clone()
                        .context("reshape input missing")?;
                    src.reshaped(op.exec_out_shape.clone())?
                }
                _ => {
                    let artifact = op
                        .artifact
                        .as_ref()
                        .with_context(|| format!("op {} has no artifact",
                                                 op.name))?;
                    let mut args: Vec<HostTensor> = op
                        .inputs
                        .iter()
                        .map(|&i| {
                            vals[i].clone().context("missing producer value")
                        })
                        .collect::<Result<_>>()?;
                    args.extend(self.weights.op_params(op)?);
                    let result = self.runtime.execute(artifact, &args)?;
                    match mode_of(schedule.xi[op.id]) {
                        Mode::Single(_) => result,
                        Mode::CoRun(w) => {
                            // Eq. 14: P = ξ·P_gpu + (1−ξ)·P_cpu.  Both
                            // executors compute the same operator, so
                            // aggregation must be the identity.
                            let agg = aggregate(&result, &result, w);
                            debug_assert!(agg
                                .data
                                .iter()
                                .zip(&result.data)
                                .all(|(a, b)| (a - b).abs() <= 1e-6
                                     * b.abs().max(1.0)));
                            agg
                        }
                    }
                }
            };
            anyhow::ensure!(
                out.shape == op.exec_out_shape,
                "op {} produced {:?}, expected {:?}",
                op.name,
                out.shape,
                op.exec_out_shape
            );
            sparsity[op.id] = out.sparsity();
            vals[op.id] = Some(out);
            // Release producer activations once all consumers are done.
            for &i in &op.inputs {
                pending[i] -= 1;
                if pending[i] == 0 && i != n - 1 {
                    vals[i] = None;
                }
            }
        }
        let output = vals[n - 1].take().context("no model output")?;
        Ok(ExecResult {
            output,
            sparsity_out: sparsity,
            host_us: t0.elapsed().as_secs_f64() * 1e6,
        })
    }
}

/// Weighted-average aggregation (Eq. 14).
pub fn aggregate(gpu: &HostTensor, cpu: &HostTensor, xi: f64) -> HostTensor {
    debug_assert_eq!(gpu.shape, cpu.shape);
    let data = gpu
        .data
        .iter()
        .zip(&cpu.data)
        .map(|(g, c)| (xi * *g as f64 + (1.0 - xi) * *c as f64) as f32)
        .collect();
    HostTensor::new(gpu.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_weights() {
        let a = HostTensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::new(vec![3], vec![3.0, 2.0, 1.0]);
        let half = aggregate(&a, &b, 0.5);
        assert_eq!(half.data, vec![2.0, 2.0, 2.0]);
        let all_gpu = aggregate(&a, &b, 1.0);
        assert_eq!(all_gpu.data, a.data);
        let all_cpu = aggregate(&a, &b, 0.0);
        assert_eq!(all_cpu.data, b.data);
    }
}
