//! Real-numerics execution of a scheduled model: walks the graph in
//! topological order, runs artifact-backed ops through the PJRT runtime,
//! applies data-movement ops natively, and performs the weighted-average
//! aggregation (Eq. 14) for co-run ops.
//!
//! Co-run note: both processors compute the *same* operator, so on the
//! single-executor real path the aggregation ξ·P + (1−ξ)·P is numerically
//! the identity — the release build skips it entirely and debug builds
//! verify the invariant instead (protects against schedule/aggregation
//! drift without taxing the request path).
//!
//! Weight slices are resolved once into an [`OpParams`] table when an
//! engine (or `api::PjrtBackend`) is constructed; the per-request walk
//! borrows those tensors instead of re-slicing `weights.bin`.

use crate::graph::{ModelGraph, OpKind};
use crate::runtime::{HostTensor, Runtime, WeightStore};
use crate::scheduler::{mode_of, Mode, Schedule};
use anyhow::{Context, Result};

/// Per-op parameter tensors, resolved once from a [`WeightStore`].
///
/// Indexed by op id; the request hot path borrows these slices instead of
/// cloning every weight tensor on every inference.
pub struct OpParams {
    per_op: Vec<Vec<HostTensor>>,
}

impl OpParams {
    /// Materialize every op's weight slices once.
    pub fn build(graph: &ModelGraph, weights: &WeightStore) -> Result<Self> {
        let per_op = graph
            .ops
            .iter()
            .map(|op| weights.op_params(op))
            .collect::<Result<Vec<_>>>()?;
        Ok(OpParams { per_op })
    }

    /// The cached parameter tensors of op `id`.
    pub fn of(&self, id: usize) -> &[HostTensor] {
        &self.per_op[id]
    }

    /// Total number of cached parameter tensors (all ops).
    pub fn tensor_count(&self) -> usize {
        self.per_op.iter().map(|p| p.len()).sum()
    }
}

pub struct HybridEngine<'a> {
    pub runtime: &'a Runtime,
    pub graph: &'a ModelGraph,
    params: OpParams,
}

/// Outcome of one real inference.
pub struct ExecResult {
    pub output: HostTensor,
    /// Measured output sparsity per op (compare with topology profile).
    pub sparsity_out: Vec<f64>,
    /// Host wall-clock of the PJRT execution path, microseconds.
    pub host_us: f64,
}

impl<'a> HybridEngine<'a> {
    pub fn new(runtime: &'a Runtime, graph: &'a ModelGraph) -> Result<Self> {
        let weights = WeightStore::load(&graph.weights_path)?;
        let params = OpParams::build(graph, &weights)?;
        Ok(HybridEngine { runtime, graph, params })
    }

    /// Pre-compile all artifacts so the request path never compiles.
    pub fn warm_up(&self) -> Result<usize> {
        self.runtime.warm_up(self.graph)
    }

    /// Execute the model on `input` under `schedule`.
    pub fn infer(&self, input: &HostTensor, schedule: &Schedule)
        -> Result<ExecResult>
    {
        execute_graph(self.runtime, self.graph, &self.params, input, schedule)
    }
}

/// Walk `graph` in topological order on `runtime`, with parameter tensors
/// borrowed from `params`.  This is the real-numerics request path shared
/// by [`HybridEngine`] and `api::PjrtBackend`.
pub fn execute_graph(
    runtime: &Runtime,
    graph: &ModelGraph,
    params: &OpParams,
    input: &HostTensor,
    schedule: &Schedule,
) -> Result<ExecResult> {
    let t0 = std::time::Instant::now();
    let n = graph.ops.len();
    let mut vals: Vec<Option<HostTensor>> = vec![None; n];
    let mut sparsity = vec![0.0f64; n];
    // Remaining-consumer counts for activation freeing.
    let mut pending: Vec<usize> =
        graph.consumers.iter().map(|c| c.len()).collect();

    for op in &graph.ops {
        let out = match op.kind {
            OpKind::Input => {
                anyhow::ensure!(
                    input.shape == op.exec_out_shape,
                    "input shape {:?} != expected {:?}",
                    input.shape,
                    op.exec_out_shape
                );
                input.clone()
            }
            OpKind::Reshape => {
                let src = vals[op.inputs[0]]
                    .clone()
                    .context("reshape input missing")?;
                src.reshaped(op.exec_out_shape.clone())?
            }
            _ => {
                let artifact = op
                    .artifact
                    .as_ref()
                    .with_context(|| format!("op {} has no artifact",
                                             op.name))?;
                let result = {
                    let mut args: Vec<&HostTensor> = op
                        .inputs
                        .iter()
                        .map(|&i| {
                            vals[i].as_ref().context("missing producer value")
                        })
                        .collect::<Result<_>>()?;
                    args.extend(params.of(op.id).iter());
                    runtime.execute_refs(artifact, &args)?
                };
                match mode_of(schedule.xi[op.id]) {
                    Mode::Single(_) => result,
                    Mode::CoRun(_w) => {
                        // Eq. 14: P = ξ·P_gpu + (1−ξ)·P_cpu.  Both
                        // executors compute the same operator, so the
                        // aggregation is the identity — skip it on the
                        // single-executor real path and only verify the
                        // invariant in debug builds.
                        #[cfg(debug_assertions)]
                        {
                            let agg = aggregate(&result, &result, _w);
                            debug_assert!(agg
                                .data
                                .iter()
                                .zip(&result.data)
                                .all(|(a, b)| (a - b).abs() <= 1e-6
                                     * b.abs().max(1.0)));
                        }
                        result
                    }
                }
            }
        };
        anyhow::ensure!(
            out.shape == op.exec_out_shape,
            "op {} produced {:?}, expected {:?}",
            op.name,
            out.shape,
            op.exec_out_shape
        );
        sparsity[op.id] = out.sparsity();
        vals[op.id] = Some(out);
        // Release producer activations once all consumers are done.
        for &i in &op.inputs {
            pending[i] -= 1;
            if pending[i] == 0 && i != n - 1 {
                vals[i] = None;
            }
        }
    }
    let output = vals[n - 1].take().context("no model output")?;
    Ok(ExecResult {
        output,
        sparsity_out: sparsity,
        host_us: t0.elapsed().as_secs_f64() * 1e6,
    })
}

/// Weighted-average aggregation (Eq. 14).
pub fn aggregate(gpu: &HostTensor, cpu: &HostTensor, xi: f64) -> HostTensor {
    debug_assert_eq!(gpu.shape, cpu.shape);
    let data = gpu
        .data
        .iter()
        .zip(&cpu.data)
        .map(|(g, c)| (xi * *g as f64 + (1.0 - xi) * *c as f64) as f32)
        .collect();
    HostTensor::new(gpu.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_weights() {
        let a = HostTensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::new(vec![3], vec![3.0, 2.0, 1.0]);
        let half = aggregate(&a, &b, 0.5);
        assert_eq!(half.data, vec![2.0, 2.0, 2.0]);
        let all_gpu = aggregate(&a, &b, 1.0);
        assert_eq!(all_gpu.data, a.data);
        let all_cpu = aggregate(&a, &b, 0.0);
        assert_eq!(all_cpu.data, b.data);
    }
}
