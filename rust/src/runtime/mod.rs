//! PJRT runtime bridge: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's CPU
//! client.  This is the only place rust touches XLA; everything above works
//! with [`HostTensor`]s.
//!
//! Design notes:
//! * Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5
//!   serialized protos — 64-bit instruction ids).
//! * Executables are compiled lazily and cached per artifact path; a model
//!   warm-up compiles everything up front so the request path never pays
//!   compile latency.
//! * Weight stores are read once from `weights.bin` (f32 little-endian)
//!   and sliced per op.

use crate::graph::{ModelGraph, Op};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A host-resident f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }
    pub fn numel(&self) -> usize {
        self.data.len()
    }
    /// Fraction of exact zeros (activation sparsity, paper Eq. 1).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|x| x.abs() < 1e-9).count();
        zeros as f64 / self.data.len() as f64
    }
    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Self> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?} changes element count", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

/// Per-model weight buffer (contents of weights.bin).
pub struct WeightStore {
    buf: Vec<f32>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights.bin length not a multiple of 4");
        }
        let buf = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(WeightStore { buf })
    }

    /// Tensors for one op's weight slices.
    pub fn op_params(&self, op: &Op) -> Result<Vec<HostTensor>> {
        op.weights
            .iter()
            .map(|w| {
                let end = w.offset + w.numel;
                if end > self.buf.len() {
                    bail!("weight slice out of range for op {}", op.name);
                }
                Ok(HostTensor::new(
                    w.shape.clone(),
                    self.buf[w.offset..end].to_vec(),
                ))
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// PJRT client + compiled-executable cache.
///
/// Not `Sync`: the engine owns one `Runtime` on its execution thread (the
/// scheduling layers never touch XLA directly).
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_root: PathBuf,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    pub fn new(artifacts_root: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            artifacts_root: artifacts_root.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for an artifact path
    /// relative to the artifacts root.
    fn ensure_compiled(&self, artifact: &str) -> Result<()> {
        if self.cache.borrow().contains_key(artifact) {
            return Ok(());
        }
        let path = self.artifacts_root.join(artifact);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf8")?,
        )
        .map_err(|e| {
            anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {artifact}: {e:?}"))?;
        self.cache.borrow_mut().insert(artifact.to_string(), exe);
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Pre-compile every artifact a model needs (warm-up path).
    pub fn warm_up(&self, graph: &ModelGraph) -> Result<usize> {
        let mut n = 0;
        for op in &graph.ops {
            if let Some(a) = &op.artifact {
                self.ensure_compiled(a)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Execute one artifact with the given arguments (inputs ++ params).
    pub fn execute(
        &self,
        artifact: &str,
        args: &[HostTensor],
    ) -> Result<HostTensor> {
        self.ensure_compiled(artifact)?;
        let cache = self.cache.borrow();
        let exe = cache.get(artifact).unwrap();

        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| {
                let dims: Vec<i64> =
                    t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {artifact}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let shape = out
            .array_shape()
            .map_err(|e| anyhow::anyhow!("result shape: {e:?}"))?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let data = out
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("result to_vec: {e:?}"))?;
        Ok(HostTensor::new(dims, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_sparsity_and_reshape() {
        let t = HostTensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0]);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
        let r = t.clone().reshaped(vec![3, 2]).unwrap();
        assert_eq!(r.shape, vec![3, 2]);
        assert!(t.clone().reshaped(vec![4]).is_err());
    }

    #[test]
    fn weight_store_slicing() {
        let dir = std::env::temp_dir().join("sparoa_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weights.bin");
        let vals: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let ws = WeightStore::load(&path).unwrap();
        assert_eq!(ws.len(), 6);
        let op = Op {
            id: 1,
            name: "t".into(),
            kind: crate::graph::OpKind::Linear,
            class: crate::graph::OpClass::MatMul,
            inputs: vec![0],
            exec_in_shapes: vec![vec![1, 2]],
            exec_out_shape: vec![1, 3],
            paper_out_shape: vec![1, 3],
            flops_exec: 0.0,
            flops_paper: 0.0,
            bytes_in_paper: 0.0,
            bytes_out_paper: 0.0,
            params_bytes_paper: 0.0,
            sparsity_in: 0.0,
            sparsity_out: 0.0,
            weights: vec![
                crate::graph::WeightSlice { offset: 0, numel: 4, shape: vec![2, 2] },
                crate::graph::WeightSlice { offset: 4, numel: 2, shape: vec![2] },
            ],
            artifact: None,
        };
        let ps = ws.op_params(&op).unwrap();
        assert_eq!(ps[0].data, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ps[1].data, vec![4.0, 5.0]);
    }
}
