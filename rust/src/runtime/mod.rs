//! PJRT runtime bridge: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's CPU
//! client.  This is the only place rust touches XLA; everything above works
//! with [`HostTensor`]s.
//!
//! Design notes:
//! * Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5
//!   serialized protos — 64-bit instruction ids).
//! * Executables are compiled lazily and cached per artifact path; a model
//!   warm-up compiles everything up front so the request path never pays
//!   compile latency.  The cache sits behind a `Mutex` so a `Runtime` can
//!   be owned by a `Send` execution backend (api::PjrtBackend).
//! * Weight stores are read once from `weights.bin` (f32 little-endian)
//!   and sliced per op; hot-path callers resolve slices once via
//!   [`crate::engine::exec::OpParams`] instead of re-slicing per request.
//! * The `xla` dependency is optional (`pjrt` cargo feature).  Without it
//!   a stub `Runtime` with the same API is compiled whose `execute`
//!   returns an error — the simulator-side stack stays fully usable.

use crate::graph::{ModelGraph, Op};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A host-resident f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }
    pub fn numel(&self) -> usize {
        self.data.len()
    }
    /// Fraction of exact zeros (activation sparsity, paper Eq. 1).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|x| x.abs() < 1e-9).count();
        zeros as f64 / self.data.len() as f64
    }
    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Self> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?} changes element count", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
    /// Seeded standard-normal tensor (the conventional synthetic input —
    /// one definition so sessions and backends stay bit-identical).
    pub fn random_normal(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = crate::util::rng::Rng::new(seed);
        HostTensor::new(
            shape.to_vec(),
            (0..n).map(|_| rng.normal() as f32).collect(),
        )
    }
}

/// Per-model weight buffer (contents of weights.bin).
pub struct WeightStore {
    buf: Vec<f32>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights.bin length not a multiple of 4");
        }
        let buf = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(WeightStore { buf })
    }

    /// Tensors for one op's weight slices.
    ///
    /// This allocates a fresh copy of every slice — fine for one-off use,
    /// but request paths should resolve all ops once into an
    /// [`crate::engine::exec::OpParams`] and borrow from it instead.
    pub fn op_params(&self, op: &Op) -> Result<Vec<HostTensor>> {
        op.weights
            .iter()
            .map(|w| {
                let end = w.offset + w.numel;
                if end > self.buf.len() {
                    bail!("weight slice out of range for op {}", op.name);
                }
                Ok(HostTensor::new(
                    w.shape.clone(),
                    self.buf[w.offset..end].to_vec(),
                ))
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(feature = "pjrt")]
mod client {
    use super::*;
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::Mutex;

    /// PJRT client + compiled-executable cache.
    ///
    /// Owned and `Send`: the executable cache is behind a `Mutex`, so an
    /// execution backend may own the runtime outright and move across
    /// threads.  Execution itself is serialized per runtime (the lock is
    /// held across `execute`), matching the single-executor engine model.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifacts_root: PathBuf,
        cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    // SAFETY: the xla handles are not declared Send (FFI pointers, and
    // executables keep internal references to their client), but a
    // `Runtime` owns the *entire* client + executable graph as one unit:
    // no handle or clone ever escapes this struct (`execute_refs` returns
    // plain `HostTensor`s), so moving a `Runtime` moves every reference
    // together onto the new thread, and the `Mutex` serializes all PJRT
    // calls.  Cross-thread *sharing* is still forbidden (no `Sync`).
    unsafe impl Send for Runtime {}

    impl Runtime {
        pub fn new(artifacts_root: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime {
                client,
                artifacts_root: artifacts_root.to_path_buf(),
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) the executable for an artifact path
        /// relative to the artifacts root.
        fn ensure_compiled(&self, artifact: &str) -> Result<()> {
            if self.cache.lock().unwrap().contains_key(artifact) {
                return Ok(());
            }
            let path = self.artifacts_root.join(artifact);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf8")?,
            )
            .map_err(|e| {
                anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display())
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {artifact}: {e:?}"))?;
            self.cache.lock().unwrap().insert(artifact.to_string(), exe);
            Ok(())
        }

        /// Number of compiled executables currently cached.
        pub fn cached(&self) -> usize {
            self.cache.lock().unwrap().len()
        }

        /// Pre-compile every artifact a model needs (warm-up path).
        pub fn warm_up(&self, graph: &ModelGraph) -> Result<usize> {
            let mut n = 0;
            for op in &graph.ops {
                if let Some(a) = &op.artifact {
                    self.ensure_compiled(a)?;
                    n += 1;
                }
            }
            Ok(n)
        }

        /// Execute one artifact with the given arguments (inputs ++ params).
        pub fn execute(
            &self,
            artifact: &str,
            args: &[HostTensor],
        ) -> Result<HostTensor> {
            let refs: Vec<&HostTensor> = args.iter().collect();
            self.execute_refs(artifact, &refs)
        }

        /// Borrowing variant of [`execute`]: the request hot path passes
        /// cached param tensors by reference instead of cloning them.
        pub fn execute_refs(
            &self,
            artifact: &str,
            args: &[&HostTensor],
        ) -> Result<HostTensor> {
            self.ensure_compiled(artifact)?;
            let cache = self.cache.lock().unwrap();
            let exe = cache.get(artifact).unwrap();

            let literals: Vec<xla::Literal> = args
                .iter()
                .map(|t| {
                    let dims: Vec<i64> =
                        t.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data)
                        .reshape(&dims)
                        .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))
                })
                .collect::<Result<_>>()?;

            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {artifact}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = lit
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
            let shape = out
                .array_shape()
                .map_err(|e| anyhow::anyhow!("result shape: {e:?}"))?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|&d| d as usize).collect();
            let data = out
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("result to_vec: {e:?}"))?;
            Ok(HostTensor::new(dims, data))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod client {
    use super::*;
    use std::path::PathBuf;

    /// Stub runtime compiled when the `pjrt` cargo feature (the `xla`
    /// crate) is absent.  Loading succeeds so simulator-only sessions can
    /// be built uniformly; any attempt to execute an artifact errors.
    pub struct Runtime {
        #[allow(dead_code)]
        artifacts_root: PathBuf,
    }

    impl Runtime {
        pub fn new(artifacts_root: &Path) -> Result<Self> {
            Ok(Runtime { artifacts_root: artifacts_root.to_path_buf() })
        }

        pub fn platform(&self) -> String {
            "stub (built without the `pjrt` feature)".into()
        }

        pub fn cached(&self) -> usize {
            0
        }

        pub fn warm_up(&self, graph: &ModelGraph) -> Result<usize> {
            if graph.ops.iter().any(|op| op.artifact.is_some()) {
                bail!(
                    "model `{}` needs PJRT execution but sparoa was built \
                     without the `pjrt` feature",
                    graph.model
                );
            }
            Ok(0)
        }

        pub fn execute(
            &self,
            artifact: &str,
            _args: &[HostTensor],
        ) -> Result<HostTensor> {
            bail!("cannot execute `{artifact}`: built without `pjrt` feature");
        }

        pub fn execute_refs(
            &self,
            artifact: &str,
            _args: &[&HostTensor],
        ) -> Result<HostTensor> {
            bail!("cannot execute `{artifact}`: built without `pjrt` feature");
        }
    }
}

pub use client::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_sparsity_and_reshape() {
        let t = HostTensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0]);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
        let r = t.clone().reshaped(vec![3, 2]).unwrap();
        assert_eq!(r.shape, vec![3, 2]);
        assert!(t.clone().reshaped(vec![4]).is_err());
    }

    #[test]
    fn weight_store_slicing() {
        let dir = std::env::temp_dir().join("sparoa_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weights.bin");
        let vals: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let ws = WeightStore::load(&path).unwrap();
        assert_eq!(ws.len(), 6);
        let op = Op {
            id: 1,
            name: "t".into(),
            kind: crate::graph::OpKind::Linear,
            class: crate::graph::OpClass::MatMul,
            inputs: vec![0],
            exec_in_shapes: vec![vec![1, 2]],
            exec_out_shape: vec![1, 3],
            paper_out_shape: vec![1, 3],
            flops_exec: 0.0,
            flops_paper: 0.0,
            bytes_in_paper: 0.0,
            bytes_out_paper: 0.0,
            params_bytes_paper: 0.0,
            sparsity_in: 0.0,
            sparsity_out: 0.0,
            weights: vec![
                crate::graph::WeightSlice { offset: 0, numel: 4, shape: vec![2, 2] },
                crate::graph::WeightSlice { offset: 4, numel: 2, shape: vec![2] },
            ],
            artifact: None,
        };
        let ps = ws.op_params(&op).unwrap();
        assert_eq!(ps[0].data, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ps[1].data, vec![4.0, 5.0]);
    }
}
