//! Operator profiler: the sparsity x computational-intensity quadrant
//! analysis of paper §2 / Fig. 2, plus latency-breakdown summaries used by
//! Fig. 7.

use crate::api::InferenceReport;
use crate::graph::ModelGraph;

/// Fig. 2 quadrants (thresholds from the paper's discussion:
/// sparsity 0.4, intensity 1e8 FLOPs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Quadrant {
    /// low sparsity, high intensity — "dense heavy": GPU territory
    DenseHeavy,
    /// high sparsity, high intensity — the counter-intuitive quadrant II
    SparseHeavy,
    /// low sparsity, low intensity — memory-bound (BatchNorm et al.)
    DenseLight,
    /// high sparsity, low intensity — CPU territory
    SparseLight,
}

pub const SPARSITY_CUT: f64 = 0.4;
/// Intensity cut separating "light" from "heavy" ops.  The paper's Fig. 2
/// draws it at 1e8 FLOPs on ImageNet-pretrained weights; with synthetic
/// weights only exact-zero (ReLU) sparsity survives, which shifts the
/// populated region — 1e6 puts the boundary at the same place in our
/// measured distribution (all four quadrants occupied, QII thin).
pub const INTENSITY_CUT_FLOPS: f64 = 1e6;

#[derive(Debug, Clone)]
pub struct OpProfile {
    pub id: usize,
    pub name: String,
    pub kind: String,
    pub sparsity: f64,
    pub flops: f64,
    pub quadrant: Quadrant,
}

/// Profile every schedulable op of a model (Fig. 2 scatter data).
pub fn quadrant_profile(graph: &ModelGraph) -> Vec<OpProfile> {
    graph
        .schedulable_ops()
        .map(|op| {
            let sparse = op.sparsity_in > SPARSITY_CUT;
            let heavy = op.flops_paper > INTENSITY_CUT_FLOPS;
            let quadrant = match (sparse, heavy) {
                (false, true) => Quadrant::DenseHeavy,
                (true, true) => Quadrant::SparseHeavy,
                (false, false) => Quadrant::DenseLight,
                (true, false) => Quadrant::SparseLight,
            };
            OpProfile {
                id: op.id,
                name: op.name.clone(),
                kind: format!("{:?}", op.kind),
                sparsity: op.sparsity_in,
                flops: op.flops_paper,
                quadrant,
            }
        })
        .collect()
}

/// Counts per quadrant.
pub fn quadrant_counts(profiles: &[OpProfile]) -> [(Quadrant, usize); 4] {
    let mut counts = [
        (Quadrant::DenseHeavy, 0),
        (Quadrant::SparseHeavy, 0),
        (Quadrant::DenseLight, 0),
        (Quadrant::SparseLight, 0),
    ];
    for p in profiles {
        for c in counts.iter_mut() {
            if c.0 == p.quadrant {
                c.1 += 1;
            }
        }
    }
    counts
}

/// Latency breakdown of a simulation (Fig. 7 bars).
#[derive(Debug, Clone)]
pub struct Breakdown {
    pub compute_us: f64,
    pub transfer_us: f64,
    pub launch_us: f64,
    pub other_us: f64,
    pub makespan_us: f64,
}

pub fn breakdown(report: &InferenceReport) -> Breakdown {
    let busy = report.cpu_busy_us + report.gpu_busy_us;
    let compute = (busy - report.launch_us).max(0.0);
    let other = (report.makespan_us
        - (compute + report.transfer_us + report.launch_us))
        .max(0.0)
        + report.aggregation_us;
    Breakdown {
        compute_us: compute,
        transfer_us: report.transfer_us,
        launch_us: report.launch_us,
        other_us: other,
        makespan_us: report.makespan_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ModelZoo;

    #[test]
    fn mobilenet_occupies_all_four_quadrants() {
        // The paper's Fig. 2 headline: sparsity and intensity are
        // orthogonal — MobileNetV3-Small has ops in every quadrant.
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let g = zoo.get("mobilenet_v3_small").unwrap();
        let profiles = quadrant_profile(g);
        let counts = quadrant_counts(&profiles);
        for (q, n) in counts {
            assert!(n > 0, "quadrant {q:?} is empty");
        }
    }

    #[test]
    fn breakdown_sums_sensibly() {
        let r = InferenceReport {
            makespan_us: 100.0,
            cpu_busy_us: 30.0,
            gpu_busy_us: 50.0,
            transfer_us: 10.0,
            launch_us: 20.0,
            aggregation_us: 0.0,
            ..Default::default()
        };
        let b = breakdown(&r);
        assert!((b.compute_us - 60.0).abs() < 1e-9);
        assert!((b.transfer_us - 10.0).abs() < 1e-9);
        assert!(b.other_us >= 0.0);
    }
}
