//! The SAC-based operator scheduler (paper §4.2, Alg. 1) — SparOA's full
//! learning-based policy.
//!
//! Trains the `rl::Sac` agent on the scheduling MDP for a model/device
//! pair, then extracts the deterministic (greedy) schedule.  Exposes the
//! convergence trace for the Fig. 10 reproduction.

use crate::rl::env::SchedulingEnv;
use crate::rl::replay::Transition;
use crate::rl::sac::{Sac, SacConfig};
use crate::scheduler::{Schedule, ScheduleCtx, Scheduler};

#[derive(Debug, Clone)]
pub struct SacSchedulerConfig {
    pub episodes: usize,
    /// gradient steps per episode (Alg. 1 line 23).
    pub grad_steps: usize,
    /// hardware-dynamics noise during training (robustness driver).
    pub noise: f64,
    pub sac: SacConfig,
    /// stop early when the eval makespan hasn't improved for this many
    /// episodes.
    pub patience: usize,
}

impl Default for SacSchedulerConfig {
    fn default() -> Self {
        SacSchedulerConfig {
            episodes: 60,
            grad_steps: 24,
            noise: 0.03,
            sac: SacConfig::default(),
            patience: 20,
        }
    }
}

/// Convergence trace entry (episode, eval makespan us, wall-clock s).
#[derive(Debug, Clone)]
pub struct ConvergencePoint {
    pub episode: usize,
    pub makespan_us: f64,
    pub wall_s: f64,
}

pub struct SacScheduler {
    pub cfg: SacSchedulerConfig,
    pub trace: Vec<ConvergencePoint>,
    pub converged_after_s: f64,
    agent: Option<Sac>,
}

impl SacScheduler {
    pub fn new(cfg: SacSchedulerConfig) -> Self {
        SacScheduler { cfg, trace: Vec::new(), converged_after_s: 0.0,
                       agent: None }
    }

    /// Deterministic rollout of the current policy; returns (xi, makespan).
    fn eval(agent: &Sac, env: &mut SchedulingEnv) -> (Vec<f64>, f64) {
        env.reset(999);
        while !env.done() {
            let s = env.observe();
            let a = agent.act_greedy(&s);
            env.step(a);
        }
        (env.xi.clone(), env.makespan_us())
    }

    /// Feed a fixed schedule through the environment as demonstration
    /// transitions (greedy/DP plans warm-start the critic — standard
    /// offline seeding, and what lets SAC start at the non-RL baselines'
    /// level before exploring beyond them).
    fn seed_demonstration(
        agent: &mut Sac,
        env: &mut SchedulingEnv,
        xi: &[f64],
        seeds: std::ops::Range<u64>,
    ) {
        for seed in seeds {
            env.reset(seed * 31 + 7);
            while !env.done() {
                let s = env.observe();
                let a = xi[env.cursor_op()];
                let (r, done) = env.step(a);
                let s2 = if done {
                    vec![0.0; crate::rl::env::STATE_DIM]
                } else {
                    env.observe().to_vec()
                };
                agent.remember(Transition {
                    state: s.to_vec(),
                    action: a,
                    reward: r,
                    next_state: s2,
                    done,
                });
            }
        }
    }

    /// Train on the ctx's graph/device; fills the convergence trace.
    pub fn train(&mut self, ctx: &ScheduleCtx) -> Schedule {
        let t0 = std::time::Instant::now();
        let mut agent = Sac::new(self.cfg.sac.clone());
        let mut env = SchedulingEnv::new(ctx.graph, ctx.device,
                                         self.cfg.noise, ctx.batch, 1);
        let mut best: Option<(f64, Vec<f64>)> = None;
        let mut since_improve = 0usize;
        self.trace.clear();

        // Demonstration seeding: greedy + DP plans, plus both pure plans.
        let greedy =
            crate::scheduler::greedy::GreedyScheduler.schedule(ctx);
        let dp = crate::scheduler::dp::DpScheduler { ensemble: 4 }
            .schedule(ctx);
        for plan in [&greedy.xi, &dp.xi] {
            Self::seed_demonstration(&mut agent, &mut env, plan, 0..3);
        }
        for uniform in [0.0, 1.0] {
            let xi = vec![uniform; ctx.graph.ops.len()];
            Self::seed_demonstration(&mut agent, &mut env, &xi, 0..2);
        }
        // Track the best demonstration as the floor.
        for plan in [&greedy, &dp] {
            let m = env.rollout(&plan.xi, 999);
            if best.as_ref().map(|(b, _)| m < *b).unwrap_or(true) {
                best = Some((m, plan.xi.clone()));
            }
        }
        // Convergence clock includes the seeding phase even when no
        // later episode improves on the demonstration floor.
        self.converged_after_s = t0.elapsed().as_secs_f64();

        for ep in 0..self.cfg.episodes {
            env.reset(ep as u64 + 1);
            while !env.done() {
                let s = env.observe();
                let a = agent.act(&s);
                let (r, done) = env.step(a);
                let s2 = if done {
                    vec![0.0; crate::rl::env::STATE_DIM]
                } else {
                    env.observe().to_vec()
                };
                agent.remember(Transition {
                    state: s.to_vec(),
                    action: a,
                    reward: r,
                    next_state: s2,
                    done,
                });
            }
            for _ in 0..self.cfg.grad_steps {
                agent.update();
            }
            let (xi, makespan) = Self::eval(&agent, &mut env);
            self.trace.push(ConvergencePoint {
                episode: ep,
                makespan_us: makespan,
                wall_s: t0.elapsed().as_secs_f64(),
            });
            let improved = best
                .as_ref()
                .map(|(m, _)| makespan < *m * 0.999)
                .unwrap_or(true);
            if improved {
                best = Some((makespan, xi));
                since_improve = 0;
                self.converged_after_s = t0.elapsed().as_secs_f64();
            } else {
                since_improve += 1;
                if since_improve >= self.cfg.patience {
                    break;
                }
            }
        }
        let (_, xi) = best.unwrap();
        self.agent = Some(agent);
        let mut xi = xi;
        // Data-movement ops follow their producer.
        for op in &ctx.graph.ops {
            if !op.class.schedulable() {
                xi[op.id] = op.inputs.first().map(|&i| xi[i]).unwrap_or(1.0);
            }
        }
        Schedule { xi, policy: "sac".into() }
    }

    /// Access the trained agent (e.g. for online re-planning).
    pub fn agent(&self) -> Option<&Sac> {
        self.agent.as_ref()
    }
}

impl Scheduler for SacScheduler {
    fn name(&self) -> &str {
        "sac"
    }
    fn schedule(&mut self, ctx: &ScheduleCtx) -> Schedule {
        self.train(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::engine::sim::{simulate, SimOptions};
    use crate::graph::ModelZoo;

    #[test]
    fn sac_beats_single_device_plans() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let reg = DeviceRegistry::load(
            &crate::repo_root().join("config/devices.json")).unwrap();
        let g = zoo.get("mobilenet_v2").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let mut s = SacScheduler::new(SacSchedulerConfig {
            episodes: 25,
            grad_steps: 12,
            ..Default::default()
        });
        let ctx = ScheduleCtx { graph: g, device: dev, thresholds: None,
                                batch: 1 };
        let plan = s.schedule(&ctx);
        assert!(!s.trace.is_empty());
        let opts = SimOptions::default();
        let sac = simulate(g, dev, &plan, &opts);
        let (cpu, gpu) = crate::bench_support::uniform_baselines(g, dev);
        assert!(sac.makespan_us < cpu);
        assert!(sac.makespan_us <= gpu * 1.02,
                "sac {} vs gpu {gpu}", sac.makespan_us);
    }
}
