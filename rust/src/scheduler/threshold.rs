//! Static threshold scheduling — "SparOA w/o RL" (Fig. 7) and the
//! +Predictor ablation stage (Fig. 9).
//!
//! Uses the threshold predictor's per-op (s*, c*): an op goes to the CPU
//! when its sparsity exceeds s* while its normalized intensity stays below
//! c* (high-sparsity/low-intensity quadrant); everything else goes to the
//! GPU.  The plan is fixed up front — no adaptation to hardware state —
//! and the engine runs it with synchronous (non-overlapped) transfers,
//! which is what Fig. 7's breakdown compares against.

use crate::scheduler::{Schedule, ScheduleCtx, Scheduler};

/// Fallback fixed thresholds when no predictor output is available
/// (the "hand-designed rule" strawman from paper §3).
pub const FIXED_SPARSITY_THRESHOLD: f64 = 0.5;
pub const FIXED_INTENSITY_THRESHOLD: f64 = 0.55;

pub struct ThresholdScheduler;

impl Scheduler for ThresholdScheduler {
    fn name(&self) -> &str {
        "static-threshold"
    }

    fn schedule(&mut self, ctx: &ScheduleCtx) -> Schedule {
        let g = ctx.graph;
        let mut xi = vec![1.0; g.ops.len()];
        for op in &g.ops {
            if !op.class.schedulable() {
                xi[op.id] =
                    op.inputs.first().map(|&i| xi[i]).unwrap_or(1.0);
                continue;
            }
            let (s_thr, c_thr) = ctx
                .thresholds
                .map(|t| t[op.id])
                .unwrap_or((FIXED_SPARSITY_THRESHOLD,
                            FIXED_INTENSITY_THRESHOLD));
            let intensity = {
                let lf = op.flops_paper.max(1.0).log10();
                ((lf - 3.0) / 9.0).clamp(0.0, 1.0)
            };
            let cpu_friendly =
                op.sparsity_in > s_thr && intensity < c_thr;
            xi[op.id] = if cpu_friendly { 0.0 } else { 1.0 };
        }
        Schedule { xi, policy: "static-threshold".into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    #[test]
    fn threshold_splits_work_across_devices() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let reg = DeviceRegistry::load(
            &crate::repo_root().join("config/devices.json")).unwrap();
        let g = zoo.get("mobilenet_v3_small").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let mut s = ThresholdScheduler;
        let plan = s.schedule(&ScheduleCtx {
            graph: g, device: dev, thresholds: None, batch: 1,
        });
        let share = plan.gpu_share(g);
        assert!(share > 0.2 && share < 1.0,
                "expected a mixed plan, gpu share {share}");
    }
}
