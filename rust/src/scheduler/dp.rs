//! Dynamic-programming scheduling baseline ("SparOA with DP", Fig. 6/10).
//!
//! Exact DP over the op sequence with the *previous placement* as state:
//! `cost[i][d] = min over d' of cost[i-1][d'] + switch(d', d) + lat(i, d)`.
//! This is optimal for a chain under a *static* cost model — which is
//! precisely its weakness (paper §6.7): it plans against nominal latencies
//! and cannot react to memory pressure or contention, so SAC beats it at
//! runtime even though DP searches exhaustively (and takes far longer on
//! big graphs; we reproduce the cost by sweeping a latency-noise ensemble).
//!
//! Implementation: one [`CostTable`] is built per `schedule()` call and
//! shared by every ensemble member (the DP recurrences are pure table
//! lookups), each candidate plan is scored through the allocation-free
//! `simulate_into` scratch path, and the winner is polished by a
//! single-op flip local search over the incremental evaluator
//! ([`crate::engine::costs::refine_flips`]) — the chain-DP ignores
//! queueing/contention, so cheap exact-makespan flips reliably shave the
//! residual.

use crate::device::Proc;
use crate::engine::costs::{refine_flips, CostTable, SimScratch};
use crate::engine::sim::SimOptions;
use crate::scheduler::{Schedule, ScheduleCtx, Scheduler};

pub struct DpScheduler {
    /// Ensemble size: DP re-plans over this many jittered cost tables and
    /// keeps the best — reproducing the paper's "exhaustive search" cost
    /// profile (39-415 s at their scale).
    pub ensemble: usize,
}

impl Default for DpScheduler {
    fn default() -> Self {
        DpScheduler { ensemble: 24 }
    }
}

impl Scheduler for DpScheduler {
    fn name(&self) -> &str {
        "dp"
    }

    fn schedule(&mut self, ctx: &ScheduleCtx) -> Schedule {
        let opts = SimOptions {
            batch: ctx.batch,
            record_timings: false,
            ..Default::default()
        };
        let table = CostTable::build(ctx.graph, ctx.device, &opts);
        let mut scratch = SimScratch::new();
        let mut best: Option<(f64, Schedule)> = None;
        for e in 0..self.ensemble.max(1) {
            let plan = self.plan_once(ctx, &table, e as u64);
            table.simulate_into(&plan, &mut scratch);
            let m = scratch.report.makespan_us;
            if best.as_ref().map(|(b, _)| m < *b).unwrap_or(true) {
                best = Some((m, plan));
            }
        }
        let (m, mut plan) = best.unwrap();
        let refined = refine_flips(&table, &mut plan, 2);
        debug_assert!(refined <= m + 1e-9,
                      "refinement worsened dp: {refined} vs {m}");
        plan
    }
}

impl DpScheduler {
    fn plan_once(
        &self,
        ctx: &ScheduleCtx,
        table: &CostTable,
        seed: u64,
    ) -> Schedule {
        use crate::util::rng::Rng;
        let g = ctx.graph;
        let mut rng = Rng::new(seed * 7919 + 13);
        // Jitter factor per (op, proc): models the nominal-vs-actual gap
        // the static plan cannot see (zero jitter for ensemble member 0).
        let amp = if seed == 0 { 0.0 } else { 0.06 };

        // Collect the schedulable chain.
        let chain: Vec<&crate::graph::Op> = g.schedulable_ops().collect();
        let n = chain.len();
        if n == 0 {
            return Schedule::uniform(g, 1.0, "dp");
        }
        let lat = |op_id: usize, p: Proc, rng: &mut Rng| -> f64 {
            table.lat(op_id, p) * (1.0 + amp * rng.normal())
        };

        // DP tables.
        let mut cost = vec![[0.0f64; 2]; n];
        let mut back = vec![[0usize; 2]; n];
        cost[0] = [lat(chain[0].id, Proc::Cpu, &mut rng),
                   lat(chain[0].id, Proc::Gpu, &mut rng)];
        for i in 1..n {
            let lc = lat(chain[i].id, Proc::Cpu, &mut rng);
            let lg = lat(chain[i].id, Proc::Gpu, &mut rng);
            let x = table.xfer_out(chain[i - 1].id);
            for (d, l) in [(0usize, lc), (1usize, lg)] {
                let stay = cost[i - 1][d] + l;
                let switch = cost[i - 1][1 - d] + x + l;
                if stay <= switch {
                    cost[i][d] = stay;
                    back[i][d] = d;
                } else {
                    cost[i][d] = switch;
                    back[i][d] = 1 - d;
                }
            }
        }
        // Trace back.
        let mut d = if cost[n - 1][0] <= cost[n - 1][1] { 0 } else { 1 };
        let mut devs = vec![0usize; n];
        for i in (0..n).rev() {
            devs[i] = d;
            d = back[i][d];
        }
        let mut xi = vec![0.0; g.ops.len()];
        for (k, op) in chain.iter().enumerate() {
            xi[op.id] = devs[k] as f64;
        }
        // Data-movement ops follow their producers.
        for op in &g.ops {
            if !op.class.schedulable() {
                xi[op.id] = op.inputs.first().map(|&i| xi[i]).unwrap_or(1.0);
            }
        }
        Schedule { xi, policy: "dp".into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::engine::sim::{simulate, SimOptions};
    use crate::graph::ModelZoo;

    #[test]
    fn dp_not_worse_than_single_device_under_static_costs() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let reg = DeviceRegistry::load(
            &crate::repo_root().join("config/devices.json")).unwrap();
        for model in ["resnet18", "vit_b16"] {
            let g = zoo.get(model).unwrap();
            let dev = reg.get("agx_orin").unwrap();
            let mut dp = DpScheduler { ensemble: 1 };
            let plan = dp.schedule(&ScheduleCtx {
                graph: g, device: dev, thresholds: None, batch: 1,
            });
            let opts = SimOptions::default();
            let r = simulate(g, dev, &plan, &opts);
            let (cpu, gpu) = crate::bench_support::uniform_baselines(g, dev);
            assert!(r.makespan_us <= cpu.min(gpu) * 1.05,
                "{model}: dp {} cpu {cpu} gpu {gpu}", r.makespan_us);
        }
    }

    #[test]
    fn dp_runs_and_refines_on_synthetic_graphs() {
        let g = crate::graph::ModelGraph::synthetic("dp_syn", 6, 2.0, 0.4);
        let dev = crate::bench_support::device_profile("orin_nano");
        let mut dp = DpScheduler { ensemble: 3 };
        let plan = dp.schedule(&ScheduleCtx {
            graph: &g, device: &dev, thresholds: None, batch: 2,
        });
        assert_eq!(plan.xi.len(), g.ops.len());
        let opts = SimOptions { batch: 2, ..Default::default() };
        let r = simulate(&g, &dev, &plan, &opts);
        let cpu = simulate(&g, &dev, &Schedule::uniform(&g, 0.0, "c"),
                           &opts);
        let gpu = simulate(&g, &dev, &Schedule::uniform(&g, 1.0, "g"),
                           &opts);
        assert!(r.makespan_us
                <= cpu.makespan_us.min(gpu.makespan_us) * 1.05,
                "dp {} cpu {} gpu {}", r.makespan_us, cpu.makespan_us,
                gpu.makespan_us);
    }
}
