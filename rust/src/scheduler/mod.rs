//! Operator scheduling: the shared [`Schedule`] representation, the
//! [`Scheduler`] trait every policy implements, and the concrete SparOA
//! policies (static-threshold, greedy, dynamic-programming, SAC).
//!
//! The paper's action space (§4.1) is a continuous ratio ξ ∈ [0,1] per
//! operator: 0 = CPU, 1 = GPU, interior = co-execute on both with
//! weighted-average aggregation (Eq. 14).

pub mod dp;
pub mod greedy;
pub mod sac_sched;
pub mod threshold;

use crate::device::{DeviceModel, Proc};
use crate::graph::ModelGraph;

/// Per-op placement ratio ξ (GPU share).
#[derive(Debug, Clone)]
pub struct Schedule {
    /// xi[i] for op i; data-movement ops inherit their producer's device.
    pub xi: Vec<f64>,
    /// Human-readable provenance (policy name) for reports.
    pub policy: String,
}

/// Interior band that triggers true co-execution (paper Alg. 1 line 10).
/// Kept narrow so co-running is a deliberate policy choice rather than the
/// default of an untrained agent (ξ starts near 0.5).
pub const CO_RUN_LO: f64 = 0.45;
pub const CO_RUN_HI: f64 = 0.55;

/// Execution mode an ξ value implies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    Single(Proc),
    /// Co-execute on both; payload is the GPU aggregation weight ξ.
    CoRun(f64),
}

pub fn mode_of(xi: f64) -> Mode {
    if xi <= CO_RUN_LO {
        Mode::Single(Proc::Cpu)
    } else if xi >= CO_RUN_HI {
        Mode::Single(Proc::Gpu)
    } else {
        Mode::CoRun(xi)
    }
}

/// Primary device of an ξ (for load-share accounting, Fig. 6).
pub fn primary_proc(xi: f64) -> Proc {
    if xi >= 0.5 {
        Proc::Gpu
    } else {
        Proc::Cpu
    }
}

impl Schedule {
    pub fn uniform(graph: &ModelGraph, xi: f64, policy: &str) -> Self {
        Schedule { xi: vec![xi; graph.ops.len()], policy: policy.into() }
    }

    /// Fraction of schedulable ops whose primary device is the GPU.
    pub fn gpu_share(&self, graph: &ModelGraph) -> f64 {
        let mut total = 0usize;
        let mut gpu = 0usize;
        for op in graph.schedulable_ops() {
            total += 1;
            if primary_proc(self.xi[op.id]) == Proc::Gpu {
                gpu += 1;
            }
        }
        if total == 0 {
            0.0
        } else {
            gpu as f64 / total as f64
        }
    }

    /// Project the whole plan onto one device, keeping the entry count.
    /// The multi-tenant serving tier uses this to derive a CPU-fallback
    /// variant of a model's hybrid schedule (the cluster scheduler's
    /// "run this batch on the other processor" option).
    pub fn project(&self, proc: Proc, label: &str) -> Schedule {
        let xi = match proc {
            Proc::Cpu => 0.0,
            Proc::Gpu => 1.0,
        };
        Schedule { xi: vec![xi; self.xi.len()], policy: label.into() }
    }

    /// Number of adjacent-op device switches (O_switch proxy).
    pub fn switch_count(&self, graph: &ModelGraph) -> usize {
        let mut last: Option<Proc> = None;
        let mut n = 0;
        for op in graph.schedulable_ops() {
            let p = primary_proc(self.xi[op.id]);
            if let Some(l) = last {
                if l != p {
                    n += 1;
                }
            }
            last = Some(p);
        }
        n
    }
}

/// Context handed to scheduling policies.
pub struct ScheduleCtx<'a> {
    pub graph: &'a ModelGraph,
    pub device: &'a DeviceModel,
    /// Per-op predicted thresholds (from the threshold predictor); index by
    /// op id.  None for policies that do not use the predictor.
    pub thresholds: Option<&'a [(f64, f64)]>,
    /// Batch size the schedule is computed for.
    pub batch: usize,
}

/// A scheduling policy.
pub trait Scheduler {
    fn name(&self) -> &str;
    fn schedule(&mut self, ctx: &ScheduleCtx) -> Schedule;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_bands() {
        assert_eq!(mode_of(0.0), Mode::Single(Proc::Cpu));
        assert_eq!(mode_of(0.44), Mode::Single(Proc::Cpu));
        assert_eq!(mode_of(0.5), Mode::CoRun(0.5));
        assert_eq!(mode_of(0.56), Mode::Single(Proc::Gpu));
        assert_eq!(mode_of(1.0), Mode::Single(Proc::Gpu));
    }

    #[test]
    fn primary_rounds() {
        assert_eq!(primary_proc(0.49), Proc::Cpu);
        assert_eq!(primary_proc(0.51), Proc::Gpu);
    }

    #[test]
    fn project_pins_every_op() {
        let s = Schedule { xi: vec![0.3, 0.7, 0.5], policy: "mix".into() };
        let cpu = s.project(Proc::Cpu, "cpu-fallback");
        assert_eq!(cpu.xi, vec![0.0; 3]);
        assert_eq!(cpu.policy, "cpu-fallback");
        let gpu = s.project(Proc::Gpu, "gpu-pin");
        assert_eq!(gpu.xi, vec![1.0; 3]);
    }
}
