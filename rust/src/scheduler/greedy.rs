//! Greedy scheduling baseline ("SparOA with Greedy", Fig. 6/10).
//!
//! Walks the ops in topological order and assigns each to whichever
//! processor minimizes that op's *immediate* completion time (compute +
//! any cross-device input transfer), with no lookahead and no awareness of
//! dynamic hardware state.  Converges almost instantly (paper: 0.04-0.24s)
//! but leaves 20%+ latency on the table versus SAC.
//!
//! The walk runs entirely on a precomputed [`CostTable`]; search loops
//! that evaluate many schedules on one (graph, device, batch) should
//! build the table once and call
//! [`GreedyScheduler::schedule_with_table`] — rebuilding the table
//! dominates the cost of the walk itself.

use crate::device::Proc;
use crate::engine::costs::CostTable;
use crate::engine::sim::SimOptions;
use crate::scheduler::{Schedule, ScheduleCtx, Scheduler};

pub struct GreedyScheduler;

impl GreedyScheduler {
    /// Table-driven greedy walk: pure lookups, no roofline math.
    pub fn schedule_with_table(table: &CostTable) -> Schedule {
        let n = table.len();
        let mut xi = vec![0.0; n];
        let mut placed = vec![Proc::Cpu; n];
        let mut cpu_free = 0.0f64;
        let mut gpu_free = 0.0f64;
        let mut finish = vec![0.0f64; n];

        for id in 0..n {
            if !table.schedulable(id) {
                let p = table
                    .inputs(id)
                    .first()
                    .map(|&i| placed[i])
                    .unwrap_or(Proc::Cpu);
                placed[id] = p;
                xi[id] = if p == Proc::Gpu { 1.0 } else { 0.0 };
                finish[id] = table
                    .inputs(id)
                    .iter()
                    .map(|&i| finish[i])
                    .fold(0.0, f64::max);
                continue;
            }
            let mut best = (f64::INFINITY, Proc::Cpu);
            for proc in [Proc::Cpu, Proc::Gpu] {
                let lat = table.lat(id, proc);
                let mut ready: f64 = 0.0;
                for &i in table.inputs(id) {
                    let mut t = finish[i];
                    if placed[i] != proc && table.has_out_bytes(i) {
                        t += table.xfer_out(i);
                    }
                    ready = ready.max(t);
                }
                let free = match proc {
                    Proc::Cpu => cpu_free,
                    Proc::Gpu => gpu_free,
                };
                let end = ready.max(free) + lat;
                if end < best.0 {
                    best = (end, proc);
                }
            }
            let (end, proc) = best;
            match proc {
                Proc::Cpu => cpu_free = end,
                Proc::Gpu => gpu_free = end,
            }
            placed[id] = proc;
            finish[id] = end;
            xi[id] = if proc == Proc::Gpu { 1.0 } else { 0.0 };
        }
        Schedule { xi, policy: "greedy".into() }
    }
}

impl Scheduler for GreedyScheduler {
    fn name(&self) -> &str {
        "greedy"
    }

    fn schedule(&mut self, ctx: &ScheduleCtx) -> Schedule {
        let opts = SimOptions {
            batch: ctx.batch,
            record_timings: false,
            ..Default::default()
        };
        let table = CostTable::build(ctx.graph, ctx.device, &opts);
        Self::schedule_with_table(&table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    #[test]
    fn greedy_beats_both_single_device_plans() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let reg = DeviceRegistry::load(
            &crate::repo_root().join("config/devices.json")).unwrap();
        let g = zoo.get("mobilenet_v3_small").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let mut sched = GreedyScheduler;
        let plan = sched.schedule(&ScheduleCtx {
            graph: g, device: dev, thresholds: None, batch: 1,
        });
        let opts = crate::engine::sim::SimOptions::default();
        let greedy = crate::engine::sim::simulate(g, dev, &plan, &opts);
        let (cpu, gpu) = crate::bench_support::uniform_baselines(g, dev);
        assert!(greedy.makespan_us <= cpu * 1.001);
        assert!(greedy.makespan_us <= gpu * 1.001);
    }

    #[test]
    fn table_walk_matches_per_call_build_on_synthetic() {
        // `schedule()` and `schedule_with_table()` over the same table
        // inputs must emit the same plan — the fast path is a pure
        // refactor of the walk, not a different policy.
        let g = crate::graph::ModelGraph::synthetic("greedy_syn", 6, 2.0,
                                                    0.5);
        let dev = crate::bench_support::device_profile("agx_orin");
        let ctx = ScheduleCtx {
            graph: &g, device: &dev, thresholds: None, batch: 4,
        };
        let via_ctx = GreedyScheduler.schedule(&ctx);
        let opts = SimOptions {
            batch: 4, record_timings: false, ..Default::default()
        };
        let table = CostTable::build(&g, &dev, &opts);
        let via_table = GreedyScheduler::schedule_with_table(&table);
        assert_eq!(via_ctx.xi, via_table.xi);
        assert!(via_ctx.xi.iter().all(|x| *x == 0.0 || *x == 1.0));
    }
}
