//! Greedy scheduling baseline ("SparOA with Greedy", Fig. 6/10).
//!
//! Walks the ops in topological order and assigns each to whichever
//! processor minimizes that op's *immediate* completion time (compute +
//! any cross-device input transfer), with no lookahead and no awareness of
//! dynamic hardware state.  Converges almost instantly (paper: 0.04-0.24s)
//! but leaves 20%+ latency on the table versus SAC.

use crate::device::Proc;
use crate::scheduler::{Schedule, ScheduleCtx, Scheduler};

pub struct GreedyScheduler;

impl Scheduler for GreedyScheduler {
    fn name(&self) -> &str {
        "greedy"
    }

    fn schedule(&mut self, ctx: &ScheduleCtx) -> Schedule {
        let g = ctx.graph;
        let dev = ctx.device;
        let batch = ctx.batch.max(1) as f64;
        let mut xi = vec![0.0; g.ops.len()];
        let mut placed = vec![Proc::Cpu; g.ops.len()];
        let mut cpu_free = 0.0f64;
        let mut gpu_free = 0.0f64;
        let mut finish = vec![0.0f64; g.ops.len()];

        for op in &g.ops {
            if !op.class.schedulable() {
                let p = op.inputs.first().map(|&i| placed[i])
                    .unwrap_or(Proc::Cpu);
                placed[op.id] = p;
                xi[op.id] = if p == Proc::Gpu { 1.0 } else { 0.0 };
                finish[op.id] = op.inputs.iter().map(|&i| finish[i])
                    .fold(0.0, f64::max);
                continue;
            }
            let flops = op.flops_paper * batch;
            let bytes = op.bytes_moved_paper() * batch;
            let opts = crate::engine::sim::SimOptions {
                batch: ctx.batch, ..Default::default()
            };
            let mut best = (f64::INFINITY, Proc::Cpu, 0.0);
            for proc in [Proc::Cpu, Proc::Gpu] {
                let (lat, _) = crate::engine::sim::op_cost_us(
                    dev, proc, op.class, flops, bytes, op.sparsity_in,
                    &opts);
                let mut ready: f64 = 0.0;
                for &i in &op.inputs {
                    let mut t = finish[i];
                    if placed[i] != proc && g.ops[i].bytes_out_paper > 0.0 {
                        t += dev.transfer_us(
                            g.ops[i].bytes_out_paper * batch, true, true);
                    }
                    ready = ready.max(t);
                }
                let free = match proc {
                    Proc::Cpu => cpu_free,
                    Proc::Gpu => gpu_free,
                };
                let end = ready.max(free) + lat;
                if end < best.0 {
                    best = (end, proc, lat);
                }
            }
            let (end, proc, _) = best;
            match proc {
                Proc::Cpu => cpu_free = end,
                Proc::Gpu => gpu_free = end,
            }
            placed[op.id] = proc;
            finish[op.id] = end;
            xi[op.id] = if proc == Proc::Gpu { 1.0 } else { 0.0 };
        }
        Schedule { xi, policy: "greedy".into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    #[test]
    fn greedy_beats_both_single_device_plans() {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return;
        }
        let zoo = ModelZoo::load(&art).unwrap();
        let reg = DeviceRegistry::load(
            &crate::repo_root().join("config/devices.json")).unwrap();
        let g = zoo.get("mobilenet_v3_small").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let mut sched = GreedyScheduler;
        let plan = sched.schedule(&ScheduleCtx {
            graph: g, device: dev, thresholds: None, batch: 1,
        });
        let opts = crate::engine::sim::SimOptions::default();
        let greedy = crate::engine::sim::simulate(g, dev, &plan, &opts);
        let cpu = crate::engine::sim::simulate(
            g, dev, &Schedule::uniform(g, 0.0, "cpu"), &opts);
        let gpu = crate::engine::sim::simulate(
            g, dev, &Schedule::uniform(g, 1.0, "gpu"), &opts);
        assert!(greedy.makespan_us <= cpu.makespan_us * 1.001);
        assert!(greedy.makespan_us <= gpu.makespan_us * 1.001);
    }
}
