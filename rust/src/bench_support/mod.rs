//! Bench + test harness substrate (the vendored crate set has neither
//! criterion nor proptest):
//!
//! * [`bench`] — wall-clock micro-benchmark with warm-up, mean/p50/p95.
//! * [`Table`] — aligned console tables for the figure reproductions.
//! * [`prop`] — a small property-testing loop over seeded random inputs.

use crate::util::rng::Rng;
use crate::util::stats;

/// Load the model zoo + device registry for benches/examples; None (with a
/// message) when `make artifacts` hasn't run.
pub fn load_env() -> Option<(crate::graph::ModelZoo,
                             crate::device::DeviceRegistry)> {
    let art = crate::artifacts_dir();
    if !art.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return None;
    }
    Some((
        crate::graph::ModelZoo::load(&art).expect("loading model zoo"),
        crate::device::DeviceRegistry::load(
            &crate::repo_root().join("config/devices.json"))
            .expect("loading device registry"),
    ))
}

/// Load one device profile from the checked-in `config/devices.json` —
/// the always-on test/bench fixture (no artifacts required).
pub fn device_profile(id: &str) -> crate::device::DeviceModel {
    crate::device::DeviceRegistry::load(
        &crate::repo_root().join("config/devices.json"))
        .expect("loading config/devices.json")
        .get(id)
        .expect("unknown device id")
        .clone()
}

/// Makespans of the uniform CPU-only / GPU-only plans for one (graph,
/// device) pair under default engine options, as `(cpu_us, gpu_us)`.
/// Memoized process-wide: every scheduler's "not worse than a single
/// device" test needs the same pair, and re-simulating the baselines per
/// test was pure duplicated work (previously inlined in the dp, greedy
/// and sac test modules).
pub fn uniform_baselines(
    g: &crate::graph::ModelGraph,
    dev: &crate::device::DeviceModel,
) -> (f64, f64) {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    type Key = (String, String, usize, u64, u64);
    static CACHE: OnceLock<Mutex<HashMap<Key, (f64, f64)>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    // Op count + total FLOPs + summed sparsity disambiguate same-named
    // graphs (synthetic fixtures reuse names across tests with
    // different shapes, and sparsity changes makespans without changing
    // FLOPs).
    let sparsity_sum: f64 = g.ops.iter().map(|o| o.sparsity_in).sum();
    let key = (
        g.model.clone(),
        dev.id.clone(),
        g.ops.len(),
        g.total_flops_paper.to_bits(),
        sparsity_sum.to_bits(),
    );
    if let Some(&v) = cache.lock().unwrap().get(&key) {
        return v;
    }
    let opts = crate::engine::sim::SimOptions {
        record_timings: false,
        ..Default::default()
    };
    let cpu = crate::engine::sim::simulate(
        g, dev, &crate::scheduler::Schedule::uniform(g, 0.0, "cpu"), &opts);
    let gpu = crate::engine::sim::simulate(
        g, dev, &crate::scheduler::Schedule::uniform(g, 1.0, "gpu"), &opts);
    let v = (cpu.makespan_us, gpu.makespan_us);
    cache.lock().unwrap().insert(key, v);
    v
}

/// The five evaluation models in the paper's Table 2 order.
pub const MODELS: [&str; 5] = [
    "resnet18",
    "mobilenet_v3_small",
    "mobilenet_v2",
    "vit_b16",
    "swin_t",
];

pub const DEVICES: [&str; 2] = ["agx_orin", "orin_nano"];

/// Timing result of a [`bench`] run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>10.2} us/iter (p50 {:>10.2}, p95 {:>10.2}, n={})",
            self.name, self.mean_us, self.p50_us, self.p95_us, self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` warm-up calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        p50_us: stats::percentile(&samples, 50.0),
        p95_us: stats::percentile(&samples, 95.0),
    }
}

/// Aligned console table builder for figure/table reproductions.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }
    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(&format!("{:>width$}  ", c, width = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w + 2))
                .collect::<String>()
        );
        for r in &self.rows {
            line(r);
        }
    }
}

/// Committed-baseline plumbing shared by the perf-gated benches
/// (hotpath, fig_fleet): flat `name -> ns` JSON files at the repo root,
/// gated in CI on a fast/reference *ratio* (runner hardware cancels
/// out).  One copy of the refuse/compare/write logic so the two gates
/// cannot drift.
pub mod baseline {
    use crate::util::json::{self, Value};
    use std::path::Path;

    /// Parse the committed baseline and extract its `num_key/den_key`
    /// ratio.  `None` when the file is missing, unparsable, or lacks
    /// positive values for either key — i.e. an empty `{}` or a
    /// bootstrap placeholder.
    pub fn committed(path: &Path, num_key: &str, den_key: &str)
        -> Option<(Value, f64)>
    {
        let v = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| json::parse(&t).ok())?;
        let n = v.get(num_key).as_f64().filter(|&x| x > 0.0)?;
        let d = v.get(den_key).as_f64().filter(|&x| x > 0.0)?;
        Some((v, n / d))
    }

    /// Exit non-zero with the standard unusable-baseline message.  An
    /// empty baseline must FAIL the gate, not skip it: a committed `{}`
    /// once silently disarmed the hotpath gate.
    pub fn refuse(path: &Path, bench: &str, num_key: &str,
                  den_key: &str) -> ! {
        eprintln!(
            "{bench} ci gate: {} is missing, empty or a bootstrap \
             placeholder (no positive {num_key} / {den_key} lines) — \
             the gate refuses to pass without a baseline.  Regenerate \
             one with `cargo bench --bench {bench} -- --write-baseline` \
             and commit it.",
            path.display()
        );
        std::process::exit(1);
    }

    /// Compare this run's ratio against the committed one and exit
    /// non-zero on a regression beyond `budget`x.
    pub fn gate_ratio(bench: &str, what: &str, new_ratio: f64,
                      old_ratio: f64, budget: f64) {
        println!("\nci gate: {what} ratio {new_ratio:.4} vs committed \
                  {old_ratio:.4}");
        if new_ratio > budget * old_ratio {
            eprintln!(
                "{bench} regression: {what} ratio slowed {:.1}x \
                 (> {budget}x budget)",
                new_ratio / old_ratio
            );
            std::process::exit(1);
        }
    }

    /// Write a baseline file (`workload` + flat `name -> ns` lines).
    /// Refusing an empty map and failing loudly on write errors are
    /// part of the contract — see [`refuse`].
    pub fn write(path: &Path, workload: &str, lines: &[(String, f64)]) {
        if lines.is_empty() {
            eprintln!("refusing to write an empty benchmark map to {}",
                      path.display());
            std::process::exit(1);
        }
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"workload\": \"{workload}\",\n"));
        for (i, (k, v)) in lines.iter().enumerate() {
            let comma = if i + 1 < lines.len() { "," } else { "" };
            out.push_str(&format!("  \"{k}\": {v:.1}{comma}\n"));
        }
        out.push_str("}\n");
        match std::fs::write(path, out) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => {
                eprintln!("\ncould not write {}: {e}", path.display());
                std::process::exit(1);
            }
        }
    }
}

/// Property-testing loop: runs `prop` against `cases` random inputs drawn
/// by `gen`; on failure, reports the failing seed/case for reproduction.
pub mod prop {
    use super::Rng;

    pub fn check<T, G, P>(name: &str, cases: usize, seed: u64,
                          mut gen: G, mut prop: P)
    where
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> Result<(), String>,
        T: std::fmt::Debug,
    {
        let mut rng = Rng::new(seed);
        for case in 0..cases {
            let input = gen(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property `{name}` failed at case {case} (seed {seed}):\n\
                     input: {input:?}\n{msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_us >= 0.0 && r.p95_us >= r.p50_us * 0.5);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2222".into()]);
        t.print();
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn prop_reports_failure() {
        prop::check("fails", 10, 1, |r| r.below(100),
                    |&x| if x < 1000 { Err(format!("x={x}")) } else { Ok(()) });
    }

    #[test]
    fn prop_passes_good_property() {
        prop::check("u64-below", 200, 2, |r| r.below(7),
                    |&x| if x < 7 { Ok(()) } else { Err("oob".into()) });
    }

    #[test]
    fn uniform_baselines_memoize_and_match_direct_simulation() {
        let g = crate::graph::ModelGraph::synthetic("bs_base", 4, 3.0, 0.2);
        let dev = device_profile("agx_orin");
        let (cpu, gpu) = uniform_baselines(&g, &dev);
        let (cpu2, gpu2) = uniform_baselines(&g, &dev); // cached path
        assert_eq!(cpu, cpu2);
        assert_eq!(gpu, gpu2);
        let direct = crate::engine::sim::simulate(
            &g, &dev,
            &crate::scheduler::Schedule::uniform(&g, 1.0, "gpu"),
            &crate::engine::sim::SimOptions::default());
        assert_eq!(gpu, direct.makespan_us);
        // Heavy dense chain: the GPU plan wins.
        assert!(gpu < cpu);
    }
}
