//! Minimal scoped-thread parallelism (the vendored crate set has no
//! rayon): a `par_iter().map().collect()` stand-in for coarse-grained
//! candidate evaluation.

/// Apply `f` to every item on its own scoped thread and collect the
/// results in input order.  Each item pays one thread spawn, so this is
/// for coarse work — e.g. one whole-model simulation per item in the
/// Alg. 2 batch-size search — not per-op math.  Slices of length 0/1
/// run inline.
///
/// Panics propagate: a panicking worker poisons the whole map, exactly
/// like `rayon::par_iter` would.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    std::thread::scope(|scope| {
        let fref = &f;
        let handles: Vec<_> = items
            .iter()
            .map(|item| scope.spawn(move || fref(item)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..8).collect();
        let ys = par_map(&xs, |&x| x * x);
        assert_eq!(ys, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn short_slices_run_inline() {
        assert_eq!(par_map(&[] as &[u64], |&x| x), Vec::<u64>::new());
        assert_eq!(par_map(&[7u64], |&x| x + 1), vec![8]);
    }

    #[test]
    fn workers_see_shared_state() {
        let base = vec![10u64, 20, 30];
        let ys = par_map(&[0usize, 1, 2], |&i| base[i] + 1);
        assert_eq!(ys, vec![11, 21, 31]);
    }
}
