//! Small statistics helpers shared by metrics, benches and the server.

/// Percentile of a sample set (linear interpolation, p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Online exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    pub value: f64,
    alpha: f64,
    initialized: bool,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { value: 0.0, alpha, initialized: false }
    }
    pub fn update(&mut self, x: f64) -> f64 {
        if self.initialized {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        } else {
            self.value = x;
            self.initialized = true;
        }
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn ema_tracks() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        assert_eq!(e.update(0.0), 5.0);
    }
}
