//! Dependency-free substrates: JSON, RNG, scoped-thread parallelism,
//! timing/stats helpers.

pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
