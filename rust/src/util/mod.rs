//! Dependency-free substrates: JSON, RNG, timing/stats helpers.

pub mod json;
pub mod rng;
pub mod stats;
