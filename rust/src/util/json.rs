//! Minimal JSON parser/serializer substrate.
//!
//! The vendored crate set available to this workspace has no `serde`
//! facade, so the coordinator carries its own JSON implementation: a
//! recursive-descent parser producing a [`Value`] tree plus a compact
//! writer.  It supports the full JSON grammar we emit from the python
//! compile path (objects, arrays, f64 numbers, strings with escapes,
//! booleans, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Value::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index access; `Value::Null` if out of range.
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Required f64 field (panics with a readable message otherwise —
    /// topology/config files are build artifacts, so malformed input is a
    /// build bug, not a runtime condition).
    pub fn f64_of(&self, key: &str) -> f64 {
        self.get(key)
            .as_f64()
            .unwrap_or_else(|| panic!("missing numeric field `{key}`"))
    }
    pub fn str_of(&self, key: &str) -> &str {
        self.get(key)
            .as_str()
            .unwrap_or_else(|| panic!("missing string field `{key}`"))
    }
    pub fn vec_f64(&self) -> Vec<f64> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default()
    }
    pub fn vec_usize(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Value, String> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        // Fast path: scan to the closing quote; if no escape and no
        // control byte is seen, bulk-copy the slice (the dominant case in
        // topology files — §Perf: cut parse time ~5x).
        let start = self.i;
        let mut j = self.i;
        while j < self.b.len() {
            let c = self.b[j];
            if c == b'"' {
                let s = std::str::from_utf8(&self.b[start..j])
                    .map_err(|_| "invalid utf8 in string".to_string())?;
                self.i = j + 1;
                return Ok(s.to_string());
            }
            if c == b'\\' || c < 0x20 {
                break;
            }
            j += 1;
        }
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad utf8")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| "invalid utf8 in string".to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }
    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.is_finite() {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            } else {
                out.push_str("null");
            }
        }
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c").as_bool(), Some(true));
        assert_eq!(v.get("d"), &Value::Null);
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn nested_and_unicode() {
        let v = parse(r#"{"k": {"inner": ["é", "ü"]}}"#).unwrap();
        assert_eq!(v.get("k").get("inner").idx(0).as_str(), Some("é"));
        assert_eq!(v.get("k").get("inner").idx(1).as_str(), Some("ü"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn helpers() {
        let v = parse(r#"{"n": 3, "s": "hi", "arr": [1,2,3]}"#).unwrap();
        assert_eq!(v.f64_of("n"), 3.0);
        assert_eq!(v.str_of("s"), "hi");
        assert_eq!(v.get("arr").vec_usize(), vec![1, 2, 3]);
        assert_eq!(v.get("missing"), &Value::Null);
    }
}
