//! Deterministic RNG substrate (no `rand` crate in the vendored set).
//!
//! xoshiro256++ with splitmix64 seeding — fast, reproducible streams for
//! the SAC agent, workload generators and the property-test harness.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with rate lambda (mean 1/lambda) — request arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -u.ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        for &x in &xs {
            m += x;
        }
        m /= n as f64;
        for &x in &xs {
            v += (x - m) * (x - m);
        }
        v /= n as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.08, "var {v}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
