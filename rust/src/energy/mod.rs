//! Energy/power accounting (substitution for the paper's INA3221 on-board
//! power rails — DESIGN.md §2).
//!
//! Model: each processor draws `static + dyn * busy_fraction` watts; the
//! SoC (DRAM + carrier) adds a constant floor.  Energy per inference is the
//! integral over the simulated makespan.  This reproduces the *ordering*
//! of Fig. 11: co-execution draws more instantaneous power than any
//! single-processor baseline but finishes so much earlier that its
//! energy-per-inference is the lowest.

use crate::device::DeviceModel;

/// Accumulated busy time per processor over one inference.
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    /// total CPU busy time, us
    pub cpu_busy_us: f64,
    /// total GPU busy time, us
    pub gpu_busy_us: f64,
    /// DMA transfer time, us (drawn against SoC)
    pub xfer_us: f64,
    /// wall-clock makespan of the inference, us
    pub makespan_us: f64,
}

impl EnergyLedger {
    pub fn add_cpu(&mut self, us: f64) {
        self.cpu_busy_us += us;
    }
    pub fn add_gpu(&mut self, us: f64) {
        self.gpu_busy_us += us;
    }
    pub fn add_xfer(&mut self, us: f64) {
        self.xfer_us += us;
    }

    /// Mean power draw over the inference, watts.
    pub fn mean_power_w(&self, dev: &DeviceModel) -> f64 {
        self.mean_power_w_over(dev, self.makespan_us)
    }

    /// Mean power draw over an observation window of `horizon_us`
    /// microseconds, watts.  In the serving context a board sits idle
    /// between batches; static power (SoC + per-processor leakage) keeps
    /// accruing over the whole window while dynamic power only accrues
    /// over busy time.  `horizon_us` is clamped up to the ledger's own
    /// makespan so a too-short window can never report utilization > 1.
    pub fn mean_power_w_over(&self, dev: &DeviceModel,
                             horizon_us: f64) -> f64 {
        let h = horizon_us.max(self.makespan_us);
        if h <= 0.0 {
            return 0.0;
        }
        let cpu_util = (self.cpu_busy_us / h).min(1.0);
        let gpu_util = (self.gpu_busy_us / h).min(1.0);
        dev.soc_static_w
            + dev.cpu.power_static_w
            + dev.cpu.power_dyn_w * cpu_util
            + dev.gpu.power_static_w
            + dev.gpu.power_dyn_w * gpu_util
    }

    /// Energy per inference, millijoules.
    pub fn energy_mj(&self, dev: &DeviceModel) -> f64 {
        self.mean_power_w(dev) * self.makespan_us / 1e3
    }

    /// Energy over an observation window of `horizon_us` microseconds,
    /// millijoules — busy energy plus the static floor across idle gaps
    /// (the serving-tier accounting; see `sparoa::power`).
    pub fn energy_mj_over(&self, dev: &DeviceModel,
                          horizon_us: f64) -> f64 {
        let h = horizon_us.max(self.makespan_us);
        self.mean_power_w_over(dev, h) * h / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use std::path::Path;

    fn agx() -> DeviceModel {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        DeviceRegistry::load(&root.join("config/devices.json"))
            .unwrap()
            .get("agx_orin")
            .unwrap()
            .clone()
    }

    #[test]
    fn idle_power_is_static_floor() {
        let dev = agx();
        let mut l = EnergyLedger::default();
        l.makespan_us = 1000.0;
        let p = l.mean_power_w(&dev);
        assert!(
            (p - (dev.soc_static_w
                + dev.cpu.power_static_w
                + dev.gpu.power_static_w))
                .abs()
                < 1e-9
        );
    }

    #[test]
    fn hybrid_draws_more_power_but_less_energy() {
        let dev = agx();
        // GPU-only: 10ms makespan, GPU busy the whole time.
        let gpu_only = EnergyLedger {
            gpu_busy_us: 10_000.0,
            makespan_us: 10_000.0,
            ..Default::default()
        };
        // Hybrid: both busy, but finishes in 6ms.
        let hybrid = EnergyLedger {
            gpu_busy_us: 5_500.0,
            cpu_busy_us: 4_000.0,
            makespan_us: 6_000.0,
            ..Default::default()
        };
        assert!(hybrid.mean_power_w(&dev) > gpu_only.mean_power_w(&dev));
        assert!(hybrid.energy_mj(&dev) < gpu_only.energy_mj(&dev));
    }

    #[test]
    fn idle_gaps_accrue_static_power_over_a_longer_horizon() {
        // Regression: the dense-inference accessors spread dynamic power
        // over the makespan only; a serving window with idle gaps must
        // keep paying the static floor over the whole horizon while
        // dynamic energy stays pinned to busy time.
        let dev = agx();
        let l = EnergyLedger {
            gpu_busy_us: 1_000.0,
            makespan_us: 1_000.0,
            ..Default::default()
        };
        let horizon = 10_000.0;
        let statics =
            dev.soc_static_w + dev.cpu.power_static_w + dev.gpu.power_static_w;
        let expect_mj = statics * horizon / 1e3
            + dev.gpu.power_dyn_w * l.gpu_busy_us / 1e3;
        assert!((l.energy_mj_over(&dev, horizon) - expect_mj).abs() < 1e-9);
        // The idle tail costs energy: windowed > dense.
        assert!(l.energy_mj_over(&dev, horizon) > l.energy_mj(&dev));
        // But mean power drops as the busy fraction shrinks.
        assert!(l.mean_power_w_over(&dev, horizon) < l.mean_power_w(&dev));
        // Degenerate horizons fall back to the dense accounting.
        assert_eq!(l.energy_mj_over(&dev, 0.0), l.energy_mj(&dev));
        assert_eq!(l.mean_power_w_over(&dev, 500.0), l.mean_power_w(&dev));
    }

    #[test]
    fn energy_scales_with_makespan() {
        let dev = agx();
        let a = EnergyLedger { makespan_us: 1_000.0, ..Default::default() };
        let b = EnergyLedger { makespan_us: 2_000.0, ..Default::default() };
        assert!((b.energy_mj(&dev) / a.energy_mj(&dev) - 2.0).abs() < 1e-9);
    }
}
