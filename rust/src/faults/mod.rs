//! Deterministic fault injection for the serving fleet.
//!
//! A [`FaultPlan`] is a seeded, virtual-time schedule of board-level
//! failures delivered into [`crate::serve::run_fleet`]'s event heap:
//!
//! * **Fail-stop crashes** ([`Fault::Crash`]): a board goes dark at
//!   `at_us`, its queued work drains back to the front tier for
//!   re-placement on survivors, its in-flight batches are lost (and
//!   retried, deadline permitting), and it optionally rejoins later.
//! * **Lane loss** ([`Fault::LaneLoss`]): one processor kind dies —
//!   the canonical case is the GPU dying so the board degrades to
//!   CPU-only service.  Loss can be permanent or restore later; the
//!   fleet re-prices the degraded board through the router's
//!   epoch/dirty-flag machinery.
//! * **Thermal slow-downs** ([`Fault::Thermal`]): a lane kind's
//!   latency is scaled by a factor `>= 1` over a window, composing
//!   multiplicatively with any DVFS rung scaling (see
//!   [`crate::power`]).
//!
//! Plans come from JSON ([`FaultPlan::from_json`]) or from seeded
//! exponential MTTF/MTTR sampling ([`FaultPlan::sample_mttf_mttr`]);
//! either way the run is fully deterministic.  [`FaultPlan::none`]
//! is the empty plan — a fleet run under it is bit-identical to a
//! run without any fault machinery armed.
//!
//! The conservation contract under any plan is exact:
//! `offered == served + shed + failed` on the merged fleet aggregate
//! — faults may fail requests, never lose them silently.

use crate::device::Proc;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};

/// First retry delay for a request lost in a crashed in-flight batch,
/// microseconds of virtual time.  Subsequent attempts double the
/// delay up to [`RETRY_BACKOFF_CAP_US`].
pub const RETRY_BACKOFF_US: f64 = 1_000.0;

/// Upper bound on the exponential retry backoff, microseconds.
pub const RETRY_BACKOFF_CAP_US: f64 = 16_000.0;

/// Maximum delivery attempts for one orphaned request before it is
/// counted failed (bounds retry work under pathological plans).
pub const MAX_RETRY_ATTEMPTS: u32 = 6;

/// Retry delay before attempt number `attempt` (0-based), microseconds:
/// exponential backoff from [`RETRY_BACKOFF_US`] capped at
/// [`RETRY_BACKOFF_CAP_US`].
pub fn retry_backoff_us(attempt: u32) -> f64 {
    (RETRY_BACKOFF_US * f64::from(1u32 << attempt.min(10)))
        .min(RETRY_BACKOFF_CAP_US)
}

/// [`retry_backoff_us`] with seeded jitter in `[0.75, 1.25)` of the
/// base delay.  Deterministic jitter from the run's own RNG stream —
/// never wall clock — de-synchronises retry herds (every request
/// orphaned by one crash would otherwise re-arrive at the same
/// instant) while keeping runs replayable: the same seed draws the
/// same delays.  Fault-free, tail-off runs never reach a call site,
/// so their output is byte-identical to the un-jittered schedule.
pub fn jittered_backoff_us(attempt: u32, rng: &mut Rng) -> f64 {
    retry_backoff_us(attempt) * (0.75 + 0.5 * rng.f64())
}

/// One scheduled fault on one board.  All times are microseconds of
/// virtual time from the start of the run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Fail-stop crash at `at_us`; the board rejoins (empty, replicas
    /// intact) at `rejoin_us`, or never if `None`.
    Crash {
        /// Board index in the fleet.
        board: usize,
        /// Crash time, us.
        at_us: f64,
        /// Rejoin time, us (`None` = permanent).
        rejoin_us: Option<f64>,
    },
    /// One processor kind's lanes die at `at_us` and restore at
    /// `restore_us` (`None` = permanent).  In-flight batches on the
    /// lost lanes are lost; queued work stays and drains through the
    /// surviving lane kind.
    LaneLoss {
        /// Board index in the fleet.
        board: usize,
        /// Which lane kind dies.
        proc: Proc,
        /// Loss time, us.
        at_us: f64,
        /// Restore time, us (`None` = permanent).
        restore_us: Option<f64>,
    },
    /// Thermal slow-down: every dispatch on `proc` between `at_us`
    /// and `until_us` runs `scale >= 1` times slower (multiplies the
    /// batch latency before any DVFS rung scaling).
    Thermal {
        /// Board index in the fleet.
        board: usize,
        /// Which lane kind slows down.
        proc: Proc,
        /// Window start, us.
        at_us: f64,
        /// Window end, us.
        until_us: f64,
        /// Latency multiplier, `>= 1`.
        scale: f64,
    },
}

/// One edge-triggered state change derived from a [`Fault`], delivered
/// to the fleet loop at `at_us`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultTransition {
    /// Delivery time, microseconds of virtual time.
    pub at_us: f64,
    /// Affected board index.
    pub board: usize,
    /// What changes.
    pub change: FaultChange,
}

/// The state change a [`FaultTransition`] applies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultChange {
    /// Fail-stop: the board stops serving and its work drains out.
    BoardDown,
    /// The board rejoins empty with its replica set intact.
    BoardUp,
    /// All lanes of this processor kind die.
    LaneDown(Proc),
    /// The processor kind's lanes restore.
    LaneUp(Proc),
    /// Dispatch latency on this kind scales by the factor (`>= 1`).
    ThermalOn(Proc, f64),
    /// The thermal window ends (scale back to 1).
    ThermalOff(Proc),
}

/// A deterministic schedule of fleet faults.  Build with
/// [`FaultPlan::none`], [`FaultPlan::from_json`] or
/// [`FaultPlan::sample_mttf_mttr`]; install via
/// `FleetOptions::faults`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// The scheduled faults, in no particular order (the fleet sorts
    /// the derived transitions).
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// The empty plan: no fault machinery is armed and the fleet run
    /// is bit-identical to one without this subsystem.
    pub fn none() -> Self {
        FaultPlan { faults: Vec::new() }
    }

    /// True when the plan schedules nothing.
    pub fn is_none(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse a plan from JSON: `{"faults": [{...}, ...]}` (or a bare
    /// array), where each entry is one of
    ///
    /// ```json
    /// {"kind": "crash", "board": 1, "at_us": 5e5, "rejoin_us": 1e6}
    /// {"kind": "lane-loss", "board": 2, "proc": "gpu", "at_us": 2e5}
    /// {"kind": "thermal", "board": 0, "proc": "gpu",
    ///  "at_us": 1e5, "until_us": 4e5, "scale": 1.5}
    /// ```
    ///
    /// `rejoin_us` / `restore_us` are optional (absent = permanent).
    /// Entry errors carry the entry index.
    pub fn from_json(text: &str) -> Result<FaultPlan> {
        let v = json::parse(text)
            .map_err(|e| anyhow::anyhow!("parsing fault plan JSON: {e}"))?;
        let arr = match &v {
            Value::Arr(_) => &v,
            Value::Obj(_) => v.get("faults"),
            _ => bail!("fault plan must be an array or {{\"faults\": [...]}}"),
        };
        let entries = arr
            .as_arr()
            .context("fault plan `faults` is not an array")?;
        let mut faults = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            faults.push(
                parse_fault(e)
                    .with_context(|| format!("fault plan entry {i}"))?,
            );
        }
        Ok(FaultPlan { faults })
    }

    /// Sample a crash/rejoin schedule from exponential MTTF/MTTR
    /// distributions: each of `n_boards` boards alternates up-time
    /// (mean `mttf_s` seconds of virtual time) and down-time (mean
    /// `mttr_s`), seeded by `seed`, until `horizon_us` is covered.
    /// A crash whose down window would extend past the horizon still
    /// rejoins (the tail is clamped inside `2 * horizon_us`), so
    /// sampled plans never leave a board permanently dark.
    pub fn sample_mttf_mttr(
        n_boards: usize,
        mttf_s: f64,
        mttr_s: f64,
        horizon_us: f64,
        seed: u64,
    ) -> Result<FaultPlan> {
        ensure!(
            mttf_s.is_finite() && mttf_s > 0.0,
            "mttf_s must be positive and finite (got {mttf_s})"
        );
        ensure!(
            mttr_s.is_finite() && mttr_s > 0.0,
            "mttr_s must be positive and finite (got {mttr_s})"
        );
        ensure!(
            horizon_us.is_finite() && horizon_us > 0.0,
            "horizon_us must be positive and finite (got {horizon_us})"
        );
        let mut faults = Vec::new();
        for b in 0..n_boards {
            // Per-board substream so adding boards never perturbs the
            // schedules of existing ones.
            let mut rng = Rng::new(
                seed ^ (b as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let mut t = 0.0f64;
            loop {
                let up_us = rng.exponential(1.0 / mttf_s) * 1e6;
                let at = t + up_us;
                if at >= horizon_us {
                    break;
                }
                let down_us = rng.exponential(1.0 / mttr_s) * 1e6;
                let rejoin = (at + down_us).min(2.0 * horizon_us);
                faults.push(Fault::Crash {
                    board: b,
                    at_us: at,
                    rejoin_us: Some(rejoin),
                });
                t = rejoin;
            }
        }
        Ok(FaultPlan { faults })
    }

    /// Validate the plan against a fleet of `n_boards` boards and
    /// expand it into edge-triggered transitions sorted by delivery
    /// time.  Errors name the offending fault: out-of-range board
    /// index, non-finite/negative times, rejoin/restore/until not
    /// after the start, or thermal scale below 1.
    pub fn timeline(
        &self,
        n_boards: usize,
    ) -> Result<Vec<FaultTransition>> {
        let mut out = Vec::with_capacity(2 * self.faults.len());
        for (i, f) in self.faults.iter().enumerate() {
            let ctx = || format!("fault {i} ({f:?})");
            match *f {
                Fault::Crash { board, at_us, rejoin_us } => {
                    check_board(board, n_boards).with_context(ctx)?;
                    check_time(at_us, "at_us").with_context(ctx)?;
                    out.push(FaultTransition {
                        at_us,
                        board,
                        change: FaultChange::BoardDown,
                    });
                    if let Some(r) = rejoin_us {
                        check_time(r, "rejoin_us").with_context(ctx)?;
                        ensure!(
                            r > at_us,
                            "{}: rejoin_us {} must be after at_us {}",
                            ctx(), r, at_us
                        );
                        out.push(FaultTransition {
                            at_us: r,
                            board,
                            change: FaultChange::BoardUp,
                        });
                    }
                }
                Fault::LaneLoss { board, proc, at_us, restore_us } => {
                    check_board(board, n_boards).with_context(ctx)?;
                    check_time(at_us, "at_us").with_context(ctx)?;
                    out.push(FaultTransition {
                        at_us,
                        board,
                        change: FaultChange::LaneDown(proc),
                    });
                    if let Some(r) = restore_us {
                        check_time(r, "restore_us").with_context(ctx)?;
                        ensure!(
                            r > at_us,
                            "{}: restore_us {} must be after at_us {}",
                            ctx(), r, at_us
                        );
                        out.push(FaultTransition {
                            at_us: r,
                            board,
                            change: FaultChange::LaneUp(proc),
                        });
                    }
                }
                Fault::Thermal { board, proc, at_us, until_us, scale } => {
                    check_board(board, n_boards).with_context(ctx)?;
                    check_time(at_us, "at_us").with_context(ctx)?;
                    check_time(until_us, "until_us").with_context(ctx)?;
                    ensure!(
                        until_us > at_us,
                        "{}: until_us {} must be after at_us {}",
                        ctx(), until_us, at_us
                    );
                    ensure!(
                        scale.is_finite() && scale >= 1.0,
                        "{}: thermal scale {} must be >= 1",
                        ctx(), scale
                    );
                    out.push(FaultTransition {
                        at_us,
                        board,
                        change: FaultChange::ThermalOn(proc, scale),
                    });
                    out.push(FaultTransition {
                        at_us: until_us,
                        board,
                        change: FaultChange::ThermalOff(proc),
                    });
                }
            }
        }
        // Stable order: time, then board, so same-time events on
        // different boards apply deterministically.
        out.sort_by(|a, b| {
            a.at_us
                .total_cmp(&b.at_us)
                .then(a.board.cmp(&b.board))
        });
        Ok(out)
    }
}

fn check_board(board: usize, n_boards: usize) -> Result<()> {
    ensure!(
        board < n_boards,
        "board index {board} out of range (fleet has {n_boards})"
    );
    Ok(())
}

fn check_time(t: f64, what: &str) -> Result<()> {
    ensure!(
        t.is_finite() && t >= 0.0,
        "{what} must be finite and non-negative (got {t})"
    );
    Ok(())
}

fn parse_proc(v: &Value) -> Result<Proc> {
    match v.as_str() {
        Some("cpu") => Ok(Proc::Cpu),
        Some("gpu") => Ok(Proc::Gpu),
        Some(other) => bail!("unknown proc `{other}` (cpu|gpu)"),
        None => bail!("missing `proc` field (cpu|gpu)"),
    }
}

fn req_f64(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .as_f64()
        .with_context(|| format!("missing numeric field `{key}`"))
}

fn opt_f64(v: &Value, key: &str) -> Result<Option<f64>> {
    match v.get(key) {
        Value::Null => Ok(None),
        x => Ok(Some(x.as_f64().with_context(|| {
            format!("field `{key}` is not a number")
        })?)),
    }
}

fn parse_fault(e: &Value) -> Result<Fault> {
    let board = e
        .get("board")
        .as_usize()
        .context("missing integer field `board`")?;
    match e.get("kind").as_str() {
        Some("crash") => Ok(Fault::Crash {
            board,
            at_us: req_f64(e, "at_us")?,
            rejoin_us: opt_f64(e, "rejoin_us")?,
        }),
        Some("lane-loss") => Ok(Fault::LaneLoss {
            board,
            proc: parse_proc(e.get("proc"))?,
            at_us: req_f64(e, "at_us")?,
            restore_us: opt_f64(e, "restore_us")?,
        }),
        Some("thermal") => Ok(Fault::Thermal {
            board,
            proc: parse_proc(e.get("proc"))?,
            at_us: req_f64(e, "at_us")?,
            until_us: req_f64(e, "until_us")?,
            scale: req_f64(e, "scale")?,
        }),
        Some(other) => {
            bail!("unknown fault kind `{other}` (crash|lane-loss|thermal)")
        }
        None => bail!("missing `kind` field (crash|lane-loss|thermal)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_empty_and_timelines_to_nothing() {
        let p = FaultPlan::none();
        assert!(p.is_none());
        assert!(p.timeline(4).unwrap().is_empty());
    }

    #[test]
    fn json_roundtrip_covers_all_kinds() {
        let p = FaultPlan::from_json(
            r#"{"faults": [
                {"kind": "crash", "board": 1, "at_us": 500000.0,
                 "rejoin_us": 900000.0},
                {"kind": "crash", "board": 2, "at_us": 100.0},
                {"kind": "lane-loss", "board": 0, "proc": "gpu",
                 "at_us": 200.0, "restore_us": 400.0},
                {"kind": "thermal", "board": 3, "proc": "cpu",
                 "at_us": 10.0, "until_us": 20.0, "scale": 1.5}
            ]}"#,
        )
        .unwrap();
        assert_eq!(p.faults.len(), 4);
        assert_eq!(
            p.faults[0],
            Fault::Crash {
                board: 1,
                at_us: 500_000.0,
                rejoin_us: Some(900_000.0)
            }
        );
        assert_eq!(
            p.faults[1],
            Fault::Crash { board: 2, at_us: 100.0, rejoin_us: None }
        );
        // A bare array parses too.
        let q = FaultPlan::from_json(
            r#"[{"kind": "crash", "board": 0, "at_us": 1.0}]"#,
        )
        .unwrap();
        assert_eq!(q.faults.len(), 1);
        // The timeline expands windows into paired edges, sorted.
        let tl = p.timeline(4).unwrap();
        assert_eq!(tl.len(), 7);
        assert!(tl.windows(2).all(|w| w[0].at_us <= w[1].at_us));
        assert_eq!(
            tl[0].change,
            FaultChange::ThermalOn(Proc::Cpu, 1.5)
        );
    }

    #[test]
    fn json_errors_carry_entry_index() {
        let e = FaultPlan::from_json(
            r#"[{"kind": "crash", "board": 0, "at_us": 1.0},
                {"kind": "meteor", "board": 1, "at_us": 2.0}]"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("entry 1"), "{msg}");
        assert!(msg.contains("meteor"), "{msg}");
        assert!(FaultPlan::from_json("not json").is_err());
        assert!(FaultPlan::from_json("42").is_err());
    }

    #[test]
    fn timeline_validates_boards_times_and_scales() {
        let bad_board = FaultPlan {
            faults: vec![Fault::Crash {
                board: 9,
                at_us: 1.0,
                rejoin_us: None,
            }],
        };
        assert!(bad_board.timeline(4).is_err());
        let bad_rejoin = FaultPlan {
            faults: vec![Fault::Crash {
                board: 0,
                at_us: 10.0,
                rejoin_us: Some(5.0),
            }],
        };
        assert!(bad_rejoin.timeline(4).is_err());
        let bad_scale = FaultPlan {
            faults: vec![Fault::Thermal {
                board: 0,
                proc: Proc::Gpu,
                at_us: 0.0,
                until_us: 10.0,
                scale: 0.5,
            }],
        };
        assert!(bad_scale.timeline(4).is_err());
        let bad_time = FaultPlan {
            faults: vec![Fault::Crash {
                board: 0,
                at_us: f64::NAN,
                rejoin_us: None,
            }],
        };
        assert!(bad_time.timeline(4).is_err());
    }

    #[test]
    fn mttf_sampling_is_seeded_and_alternates() {
        let a = FaultPlan::sample_mttf_mttr(4, 0.5, 0.1, 2e6, 42)
            .unwrap();
        let b = FaultPlan::sample_mttf_mttr(4, 0.5, 0.1, 2e6, 42)
            .unwrap();
        assert_eq!(a, b, "same seed must give the same plan");
        let c = FaultPlan::sample_mttf_mttr(4, 0.5, 0.1, 2e6, 43)
            .unwrap();
        assert_ne!(a, c, "different seed should perturb the plan");
        assert!(!a.is_none(), "mttf 0.5s over a 2s horizon must crash");
        // Every sampled crash rejoins, within the clamped tail.
        for f in &a.faults {
            match *f {
                Fault::Crash { at_us, rejoin_us, .. } => {
                    let r = rejoin_us.expect("sampled crashes rejoin");
                    assert!(r > at_us && r <= 4e6);
                    assert!(at_us < 2e6);
                }
                _ => panic!("sampler only emits crashes"),
            }
        }
        // Per-board windows never overlap (alternating up/down).
        for bidx in 0..4 {
            let mut last = 0.0;
            for f in &a.faults {
                if let Fault::Crash { board, at_us, rejoin_us } = *f {
                    if board == bidx {
                        assert!(at_us >= last);
                        last = rejoin_us.unwrap();
                    }
                }
            }
        }
        assert!(
            FaultPlan::sample_mttf_mttr(4, 0.0, 0.1, 1e6, 1).is_err()
        );
        assert!(
            FaultPlan::sample_mttf_mttr(4, 0.5, -1.0, 1e6, 1).is_err()
        );
    }

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(retry_backoff_us(0), RETRY_BACKOFF_US);
        assert_eq!(retry_backoff_us(1), 2.0 * RETRY_BACKOFF_US);
        assert_eq!(retry_backoff_us(10), RETRY_BACKOFF_CAP_US);
        assert_eq!(retry_backoff_us(31), RETRY_BACKOFF_CAP_US);
    }

    #[test]
    fn jittered_backoff_stays_in_band_and_replays() {
        let mut rng = Rng::new(42);
        for attempt in 0..8 {
            let base = retry_backoff_us(attempt);
            let j = jittered_backoff_us(attempt, &mut rng);
            assert!(
                j >= 0.75 * base && j < 1.25 * base,
                "jitter out of band: {j} vs base {base}"
            );
        }
        // Same seed, same stream: replayable by construction.
        let a: Vec<f64> = {
            let mut r = Rng::new(7);
            (0..4).map(|i| jittered_backoff_us(i, &mut r)).collect()
        };
        let b: Vec<f64> = {
            let mut r = Rng::new(7);
            (0..4).map(|i| jittered_backoff_us(i, &mut r)).collect()
        };
        assert_eq!(a, b);
    }
}
