//! Soft Actor-Critic (paper §4.2, Alg. 1) on the `nn` substrate.
//!
//! * tanh-squashed Gaussian policy over the 1-D action (ξ mapped to
//!   [-1, 1] internally, [0, 1] at the environment boundary);
//! * twin Q-networks with Polyak-averaged targets (Eq. 10, 12);
//! * maximum-entropy objective with auto-tuned temperature α
//!   (Eq. 11, 13), target entropy H̄ = −dim(A) = −1.
//!
//! All gradients are exact manual backprop: the policy gradient flows
//! through Q's input-gradient (reparameterization trick) and through the
//! closed-form tanh-Gaussian log-density derivatives.

use crate::nn::{Act, Adam, Grads, Mlp};
use crate::rl::replay::{ReplayBuffer, Transition};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SacConfig {
    pub state_dim: usize,
    pub hidden: usize,
    pub gamma: f64,
    pub tau: f64,
    pub lr: f64,
    pub alpha_lr: f64,
    pub batch: usize,
    pub replay_capacity: usize,
    pub target_entropy: f64,
    pub seed: u64,
}

impl Default for SacConfig {
    fn default() -> Self {
        SacConfig {
            state_dim: crate::rl::env::STATE_DIM,
            hidden: 64,
            gamma: 0.99,
            tau: 0.01,
            lr: 3e-4,
            alpha_lr: 3e-4,
            batch: 64,
            replay_capacity: 50_000,
            target_entropy: -1.0,
            seed: 7,
        }
    }
}

const LOG_STD_MIN: f64 = -5.0;
const LOG_STD_MAX: f64 = 2.0;

pub struct Sac {
    pub cfg: SacConfig,
    /// policy: state -> [mean, log_std]
    pub policy: Mlp,
    pub q1: Mlp,
    pub q2: Mlp,
    pub q1_target: Mlp,
    pub q2_target: Mlp,
    opt_policy: Adam,
    opt_q1: Adam,
    opt_q2: Adam,
    pub log_alpha: f64,
    pub rng: Rng,
    pub replay: ReplayBuffer,
    pub updates: u64,
}

/// A sampled (squashed) action with the quantities needed for gradients.
struct Sampled {
    a: f64,      // tanh(u) in [-1, 1]
    eps: f64,    // the reparameterization noise
    sigma: f64,  // std
    logp: f64,   // log pi(a|s)
}

impl Sac {
    pub fn new(cfg: SacConfig) -> Self {
        let s = cfg.state_dim;
        let h = cfg.hidden;
        let policy = Mlp::new(&[s, h, h, 2], Act::Relu, cfg.seed);
        let q1 = Mlp::new(&[s + 1, h, h, 1], Act::Relu, cfg.seed + 1);
        let q2 = Mlp::new(&[s + 1, h, h, 1], Act::Relu, cfg.seed + 2);
        let q1_target = q1.clone();
        let q2_target = q2.clone();
        Sac {
            opt_policy: Adam::new(&policy, cfg.lr),
            opt_q1: Adam::new(&q1, cfg.lr),
            opt_q2: Adam::new(&q2, cfg.lr),
            rng: Rng::new(cfg.seed + 3),
            replay: ReplayBuffer::new(cfg.replay_capacity),
            log_alpha: (0.2f64).ln(),
            updates: 0,
            cfg,
            policy,
            q1,
            q2,
            q1_target,
            q2_target,
        }
    }

    pub fn alpha(&self) -> f64 {
        self.log_alpha.exp()
    }

    fn policy_out(&self, state: &[f64]) -> (f64, f64) {
        let out = self.policy.infer(state);
        let mean = out[0];
        let log_std = out[1].clamp(LOG_STD_MIN, LOG_STD_MAX);
        (mean, log_std)
    }

    fn sample_from(&mut self, mean: f64, log_std: f64) -> Sampled {
        let sigma = log_std.exp();
        let eps = self.rng.normal();
        let u = mean + sigma * eps;
        let a = u.tanh();
        let logp = -0.5 * eps * eps
            - log_std
            - 0.5 * (2.0 * std::f64::consts::PI).ln()
            - (1.0 - a * a + 1e-6).ln();
        Sampled { a, eps, sigma, logp }
    }

    /// Stochastic action ξ ∈ [0, 1] (training).
    pub fn act(&mut self, state: &[f64]) -> f64 {
        let (m, ls) = self.policy_out(state);
        let s = self.sample_from(m, ls);
        (s.a + 1.0) / 2.0
    }

    /// Deterministic action ξ ∈ [0, 1] (evaluation): tanh(mean).
    pub fn act_greedy(&self, state: &[f64]) -> f64 {
        let (m, _) = self.policy_out(state);
        (m.tanh() + 1.0) / 2.0
    }

    pub fn remember(&mut self, t: Transition) {
        self.replay.push(t);
    }

    fn q_eval(q: &Mlp, state: &[f64], a: f64) -> f64 {
        let mut input = state.to_vec();
        input.push(a);
        q.infer(&input)[0]
    }

    /// One gradient step over a replay minibatch (Alg. 1 lines 23-30).
    /// Returns (q_loss, policy_loss) for logging.
    pub fn update(&mut self) -> Option<(f64, f64)> {
        if self.replay.len() < self.cfg.batch {
            return None;
        }
        let batch_n = self.cfg.batch;
        let gamma = self.cfg.gamma;
        let alpha = self.alpha();

        // Sample transitions (clone out to appease the borrow checker).
        let mut rng = self.rng.clone();
        let batch: Vec<Transition> = self
            .replay
            .sample(batch_n, &mut rng)
            .into_iter()
            .cloned()
            .collect();
        self.rng = rng;

        // ---- critic update --------------------------------------------
        let mut g_q1 = Grads::zeros_like(&self.q1);
        let mut g_q2 = Grads::zeros_like(&self.q2);
        let mut q_loss_acc = 0.0;
        for t in &batch {
            // target: y = r + gamma (minQ'(s',a') - alpha logpi(a'|s'))
            let y = if t.done {
                t.reward
            } else {
                let (m, ls) = self.policy_out(&t.next_state);
                let s = self.sample_from(m, ls);
                let q1t = Self::q_eval(&self.q1_target, &t.next_state, s.a);
                let q2t = Self::q_eval(&self.q2_target, &t.next_state, s.a);
                t.reward + gamma * (q1t.min(q2t) - alpha * s.logp)
            };
            let mut input = t.state.clone();
            input.push(2.0 * t.action - 1.0); // env actions live in [0,1]
            for (q, opt_g) in
                [(&self.q1, &mut g_q1), (&self.q2, &mut g_q2)]
            {
                let (out, cache) = q.forward(&input, 1);
                let err = out[0] - y;
                q_loss_acc += 0.5 * err * err;
                let (g, _) = q.backward(&cache, &[err]);
                opt_g.add(&g);
            }
        }
        let scale = 1.0 / batch_n as f64;
        g_q1.scale(scale);
        g_q2.scale(scale);
        self.opt_q1.step(&mut self.q1, &g_q1);
        self.opt_q2.step(&mut self.q2, &g_q2);

        // ---- actor + temperature update --------------------------------
        let mut g_pi = Grads::zeros_like(&self.policy);
        let mut pi_loss_acc = 0.0;
        let mut logp_acc = 0.0;
        for t in &batch {
            let (out, cache) = self.policy.forward(&t.state, 1);
            let mean = out[0];
            let log_std = out[1].clamp(LOG_STD_MIN, LOG_STD_MAX);
            let s = self.sample_from(mean, log_std);
            // L = alpha * logpi - min(Q1, Q2)(s, a)
            let mut qin = t.state.clone();
            qin.push(s.a);
            let (q1v, c1) = self.q1.forward(&qin, 1);
            let (q2v, c2) = self.q2.forward(&qin, 1);
            let (qmin, use_q1) = if q1v[0] <= q2v[0] {
                (q1v[0], true)
            } else {
                (q2v[0], false)
            };
            pi_loss_acc += alpha * s.logp - qmin;
            logp_acc += s.logp;

            // dQ/da via critic input gradient.
            let dqda = if use_q1 {
                let (_, dx) = self.q1.backward(&c1, &[1.0]);
                dx[t.state.len()]
            } else {
                let (_, dx) = self.q2.backward(&c2, &[1.0]);
                dx[t.state.len()]
            };
            let one_m_a2 = 1.0 - s.a * s.a;
            // d logpi / dmean = 2a ; dlogpi/dlogstd = -1 + 2a*sigma*eps
            // da/dmean = (1-a^2) ; da/dlogstd = (1-a^2)*sigma*eps
            let dl_dmean = alpha * (2.0 * s.a) - dqda * one_m_a2;
            let dl_dlogstd = alpha * (-1.0 + 2.0 * s.a * s.sigma * s.eps)
                - dqda * one_m_a2 * s.sigma * s.eps;
            let (g, _) = self.policy.backward(&cache, &[dl_dmean, dl_dlogstd]);
            g_pi.add(&g);
        }
        g_pi.scale(scale);
        self.opt_policy.step(&mut self.policy, &g_pi);

        // temperature: J(alpha) = E[-alpha (logpi + target_entropy)]
        let dj_dlogalpha =
            -self.alpha() * (logp_acc * scale + self.cfg.target_entropy);
        self.log_alpha -= self.cfg.alpha_lr * dj_dlogalpha;
        self.log_alpha = self.log_alpha.clamp(-8.0, 2.0);

        // Polyak targets (Eq. 12).
        self.q1_target.polyak_from(&self.q1, self.cfg.tau);
        self.q2_target.polyak_from(&self.q2, self.cfg.tau);

        self.updates += 1;
        Some((q_loss_acc * scale, pi_loss_acc * scale))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 1-step bandit: reward = -(a - target)^2.  SAC must find the
    /// target action.  This exercises the full actor/critic/alpha loop.
    #[test]
    fn sac_solves_continuous_bandit() {
        let cfg = SacConfig {
            state_dim: 2,
            hidden: 32,
            batch: 32,
            lr: 3e-3,
            alpha_lr: 3e-3,
            seed: 5,
            ..Default::default()
        };
        let mut sac = Sac::new(cfg);
        let target = 0.8; // in env action space [0,1]
        let state = vec![0.3, -0.5];
        for _ in 0..900 {
            let a = sac.act(&state);
            let r = -(a - target) * (a - target) * 10.0;
            sac.remember(Transition {
                state: state.clone(),
                action: a,
                reward: r,
                next_state: state.clone(),
                done: true,
            });
            sac.update();
        }
        let a = sac.act_greedy(&state);
        assert!(
            (a - target).abs() < 0.15,
            "greedy action {a}, want ~{target}"
        );
    }

    /// State-dependent bandit: optimal action flips with the state bit.
    #[test]
    fn sac_learns_state_dependent_policy() {
        let cfg = SacConfig {
            state_dim: 2,
            hidden: 32,
            batch: 32,
            lr: 3e-3,
            alpha_lr: 3e-3,
            seed: 11,
            ..Default::default()
        };
        let mut sac = Sac::new(cfg);
        let mut rng = Rng::new(2);
        for _ in 0..1500 {
            let bit = rng.below(2) as f64;
            let state = vec![bit, 1.0 - bit];
            let target = if bit > 0.5 { 0.9 } else { 0.1 };
            let a = sac.act(&state);
            let r = -(a - target) * (a - target) * 10.0;
            sac.remember(Transition {
                state: state.clone(),
                action: a,
                reward: r,
                next_state: state,
                done: true,
            });
            sac.update();
        }
        let a1 = sac.act_greedy(&[1.0, 0.0]);
        let a0 = sac.act_greedy(&[0.0, 1.0]);
        assert!(a1 > 0.6, "state-1 action {a1}");
        assert!(a0 < 0.4, "state-0 action {a0}");
    }

    #[test]
    fn alpha_stays_positive_and_bounded() {
        let mut sac = Sac::new(SacConfig {
            state_dim: 2,
            ..Default::default()
        });
        for i in 0..200 {
            sac.remember(Transition {
                state: vec![0.0, 1.0],
                action: (i % 10) as f64 / 10.0,
                reward: -1.0,
                next_state: vec![0.0, 1.0],
                done: false,
            });
            sac.update();
        }
        let a = sac.alpha();
        assert!(a > 0.0 && a < 10.0, "alpha {a}");
    }
}
