//! Reinforcement-learning substrate for the SparOA operator scheduler:
//! the scheduling MDP environment (paper §4.1) and a from-scratch Soft
//! Actor-Critic implementation (paper §4.2) on the `nn` substrate.

pub mod env;
pub mod replay;
pub mod sac;

pub use env::{SchedulingEnv, STATE_DIM};
pub use replay::ReplayBuffer;
pub use sac::{Sac, SacConfig};
