//! Bounded replay buffer for SAC (paper Alg. 1 line 19).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Transition {
    pub state: Vec<f64>,
    pub action: f64,
    pub reward: f64,
    pub next_state: Vec<f64>,
    pub done: bool,
}

pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    head: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        ReplayBuffer { buf: Vec::with_capacity(capacity), capacity, head: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn sample<'a>(&'a self, n: usize, rng: &mut Rng) -> Vec<&'a Transition> {
        (0..n).map(|_| &self.buf[rng.below(self.buf.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f64) -> Transition {
        Transition {
            state: vec![0.0; 7],
            action: 0.5,
            reward: r,
            next_state: vec![0.0; 7],
            done: false,
        }
    }

    #[test]
    fn bounded_and_overwrites_oldest() {
        let mut b = ReplayBuffer::new(4);
        for i in 0..10 {
            b.push(t(i as f64));
        }
        assert_eq!(b.len(), 4);
        let rewards: Vec<f64> = b.buf.iter().map(|x| x.reward).collect();
        // after 10 pushes into cap 4, contents are {8,9,6,7} in ring order
        assert!(rewards.iter().all(|&r| r >= 6.0));
    }

    #[test]
    fn sampling_uniform() {
        let mut b = ReplayBuffer::new(100);
        for i in 0..100 {
            b.push(t(i as f64));
        }
        let mut rng = Rng::new(3);
        let s = b.sample(1000, &mut rng);
        let mean: f64 =
            s.iter().map(|x| x.reward).sum::<f64>() / s.len() as f64;
        assert!((mean - 49.5).abs() < 5.0, "mean {mean}");
    }
}
