//! The operator-scheduling MDP (paper §4.1).
//!
//! State  S = {ρ, I, N_in, N_out, M_gpu, M_cpu, O_switch}   (Eq. 7)
//! Action A ∈ [0, 1]: GPU allocation ratio ξ                (Eq. 8)
//! Reward r = −(λ1·L + λ2·(M_gpu + M_cpu) + λ3·O_switch)    (Eq. 9)
//!
//! The environment walks a model graph's ops in topological order and
//! maintains the same two-processor virtual timeline as engine::sim (an
//! integration test asserts the totals agree), including the stochastic
//! hardware dynamics (contention jitter, memory pressure) that make the
//! learned policy beat static DP plans.
//!
//! The per-step costs come from a [`CostTable`] precomputed at
//! construction (the SAC reward loop steps this environment millions of
//! times per training run; re-deriving roofline costs per step was the
//! single hottest path in policy search).

use crate::device::{DeviceModel, HardwareState, Proc};
use crate::engine::costs::CostTable;
use crate::engine::sim::{SimOptions, AGGREGATION_US};
use crate::graph::ModelGraph;
use crate::scheduler::{mode_of, Mode};

pub const STATE_DIM: usize = 7;

/// Reward weights λ1..λ3 (latency in ms, memory normalized, switches).
#[derive(Debug, Clone)]
pub struct RewardWeights {
    pub lambda_latency: f64,
    pub lambda_memory: f64,
    pub lambda_switch: f64,
}

impl Default for RewardWeights {
    fn default() -> Self {
        // Latency is expressed in ms; memory/switch penalties are kept an
        // order of magnitude below a typical per-op latency delta so the
        // agent optimizes makespan first (paper: lambda balances goals).
        RewardWeights {
            lambda_latency: 1.0,
            lambda_memory: 0.002,
            lambda_switch: 0.002,
        }
    }
}

pub struct SchedulingEnv<'a> {
    pub graph: &'a ModelGraph,
    pub device: &'a DeviceModel,
    pub weights: RewardWeights,
    /// Engine options the policy is trained against (SparOA engine).
    pub opts: SimOptions,
    pub noise: f64,
    pub batch: usize,
    /// Precomputed per-op placement costs.  The table depends only on
    /// (graph, device, opts, batch), not on the episode seed; `reset`
    /// rebuilds it so callers that mutate the pub `opts`/`batch` fields
    /// between episodes keep getting live costs.
    costs: CostTable,
    // timeline state
    cursor: usize,
    cpu_free: f64,
    gpu_free: f64,
    finish: Vec<f64>,
    placed: Vec<Proc>,
    hw: HardwareState,
    seed: u64,
    /// ξ chosen per op (filled as the episode progresses).
    pub xi: Vec<f64>,
}

impl<'a> SchedulingEnv<'a> {
    pub fn new(
        graph: &'a ModelGraph,
        device: &'a DeviceModel,
        noise: f64,
        batch: usize,
        seed: u64,
    ) -> Self {
        let n = graph.ops.len();
        let opts = SimOptions { noise, batch, seed, ..Default::default() };
        let costs = CostTable::build(graph, device, &opts);
        let mut env = SchedulingEnv {
            graph,
            device,
            weights: RewardWeights::default(),
            opts,
            noise,
            batch,
            costs,
            cursor: 0,
            cpu_free: 0.0,
            gpu_free: 0.0,
            finish: vec![0.0; n],
            placed: vec![Proc::Cpu; n],
            hw: HardwareState::new(device, seed, noise),
            seed,
            xi: vec![0.0; n],
        };
        env.skip_unschedulable();
        env
    }

    pub fn reset(&mut self, seed: u64) {
        let n = self.graph.ops.len();
        self.cursor = 0;
        self.cpu_free = 0.0;
        self.gpu_free = 0.0;
        self.finish = vec![0.0; n];
        self.placed = vec![Proc::Cpu; n];
        self.seed = seed;
        self.hw = HardwareState::new(self.device, seed, self.noise);
        self.xi = vec![0.0; n];
        // Honor post-construction mutation of the pub opts/batch knobs:
        // one table build per episode is amortized over the episode's
        // per-op steps (which are now pure lookups).
        let mut o = self.opts.clone();
        o.batch = self.batch;
        self.costs = CostTable::build(self.graph, self.device, &o);
        self.skip_unschedulable();
    }

    /// Advance past ops that are not scheduling decisions (they execute on
    /// their producer's device with negligible cost contributions handled
    /// at dispatch of the consumer).
    fn skip_unschedulable(&mut self) {
        while self.cursor < self.graph.ops.len()
            && !self.graph.ops[self.cursor].class.schedulable()
        {
            let op = &self.graph.ops[self.cursor];
            let p = op
                .inputs
                .first()
                .map(|&i| self.placed[i])
                .unwrap_or(Proc::Cpu);
            self.placed[op.id] = p;
            self.finish[op.id] = op
                .inputs
                .iter()
                .map(|&i| self.finish[i])
                .fold(0.0, f64::max);
            self.cursor += 1;
        }
    }

    pub fn done(&self) -> bool {
        self.cursor >= self.graph.ops.len()
    }

    /// Op id of the pending scheduling decision.
    pub fn cursor_op(&self) -> usize {
        self.cursor
    }

    /// Current makespan of the partial schedule, us.
    pub fn makespan_us(&self) -> f64 {
        self.cpu_free.max(self.gpu_free)
    }

    /// Observation for the op at the cursor (Eq. 7), normalized.
    pub fn observe(&self) -> [f64; STATE_DIM] {
        let op = &self.graph.ops[self.cursor];
        let n_in: usize = op
            .exec_in_shapes
            .first()
            .map(|s| s.iter().product())
            .unwrap_or(0);
        let n_out = op.out_numel_exec();
        let intensity = {
            let lf = op.flops_paper.max(1.0).log10();
            ((lf - 3.0) / 9.0).clamp(0.0, 1.0)
        };
        let switch_pending = match self.hw.last_proc {
            Some(Proc::Gpu) => 0.0, // staying on GPU is free
            Some(Proc::Cpu) => 1.0,
            None => 0.5,
        };
        [
            op.sparsity_in,
            intensity,
            (n_in as f64 / 1e6).min(2.0),
            (n_out as f64 / 1e6).min(2.0),
            self.hw.gpu_pressure(),
            self.hw.cpu_load,
            switch_pending,
        ]
    }

    /// Place the current op with ratio ξ; returns (reward, done).
    pub fn step(&mut self, xi: f64) -> (f64, bool) {
        let before = self.makespan_us();
        let op_id = self.cursor;
        let xi = xi.clamp(0.0, 1.0);
        self.xi[op_id] = xi;
        let op = &self.graph.ops[op_id];

        let switches_before = self.hw.switches;
        match mode_of(xi) {
            Mode::Single(proc) => {
                let lat = self.costs.lat(op_id, proc)
                    * self.hw.contention_factor(proc);
                let mut ready: f64 = 0.0;
                for &i in &op.inputs {
                    let mut t = self.finish[i];
                    if self.placed[i] != proc && self.costs.has_out_bytes(i)
                    {
                        t += self.costs.xfer_out(i);
                    }
                    ready = ready.max(t);
                }
                let free = match proc {
                    Proc::Cpu => self.cpu_free,
                    Proc::Gpu => self.gpu_free,
                };
                let end = ready.max(free) + lat;
                match proc {
                    Proc::Cpu => self.cpu_free = end,
                    Proc::Gpu => self.gpu_free = end,
                }
                self.finish[op_id] = end;
                self.placed[op_id] = proc;
                self.hw.dispatch(proc, self.costs.out_bytes_batch(op_id),
                                 self.costs.params_bytes(op_id));
            }
            Mode::CoRun(_) => {
                let lat_c = self.costs.lat(op_id, Proc::Cpu)
                    * self.hw.contention_factor(Proc::Cpu);
                let lat_g = self.costs.lat(op_id, Proc::Gpu)
                    * self.hw.contention_factor(Proc::Gpu);
                let mut rc: f64 = 0.0;
                let mut rg: f64 = 0.0;
                for &i in &op.inputs {
                    let t = self.finish[i];
                    let x = self.costs.xfer_out(i);
                    rc = rc.max(if self.placed[i] != Proc::Cpu { t + x } else { t });
                    rg = rg.max(if self.placed[i] != Proc::Gpu { t + x } else { t });
                }
                let ec = rc.max(self.cpu_free) + lat_c;
                let eg = rg.max(self.gpu_free) + lat_g;
                self.cpu_free = ec;
                self.gpu_free = eg;
                let xfer = self.costs.xfer_out(op_id);
                self.finish[op_id] = ec.max(eg) + xfer + AGGREGATION_US;
                self.placed[op_id] = Proc::Gpu;
                self.hw.dispatch(Proc::Gpu,
                                 self.costs.out_bytes_batch(op_id),
                                 self.costs.params_bytes(op_id));
            }
        }
        let switched = (self.hw.switches - switches_before) as f64;
        self.cursor += 1;
        self.skip_unschedulable();

        let delta_ms = (self.makespan_us() - before) / 1e3;
        let mem_pen = self.hw.gpu_pressure() + self.hw.cpu_load;
        let r = -(self.weights.lambda_latency * delta_ms
            + self.weights.lambda_memory * mem_pen
            + self.weights.lambda_switch * switched);
        (r, self.done())
    }

    /// Play out a full fixed schedule; returns final makespan (us).
    pub fn rollout(&mut self, xi: &[f64], seed: u64) -> f64 {
        self.reset(seed);
        while !self.done() {
            let id = self.cursor;
            self.step(xi[id]);
        }
        self.makespan_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceRegistry;
    use crate::graph::ModelZoo;

    fn setup() -> Option<(ModelZoo, DeviceRegistry)> {
        let art = crate::artifacts_dir();
        if !art.join("manifest.json").exists() {
            return None;
        }
        Some((
            ModelZoo::load(&art).unwrap(),
            DeviceRegistry::load(
                &crate::repo_root().join("config/devices.json"))
                .unwrap(),
        ))
    }

    #[test]
    fn env_timeline_matches_simulator() {
        let Some((zoo, reg)) = setup() else { return };
        for model in ["resnet18", "mobilenet_v3_small"] {
            let g = zoo.get(model).unwrap();
            let dev = reg.get("agx_orin").unwrap();
            for xi_val in [0.0, 1.0] {
                let sched = crate::scheduler::Schedule::uniform(g, xi_val, "t");
                let sim = crate::engine::sim::simulate(
                    g, dev, &sched, &crate::engine::sim::SimOptions {
                        noise: 0.0,
                        ..Default::default()
                    });
                let mut env = SchedulingEnv::new(g, dev, 0.0, 1, 1);
                let m = env.rollout(&sched.xi, 1);
                let rel = (m - sim.makespan_us).abs() / sim.makespan_us;
                assert!(rel < 0.05,
                        "{model} xi={xi_val}: env {m} vs sim {}",
                        sim.makespan_us);
            }
        }
    }

    #[test]
    fn rewards_penalize_latency() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("vit_b16").unwrap();
        let dev = reg.get("agx_orin").unwrap();
        let mut env = SchedulingEnv::new(g, dev, 0.0, 1, 1);
        // All-CPU episode reward must be far worse than all-GPU.
        let mut r_cpu = 0.0;
        env.reset(1);
        while !env.done() {
            r_cpu += env.step(0.0).0;
        }
        let mut r_gpu = 0.0;
        env.reset(1);
        while !env.done() {
            r_gpu += env.step(1.0).0;
        }
        assert!(r_gpu > r_cpu, "gpu {r_gpu} vs cpu {r_cpu}");
    }

    #[test]
    fn observation_in_range() {
        let Some((zoo, reg)) = setup() else { return };
        let g = zoo.get("swin_t").unwrap();
        let dev = reg.get("orin_nano").unwrap();
        let mut env = SchedulingEnv::new(g, dev, 0.01, 1, 3);
        while !env.done() {
            let s = env.observe();
            for (i, v) in s.iter().enumerate() {
                assert!(v.is_finite() && *v >= 0.0 && *v <= 2.0,
                        "state[{i}] = {v}");
            }
            env.step(0.7);
        }
    }
}
