//! Operator-graph IR: the rust-side mirror of python/compile/graph_ir.py.
//!
//! A [`ModelGraph`] is loaded from `artifacts/models/<name>/topology.json`
//! and carries, per operator: kind/class, dependencies, exec-scale shapes
//! (for PJRT execution), paper-scale FLOPs/bytes (for the device
//! simulator), measured activation sparsity, HLO artifact reference and
//! weight slices.

use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Operator kind — must stay in sync with `graph_ir.KINDS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Input,
    Conv2d,
    DwConv,
    Linear,
    MatMul,
    BatchNorm,
    LayerNorm,
    Relu,
    Relu6,
    HardSwish,
    HardSigmoid,
    Gelu,
    Softmax,
    Attention,
    Add,
    Mul,
    MaxPool,
    AvgPool,
    GlobalAvgPool,
    Reshape,
    Roll,
    Concat,
    WindowPart,
    WindowRev,
    SpaceToDepth,
}

impl OpKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "input" => Self::Input,
            "conv2d" => Self::Conv2d,
            "dwconv" => Self::DwConv,
            "linear" => Self::Linear,
            "matmul" => Self::MatMul,
            "batchnorm" => Self::BatchNorm,
            "layernorm" => Self::LayerNorm,
            "relu" => Self::Relu,
            "relu6" => Self::Relu6,
            "hardswish" => Self::HardSwish,
            "hardsigmoid" => Self::HardSigmoid,
            "gelu" => Self::Gelu,
            "softmax" => Self::Softmax,
            "attention" => Self::Attention,
            "add" => Self::Add,
            "mul" => Self::Mul,
            "maxpool" => Self::MaxPool,
            "avgpool" => Self::AvgPool,
            "globalavgpool" => Self::GlobalAvgPool,
            "reshape" => Self::Reshape,
            "roll" => Self::Roll,
            "concat" => Self::Concat,
            "window_part" => Self::WindowPart,
            "window_rev" => Self::WindowRev,
            "space_to_depth" => Self::SpaceToDepth,
            other => bail!("unknown op kind `{other}`"),
        })
    }

    /// True for ops the engine applies natively (pure data movement on the
    /// host buffer) instead of via a PJRT executable.
    pub fn is_native(self) -> bool {
        matches!(self, Self::Input | Self::Reshape)
    }
}

/// Device-model op class (keys in devices.json `util` tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    MatMul,
    Conv,
    DwConv,
    Attention,
    Norm,
    Elementwise,
    Pool,
    Softmax,
    Other,
}

impl OpClass {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "matmul" => Self::MatMul,
            "conv" => Self::Conv,
            "dwconv" => Self::DwConv,
            "attention" => Self::Attention,
            "norm" => Self::Norm,
            "elementwise" => Self::Elementwise,
            "pool" => Self::Pool,
            "softmax" => Self::Softmax,
            "other" => Self::Other,
            other => bail!("unknown op class `{other}`"),
        })
    }
    pub fn key(self) -> &'static str {
        match self {
            Self::MatMul => "matmul",
            Self::Conv => "conv",
            Self::DwConv => "dwconv",
            Self::Attention => "attention",
            Self::Norm => "norm",
            Self::Elementwise => "elementwise",
            Self::Pool => "pool",
            Self::Softmax => "softmax",
            Self::Other => "other",
        }
    }
    /// True when the op is worth dispatching to an accelerator at all —
    /// data-movement ops always run where their input lives.
    pub fn schedulable(self) -> bool {
        !matches!(self, Self::Other)
    }
}

/// One weight slice into the model's `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightSlice {
    pub offset: usize,
    pub numel: usize,
    pub shape: Vec<usize>,
}

/// One operator node.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: usize,
    pub name: String,
    pub kind: OpKind,
    pub class: OpClass,
    pub inputs: Vec<usize>,
    pub exec_in_shapes: Vec<Vec<usize>>,
    pub exec_out_shape: Vec<usize>,
    pub paper_out_shape: Vec<usize>,
    pub flops_exec: f64,
    pub flops_paper: f64,
    pub bytes_in_paper: f64,
    pub bytes_out_paper: f64,
    pub params_bytes_paper: f64,
    /// Activation sparsity of this op's *input* (what scheduling keys on).
    pub sparsity_in: f64,
    /// Activation sparsity of this op's output (producers feed consumers).
    pub sparsity_out: f64,
    pub weights: Vec<WeightSlice>,
    /// Relative path of the HLO artifact (None for native ops).
    pub artifact: Option<String>,
}

impl Op {
    /// Bytes this op moves at paper scale (inputs + outputs + params).
    pub fn bytes_moved_paper(&self) -> f64 {
        self.bytes_in_paper + self.bytes_out_paper + self.params_bytes_paper
    }
    pub fn out_numel_exec(&self) -> usize {
        self.exec_out_shape.iter().product()
    }
}

/// A loaded model topology.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub model: String,
    pub input_shape_exec: Vec<usize>,
    pub input_shape_paper: Vec<usize>,
    pub total_flops_paper: f64,
    pub weights_path: PathBuf,
    pub ops: Vec<Op>,
    /// consumers[i] = ops that read op i's output.
    pub consumers: Vec<Vec<usize>>,
}

impl ModelGraph {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("topology.json"))
            .with_context(|| format!("reading {}", dir.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing topology.json: {e}"))?;
        Self::from_json(&v, dir)
    }

    pub fn from_json(v: &Value, dir: &Path) -> Result<Self> {
        let mut ops = Vec::new();
        for o in v.get("ops").as_arr().context("ops array")? {
            let weights = o
                .get("weights")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|w| WeightSlice {
                    offset: w.f64_of("offset") as usize,
                    numel: w.f64_of("numel") as usize,
                    shape: w.get("shape").vec_usize(),
                })
                .collect();
            ops.push(Op {
                id: o.f64_of("id") as usize,
                name: o.str_of("name").to_string(),
                kind: OpKind::parse(o.str_of("kind"))?,
                class: OpClass::parse(o.str_of("class"))?,
                inputs: o.get("inputs").vec_usize(),
                exec_in_shapes: o
                    .get("exec_in_shapes")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|s| s.vec_usize())
                    .collect(),
                exec_out_shape: o.get("exec_out_shape").vec_usize(),
                paper_out_shape: o.get("paper_out_shape").vec_usize(),
                flops_exec: o.f64_of("flops_exec"),
                flops_paper: o.f64_of("flops_paper"),
                bytes_in_paper: o.f64_of("bytes_in_paper"),
                bytes_out_paper: o.f64_of("bytes_out_paper"),
                params_bytes_paper: o.f64_of("params_bytes_paper"),
                sparsity_in: o.f64_of("sparsity_in"),
                sparsity_out: o.f64_of("sparsity_out"),
                weights,
                artifact: o.get("artifact").as_str().map(|s| s.to_string()),
            });
        }
        let n = ops.len();
        let mut consumers = vec![Vec::new(); n];
        for op in &ops {
            for &i in &op.inputs {
                consumers[i].push(op.id);
            }
        }
        Ok(ModelGraph {
            model: v.str_of("model").to_string(),
            input_shape_exec: v.get("input_shape_exec").vec_usize(),
            input_shape_paper: v.get("input_shape_paper").vec_usize(),
            total_flops_paper: v.f64_of("total_flops_paper"),
            weights_path: dir.join(v.str_of("weights_file")),
            ops,
            consumers,
        })
    }

    /// Validate topological order and dependency sanity.
    pub fn validate(&self) -> Result<()> {
        for op in &self.ops {
            for &i in &op.inputs {
                if i >= op.id {
                    bail!("op {} depends on later op {}", op.id, i);
                }
            }
            if op.id != 0 && op.inputs.is_empty() && op.kind != OpKind::Input {
                bail!("op {} ({}) has no inputs", op.id, op.name);
            }
        }
        Ok(())
    }

    /// Ops eligible for CPU/GPU placement decisions.
    pub fn schedulable_ops(&self) -> impl Iterator<Item = &Op> {
        self.ops.iter().filter(|o| o.class.schedulable())
    }

    /// Build a synthetic conv-stack model so tests, benches and serving
    /// demos can run without `make artifacts`.
    ///
    /// The graph is a chain of `blocks` x (conv -> batchnorm -> relu)
    /// followed by a global-average-pool + linear head.  `flops_scale`
    /// sets the compute weight of the model (1.0 ~ a small mobile CNN)
    /// and `relu_sparsity` is the activation sparsity every ReLU emits —
    /// together they place the model anywhere on the paper's Fig. 2
    /// sparsity/intensity plane (dense-heavy => GPU-bound, sparse-light
    /// => CPU-amenable).  Paper-scale FLOPs/bytes drive the simulator;
    /// exec-scale shapes are kept tiny so numerics backends stay cheap.
    pub fn synthetic(
        name: &str,
        blocks: usize,
        flops_scale: f64,
        relu_sparsity: f64,
    ) -> ModelGraph {
        let scale = flops_scale.max(0.01);
        let sparsity = relu_sparsity.clamp(0.0, 1.0);
        // Activation tensor size (paper scale): ~64 KB at scale 1.
        let act_elems = (16_384.0 * scale.sqrt()).max(64.0);
        let act_bytes = 4.0 * act_elems;
        let conv_flops = 1.5e8 * scale;
        let conv_params_bytes = 4.0 * 9.0 * 64.0 * 64.0 * scale.sqrt();

        let mut ops: Vec<Op> = Vec::with_capacity(3 * blocks.max(1) + 3);
        let mut push = |ops: &mut Vec<Op>,
                        name: String,
                        kind: OpKind,
                        class: OpClass,
                        flops: f64,
                        bytes_out: f64,
                        params_bytes: f64,
                        sparsity_out: f64| {
            let id = ops.len();
            let (inputs, bytes_in, sparsity_in) = if id == 0 {
                (vec![], 0.0, 0.0)
            } else {
                let prev = &ops[id - 1];
                (vec![id - 1], prev.bytes_out_paper, prev.sparsity_out)
            };
            ops.push(Op {
                id,
                name,
                kind,
                class,
                inputs,
                exec_in_shapes: if id == 0 {
                    vec![]
                } else {
                    vec![vec![1, 4, 4, 8]]
                },
                exec_out_shape: vec![1, 4, 4, 8],
                paper_out_shape: vec![1, act_elems as usize],
                flops_exec: flops * 1e-4,
                flops_paper: flops,
                bytes_in_paper: bytes_in,
                bytes_out_paper: bytes_out,
                params_bytes_paper: params_bytes,
                sparsity_in,
                sparsity_out,
                weights: vec![],
                artifact: None,
            });
        };

        push(&mut ops, "input".into(), OpKind::Input, OpClass::Other,
             0.0, act_bytes, 0.0, 0.0);
        for b in 0..blocks.max(1) {
            push(&mut ops, format!("conv{b}"), OpKind::Conv2d,
                 OpClass::Conv, conv_flops, act_bytes,
                 conv_params_bytes, 0.0);
            push(&mut ops, format!("bn{b}"), OpKind::BatchNorm,
                 OpClass::Norm, 2.0 * act_elems, act_bytes, 0.0, 0.0);
            push(&mut ops, format!("relu{b}"), OpKind::Relu,
                 OpClass::Elementwise, act_elems, act_bytes, 0.0,
                 sparsity);
        }
        push(&mut ops, "gap".into(), OpKind::GlobalAvgPool, OpClass::Pool,
             act_elems, 4.0 * 256.0, 0.0, 0.0);
        push(&mut ops, "fc".into(), OpKind::Linear, OpClass::MatMul,
             2.0 * 256.0 * 1000.0, 4.0 * 1000.0, 4.0 * 256.0 * 1000.0,
             0.0);

        let n = ops.len();
        let mut consumers = vec![Vec::new(); n];
        for op in &ops {
            for &i in &op.inputs {
                consumers[i].push(op.id);
            }
        }
        let total_flops: f64 = ops.iter().map(|o| o.flops_paper).sum();
        ModelGraph {
            model: name.to_string(),
            input_shape_exec: vec![1, 4, 4, 8],
            input_shape_paper: vec![1, act_elems as usize],
            total_flops_paper: total_flops,
            weights_path: PathBuf::from(format!("{name}.weights.bin")),
            ops,
            consumers,
        }
    }
}

/// Registry of all models under `artifacts/models`.
pub struct ModelZoo {
    pub root: PathBuf,
    pub graphs: BTreeMap<String, ModelGraph>,
}

impl ModelZoo {
    pub fn load(artifacts: &Path) -> Result<Self> {
        let mut graphs = BTreeMap::new();
        let dir = artifacts.join("models");
        for entry in std::fs::read_dir(&dir)
            .with_context(|| format!("reading {}", dir.display()))?
        {
            let entry = entry?;
            if entry.file_type()?.is_dir() {
                let g = ModelGraph::load(&entry.path())?;
                g.validate()?;
                graphs.insert(g.model.clone(), g);
            }
        }
        Ok(ModelZoo { root: artifacts.to_path_buf(), graphs })
    }

    pub fn get(&self, name: &str) -> Result<&ModelGraph> {
        self.graphs
            .get(name)
            .with_context(|| format!("model `{name}` not in artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_topology() -> Value {
        json::parse(
            r#"{
              "model": "tiny", "input_shape_exec": [1,4,4,3],
              "input_shape_paper": [1,8,8,3], "total_flops_paper": 100.0,
              "weights_file": "weights.bin",
              "ops": [
                {"id":0,"name":"input","kind":"input","class":"other",
                 "inputs":[],"exec_in_shapes":[],"exec_out_shape":[1,4,4,3],
                 "paper_in_shapes":[],"paper_out_shape":[1,8,8,3],
                 "flops_exec":0,"flops_paper":0,"bytes_in_paper":0,
                 "bytes_out_paper":768,"params_bytes_paper":0,
                 "sparsity_in":0,"sparsity_out":0,"weights":[],
                 "artifact":null},
                {"id":1,"name":"c1","kind":"conv2d","class":"conv",
                 "inputs":[0],"exec_in_shapes":[[1,4,4,3]],
                 "exec_out_shape":[1,4,4,8],
                 "paper_in_shapes":[[1,8,8,3]],"paper_out_shape":[1,8,8,8],
                 "flops_exec":100,"flops_paper":1000,"bytes_in_paper":768,
                 "bytes_out_paper":2048,"params_bytes_paper":864,
                 "sparsity_in":0.0,"sparsity_out":0.1,
                 "weights":[{"offset":0,"numel":216,"shape":[3,3,3,8]}],
                 "artifact":"ops/x.hlo.txt"}
              ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn loads_and_validates() {
        let g =
            ModelGraph::from_json(&tiny_topology(), Path::new("/tmp")).unwrap();
        g.validate().unwrap();
        assert_eq!(g.ops.len(), 2);
        assert_eq!(g.ops[1].kind, OpKind::Conv2d);
        assert_eq!(g.consumers[0], vec![1]);
        assert_eq!(g.ops[1].weights[0].numel, 216);
        assert!(g.ops[1].class.schedulable());
        assert!(!g.ops[0].class.schedulable());
    }

    #[test]
    fn synthetic_graph_is_valid_and_scales() {
        let g = ModelGraph::synthetic("syn", 4, 1.0, 0.6);
        g.validate().unwrap();
        assert_eq!(g.ops.len(), 1 + 4 * 3 + 2);
        // ReLU sparsity propagates to the next conv's input.
        let conv1 = g.ops.iter().find(|o| o.name == "conv1").unwrap();
        assert!((conv1.sparsity_in - 0.6).abs() < 1e-12);
        let heavy = ModelGraph::synthetic("heavy", 4, 8.0, 0.0);
        assert!(heavy.total_flops_paper > 4.0 * g.total_flops_paper);
        assert!(g.schedulable_ops().count() >= 4 * 3);
    }

    #[test]
    fn kind_roundtrip() {
        for s in [
            "conv2d", "dwconv", "linear", "batchnorm", "layernorm", "relu",
            "attention", "window_part", "space_to_depth",
        ] {
            OpKind::parse(s).unwrap();
        }
        assert!(OpKind::parse("bogus").is_err());
    }
}
