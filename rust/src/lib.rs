//! # SparOA
//!
//! Reproduction of *"SparOA: Sparse and Operator-aware Hybrid Scheduling
//! for Edge DNN Inference"* (Zhang, Liu, Mottola, 2025) as a three-layer
//! Rust + JAX + Pallas stack.  See `docs/ARCHITECTURE.md` for the full
//! architecture guide (layer map, life of a request, paper-to-module
//! table) and `README.md` for the CLI quickstart.
//!
//! Layer map:
//! * L1/L2 (build-time python): Pallas kernels + JAX operator graphs,
//!   AOT-lowered to HLO text artifacts.
//! * L3 (this crate): the SparOA coordinator, organized around one seam —
//!   [`api`], the owned [`api::Session`] over a pluggable
//!   [`api::ExecutionBackend`]:
//!     * `api`        — **primary public surface**: `SessionBuilder` →
//!                      `Session::{infer, infer_batch, serve}`, the
//!                      `ExecutionBackend` trait with `SimBackend` /
//!                      `PjrtBackend`, and the unified `InferenceReport`.
//!     * `engine`     — execution internals behind the backends: the
//!                      virtual-time simulator, the real PJRT graph
//!                      walker, Alg. 2 dynamic batching, and the
//!                      `engine::costs` fast path (precomputed
//!                      `CostTable`, allocation-free `simulate_into`,
//!                      incremental `eval_flip`).  Which entry point
//!                      when: search loops evaluating many candidates
//!                      on one (graph, device, options) build a
//!                      `CostTable` once and use the scratch /
//!                      incremental walkers with
//!                      `SimOptions::record_timings = false`; one-shot
//!                      report/figure paths call `engine::sim::simulate`
//!                      (a thin wrapper over the same walk, per-op
//!                      timings on).
//!     * `scheduler`  — placement policies (threshold, greedy, DP, SAC)
//!                      over the shared `Schedule` representation.
//!     * `predictor`  — the Transformer-LSTM threshold predictor client.
//!     * `rl`         — the SAC learner + virtual-time RL environment.
//!     * `baselines`  — the paper's eleven comparison systems as policy +
//!                      engine-options pairs run through the same API.
//!     * `server`     — request streams, batching policies and serving
//!                      metrics (the online half of §5).
//!     * `serve`      — multi-tenant SLO-aware serving above `api`: a
//!                      `ModelRegistry` of warmed sessions, per-class
//!                      admission control + load shedding, and an
//!                      event-driven virtual-time cluster scheduler that
//!                      co-schedules CPU/GPU capacity across models
//!                      using the paper's sparsity/intensity signals
//!                      (`serve-multi` CLI, `fig13_multimodel` bench).
//!                      The dispatch core is indexed: per-(model,
//!                      class) queues sorted on insert (borrowing
//!                      `dispatch_view`, sort-free `take_batch`,
//!                      head-pop expiry), per-board lane-event heaps,
//!                      and epoch-cached router scores — pinned
//!                      bit-identical to the flat clone+sort spec
//!                      (`serve::slo::ReferenceQueues`) by
//!                      `rust/tests/slo_indexed.rs`.
//!     * `serve::fleet` — distributed multi-board serving: N board
//!                      schedulers (per-board `LaneMatrix` + admission
//!                      queues) in one virtual clock behind a front-tier
//!                      router (round-robin | jsq | cost-aware), with
//!                      replica autoscaling from per-board attainment /
//!                      queue-pressure windows (`serve-fleet` CLI,
//!                      `fig_fleet` bench).
//!     * `faults`     — deterministic fault injection for the fleet:
//!                      seeded `FaultPlan`s (JSON or MTTF/MTTR
//!                      sampling) of fail-stop board crashes with
//!                      rejoin, lane loss (GPU dies → CPU-only board)
//!                      and thermal slow-downs, delivered into the
//!                      fleet event heap with failover re-placement,
//!                      deadline-aware retry and exact conservation
//!                      (`serve-fleet --faults/--mttf/--mttr`,
//!                      `fig_chaos` bench).
//!     * `power`      — DVFS governor subsystem for the serving tier:
//!                      per-lane frequency ladders from
//!                      `config/devices.json`, race-to-idle /
//!                      stretch-to-deadline / fixed governors picking a
//!                      state per dispatched batch, board power caps
//!                      with throttle accounting, and the busy/idle/SoC
//!                      energy model behind `PerfSnapshot`'s
//!                      J-per-inference (`serve-fleet --governor`,
//!                      `fig_energy_serve` bench).
//!     * `obs`        — built-in virtual-time profiler: per-board
//!                      `Tracer` (zero-cost when disabled) recording
//!                      typed admit/dispatch/DMA/compute/shed/throttle
//!                      events into a bounded buffer, exact
//!                      per-(model, class) `PhaseBreakdown`
//!                      accumulators on every `PerfSnapshot`, and
//!                      folded-stack (flamegraph.pl/inferno) + Chrome
//!                      trace-event (Perfetto) exporters
//!                      (`serve-fleet --trace_out`, `fig_scale` bench).
//!     * `runtime`    — the PJRT bridge (optional `pjrt` cargo feature)
//!                      and host tensors / weight stores.
//!     * `device`/`energy`/`graph`/`profiler` — calibrated device models,
//!                      energy ledger, model graphs, quadrant profiling.
//!     * `config`/`bench_support`/`util` — CLI config, bench/test
//!                      substrate, vendored-free helpers.
//!
//! # Quickstart
//!
//! Build a session, run one inference, serve a stream — every consumer
//! (CLI, server, benches, examples) goes through this same path:
//!
//! ```no_run
//! use sparoa::api::{BackendChoice, SessionBuilder};
//! use sparoa::server::{batcher::poisson_stream, BatchPolicy};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = SessionBuilder::new()
//!     .model("mobilenet_v3_small")
//!     .device("agx_orin")
//!     .policy("sac")           // threshold | greedy | dp | sac | ...
//!     .episodes(30)
//!     .backend(BackendChoice::Sim)  // or BackendChoice::Pjrt
//!     .build()?;
//!
//! let report = session.infer()?;          // unified InferenceReport
//! println!("{}", report.summary());
//!
//! let stream = poisson_stream(200, 150.0, 42);
//! let served = session.serve(&stream, &BatchPolicy::Dynamic {
//!     max: 64, optimizer_cost_us: 30.0 })?;
//! println!("p99 {:.0}us", served.p99_latency_us);
//! # Ok(()) }
//! ```
//!
//! Multi-tenant serving hosts many sessions behind SLO classes and a
//! cross-model cluster scheduler (run `sparoa serve-multi` for the full
//! demo):
//!
//! ```no_run
//! use sparoa::serve::{
//!     demo, merge_arrivals, run_cluster, ClusterOptions,
//! };
//!
//! # fn main() -> anyhow::Result<()> {
//! let registry = demo::registry(&sparoa::artifacts_dir(), "agx_orin")?;
//! let classes = demo::classes();
//! let tenants = demo::tenants(&registry, 1.0, 500, 42, None)?;
//! let arrivals = merge_arrivals(&tenants, 42);
//! let snapshot = run_cluster(&registry, &classes, &tenants, &arrivals,
//!                            &ClusterOptions::default())?;
//! println!("{}", snapshot.summary());
//! println!("{}", snapshot.to_json_string());
//! # Ok(()) }
//! ```

// Documentation policy: `#![warn(missing_docs)]` is intentionally NOT
// enabled crate-wide yet — the inner layers (engine, scheduler, rl)
// predate the doc pass and would drown CI's `cargo doc -D warnings`
// gate in noise.  The public serving surface (`serve`, `engine::costs`)
// is documented per item with units stated (us, bytes, ratios); enable
// the lint once the older layers catch up.
pub mod api;
pub mod baselines;
pub mod bench_support;
pub mod config;
pub mod device;
pub mod energy;
pub mod engine;
pub mod faults;
pub mod graph;
pub mod nn;
pub mod obs;
pub mod power;
pub mod predictor;
pub mod profiler;
pub mod rl;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod server;
pub mod util;

pub use api::{
    BackendChoice, ExecutionBackend, InferenceReport, Session,
    SessionBuilder,
};

use std::path::PathBuf;

/// Repository root (build-time) — used by tests/benches/examples to find
/// `artifacts/` and `config/` without needing a CLI flag.
pub fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Default artifacts directory.
pub fn artifacts_dir() -> PathBuf {
    repo_root().join("artifacts")
}
