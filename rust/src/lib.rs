//! # SparOA
//!
//! Reproduction of *"SparOA: Sparse and Operator-aware Hybrid Scheduling
//! for Edge DNN Inference"* (Zhang, Liu, Mottola, 2025) as a three-layer
//! Rust + JAX + Pallas stack.  See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for the paper-vs-measured record.
//!
//! Layer map:
//! * L1/L2 (build-time python): Pallas kernels + JAX operator graphs,
//!   AOT-lowered to HLO text artifacts.
//! * L3 (this crate): the SparOA coordinator — threshold predictor client,
//!   SAC operator scheduler, hybrid inference engine, heterogeneous device
//!   simulator, all eleven baselines, energy/memory accounting, and the
//!   serving front-end.

pub mod baselines;
pub mod bench_support;
pub mod config;
pub mod device;
pub mod energy;
pub mod engine;
pub mod graph;
pub mod nn;
pub mod predictor;
pub mod profiler;
pub mod rl;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod util;

use std::path::PathBuf;

/// Repository root (build-time) — used by tests/benches/examples to find
/// `artifacts/` and `config/` without needing a CLI flag.
pub fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Default artifacts directory.
pub fn artifacts_dir() -> PathBuf {
    repo_root().join("artifacts")
}
