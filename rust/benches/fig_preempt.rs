//! Preemption figure: an overloaded 8-board fleet where a best-effort
//! flood pins six boards' lanes with long full-cap batches while a
//! tight-deadline interactive stream round-robins across everything —
//! the cross-board preemption extension's headline numbers.
//!
//! Arms:
//! * `off` — run-to-completion (bit-identical to the pre-preemption
//!   path; its report carries no preempt counters);
//! * `deadline-burn` — boards cancel a lower-class in-flight batch
//!   when an interactive head would otherwise burn its deadline; the
//!   victim's requests re-queue with arrival/deadline preserved and
//!   the cancelled tail is refunded from lane time and energy;
//! * `burn-plus-steal` — adds the fleet's work-stealing pass: queued
//!   (never dispatched) work stranded behind a stalled board's batches
//!   re-places onto cheaper boards through the price tables (the two
//!   interactive-only boards make the steal path deterministic here).
//!
//! Every arm is checked for exact conservation: offered == served +
//! shed + failed, preempted and stolen requests settle exactly once.
//! The virtual-time fleet is deterministic, so every number is
//! machine-independent.  Full runs write the measured lines to
//! `BENCH_preempt.json`; `--ci` re-checks conservation, requires
//! DeadlineBurn to strictly beat Off on interactive attainment, caps
//! preempted waste at 10% of served busy time, and gates the
//! burn/off attainment ratio against the committed baseline.

use sparoa::bench_support::{baseline, Table};
use sparoa::device::Proc;
use sparoa::serve::{
    demo, merge_arrivals, run_fleet, ArrivalPattern, FleetOptions,
    FleetSnapshot, PreemptionPolicy, RouterPolicy, SloClass, Tenant,
};

const BOARDS: usize = 8;
/// Boards hosting the flood model; the remaining boards host only the
/// interactive model and sit near-idle — the steal destinations.
const FLOOD_HOSTS: usize = 6;
/// Flood arrival rate as a multiple of its hosts' aggregate capacity.
const OVERLOAD: f64 = 1.7;
const N_FLOOD: usize = 700;
const SEED: u64 = 29;
/// `--ci` cap on lane time wasted on cancelled batch prefixes,
/// as a fraction of the fleet's served busy time.
const CI_WASTE_FRAC: f64 = 0.10;
/// `--ci` budget on the burn/off interactive-attainment ratio drift
/// against the committed baseline.
const CI_RATIO_BUDGET: f64 = 1.05;
const CI_NUM_KEY: &str = "attain_hi_burn";
const CI_DEN_KEY: &str = "attain_hi_off";

struct Arm {
    policy: PreemptionPolicy,
    snap: FleetSnapshot,
}

fn conserved(name: &str, snap: &FleetSnapshot, n: usize) -> bool {
    let offered = snap.aggregate.total_offered();
    let settled = snap.aggregate.total_served()
        + snap.aggregate.total_shed()
        + snap.total_failed();
    if offered as usize != n || settled != offered {
        eprintln!(
            "fig_preempt conservation broken in `{name}`: {n} \
             arrivals, offered {offered}, served {} + shed {} + \
             failed {} = {settled}",
            snap.aggregate.total_served(),
            snap.aggregate.total_shed(),
            snap.total_failed()
        );
        return false;
    }
    true
}

/// Interactive-class (class 0) deadline attainment.
fn hi_attain(snap: &FleetSnapshot) -> f64 {
    let g = &snap.aggregate.per_class[0];
    g.met as f64 / g.offered.max(1) as f64
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");

    let device = "agx_orin";
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");

    // Calibrate the roles instead of hard-coding indices, so the arms
    // keep their shape on both the synthetic and artifact registries:
    // the flood model is the one with the longest full-cap batch, the
    // interactive model the one with the cheapest batch-1 latency.
    let cal: Vec<(f64, f64, f64)> = (0..registry.len())
        .map(|m| {
            let e = registry.get(m);
            let cap = e.gpu_batch_cap.max(1);
            let batch_lat = e.latency_us(Proc::Gpu, cap).unwrap();
            let rate = cap as f64 / batch_lat * 1e6;
            (rate, e.cheapest_latency_us(1).unwrap(), batch_lat)
        })
        .collect();
    let flood = (0..cal.len())
        .max_by(|&a, &b| cal[a].2.total_cmp(&cal[b].2))
        .unwrap();
    let inter = (0..cal.len())
        .min_by(|&a, &b| cal[a].1.total_cmp(&cal[b].1))
        .unwrap();
    assert_ne!(flood, inter, "degenerate registry: one model is both \
                              the flood and the interactive role");
    let (flood_rate, _, flood_batch) = cal[flood];
    let (inter_rate, inter_lat1, _) = cal[inter];

    // The interactive weight outranks a full flood batch (preemption
    // only cancels a victim whose still-meetable weight is below the
    // rescued class weight); its deadline sits well under the flood
    // batch runtime so queued heads genuinely burn behind one.
    let fe = registry.get(flood);
    let cap_w = fe.gpu_batch_cap.max(fe.cpu_batch_cap) as f64;
    let deadline_us = (10.0 * inter_lat1)
        .min(0.5 * flood_batch)
        .max(1.05 * inter_lat1);
    let classes = vec![
        SloClass::new("interactive", deadline_us, 128, cap_w + 64.0),
        SloClass::new("best-effort", 20.0 * flood_batch, 512, 1.0),
    ];
    let flood_per_s = OVERLOAD * FLOOD_HOSTS as f64 * flood_rate;
    let horizon_s = N_FLOOD as f64 / flood_per_s;
    let inter_per_s = 0.35 * inter_rate;
    let n_inter = ((inter_per_s * horizon_s) as usize).max(150);
    let tenants = vec![
        Tenant {
            name: "flood-be".into(),
            model: registry.get(flood).name.clone(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: flood_per_s,
                n: N_FLOOD,
            },
        },
        Tenant {
            name: "interactive".into(),
            model: registry.get(inter).name.clone(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: inter_per_s,
                n: n_inter,
            },
        },
    ];
    let arrivals = merge_arrivals(&tenants, SEED);

    // Boards 0..FLOOD_HOSTS host everything; the rest host only the
    // interactive model.  Round-robin routing sends interactive work
    // onto the flooded boards too, where it burns (or gets stolen).
    let mut placement: Vec<Vec<usize>> = Vec::new();
    for b in 0..BOARDS {
        placement.push(if b < FLOOD_HOSTS {
            (0..registry.len()).collect()
        } else {
            vec![inter]
        });
    }
    let run = |policy: PreemptionPolicy| -> FleetSnapshot {
        let opts = FleetOptions {
            router: RouterPolicy::RoundRobin,
            placement: placement.clone(),
            preempt: policy,
            ..FleetOptions::new(BOARDS, registry.len())
        };
        run_fleet(&registry, &classes, &tenants, &arrivals, &opts)
            .expect("fleet run")
    };
    let arms: Vec<Arm> = [
        PreemptionPolicy::Off,
        PreemptionPolicy::DeadlineBurn,
        PreemptionPolicy::BurnPlusSteal,
    ]
    .into_iter()
    .map(|policy| Arm { policy, snap: run(policy) })
    .collect();

    let mut ok = true;
    for a in &arms {
        ok &= conserved(a.policy.name(), &a.snap, arrivals.len());
    }

    let mut t = Table::new(
        &format!(
            "preempt — {BOARDS} boards ({FLOOD_HOSTS} flooded x\
             {OVERLOAD:.1}) on {device}, {} requests",
            arrivals.len()
        ),
        &["arm", "interactive attain", "attainment", "served",
          "preempted", "stolen", "waste ms"],
    );
    for a in &arms {
        t.row(vec![
            a.policy.name().into(),
            format!("{:.1}%", 100.0 * hi_attain(&a.snap)),
            format!("{:.1}%", 100.0 * a.snap.aggregate_attainment()),
            a.snap.aggregate.total_served().to_string(),
            a.snap.total_preemptions().to_string(),
            a.snap.total_steals().to_string(),
            format!("{:.1}", a.snap.total_preempt_waste_us() / 1e3),
        ]);
    }
    t.print();

    let (off, burn, steal) =
        (&arms[0].snap, &arms[1].snap, &arms[2].snap);
    println!(
        "\ncancelling best-effort batches rescues interactive \
         deadlines: attainment {:.1}% (off) -> {:.1}% (deadline-burn, \
         {} preemptions, {:.1} ms wasted) -> {:.1}% (burn-plus-steal, \
         {} stolen).",
        100.0 * hi_attain(off),
        100.0 * hi_attain(burn),
        burn.total_preemptions(),
        burn.total_preempt_waste_us() / 1e3,
        100.0 * hi_attain(steal),
        steal.total_steals(),
    );

    let lines: Vec<(String, f64)> = vec![
        ("attain_hi_off".into(), hi_attain(off)),
        ("attain_hi_burn".into(), hi_attain(burn)),
        ("attain_hi_steal".into(), hi_attain(steal)),
        ("attain_all_off".into(), off.aggregate_attainment()),
        ("attain_all_burn".into(), burn.aggregate_attainment()),
        ("served_off".into(), off.aggregate.total_served() as f64),
        ("served_burn".into(), burn.aggregate.total_served() as f64),
        ("preemptions_burn".into(), burn.total_preemptions() as f64),
        ("steals_steal".into(), steal.total_steals() as f64),
        ("waste_ms_burn".into(),
         burn.total_preempt_waste_us() / 1e3),
    ];

    let path = sparoa::repo_root().join("BENCH_preempt.json");
    if ci {
        // Hard invariants — the PR acceptance criteria, deterministic
        // on any runner.
        let mut bad = Vec::new();
        if !ok {
            bad.push("conservation failed in at least one arm".into());
        }
        if off.total_preemptions() != 0 || off.total_steals() != 0 {
            bad.push("the off arm preempted or stole".into());
        }
        if burn.total_preemptions() == 0 {
            bad.push("deadline-burn never preempted".into());
        }
        if burn.total_steals() != 0 {
            bad.push("deadline-burn stole work".into());
        }
        if steal.total_steals() == 0 {
            bad.push("burn-plus-steal never stole".into());
        }
        if hi_attain(burn) <= hi_attain(off) {
            bad.push(format!(
                "deadline-burn interactive attainment {:.4} <= off \
                 {:.4}",
                hi_attain(burn),
                hi_attain(off)
            ));
        }
        for a in &arms[1..] {
            let busy = a.snap.aggregate.cpu_busy_us
                + a.snap.aggregate.gpu_busy_us;
            let waste = a.snap.total_preempt_waste_us();
            if waste > CI_WASTE_FRAC * busy {
                bad.push(format!(
                    "{}: preempt waste {waste:.0}us > {:.0}% of \
                     {busy:.0}us busy",
                    a.policy.name(),
                    100.0 * CI_WASTE_FRAC
                ));
            }
        }
        if !bad.is_empty() {
            for b in &bad {
                eprintln!("fig_preempt invariant failed: {b}");
            }
            std::process::exit(1);
        }
        // Then the committed-baseline drift gate (refuses a missing or
        // bootstrap-placeholder baseline — CI regenerates one first).
        let Some((_, old_ratio)) =
            baseline::committed(&path, CI_NUM_KEY, CI_DEN_KEY)
        else {
            baseline::refuse(&path, "fig_preempt", CI_NUM_KEY,
                             CI_DEN_KEY);
        };
        let new_ratio = hi_attain(burn) / hi_attain(off).max(1e-12);
        baseline::gate_ratio(
            "fig_preempt",
            &format!("{CI_NUM_KEY}/{CI_DEN_KEY}"),
            new_ratio,
            old_ratio,
            CI_RATIO_BUDGET,
        );
    } else {
        if !ok {
            std::process::exit(1);
        }
        baseline::write(&path, "preempt", &lines);
    }
}
