//! Hot-path micro-benchmarks (the §Perf harness): per-op scheduling +
//! dispatch cost, simulator throughput, SAC step cost, batcher step,
//! JSON parse, and real PJRT op execution.  The SPAROA_DISPATCH_US
//! constant in the device simulator must stay honest against the
//! `engine dispatch decision` line below.

use sparoa::bench_support::{bench, load_env};
use sparoa::device::Proc;
use sparoa::engine::sim::{op_cost_us, simulate, SimOptions};
use sparoa::graph::OpClass;
use sparoa::rl::env::SchedulingEnv;
use sparoa::rl::replay::Transition;
use sparoa::rl::sac::{Sac, SacConfig};
use sparoa::runtime::{HostTensor, Runtime};
use sparoa::scheduler::{greedy::GreedyScheduler, Schedule, ScheduleCtx,
                        Scheduler};
use sparoa::util::rng::Rng;

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let g = zoo.get("mobilenet_v3_small").unwrap();
    let dev = reg.get("agx_orin").unwrap();
    let opts = SimOptions::default();
    let mut results = Vec::new();

    // 1. Pure per-op cost evaluation (the innermost scheduling primitive).
    results.push(bench("op_cost_us (single op)", 1000, 200000, || {
        std::hint::black_box(op_cost_us(
            dev, Proc::Gpu, OpClass::Conv, 1e7, 1e6, 0.4, &opts));
    }));

    // 2. Whole-model simulation (one inference on the virtual timeline).
    let sched = Schedule::uniform(g, 1.0, "gpu");
    results.push(bench("simulate() mobilenet_v3 (156 ops)", 20, 400, || {
        std::hint::black_box(simulate(g, dev, &sched, &opts));
    }));

    // 3. Greedy full-model schedule.
    let ctx = ScheduleCtx { graph: g, device: dev, thresholds: None,
                            batch: 1 };
    results.push(bench("greedy schedule (full model)", 10, 200, || {
        std::hint::black_box(GreedyScheduler.schedule(&ctx));
    }));

    // 4. RL environment step + SAC action.
    let mut env = SchedulingEnv::new(g, dev, 0.0, 1, 1);
    let mut sac = Sac::new(SacConfig::default());
    results.push(bench("env.step + sac.act (per op)", 200, 20000, || {
        if env.done() {
            env.reset(1);
        }
        let s = env.observe();
        let a = sac.act(&s);
        std::hint::black_box(env.step(a));
    }));

    // 5. SAC gradient update (batch 64).
    for i in 0..256 {
        sac.remember(Transition {
            state: vec![0.1; 7],
            action: (i % 10) as f64 / 10.0,
            reward: -0.1,
            next_state: vec![0.1; 7],
            done: false,
        });
    }
    results.push(bench("sac.update (batch 64)", 5, 100, || {
        std::hint::black_box(sac.update());
    }));

    // 6. JSON parse of a topology file.
    let topo = std::fs::read_to_string(
        sparoa::artifacts_dir()
            .join("models/mobilenet_v3_small/topology.json"))
        .unwrap();
    results.push(bench("json parse topology (156 ops)", 5, 100, || {
        std::hint::black_box(sparoa::util::json::parse(&topo).unwrap());
    }));

    // 7. Real PJRT op execution (first conv of mobilenet).
    let rt = Runtime::new(&sparoa::artifacts_dir()).unwrap();
    let ws = sparoa::runtime::WeightStore::load(&g.weights_path).unwrap();
    let conv = g.ops.iter()
        .find(|o| o.kind == sparoa::graph::OpKind::Conv2d).unwrap();
    let mut rng = Rng::new(1);
    let n: usize = conv.exec_in_shapes[0].iter().product();
    let mut args = vec![HostTensor::new(
        conv.exec_in_shapes[0].clone(),
        (0..n).map(|_| rng.normal() as f32).collect())];
    args.extend(ws.op_params(conv).unwrap());
    let artifact = conv.artifact.clone().unwrap();
    rt.execute(&artifact, &args).unwrap(); // compile outside the loop
    results.push(bench("pjrt execute (stem conv)", 5, 200, || {
        std::hint::black_box(rt.execute(&artifact, &args).unwrap());
    }));

    println!("\n=== hotpath micro-benchmarks ===");
    for r in &results {
        println!("{}", r.report());
    }
    // Honesty check for the simulator's dispatch constant.
    let decision = &results[3];
    println!(
        "\nper-op decision+dispatch = {:.2}us (simulator assumes \
         SPAROA_DISPATCH_US = {}us)",
        decision.mean_us,
        sparoa::engine::sim::SPAROA_DISPATCH_US
    );
}
