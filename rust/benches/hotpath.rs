//! Hot-path micro-benchmarks (the §Perf harness): per-op scheduling +
//! dispatch cost, simulator throughput (reference vs the engine::costs
//! fast path), incremental flip evaluation, greedy schedule search, SAC
//! step cost, batcher step, JSON parse, and real PJRT op execution.
//!
//! Always-on: falls back to a synthetic ~150-op conv stack when `make
//! artifacts` hasn't run, so the perf trajectory is tracked in every
//! checkout.  Each run writes machine-readable `BENCH_hotpath.json`
//! (name -> ns/op, plus the `workload` it was measured on) at the repo
//! root; `--ci` runs short iteration counts and exits non-zero when the
//! fast-path simulate line regresses >2x against the committed
//! baseline (same-workload, fastpath/reference-ratio comparison, so
//! runner hardware cancels out).
//!
//! The SPAROA_DISPATCH_US constant in the device simulator must stay
//! honest against the `env.step + sac.act` line below.

use sparoa::bench_support::{baseline, bench, load_env, BenchResult};
use sparoa::device::Proc;
use sparoa::engine::costs::{CostTable, SimScratch};
use sparoa::engine::sim::{
    op_cost_us, simulate, simulate_reference, SimOptions,
};
use sparoa::graph::{ModelGraph, OpClass};
use sparoa::rl::env::SchedulingEnv;
use sparoa::rl::replay::Transition;
use sparoa::rl::sac::{Sac, SacConfig};
use sparoa::scheduler::{greedy::GreedyScheduler, Schedule, ScheduleCtx,
                        Scheduler};

/// Regression budget for `--ci`: fail when the fast-path simulate line
/// slows more than this factor relative to the committed baseline.  The
/// comparison is on the *fastpath/reference ratio* (both measured in the
/// same run), so a slower/noisier CI runner cancels out and only a real
/// fast-path regression trips the gate.
const CI_REGRESSION_FACTOR: f64 = 2.0;
const CI_GATE_KEY: &str = "simulate_fastpath";
const CI_REF_KEY: &str = "simulate_reference";

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");
    // `--write-baseline`: CI-short iteration counts but the write path
    // instead of the gate — how CI bootstraps a usable baseline when
    // the committed one is a placeholder (the gate refuses those).
    let write_baseline = args.iter().any(|a| a == "--write-baseline");
    // Short runs: the gate tolerates 2x, so ~1/10 the samples is
    // plenty of signal.
    let short = ci || write_baseline;
    let it = |n: usize| if short { (n / 10).max(5) } else { n };

    let env_data = load_env();
    let have_artifacts = env_data.is_some();
    let (g, dev) = match &env_data {
        Some((zoo, reg)) => (
            zoo.get("mobilenet_v3_small").unwrap().clone(),
            reg.get("agx_orin").unwrap().clone(),
        ),
        None => (
            // ~153 ops: the same scale as mobilenet_v3_small's 156.
            ModelGraph::synthetic("hotpath_syn", 50, 1.0, 0.4),
            sparoa::bench_support::device_profile("agx_orin"),
        ),
    };
    let n_ops = g.ops.len();
    let mut results: Vec<(&'static str, BenchResult)> = Vec::new();

    // 1. Pure per-op cost evaluation (the innermost roofline primitive).
    let opts = SimOptions::default();
    results.push(("op_cost_us", bench(
        "op_cost_us (single op)", 1000, it(200000), || {
            std::hint::black_box(op_cost_us(
                &dev, Proc::Gpu, OpClass::Conv, 1e7, 1e6, 0.4, &opts));
        })));

    // 2a. Whole-model simulation, reference path (per-call roofline
    //     re-derivation + per-call allocation).
    let sched = Schedule::uniform(&g, 1.0, "gpu");
    results.push(("simulate_reference", bench(
        &format!("simulate_reference ({n_ops} ops)"), 20, it(400), || {
            std::hint::black_box(
                simulate_reference(&g, &dev, &sched, &opts));
        })));

    // 2b. Fast path: prebuilt CostTable + reused scratch, no timing vec —
    //     the configuration every search loop runs in.
    let fast_opts = SimOptions { record_timings: false, ..opts.clone() };
    let table = CostTable::build(&g, &dev, &fast_opts);
    let mut scratch = SimScratch::new();
    results.push(("simulate_fastpath", bench(
        &format!("simulate() fast path ({n_ops} ops)"), 20, it(4000), || {
            table.simulate_into(&sched, &mut scratch);
            std::hint::black_box(scratch.report.makespan_us);
        })));

    // 2b'. The same fast-path walk with a disabled obs::Tracer poked
    //      each iteration — measures the "zero cost when off" claim on
    //      the hottest loop (printed as tracer_disabled_overhead below;
    //      the enforced <= 1.05x gate lives in fig_scale --ci).
    let mut off_tracer = sparoa::obs::Tracer::disabled();
    results.push(("simulate_fastpath_traced_off", bench(
        &format!("simulate() fast path + disabled tracer ({n_ops} ops)"),
        20, it(4000), || {
            off_tracer.record(0.0, sparoa::obs::NONE, sparoa::obs::NONE,
                              sparoa::obs::TraceEvent::Admit);
            table.simulate_into(&sched, &mut scratch);
            std::hint::black_box(scratch.report.makespan_us);
        })));

    // 2c. One-shot wrapper (table build + walk) — what `simulate()`
    //     costs a caller that doesn't reuse anything.
    results.push(("simulate_wrapper", bench(
        &format!("simulate() one-shot wrapper ({n_ops} ops)"), 20, it(400),
        || {
            std::hint::black_box(simulate(&g, &dev, &sched, &fast_opts));
        })));

    // 2d. Table build alone — the batched (SoA, hoisted-constant)
    //     roofline pass; what separates the one-shot wrapper from the
    //     cached fast path.
    results.push(("cost_table_build", bench(
        &format!("CostTable::build ({n_ops} ops)"), 20, it(2000), || {
            std::hint::black_box(CostTable::build(&g, &dev, &fast_opts));
        })));

    // 3. Incremental single-flip evaluation (suffix re-timing only).
    let mixed: Vec<f64> =
        (0..n_ops).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
    let mixed = Schedule { xi: mixed, policy: "alt".into() };
    let mut inc = table.incremental(&mixed.xi);
    let flip_at = n_ops / 2;
    let mut flip_to = 0.0;
    results.push(("eval_flip", bench(
        "eval_flip (mid-graph op)", 100, it(20000), || {
            flip_to = 1.0 - flip_to;
            std::hint::black_box(inc.eval_flip(flip_at, flip_to));
        })));

    // 4a. Greedy full-model schedule, end to end (builds its own table).
    let ctx = ScheduleCtx { graph: &g, device: &dev, thresholds: None,
                            batch: 1 };
    results.push(("greedy_schedule", bench(
        "greedy schedule (full model)", 10, it(200), || {
            std::hint::black_box(GreedyScheduler.schedule(&ctx));
        })));

    // 4b. Greedy over a cached table — the search-loop configuration.
    let greedy_table = CostTable::build(&g, &dev, &SimOptions {
        batch: 1, record_timings: false, ..Default::default()
    });
    results.push(("greedy_fastpath", bench(
        "greedy schedule (cached CostTable)", 10, it(4000), || {
            std::hint::black_box(
                GreedyScheduler::schedule_with_table(&greedy_table));
        })));

    // 5. RL environment step + SAC action.
    let mut env = SchedulingEnv::new(&g, &dev, 0.0, 1, 1);
    let mut sac = Sac::new(SacConfig::default());
    results.push(("env_step_sac_act", bench(
        "env.step + sac.act (per op)", 200, it(20000), || {
            if env.done() {
                env.reset(1);
            }
            let s = env.observe();
            let a = sac.act(&s);
            std::hint::black_box(env.step(a));
        })));

    // 6. SAC gradient update (batch 64).
    for i in 0..256 {
        sac.remember(Transition {
            state: vec![0.1; 7],
            action: (i % 10) as f64 / 10.0,
            reward: -0.1,
            next_state: vec![0.1; 7],
            done: false,
        });
    }
    results.push(("sac_update", bench(
        "sac.update (batch 64)", 5, it(100), || {
            std::hint::black_box(sac.update());
        })));

    // 7. Artifacts-only lines: topology JSON parse + real PJRT execution.
    if have_artifacts {
        if let Ok(topo) = std::fs::read_to_string(
            sparoa::artifacts_dir()
                .join("models/mobilenet_v3_small/topology.json"))
        {
            results.push(("json_parse_topology", bench(
                "json parse topology", 5, it(100), || {
                    std::hint::black_box(
                        sparoa::util::json::parse(&topo).unwrap());
                })));
        }
        if let Some(r) = pjrt_line(&g, it(200)) {
            results.push(("pjrt_execute", r));
        }
    }

    println!("\n=== hotpath micro-benchmarks ===");
    for (_, r) in &results {
        println!("{}", r.report());
    }

    let ns = |key: &str| -> Option<f64> {
        results
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, r)| r.mean_us * 1000.0)
    };
    if let (Some(rf), Some(fp)) =
        (ns("simulate_reference"), ns("simulate_fastpath"))
    {
        println!("\nsimulate fast-path speedup: {:.1}x \
                  (reference {:.0} ns -> fast {:.0} ns)",
                 rf / fp, rf, fp);
    }
    if let (Some(fp), Some(tr)) =
        (ns("simulate_fastpath"), ns("simulate_fastpath_traced_off"))
    {
        println!("tracer_disabled_overhead: {:.3}x \
                  (fast {:.0} ns -> with disabled tracer {:.0} ns)",
                 tr / fp, fp, tr);
    }
    if let (Some(gr), Some(gf)) =
        (ns("greedy_schedule"), ns("greedy_fastpath"))
    {
        println!("greedy cached-table speedup: {:.1}x \
                  (end-to-end {:.0} ns -> cached {:.0} ns)",
                 gr / gf, gr, gf);
    }
    // Honesty check for the simulator's dispatch constant.
    if let Some(d) = results.iter().find(|(k, _)| *k == "env_step_sac_act")
    {
        println!(
            "per-op decision+dispatch = {:.2}us (simulator assumes \
             SPAROA_DISPATCH_US = {}us)",
            d.1.mean_us,
            sparoa::engine::sim::SPAROA_DISPATCH_US
        );
    }

    let baseline_path = sparoa::repo_root().join("BENCH_hotpath.json");
    if ci {
        // Gate against the committed baseline.  Hardware-independent
        // comparison: committed fast/ref ratio vs this run's fast/ref
        // ratio (absolute ns would make the gate flaky whenever the
        // committing machine and the CI runner differ, which is
        // always).  A missing, empty or bootstrap-placeholder baseline
        // FAILS the gate (`baseline::refuse`); CI regenerates a usable
        // baseline first (see .github/workflows/ci.yml) so this only
        // trips when that step is broken too.
        let Some((v, old)) = baseline::committed(
            &baseline_path, CI_GATE_KEY, CI_REF_KEY) else {
            baseline::refuse(&baseline_path, "hotpath",
                             CI_GATE_KEY, CI_REF_KEY);
        };
        // Only gate against the same workload: a baseline committed
        // from an artifacts checkout benches mobilenet_v3_small while
        // an artifact-less runner benches the synthetic fallback;
        // their ratios are not comparable.
        let same_workload =
            v.get("workload").as_str() == Some(g.model.as_str());
        let measured = match (ns(CI_GATE_KEY), ns(CI_REF_KEY)) {
            (Some(f), Some(r)) if r > 0.0 => Some(f / r),
            _ => None,
        };
        match (same_workload, measured) {
            (true, Some(new)) => baseline::gate_ratio(
                "hotpath",
                &format!("{CI_GATE_KEY}/{CI_REF_KEY}"),
                new,
                old,
                CI_REGRESSION_FACTOR,
            ),
            (false, _) => println!(
                "\nci gate: baseline measured on a different workload \
                 than `{}` — ratios not comparable, comparison skipped \
                 (baseline is non-empty, so the gate stays green)",
                g.model
            ),
            (_, None) => {
                eprintln!("hotpath ci gate: this run produced no \
                           {CI_GATE_KEY}/{CI_REF_KEY} lines");
                std::process::exit(1);
            }
        }
    } else {
        // Full local runs (and CI's `--write-baseline` bootstrap)
        // refresh the committed perf trajectory; `baseline::write`
        // refuses an empty map (a `{}` file silently disarms the gate).
        let lines: Vec<(String, f64)> = results
            .iter()
            .map(|(k, r)| (k.to_string(), r.mean_us * 1000.0))
            .collect();
        baseline::write(&baseline_path, &g.model, &lines);
    }
}

/// Real PJRT op execution (first conv of the model); None when the
/// runtime is the no-pjrt stub or the model carries no artifacts.
fn pjrt_line(g: &ModelGraph, iters: usize) -> Option<BenchResult> {
    use sparoa::runtime::{HostTensor, Runtime, WeightStore};
    use sparoa::util::rng::Rng;
    let rt = Runtime::new(&sparoa::artifacts_dir()).ok()?;
    let ws = WeightStore::load(&g.weights_path).ok()?;
    let conv = g
        .ops
        .iter()
        .find(|o| o.kind == sparoa::graph::OpKind::Conv2d)?;
    let artifact = conv.artifact.clone()?;
    let mut rng = Rng::new(1);
    let n: usize = conv.exec_in_shapes.first()?.iter().product();
    let mut args = vec![HostTensor::new(
        conv.exec_in_shapes[0].clone(),
        (0..n).map(|_| rng.normal() as f32).collect(),
    )];
    args.extend(ws.op_params(conv).ok()?);
    rt.execute(&artifact, &args).ok()?; // compile outside the loop
    Some(bench("pjrt execute (stem conv)", 5, iters, || {
        std::hint::black_box(rt.execute(&artifact, &args).unwrap());
    }))
}
