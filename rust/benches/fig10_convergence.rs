//! Figure 10: convergence time of the scheduling algorithms on AGX Orin.
//! Paper: Greedy 0.04-0.24s (but ~22% worse latency), DP 39-415s and
//! suboptimal under dynamics (63ms vs SAC 48ms on MobileNetV2), SAC
//! 33-46s with sublinear growth in model complexity.

use sparoa::bench_support::{load_env, Table, MODELS};
use sparoa::engine::sim::{simulate, SimOptions};
use sparoa::scheduler::{
    dp::DpScheduler, greedy::GreedyScheduler,
    sac_sched::{SacScheduler, SacSchedulerConfig}, ScheduleCtx, Scheduler,
};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let dev = reg.get("agx_orin").unwrap();
    let mut t = Table::new(
        "Fig.10 — scheduler convergence on AGX Orin",
        &["model", "algorithm", "converge (s)", "plan latency (us)"],
    );
    // Evaluate all plans under the same mild hardware dynamics — the
    // regime the paper's §6.7 comparison describes.
    let eval_opts = SimOptions { noise: 0.03, seed: 5, ..Default::default() };
    for model in MODELS {
        let g = zoo.get(model).unwrap();
        let ctx = ScheduleCtx { graph: g, device: dev, thresholds: None,
                                batch: 1 };
        // Greedy.
        let t0 = std::time::Instant::now();
        let greedy = GreedyScheduler.schedule(&ctx);
        let greedy_s = t0.elapsed().as_secs_f64();
        // DP (ensemble sweep = the exhaustive-search cost profile).
        let t0 = std::time::Instant::now();
        let dp = DpScheduler { ensemble: 48 }.schedule(&ctx);
        let dp_s = t0.elapsed().as_secs_f64();
        // SAC.
        let mut sac = SacScheduler::new(SacSchedulerConfig {
            episodes: 60,
            ..Default::default()
        });
        let sac_plan = sac.schedule(&ctx);
        let sac_s = sac.converged_after_s;

        for (name, secs, plan) in [
            ("Greedy", greedy_s, &greedy),
            ("DP", dp_s, &dp),
            ("SAC", sac_s, &sac_plan),
        ] {
            let lat = simulate(g, dev, plan, &eval_opts).makespan_us;
            t.row(vec![
                model.into(),
                name.into(),
                format!("{secs:.3}"),
                format!("{lat:.0}"),
            ]);
        }
    }
    t.print();
    println!(
        "\nExpected shape (paper Fig.10): Greedy converges near-instantly \
         but yields worse plans; DP costs the most wall-clock; SAC sits \
         between on time and wins on plan latency under dynamics."
    );
}
