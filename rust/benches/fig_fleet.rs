//! Fleet extension figure: distributed multi-board serving under
//! increasing load — router policies compared, autoscaled vs static
//! replica placement — plus the indexed-dispatch micro-bench
//! (dispatch ns/req at Q = 10^2..10^4, the sorted-on-insert
//! `AdmissionQueues` vs the flat clone+sort `ReferenceQueues`).
//!
//! Like `fig13_multimodel` this bench never skips: it uses the
//! artifact models when `make artifacts` has run and the synthetic
//! demo fleet otherwise.  Emits the fleet-level JSON report (aggregate
//! + per-board attainment/utilization/shed rate, replica-count
//! timeline) on stdout after the tables, and writes the dispatch
//! ns/req lines to `BENCH_fleet.json` at the repo root.
//!
//! Modes (mirroring the hotpath bench): `--ci` runs only the dispatch
//! micro-bench with short iteration counts and fails on (a) a missing/
//! empty/bootstrap baseline, (b) an indexed/reference dispatch ratio
//! that regressed >2x against the committed one (hardware cancels out
//! of the ratio), or (c) an indexed path less than 5x faster than the
//! reference at Q = 10^4 (the PR acceptance floor — the real margin is
//! orders of magnitude).  `--write-baseline` regenerates the JSON with
//! short counts (how CI bootstraps a placeholder baseline).

use sparoa::bench_support::{baseline, bench, BenchResult, Table};
use sparoa::serve::slo::ReferenceQueues;
use sparoa::serve::{
    demo, merge_arrivals, run_fleet, AdmissionQueues, AutoscalePolicy,
    FleetOptions, QueuedReq, RouterPolicy, ShedPolicy, SloClass,
};
use sparoa::util::json::{self, Value};
use std::collections::BTreeMap;

/// Queue depths the dispatch micro-bench measures.
const DISPATCH_QS: [usize; 3] = [100, 1_000, 10_000];
/// Requests drained per dispatch cycle (a realistic Alg. 2 batch).
const DISPATCH_BATCH: usize = 32;
/// Models the backlog is spread over (the demo-fleet shape).
const DISPATCH_MODELS: usize = 3;
/// `--ci` regression budget on the indexed/reference ratio.
const CI_REGRESSION_FACTOR: f64 = 2.0;
/// `--ci` acceptance floor: indexed must beat reference by at least
/// this factor at the largest queue depth.
const CI_SPEEDUP_FLOOR: f64 = 5.0;
const CI_IDX_KEY: &str = "dispatch_indexed_q10000";
const CI_REF_KEY: &str = "dispatch_reference_q10000";

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");
    let write_baseline = args.iter().any(|a| a == "--write-baseline");
    if ci || write_baseline {
        // Gate/bootstrap mode: dispatch micro-bench only, short iters.
        dispatch_bench(true, ci);
        return;
    }

    let device = "agx_orin";
    let boards = 4usize;
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");
    let classes = demo::classes();

    let mut t = Table::new(
        &format!(
            "fleet — {} boards x {} models on {}",
            boards, registry.len(), device
        ),
        &["load", "router", "autoscale", "attainment", "shed",
          "mean batch", "gpu util", "scale events", "mean replicas"],
    );
    let mut scenarios = Vec::new();
    for load in [0.5, 2.0, 4.0] {
        let tenants = demo::tenants(&registry, load, 300, 23, None)
            .expect("building tenants");
        let arrivals = merge_arrivals(&tenants, 23);
        // Three routers autoscaled, plus the static ablation on the
        // cost-aware router.
        let runs: Vec<(RouterPolicy, bool)> = vec![
            (RouterPolicy::RoundRobin, true),
            (RouterPolicy::JoinShortestQueue, true),
            (RouterPolicy::CostAware, true),
            (RouterPolicy::CostAware, false),
        ];
        let mut snaps = Vec::new();
        for (router, autoscaled) in runs {
            let mut opts = FleetOptions::new(boards, registry.len());
            opts.router = router;
            if autoscaled {
                opts.autoscale = Some(AutoscalePolicy::default());
            }
            let snap = run_fleet(
                &registry, &classes, &tenants, &arrivals, &opts)
                .expect("fleet run");
            let reps: Vec<String> = snap
                .mean_replicas
                .iter()
                .map(|x| format!("{x:.1}"))
                .collect();
            t.row(vec![
                format!("x{load:.1}"),
                snap.router.clone(),
                if autoscaled { "on" } else { "off" }.into(),
                format!("{:.1}%", 100.0 * snap.aggregate_attainment()),
                snap.total_shed().to_string(),
                format!("{:.1}", snap.aggregate.mean_batch()),
                format!("{:.0}%", 100.0 * snap.mean_gpu_util()),
                snap.scale_events.len().to_string(),
                reps.join("/"),
            ]);
            snaps.push(snap);
        }
        scenarios.push((load, snaps));
    }
    t.print();

    // Headline: cost-aware routing vs round-robin at the top load.
    let top = scenarios.last().unwrap();
    let (rr, cost) = (
        top.1[0].aggregate_attainment(),
        top.1[2].aggregate_attainment(),
    );
    println!(
        "\nAt x{:.1} load: cost-aware router {:.1}% vs round-robin \
         {:.1}% aggregate attainment ({:+.1} pts); autoscale sheds {} \
         vs {} static.",
        top.0,
        100.0 * cost,
        100.0 * rr,
        100.0 * (cost - rr),
        top.1[2].total_shed(),
        top.1[3].total_shed(),
    );

    // Dispatch micro-bench (full iteration counts) + baseline refresh.
    dispatch_bench(false, false);

    // Machine-readable fleet report.
    let report = Value::Obj(
        [
            ("bench".to_string(), Value::Str("fig_fleet".into())),
            ("device".to_string(), Value::Str(device.into())),
            ("boards".to_string(), Value::Num(boards as f64)),
            (
                "scenarios".to_string(),
                Value::Arr(
                    scenarios
                        .iter()
                        .map(|(load, snaps)| {
                            let mut o = BTreeMap::new();
                            o.insert("load".into(), Value::Num(*load));
                            o.insert(
                                "runs".into(),
                                Value::Arr(snaps
                                    .iter()
                                    .map(|s| s.to_json())
                                    .collect()),
                            );
                            Value::Obj(o)
                        })
                        .collect(),
                ),
            ),
        ]
        .into_iter()
        .collect(),
    );
    println!("\n{}", json::to_string(&report));
}

/// SLO classes for the dispatch micro-bench: caps sized to hold the
/// whole backlog, deadlines far out so the cycle times dispatch, not
/// expiry.
fn dispatch_classes(q: usize) -> Vec<SloClass> {
    vec![
        SloClass::new("interactive", 1e12, q, 4.0),
        SloClass::new("standard", 2e12, q, 2.0),
        SloClass::new("best-effort", 4e12, q, 1.0),
    ]
}

/// One indexed dispatch cycle: score every model off the borrowing
/// view + O(1)/O(classes) aggregates (the `BoardSim::pump` shape),
/// drain the winner's heads, re-offer to hold Q steady.  Returns the
/// drained count.
fn indexed_cycle(
    q: &mut AdmissionQueues,
    classes: &[SloClass],
    now: &mut f64,
) -> usize {
    let mut best_m = 0usize;
    let mut best_s = f64::NEG_INFINITY;
    for m in 0..DISPATCH_MODELS {
        if q.queue_len(m) == 0 {
            continue;
        }
        let head = q.head_arrival_us(m);
        let finish = *now + 5_000.0;
        let met: f64 = q
            .dispatch_view(m)
            .take(DISPATCH_BATCH)
            .filter(|r| r.deadline_us >= finish)
            .map(|r| classes[r.class].weight)
            .sum();
        let s = met - 1e-9 * head;
        if s > best_s {
            best_s = s;
            best_m = m;
        }
    }
    let taken = q.take_batch(best_m, DISPATCH_BATCH, true);
    let n = taken.len();
    for r in &taken {
        *now += 1.0;
        q.offer(r.req, r.tenant, r.model, r.class, *now);
    }
    n
}

/// The same dispatch cycle through the reference path: clone+sort per
/// scored model, sort again inside `take_batch` — the O(Q log Q) cost
/// the indexed core removes.
fn reference_cycle(
    q: &mut ReferenceQueues,
    classes: &[SloClass],
    now: &mut f64,
) -> usize {
    let mut best_m = 0usize;
    let mut best_s = f64::NEG_INFINITY;
    for m in 0..DISPATCH_MODELS {
        if q.queue_len(m) == 0 {
            continue;
        }
        let sorted: Vec<QueuedReq> = q.sorted_queue(m);
        let head = sorted
            .iter()
            .map(|r| r.arrival_us)
            .fold(f64::INFINITY, f64::min);
        let finish = *now + 5_000.0;
        let met: f64 = sorted
            .iter()
            .take(DISPATCH_BATCH)
            .filter(|r| r.deadline_us >= finish)
            .map(|r| classes[r.class].weight)
            .sum();
        let s = met - 1e-9 * head;
        if s > best_s {
            best_s = s;
            best_m = m;
        }
    }
    let taken = q.take_batch(best_m, DISPATCH_BATCH, true);
    let n = taken.len();
    for r in &taken {
        *now += 1.0;
        q.offer(r.req, r.tenant, r.model, r.class, *now);
    }
    n
}

/// The dispatch ns/req micro-bench: reference vs indexed at each queue
/// depth, with table output and (write mode) the `BENCH_fleet.json`
/// baseline, (gate mode) the `--ci` regression check.
fn dispatch_bench(short: bool, gate: bool) {
    let it = |n: usize| if short { (n / 10).max(5) } else { n };
    let mut t = Table::new(
        "indexed dispatch core — ns per dispatched request",
        &["queue depth", "reference", "indexed", "speedup"],
    );
    let mut lines: Vec<(String, f64)> = Vec::new();
    for &qd in &DISPATCH_QS {
        let classes = dispatch_classes(qd);
        let mut iq = AdmissionQueues::new(
            &classes, ShedPolicy::RejectNew, DISPATCH_MODELS);
        let mut rq = ReferenceQueues::new(
            &classes, ShedPolicy::RejectNew, DISPATCH_MODELS);
        let mut now = 0.0f64;
        for i in 0..qd {
            now += 1.0;
            let (m, c) = (i % DISPATCH_MODELS, (i / DISPATCH_MODELS) % 3);
            iq.offer(i, 0, m, c, now);
            rq.offer(i, 0, m, c, now);
        }
        // Iteration budget shrinks with depth (the reference cycle is
        // O(Q log Q)); both sides use the same count for fairness.
        let iters = it(match qd {
            100 => 20_000,
            1_000 => 4_000,
            _ => 400,
        });
        let mut rnow = now;
        let rres: BenchResult = bench(
            &format!("reference dispatch (Q={qd})"), 20, iters, || {
                std::hint::black_box(reference_cycle(
                    &mut rq, &classes, &mut rnow));
            });
        let mut inow = now;
        let ires: BenchResult = bench(
            &format!("indexed dispatch (Q={qd})"), 20, iters, || {
                std::hint::black_box(indexed_cycle(
                    &mut iq, &classes, &mut inow));
            });
        let ref_ns = rres.mean_us * 1000.0 / DISPATCH_BATCH as f64;
        let idx_ns = ires.mean_us * 1000.0 / DISPATCH_BATCH as f64;
        t.row(vec![
            format!("{qd}"),
            format!("{ref_ns:.0} ns/req"),
            format!("{idx_ns:.0} ns/req"),
            format!("{:.1}x", ref_ns / idx_ns.max(1e-9)),
        ]);
        lines.push((format!("dispatch_reference_q{qd}"), ref_ns));
        lines.push((format!("dispatch_indexed_q{qd}"), idx_ns));
    }
    t.print();

    let baseline_path = sparoa::repo_root().join("BENCH_fleet.json");
    let find = |key: &str| -> Option<f64> {
        lines.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    };
    if gate {
        // Mirror of the hotpath gate: compare indexed/reference ratios
        // so runner hardware cancels; refuse missing/empty/bootstrap
        // baselines (`baseline::refuse` — CI regenerates one first,
        // see ci.yml).
        let Some((_, old_ratio)) = baseline::committed(
            &baseline_path, CI_IDX_KEY, CI_REF_KEY) else {
            baseline::refuse(&baseline_path, "fig_fleet",
                             CI_IDX_KEY, CI_REF_KEY);
        };
        let (idx, rf) = (find(CI_IDX_KEY).unwrap(),
                         find(CI_REF_KEY).unwrap());
        baseline::gate_ratio(
            "fig_fleet",
            &format!("{CI_IDX_KEY}/{CI_REF_KEY}"),
            idx / rf,
            old_ratio,
            CI_REGRESSION_FACTOR,
        );
        if rf < CI_SPEEDUP_FLOOR * idx {
            eprintln!(
                "fleet dispatch floor: indexed path only {:.1}x faster \
                 than the reference clone+sort at Q=10^4 \
                 (acceptance floor {CI_SPEEDUP_FLOOR}x)",
                rf / idx.max(1e-9)
            );
            std::process::exit(1);
        }
    } else {
        // Refresh the committed baseline; `baseline::write` refuses an
        // empty map (a `{}` placeholder silently disarms the gate).
        baseline::write(&baseline_path, "indexed-dispatch", &lines);
    }
}
