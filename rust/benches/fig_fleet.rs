//! Fleet extension figure: distributed multi-board serving under
//! increasing load — router policies compared, autoscaled vs static
//! replica placement.
//!
//! Like `fig13_multimodel` this bench never skips: it uses the
//! artifact models when `make artifacts` has run and the synthetic
//! demo fleet otherwise.  Emits the fleet-level JSON report (aggregate
//! + per-board attainment/utilization/shed rate, replica-count
//! timeline) on stdout after the tables.

use sparoa::bench_support::Table;
use sparoa::serve::{
    demo, merge_arrivals, run_fleet, AutoscalePolicy, FleetOptions,
    RouterPolicy,
};
use sparoa::util::json::{self, Value};
use std::collections::BTreeMap;

fn main() {
    let device = "agx_orin";
    let boards = 4usize;
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");
    let classes = demo::classes();

    let mut t = Table::new(
        &format!(
            "fleet — {} boards x {} models on {}",
            boards, registry.len(), device
        ),
        &["load", "router", "autoscale", "attainment", "shed",
          "mean batch", "gpu util", "scale events", "mean replicas"],
    );
    let mut scenarios = Vec::new();
    for load in [0.5, 2.0, 4.0] {
        let tenants = demo::tenants(&registry, load, 300, 23, None)
            .expect("building tenants");
        let arrivals = merge_arrivals(&tenants, 23);
        // Three routers autoscaled, plus the static ablation on the
        // cost-aware router.
        let runs: Vec<(RouterPolicy, bool)> = vec![
            (RouterPolicy::RoundRobin, true),
            (RouterPolicy::JoinShortestQueue, true),
            (RouterPolicy::CostAware, true),
            (RouterPolicy::CostAware, false),
        ];
        let mut snaps = Vec::new();
        for (router, autoscaled) in runs {
            let mut opts = FleetOptions::new(boards, registry.len());
            opts.router = router;
            if autoscaled {
                opts.autoscale = Some(AutoscalePolicy::default());
            }
            let snap = run_fleet(
                &registry, &classes, &tenants, &arrivals, &opts)
                .expect("fleet run");
            let reps: Vec<String> = snap
                .mean_replicas
                .iter()
                .map(|x| format!("{x:.1}"))
                .collect();
            t.row(vec![
                format!("x{load:.1}"),
                snap.router.clone(),
                if autoscaled { "on" } else { "off" }.into(),
                format!("{:.1}%", 100.0 * snap.aggregate_attainment()),
                snap.total_shed().to_string(),
                format!("{:.1}", snap.aggregate.mean_batch()),
                format!("{:.0}%", 100.0 * snap.mean_gpu_util()),
                snap.scale_events.len().to_string(),
                reps.join("/"),
            ]);
            snaps.push(snap);
        }
        scenarios.push((load, snaps));
    }
    t.print();

    // Headline: cost-aware routing vs round-robin at the top load.
    let top = scenarios.last().unwrap();
    let (rr, cost) = (
        top.1[0].aggregate_attainment(),
        top.1[2].aggregate_attainment(),
    );
    println!(
        "\nAt x{:.1} load: cost-aware router {:.1}% vs round-robin \
         {:.1}% aggregate attainment ({:+.1} pts); autoscale sheds {} \
         vs {} static.",
        top.0,
        100.0 * cost,
        100.0 * rr,
        100.0 * (cost - rr),
        top.1[2].total_shed(),
        top.1[3].total_shed(),
    );

    // Machine-readable fleet report.
    let report = Value::Obj(
        [
            ("bench".to_string(), Value::Str("fig_fleet".into())),
            ("device".to_string(), Value::Str(device.into())),
            ("boards".to_string(), Value::Num(boards as f64)),
            (
                "scenarios".to_string(),
                Value::Arr(
                    scenarios
                        .iter()
                        .map(|(load, snaps)| {
                            let mut o = BTreeMap::new();
                            o.insert("load".into(), Value::Num(*load));
                            o.insert(
                                "runs".into(),
                                Value::Arr(snaps
                                    .iter()
                                    .map(|s| s.to_json())
                                    .collect()),
                            );
                            Value::Obj(o)
                        })
                        .collect(),
                ),
            ),
        ]
        .into_iter()
        .collect(),
    );
    println!("\n{}", json::to_string(&report));
}
