//! Figure 9: component ablation.  Baseline = plain hybrid engine with
//! fixed hand-set thresholds (no predictor, no learned scheduler);
//! +Predictor = learned thresholds drive the static plan; +Scheduler =
//! the full SAC policy.  Paper: MobileNetV2 gains 1.4-1.6x from the
//! predictor and 1.9-2.4x total; ViT-B16 1.7-2.1x total; gains are
//! smaller on the memory-limited Orin Nano.

use sparoa::baselines::Baseline;
use sparoa::bench_support::{load_env, Table, DEVICES};
use sparoa::engine::sim::simulate;
use sparoa::predictor::ThresholdPredictor;
use sparoa::runtime::Runtime;
use sparoa::scheduler::{threshold::ThresholdScheduler, ScheduleCtx,
                        Scheduler};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let rt = Runtime::new(&sparoa::artifacts_dir()).unwrap();
    let predictor = ThresholdPredictor::new(&rt);
    let mut t = Table::new(
        "Fig.9 — ablation speedup over plain hybrid engine",
        &["device", "model", "baseline (us)", "+Predictor", "+Scheduler"],
    );
    for device in DEVICES {
        let dev = reg.get(device).unwrap();
        for model in ["mobilenet_v2", "vit_b16"] {
            let g = zoo.get(model).unwrap();
            let opts = Baseline::SparoaNoRl.options(1, 1);
            // Stage 0: fixed hand-set thresholds (paper §3's strawman).
            let base_sched = ThresholdScheduler.schedule(&ScheduleCtx {
                graph: g, device: dev, thresholds: None, batch: 1,
            });
            let base = simulate(g, dev, &base_sched, &opts).makespan_us;
            // Stage 1: + learned per-op thresholds.
            let th = predictor.predict_graph(g).unwrap();
            let pred_sched = ThresholdScheduler.schedule(&ScheduleCtx {
                graph: g, device: dev, thresholds: Some(&th), batch: 1,
            });
            let with_pred = simulate(g, dev, &pred_sched, &opts).makespan_us;
            // Stage 2: + SAC scheduler (full engine options).
            let (_, full) = Baseline::Sparoa.run(g, dev, Some(&th), 1, 40);
            t.row(vec![
                device.into(),
                model.into(),
                format!("{base:.0}"),
                format!("{:.2}x", base / with_pred),
                format!("{:.2}x", base / full.makespan_us),
            ]);
        }
    }
    t.print();
    println!(
        "\nExpected shape (paper Fig.9): each stage compounds; MobileNetV2 \
         gains most; Orin Nano gains are capped by memory limits."
    );
}
