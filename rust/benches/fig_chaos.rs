//! Chaos figure: the demo tenant mix on an 8-board fleet under
//! injected faults — the robustness extension's headline numbers.
//!
//! Arms:
//! * `fault-free` — the control; the same stream with no plan armed;
//! * `crash+rejoin` — board 2 fail-stops at 40% of the horizon and
//!   rejoins at 70%: queued work drains back through the front tier
//!   onto survivors, lost in-flight batches get deadline-aware
//!   retries;
//! * `crash, no failover` — the same plan with the failover ablation
//!   off: every stranded request fails on the spot (still conserved);
//! * `degraded gpu` — board 1 permanently loses its GPU lane at 25%
//!   and serves CPU-only for the rest of the run;
//! * an MTTF/MTTR sweep — seeded exponential crash/rejoin schedules
//!   across all boards at three failure rates.
//!
//! Every arm is checked for exact conservation: admitted == served +
//! shed + failed, nothing silently lost.  The virtual-time fleet is
//! deterministic, so every number is machine-independent.  Full runs
//! write the measured lines to `BENCH_chaos.json`; `--ci` re-checks
//! conservation and the failover orderings, gates the single-crash
//! attainment loss against a fixed budget, and refuses a
//! missing/placeholder baseline.

use sparoa::bench_support::{baseline, Table};
use sparoa::device::Proc;
use sparoa::faults::{Fault, FaultPlan};
use sparoa::serve::{
    demo, merge_arrivals, run_fleet, FleetOptions, FleetSnapshot,
};

const BOARDS: usize = 8;
const LOAD: f64 = 2.0;
const REQUESTS: usize = 500;
const SEED: u64 = 23;
/// `--ci` budget on attainment lost to one mid-run board crash (with
/// rejoin and failover) versus the fault-free control, in attainment
/// points.  The runs are deterministic; the budget absorbs
/// intentional retunes only.
const CI_ATTAIN_LOSS_BUDGET: f64 = 0.10;
/// `--ci` budget on the crash/fault-free attainment ratio drift
/// against the committed baseline.
const CI_RATIO_BUDGET: f64 = 1.02;
const CI_NUM_KEY: &str = "attain_crash_rejoin";
const CI_DEN_KEY: &str = "attain_fault_free";

struct Arm {
    name: &'static str,
    snap: FleetSnapshot,
}

fn conserved(name: &str, snap: &FleetSnapshot, n: usize) -> bool {
    let offered = snap.aggregate.total_offered();
    let settled = snap.aggregate.total_served()
        + snap.aggregate.total_shed()
        + snap.total_failed();
    if offered as usize != n || settled != offered {
        eprintln!(
            "fig_chaos conservation broken in `{name}`: {n} arrivals, \
             offered {offered}, served {} + shed {} + failed {} = \
             {settled}",
            snap.aggregate.total_served(),
            snap.aggregate.total_shed(),
            snap.total_failed()
        );
        return false;
    }
    true
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");
    // `--write-baseline` is accepted for CLI symmetry with the other
    // gated benches; every non-ci run refreshes the baseline.

    let device = "agx_orin";
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");
    let classes = demo::classes();
    let tenants = demo::tenants(&registry, LOAD, REQUESTS, SEED, None)
        .expect("building tenants");
    let arrivals = merge_arrivals(&tenants, SEED);
    let horizon_us = arrivals.last().expect("non-empty stream").at_us;

    let run = |faults: FaultPlan, failover: bool| -> FleetSnapshot {
        let mut opts = FleetOptions::new(BOARDS, registry.len());
        // Every model warm on every board, so any single failure
        // leaves survivors hosting the whole registry.
        opts.placement = vec![(0..registry.len()).collect(); BOARDS];
        opts.faults = faults;
        opts.failover = failover;
        run_fleet(&registry, &classes, &tenants, &arrivals, &opts)
            .expect("fleet run")
    };

    let crash_plan = FaultPlan {
        faults: vec![Fault::Crash {
            board: 2,
            at_us: 0.4 * horizon_us,
            rejoin_us: Some(0.7 * horizon_us),
        }],
    };
    let degraded_plan = FaultPlan {
        faults: vec![Fault::LaneLoss {
            board: 1,
            proc: Proc::Gpu,
            at_us: 0.25 * horizon_us,
            restore_us: None,
        }],
    };
    let horizon_s = horizon_us / 1e6;
    let mut arms = vec![
        Arm { name: "fault-free", snap: run(FaultPlan::none(), true) },
        Arm { name: "crash+rejoin", snap: run(crash_plan.clone(), true) },
        Arm {
            name: "crash, no failover",
            snap: run(crash_plan, false),
        },
        Arm { name: "degraded gpu", snap: run(degraded_plan, true) },
    ];
    // MTTF/MTTR sweep: mean up-time at 4x / 2x / 1x the horizon (one
    // expected crash per board at 1x), mean repair 15% of the horizon.
    let sweep = [("mttf 4.0x", 4.0), ("mttf 2.0x", 2.0),
                 ("mttf 1.0x", 1.0)];
    for (name, mult) in sweep {
        let plan = FaultPlan::sample_mttf_mttr(
            BOARDS,
            mult * horizon_s,
            0.15 * horizon_s,
            horizon_us,
            SEED,
        )
        .expect("sampling MTTF/MTTR plan");
        arms.push(Arm { name, snap: run(plan, true) });
    }

    let mut ok = true;
    for a in &arms {
        ok &= conserved(a.name, &a.snap, arrivals.len());
    }

    let mut t = Table::new(
        &format!(
            "chaos — {BOARDS} boards x {} models on {device}, load \
             x{LOAD:.1}, {} requests",
            registry.len(),
            arrivals.len()
        ),
        &["arm", "attainment", "served", "shed", "failed", "failovers",
          "requeued", "retries", "down ms"],
    );
    for a in &arms {
        t.row(vec![
            a.name.into(),
            format!("{:.1}%", 100.0 * a.snap.aggregate_attainment()),
            a.snap.aggregate.total_served().to_string(),
            a.snap.total_shed().to_string(),
            a.snap.total_failed().to_string(),
            a.snap.total_failovers().to_string(),
            a.snap.total_requeued().to_string(),
            a.snap.total_retries().to_string(),
            format!("{:.1}", a.snap.total_downtime_us() / 1e3),
        ]);
    }
    t.print();

    let (clean, crash, ctl, degraded) =
        (&arms[0].snap, &arms[1].snap, &arms[2].snap, &arms[3].snap);
    println!(
        "\none board crash (12.5% of the fleet, down 30% of the run): \
         attainment {:.1}% vs {:.1}% fault-free ({:+.1} pts); \
         failover requeued {} + retried {} vs the no-failover control \
         failing {} outright ({:.1}%); GPU-degraded board holds \
         {:.1}%.",
        100.0 * crash.aggregate_attainment(),
        100.0 * clean.aggregate_attainment(),
        100.0
            * (crash.aggregate_attainment()
                - clean.aggregate_attainment()),
        crash.total_requeued(),
        crash.total_retries(),
        ctl.total_failed(),
        100.0 * ctl.aggregate_attainment(),
        100.0 * degraded.aggregate_attainment(),
    );

    let lines: Vec<(String, f64)> = vec![
        ("attain_fault_free".into(), clean.aggregate_attainment()),
        ("attain_crash_rejoin".into(), crash.aggregate_attainment()),
        ("attain_crash_no_failover".into(),
         ctl.aggregate_attainment()),
        ("attain_degraded_gpu".into(),
         degraded.aggregate_attainment()),
        ("served_crash_rejoin".into(),
         crash.aggregate.total_served() as f64),
        ("requeued_crash_rejoin".into(),
         crash.total_requeued() as f64),
        ("retries_crash_rejoin".into(), crash.total_retries() as f64),
        ("failed_crash_no_failover".into(),
         ctl.total_failed() as f64),
        ("downtime_ms_crash_rejoin".into(),
         crash.total_downtime_us() / 1e3),
        ("attain_mttf_4x".into(),
         arms[4].snap.aggregate_attainment()),
        ("attain_mttf_2x".into(),
         arms[5].snap.aggregate_attainment()),
        ("attain_mttf_1x".into(),
         arms[6].snap.aggregate_attainment()),
    ];

    let path = sparoa::repo_root().join("BENCH_chaos.json");
    if ci {
        // Hard invariants — the PR acceptance criteria, deterministic
        // on any runner.
        let mut bad = Vec::new();
        if !ok {
            bad.push("conservation failed in at least one arm".into());
        }
        if crash.total_requeued() + crash.aggregate.lost_batches == 0 {
            bad.push("the mid-run crash stranded no work".into());
        }
        if crash.aggregate.total_served()
            <= ctl.aggregate.total_served()
        {
            bad.push(format!(
                "failover served {} <= no-failover {}",
                crash.aggregate.total_served(),
                ctl.aggregate.total_served()
            ));
        }
        if crash.aggregate_attainment() < ctl.aggregate_attainment() {
            bad.push(format!(
                "failover attainment {:.4} < no-failover {:.4}",
                crash.aggregate_attainment(),
                ctl.aggregate_attainment()
            ));
        }
        if clean.aggregate_attainment() - crash.aggregate_attainment()
            > CI_ATTAIN_LOSS_BUDGET
        {
            bad.push(format!(
                "single crash cost {:.3} attainment (> {} budget)",
                clean.aggregate_attainment()
                    - crash.aggregate_attainment(),
                CI_ATTAIN_LOSS_BUDGET
            ));
        }
        if !bad.is_empty() {
            for b in &bad {
                eprintln!("fig_chaos invariant failed: {b}");
            }
            std::process::exit(1);
        }
        // Then the committed-baseline drift gate (refuses a missing or
        // bootstrap-placeholder baseline — CI regenerates one first).
        let Some((_, old_ratio)) =
            baseline::committed(&path, CI_NUM_KEY, CI_DEN_KEY)
        else {
            baseline::refuse(&path, "fig_chaos", CI_NUM_KEY,
                             CI_DEN_KEY);
        };
        let new_ratio = crash.aggregate_attainment()
            / clean.aggregate_attainment().max(1e-12);
        baseline::gate_ratio(
            "fig_chaos",
            &format!("{CI_NUM_KEY}/{CI_DEN_KEY}"),
            new_ratio,
            old_ratio,
            CI_RATIO_BUDGET,
        );
    } else {
        if !ok {
            std::process::exit(1);
        }
        // Full runs and `--write-baseline` both refresh the committed
        // baseline; `baseline::write` refuses an empty map, so a `{}`
        // placeholder can never silently disarm the `--ci` gate.
        baseline::write(&path, "chaos", &lines);
    }
}
