//! Figure 12: memory usage per baseline on AGX Orin.  Paper: SparOA's
//! sharded co-execution storage costs ~23.1% more memory than GPU-Only,
//! comparable to IOS/POS and below CoDL (which replicates more state).

use sparoa::baselines::{Baseline, ALL};
use sparoa::bench_support::{load_env, Table, MODELS};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let dev = reg.get("agx_orin").unwrap();
    let mut t = Table::new(
        "Fig.12 — peak memory footprint (MB, AGX Orin)",
        &["baseline", "resnet18", "mbv3-s", "mbv2", "vit_b16", "swin_t"],
    );
    let mut mem = vec![vec![0.0f64; MODELS.len()]; ALL.len()];
    for (mi, model) in MODELS.iter().enumerate() {
        let g = zoo.get(model).unwrap();
        for (bi, b) in ALL.iter().enumerate() {
            let ep = if *b == Baseline::Sparoa { 30 } else { 0 };
            let (_, rep) = b.run(g, dev, None, 1, ep);
            mem[bi][mi] = rep.total_mem_mb();
        }
    }
    for (bi, b) in ALL.iter().enumerate() {
        let mut row = vec![b.name().to_string()];
        for mi in 0..MODELS.len() {
            row.push(format!("{:.0}", mem[bi][mi]));
        }
        t.row(row);
    }
    t.print();
    let idx = |target: Baseline| ALL.iter().position(|b| *b == target)
        .unwrap();
    let overheads: Vec<f64> = (0..MODELS.len())
        .map(|mi| {
            100.0 * (mem[idx(Baseline::Sparoa)][mi]
                     / mem[idx(Baseline::GpuOnlyPyTorch)][mi] - 1.0)
        })
        .collect();
    let mean = overheads.iter().sum::<f64>() / overheads.len() as f64;
    println!(
        "\nSparOA memory overhead vs GPU-Only: mean {mean:.1}% \
         (paper ~23.1%); should sit below CoDL and near IOS/POS."
    );
}
