//! fig_scale — the million-request scale harness for the virtual-time
//! profiler (`sparoa::obs`).
//!
//! Pushes `run_fleet` to 1e6 requests across 64 boards twice — tracer
//! off, then tracer on (bounded per-board rings) — and reports:
//!
//! * wall time + virtual-requests/sec of both runs and their ratio
//!   (`trace_overhead_ratio`, the cost of *enabled* tracing);
//! * trace ingest rate (`events_per_sec`) and `bytes_per_request`
//!   of the retained ring contents;
//! * `tracer_disabled_overhead`: a p50 micro-pair (hot simulate loop
//!   with vs without a disabled `Tracer::record` call) — the
//!   "zero cost when off" claim, measured.
//!
//! Modes (mirroring the hotpath bench): full runs refresh
//! `BENCH_scale.json` at the repo root; `--write-baseline` bootstraps
//! it; `--ci` additionally gates: the disabled-tracer micro ratio must
//! come in <= 1.05x (best of three attempts, p50 — single-sample noise
//! must not fail CI) and the traced run must ingest >= 10k events/sec.

use sparoa::api::SessionBuilder;
use sparoa::bench_support::{baseline, bench, device_profile};
use sparoa::device::Proc;
use sparoa::engine::costs::{CostTable, SimScratch};
use sparoa::engine::sim::SimOptions;
use sparoa::graph::ModelGraph;
use sparoa::obs::{TraceConfig, TraceEvent, TraceRecord, Tracer, NONE};
use sparoa::scheduler::Schedule;
use sparoa::serve::{
    merge_arrivals, run_fleet, spread_placement, ArrivalPattern,
    FleetOptions, FleetSnapshot, ModelRegistry, SloClass, Tenant,
};

const BOARDS: usize = 64;
const TOTAL_REQUESTS: usize = 1_000_000;
/// Per-board ring capacity for the traced run: 64 boards at the
/// default 256k-record ring would hold ~512 MB of records; 16k/board
/// (~32 MB total) exercises the drop-and-count path at this scale.
const RING_CAPACITY: usize = 16_384;
/// `--ci` floor on the traced run's event ingest rate.  Deliberately
/// conservative (real runs ingest orders of magnitude more): it only
/// trips when tracing collapses, not when the runner is slow.
const EVENTS_PER_SEC_FLOOR: f64 = 10_000.0;
/// `--ci` ceiling on the disabled-tracer micro ratio.
const DISABLED_OVERHEAD_GATE: f64 = 1.05;
const GATE_ATTEMPTS: usize = 3;

/// Four light synthetic models sized so 1e6 requests stay in seconds
/// of host time while keeping all 128 lanes busy.
fn registry4() -> ModelRegistry {
    let dev = device_profile("agx_orin");
    let mut reg = ModelRegistry::new();
    for (name, blocks, scale, sparsity) in [
        ("s_a", 4, 0.4, 0.6),
        ("s_b", 4, 0.6, 0.5),
        ("s_c", 5, 0.8, 0.4),
        ("s_d", 4, 0.3, 0.7),
    ] {
        let s = SessionBuilder::new()
            .with_graph(ModelGraph::synthetic(name, blocks, scale, sparsity))
            .with_device(dev.clone())
            .policy("greedy")
            .build()
            .unwrap();
        reg.register(s).unwrap();
    }
    reg
}

/// Max req/s of one replica's best lane at the full Alg. 2 batch.
fn rate_of(reg: &ModelRegistry, m: usize) -> f64 {
    let e = reg.get(m);
    let gcap = e.gpu_batch_cap.max(1);
    let gpu =
        gcap as f64 / e.latency_us(Proc::Gpu, gcap).unwrap() * 1e6;
    let ccap = e.cpu_batch_cap.max(1);
    let cpu =
        ccap as f64 / e.latency_us(Proc::Cpu, ccap).unwrap() * 1e6;
    gpu.max(cpu)
}

fn workload(
    reg: &ModelRegistry,
) -> (Vec<SloClass>, Vec<Tenant>, Vec<sparoa::serve::Arrival>) {
    let lat = reg.get(0).cheapest_latency_us(1).unwrap();
    let classes = vec![
        SloClass::new("standard", 200.0 * lat, 4096, 2.0),
        SloClass::new("best-effort", 600.0 * lat, 8192, 1.0),
    ];
    let per_tenant = TOTAL_REQUESTS / 4;
    let tenants: Vec<Tenant> = (0..4)
        .map(|m| Tenant {
            name: format!("t{m}"),
            model: reg.get(m).name.clone(),
            class: m % 2,
            // ~half the fleet-wide capacity of each model once the
            // four tenants share every board's two lanes.
            pattern: ArrivalPattern::Poisson {
                rate_per_s: 0.12 * BOARDS as f64 * rate_of(reg, m),
                n: per_tenant,
            },
        })
        .collect();
    let arrivals = merge_arrivals(&tenants, 41);
    assert_eq!(arrivals.len(), TOTAL_REQUESTS);
    (classes, tenants, arrivals)
}

fn run_once(
    reg: &ModelRegistry,
    classes: &[SloClass],
    tenants: &[Tenant],
    arrivals: &[sparoa::serve::Arrival],
    trace: Option<TraceConfig>,
) -> (FleetSnapshot, f64) {
    let mut opts = FleetOptions::new(BOARDS, reg.len());
    opts.placement = spread_placement(BOARDS, &[BOARDS; 4]);
    opts.trace = trace;
    let t0 = std::time::Instant::now();
    let snap = run_fleet(reg, classes, tenants, arrivals, &opts)
        .expect("fleet run");
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        snap.aggregate.total_served() + snap.aggregate.total_shed(),
        TOTAL_REQUESTS as u64,
        "conservation broke at scale"
    );
    (snap, wall_s)
}

/// p50 micro-pair: the hot simulate loop with vs without one disabled
/// `Tracer::record` per iteration.
fn disabled_overhead_ratio() -> f64 {
    let g = ModelGraph::synthetic("scale_syn", 50, 1.0, 0.4);
    let dev = device_profile("agx_orin");
    let opts = SimOptions { record_timings: false, ..Default::default() };
    let table = CostTable::build(&g, &dev, &opts);
    let sched = Schedule::uniform(&g, 1.0, "gpu");
    let mut scratch = SimScratch::new();
    let base = bench("fastpath (no tracer)", 50, 2000, || {
        table.simulate_into(&sched, &mut scratch);
        std::hint::black_box(scratch.report.makespan_us);
    });
    let mut tracer = Tracer::disabled();
    let with = bench("fastpath + disabled tracer", 50, 2000, || {
        tracer.record(0.0, NONE, NONE, TraceEvent::Admit);
        table.simulate_into(&sched, &mut scratch);
        std::hint::black_box(scratch.report.makespan_us);
    });
    with.p50_us / base.p50_us.max(1e-12)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");
    let write_baseline = args.iter().any(|a| a == "--write-baseline");

    let reg = registry4();
    let (classes, tenants, arrivals) = workload(&reg);
    println!(
        "=== fig_scale — {} requests x {} boards ===",
        TOTAL_REQUESTS, BOARDS
    );

    let (_plain, untraced_s) =
        run_once(&reg, &classes, &tenants, &arrivals, None);
    let (traced, traced_s) = run_once(
        &reg,
        &classes,
        &tenants,
        &arrivals,
        Some(TraceConfig { capacity: RING_CAPACITY }),
    );

    let kept: usize =
        traced.boards.iter().map(|b| b.trace_events.len()).sum();
    let dropped: u64 =
        traced.boards.iter().map(|b| b.trace_dropped).sum();
    let recorded = kept as u64 + dropped;
    let events_per_sec = recorded as f64 / traced_s.max(1e-9);
    let bytes_per_request = (kept * std::mem::size_of::<TraceRecord>())
        as f64
        / TOTAL_REQUESTS as f64;
    let trace_overhead = traced_s / untraced_s.max(1e-9);
    for b in &traced.boards {
        assert!(b.trace_events.len() <= RING_CAPACITY,
                "ring exceeded its capacity");
    }

    println!(
        "scale_untraced: {untraced_s:.2} s ({:.0} req/s)",
        TOTAL_REQUESTS as f64 / untraced_s.max(1e-9)
    );
    println!(
        "scale_traced:   {traced_s:.2} s ({:.0} req/s)",
        TOTAL_REQUESTS as f64 / traced_s.max(1e-9)
    );
    println!("trace_overhead_ratio: {trace_overhead:.3}x (tracing on)");
    println!(
        "events: {recorded} recorded ({kept} kept, {dropped} dropped \
         by the bounded rings) -> {events_per_sec:.0} events/sec"
    );
    println!("bytes_per_request: {bytes_per_request:.1} (retained)");

    // Disabled-tracer micro-pair; best of three p50 attempts in gate
    // modes so one noisy sample can't fail CI.
    let attempts = if ci { GATE_ATTEMPTS } else { 1 };
    let mut disabled_ratio = f64::INFINITY;
    for _ in 0..attempts {
        disabled_ratio = disabled_ratio.min(disabled_overhead_ratio());
        if disabled_ratio <= DISABLED_OVERHEAD_GATE {
            break;
        }
    }
    println!(
        "tracer_disabled_overhead: {disabled_ratio:.3}x (p50 micro \
         pair, gate <= {DISABLED_OVERHEAD_GATE}x)"
    );

    if ci {
        let mut failed = false;
        if disabled_ratio > DISABLED_OVERHEAD_GATE {
            eprintln!(
                "fig_scale ci gate: disabled tracer costs \
                 {disabled_ratio:.3}x > {DISABLED_OVERHEAD_GATE}x \
                 on the hot loop"
            );
            failed = true;
        }
        if events_per_sec < EVENTS_PER_SEC_FLOOR {
            eprintln!(
                "fig_scale ci gate: ingest {events_per_sec:.0} \
                 events/sec < floor {EVENTS_PER_SEC_FLOOR:.0}"
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "ci gate: disabled-tracer {disabled_ratio:.3}x <= \
             {DISABLED_OVERHEAD_GATE}x and ingest \
             {events_per_sec:.0} >= {EVENTS_PER_SEC_FLOOR:.0} \
             events/sec — green"
        );
    }
    if !ci || write_baseline {
        let lines = vec![
            ("scale_untraced_ns".to_string(), untraced_s * 1e9),
            ("scale_traced_ns".to_string(), traced_s * 1e9),
            ("trace_overhead_ratio".to_string(), trace_overhead),
            ("events_per_sec".to_string(), events_per_sec),
            ("bytes_per_request".to_string(), bytes_per_request),
            ("tracer_disabled_overhead".to_string(), disabled_ratio),
        ];
        let path = sparoa::repo_root().join("BENCH_scale.json");
        baseline::write(&path, "scale_fleet", &lines);
    }
}
