//! Figure 13 (extension): multi-tenant SLO attainment under increasing
//! load — cross-model sparsity-aware cluster scheduling vs N independent
//! single-queue batchers on a static CPU/GPU split.
//!
//! Unlike the paper-figure benches this one never skips: it uses the
//! artifact models when `make artifacts` has run and the synthetic demo
//! fleet otherwise.  Emits a JSON report (per-class p50/p95/p99, shed
//! rate, attainment) on stdout after the tables.

use sparoa::bench_support::Table;
use sparoa::serve::{
    demo, merge_arrivals, run_cluster, ClusterOptions, ClusterPolicy,
};
use sparoa::util::json::{self, Value};
use std::collections::BTreeMap;

fn main() {
    let device = "agx_orin";
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");
    let classes = demo::classes();

    let mut t = Table::new(
        &format!(
            "Fig.13 — multi-model SLO attainment, {} models on {}",
            registry.len(), device
        ),
        &["load", "policy", "attainment", "shed", "p99(interactive)",
          "cpu util", "gpu util", "mean batch"],
    );
    let mut scenarios = Vec::new();
    for load in [0.5, 1.5, 3.0] {
        let tenants = demo::tenants(&registry, load, 400, 23, None)
            .expect("building tenants");
        let arrivals = merge_arrivals(&tenants, 23);
        let mut per_policy = Vec::new();
        for policy in
            [ClusterPolicy::SparsityAware, ClusterPolicy::StaticSplit]
        {
            let snap = run_cluster(&registry, &classes, &tenants,
                &arrivals,
                &ClusterOptions { policy, ..Default::default() })
                .expect("cluster run");
            t.row(vec![
                format!("x{load:.1}"),
                snap.policy.clone(),
                format!("{:.1}%", 100.0 * snap.aggregate_attainment()),
                snap.total_shed().to_string(),
                snap.per_class[0].percentile_str(99.0),
                format!("{:.0}%", 100.0 * snap.cpu_util()),
                format!("{:.0}%", 100.0 * snap.gpu_util()),
                format!("{:.1}", snap.mean_batch()),
            ]);
            per_policy.push(snap);
        }
        scenarios.push((load, per_policy));
    }
    t.print();

    // Headline: the cross-model scheduler must win under overload.
    let overload = scenarios.last().unwrap();
    let (dyn_a, stat_a) = (
        overload.1[0].aggregate_attainment(),
        overload.1[1].aggregate_attainment(),
    );
    println!(
        "\nAt x{:.1} load: cluster {:.1}% vs static split {:.1}% \
         aggregate attainment ({:+.1} pts).",
        overload.0,
        100.0 * dyn_a,
        100.0 * stat_a,
        100.0 * (dyn_a - stat_a)
    );

    // Machine-readable report.
    let report = Value::Obj(
        [
            ("bench".to_string(), Value::Str("fig13_multimodel".into())),
            ("device".to_string(), Value::Str(device.into())),
            (
                "scenarios".to_string(),
                Value::Arr(
                    scenarios
                        .iter()
                        .map(|(load, snaps)| {
                            let mut o = BTreeMap::new();
                            o.insert("load".into(), Value::Num(*load));
                            o.insert(
                                "policies".into(),
                                Value::Arr(snaps
                                    .iter()
                                    .map(|s| s.to_json())
                                    .collect()),
                            );
                            Value::Obj(o)
                        })
                        .collect(),
                ),
            ),
        ]
        .into_iter()
        .collect(),
    );
    println!("\n{}", json::to_string(&report));
}
