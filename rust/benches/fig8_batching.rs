//! Figure 8: end-to-end batching overhead as a share of total serving
//! time.  Paper: gradient-based dynamic batching keeps overhead at
//! 2.3-8.6% vs 15.4-28.7% for static fixed-batch frameworks, on both
//! devices.  Also exercises Algorithm 2's batch-size search.

use sparoa::bench_support::{load_env, Table, DEVICES, MODELS};
use sparoa::engine::batching::{optimize_batch, BatchConstraints};
use sparoa::engine::sim::SimOptions;
use sparoa::scheduler::Schedule;
use sparoa::server::{batcher::poisson_stream, run_batching_sim, BatchPolicy};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let mut t = Table::new(
        "Fig.8 — batching overhead share of end-to-end time",
        &["device", "model", "static fixed-32", "SparOA dynamic",
          "alg2 batch"],
    );
    let mut stat_all = Vec::new();
    let mut dyn_all = Vec::new();
    for device in DEVICES {
        let dev = reg.get(device).unwrap();
        for model in MODELS {
            let g = zoo.get(model).unwrap();
            let sched = Schedule::uniform(g, 1.0, "gpu");
            let opts = SimOptions::default();
            // Alg. 2 picks the dynamic cap from the model/hardware.
            let plan = optimize_batch(g, dev, &sched, &opts, 8,
                                      &BatchConstraints::for_device(dev));
            let reqs = poisson_stream(300, 250.0, 17);
            let fixed = run_batching_sim(g, dev, &sched, &opts, &reqs,
                &BatchPolicy::Fixed { size: 32, timeout_us: 25_000.0 });
            let dynamic = run_batching_sim(g, dev, &sched, &opts, &reqs,
                &BatchPolicy::Dynamic {
                    max: plan.batch.max(1),
                    optimizer_cost_us: 30.0,
                });
            stat_all.push(fixed.overhead_pct());
            dyn_all.push(dynamic.overhead_pct());
            t.row(vec![
                device.into(),
                model.into(),
                format!("{:.1}%", fixed.overhead_pct()),
                format!("{:.1}%", dynamic.overhead_pct()),
                plan.batch.to_string(),
            ]);
        }
    }
    t.print();
    let rng = |v: &[f64]| {
        (v.iter().cloned().fold(f64::INFINITY, f64::min),
         v.iter().cloned().fold(0.0, f64::max))
    };
    let (slo, shi) = rng(&stat_all);
    let (dlo, dhi) = rng(&dyn_all);
    println!(
        "\nStatic {slo:.1}%..{shi:.1}% (paper 15.4..28.7%), \
         dynamic {dlo:.1}%..{dhi:.1}% (paper 2.3..8.6%)."
    );
}
