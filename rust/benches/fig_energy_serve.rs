//! Energy-at-fleet-scale figure: the demo tenant mix on a 3-board
//! fleet under each DVFS governor, plus the Fig. 11 policy ordering
//! (co-execution vs a static CPU/GPU split) and a power-capped arm.
//!
//! Arms:
//! * `race-to-idle` / `stretch-to-deadline` / `fixed:2` governors on
//!   the sparsity-aware co-execution scheduler — the headline is
//!   stretch spending fewer millijoules per inference than race at a
//!   <= 0.5 pp attainment give-up (the diurnal tenant is part of the
//!   demo mix);
//! * the same workload on `StaticSplit` boards (race governor) — the
//!   paper's Fig. 11 ordering at fleet scale: co-execution finishes
//!   sooner, so the idle/SoC floor accrues over a shorter horizon and
//!   joules per inference stay lowest;
//! * a power-capped race arm (cap excludes the GPU's max rung) showing
//!   clamp-and-defer throttling in the throttle-event counter.
//!
//! The virtual-time fleet is deterministic, so every number here is
//! machine-independent.  `--write-baseline` writes the measured lines
//! to `BENCH_energy_serve.json`; `--ci` refuses a missing/placeholder
//! baseline, re-checks the governor/policy orderings above, and gates
//! the stretch/race energy ratio against the committed one.

use sparoa::bench_support::{baseline, Table};
use sparoa::power::{Governor, PowerConfig, PowerProfile};
use sparoa::serve::{
    demo, merge_arrivals, run_fleet, ClusterPolicy, FleetOptions,
    FleetSnapshot, RouterPolicy,
};

const BOARDS: usize = 3;
const LOAD: f64 = 0.5;
const REQUESTS: usize = 300;
const SEED: u64 = 23;
/// `--ci` budget on the stretch/race mJ-per-inference ratio (the runs
/// are deterministic; the budget absorbs intentional retunes only).
const CI_RATIO_BUDGET: f64 = 1.05;
const CI_NUM_KEY: &str = "mj_per_inf_stretch";
const CI_DEN_KEY: &str = "mj_per_inf_race";
/// Acceptance noise floor on the stretch attainment give-up (0.5 pp).
const ATTAIN_NOISE_FLOOR: f64 = 0.005;

struct Arm {
    name: &'static str,
    snap: FleetSnapshot,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");
    // `--write-baseline` is accepted for CLI symmetry with the other
    // gated benches; every non-ci run refreshes the baseline.

    let device = "agx_orin";
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");
    let classes = demo::classes();
    let tenants = demo::tenants(&registry, LOAD, REQUESTS, SEED, None)
        .expect("building tenants");
    let arrivals = merge_arrivals(&tenants, SEED);
    let profile =
        PowerProfile::from_device(registry.get(0).session.device())
            .expect("device power profile");

    let run = |policy: ClusterPolicy,
               governor: Governor,
               cap_w: Option<f64>|
     -> FleetSnapshot {
        let mut pc = PowerConfig::new(profile.clone(), governor);
        pc.cap_w = cap_w;
        let mut opts = FleetOptions::new(BOARDS, registry.len());
        opts.router = RouterPolicy::CostAware;
        opts.policy = policy;
        opts.power = Some(pc);
        run_fleet(&registry, &classes, &tenants, &arrivals, &opts)
            .expect("fleet run")
    };

    // Cap fits {gpu mid rung + idle cpu} but not the gpu max rung, so
    // race-to-idle's picks clamp/defer throughout the capped arm.
    let cap = profile.soc_static_w
        + profile.cpu.idle_w
        + profile.gpu.states[1].busy_power_w()
        + 0.01;
    let co = ClusterPolicy::SparsityAware;
    let arms = [
        Arm {
            name: "race-to-idle",
            snap: run(co, Governor::RaceToIdle, None),
        },
        Arm {
            name: "stretch-to-deadline",
            snap: run(co, Governor::StretchToDeadline, None),
        },
        Arm {
            name: "fixed:2 (low)",
            snap: run(co, Governor::FixedState(2), None),
        },
        Arm {
            name: "static-split + race",
            snap: run(
                ClusterPolicy::StaticSplit,
                Governor::RaceToIdle,
                None,
            ),
        },
        Arm {
            name: "race, capped",
            snap: run(co, Governor::RaceToIdle, Some(cap)),
        },
    ];

    let mut t = Table::new(
        &format!(
            "energy-aware fleet — {BOARDS} boards x {} models on \
             {device}, load x{LOAD:.1} (capped arm: {cap:.1} W/board)",
            registry.len()
        ),
        &["arm", "attainment", "shed", "mJ/inf", "mean W", "throttles"],
    );
    for a in &arms {
        t.row(vec![
            a.name.into(),
            format!("{:.1}%", 100.0 * a.snap.aggregate_attainment()),
            a.snap.total_shed().to_string(),
            format!("{:.2}", a.snap.energy_per_inference_mj()),
            format!("{:.1}", a.snap.mean_power_w()),
            a.snap.total_throttles().to_string(),
        ]);
    }
    t.print();

    let (race, stretch, fixed, split, capped) =
        (&arms[0].snap, &arms[1].snap, &arms[2].snap, &arms[3].snap,
         &arms[4].snap);
    println!(
        "\nstretch-to-deadline: {:.2} mJ/inf vs race-to-idle {:.2} \
         ({:+.1}%), attainment {:.1}% vs {:.1}%; co-execution {:.2} \
         mJ/inf vs static split {:.2}; cap throttled {} dispatches.",
        stretch.energy_per_inference_mj(),
        race.energy_per_inference_mj(),
        100.0
            * (stretch.energy_per_inference_mj()
                / race.energy_per_inference_mj().max(1e-12)
                - 1.0),
        100.0 * stretch.aggregate_attainment(),
        100.0 * race.aggregate_attainment(),
        race.energy_per_inference_mj(),
        split.energy_per_inference_mj(),
        capped.total_throttles(),
    );

    let lines: Vec<(String, f64)> = vec![
        ("mj_per_inf_race".into(), race.energy_per_inference_mj()),
        ("mj_per_inf_stretch".into(),
         stretch.energy_per_inference_mj()),
        ("mj_per_inf_fixed_low".into(),
         fixed.energy_per_inference_mj()),
        ("attain_race".into(), race.aggregate_attainment()),
        ("attain_stretch".into(), stretch.aggregate_attainment()),
        ("mean_w_race".into(), race.mean_power_w()),
        ("mean_w_stretch".into(), stretch.mean_power_w()),
        ("mj_per_inf_coexec".into(), race.energy_per_inference_mj()),
        ("mj_per_inf_static_split".into(),
         split.energy_per_inference_mj()),
        ("throttle_events_capped".into(),
         capped.total_throttles() as f64),
    ];

    let path = sparoa::repo_root().join("BENCH_energy_serve.json");
    if ci {
        // Hard invariants first — these are the PR acceptance
        // criteria, deterministic on any runner.
        let mut bad = Vec::new();
        if stretch.energy_per_inference_mj()
            > race.energy_per_inference_mj()
        {
            bad.push(format!(
                "stretch {:.3} mJ/inf > race {:.3} mJ/inf",
                stretch.energy_per_inference_mj(),
                race.energy_per_inference_mj()
            ));
        }
        if race.aggregate_attainment() - stretch.aggregate_attainment()
            > ATTAIN_NOISE_FLOOR
        {
            bad.push(format!(
                "stretch gave up {:.3} attainment (> {} noise floor)",
                race.aggregate_attainment()
                    - stretch.aggregate_attainment(),
                ATTAIN_NOISE_FLOOR
            ));
        }
        if race.energy_per_inference_mj()
            > 1.02 * split.energy_per_inference_mj()
        {
            bad.push(format!(
                "co-execution {:.3} mJ/inf > static split {:.3} — the \
                 Fig. 11 ordering inverted",
                race.energy_per_inference_mj(),
                split.energy_per_inference_mj()
            ));
        }
        if capped.total_throttles() == 0 {
            bad.push("binding cap produced no throttle events".into());
        }
        if !bad.is_empty() {
            for b in &bad {
                eprintln!("fig_energy_serve invariant failed: {b}");
            }
            std::process::exit(1);
        }
        // Then the committed-baseline ratio gate (refuses a missing or
        // bootstrap-placeholder baseline — CI regenerates one first).
        let Some((_, old_ratio)) =
            baseline::committed(&path, CI_NUM_KEY, CI_DEN_KEY)
        else {
            baseline::refuse(&path, "fig_energy_serve", CI_NUM_KEY,
                             CI_DEN_KEY);
        };
        let new_ratio = stretch.energy_per_inference_mj()
            / race.energy_per_inference_mj().max(1e-12);
        baseline::gate_ratio(
            "fig_energy_serve",
            &format!("{CI_NUM_KEY}/{CI_DEN_KEY}"),
            new_ratio,
            old_ratio,
            CI_RATIO_BUDGET,
        );
    } else {
        // Full runs and `--write-baseline` both refresh the committed
        // baseline; `baseline::write` refuses an empty map, so a `{}`
        // placeholder can never silently disarm the `--ci` gate.
        baseline::write(&path, "energy-serve", &lines);
    }
}
