//! Figure 2: sparsity x computational-intensity distribution of operators
//! (MobileNetV3-Small on AGX Orin, batch 1) — the paper's motivating
//! observation that the two metrics are orthogonal and all four quadrants
//! are occupied.

use sparoa::bench_support::{load_env, Table};
use sparoa::profiler::{quadrant_counts, quadrant_profile, Quadrant};

fn main() {
    let Some((zoo, _)) = load_env() else { return };
    for model in ["mobilenet_v3_small", "resnet18"] {
        let g = zoo.get(model).unwrap();
        let profiles = quadrant_profile(g);
        let counts = quadrant_counts(&profiles);
        let mut t = Table::new(
            &format!("Fig.2 — operator quadrants, {model} (batch 1)"),
            &["quadrant", "ops", "share", "paper's reading"],
        );
        let total: usize = counts.iter().map(|(_, n)| n).sum();
        for (q, n) in counts {
            let reading = match q {
                Quadrant::DenseHeavy => "QI: dense+heavy -> GPU",
                Quadrant::SparseHeavy => "QII: sparse+heavy (counter-intuitive)",
                Quadrant::DenseLight => "QIII: dense+light, memory-bound",
                Quadrant::SparseLight => "QIV: sparse+light -> CPU",
            };
            t.row(vec![
                format!("{q:?}"),
                n.to_string(),
                format!("{:.0}%", 100.0 * n as f64 / total as f64),
                reading.into(),
            ]);
        }
        t.print();

        // Scatter sample: the extreme op of each quadrant.
        println!("  representative ops:");
        for target in [
            Quadrant::DenseHeavy,
            Quadrant::SparseHeavy,
            Quadrant::DenseLight,
            Quadrant::SparseLight,
        ] {
            if let Some(p) = profiles
                .iter()
                .filter(|p| p.quadrant == target)
                .max_by(|a, b| a.flops.partial_cmp(&b.flops).unwrap())
            {
                println!(
                    "    {:?}: {} (kind {}, rho={:.2}, I={:.2e} FLOPs)",
                    target, p.name, p.kind, p.sparsity, p.flops
                );
            }
        }
    }
    println!(
        "\nExpected shape (paper Fig.2): all four quadrants populated — \
         sparsity and intensity are independent scheduling dimensions."
    );
}
