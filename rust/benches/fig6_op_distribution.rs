//! Figure 6: CPU/GPU operator load share during inference for the three
//! SparOA scheduling policies.  Paper: SAC pushes the GPU share to 72.6%
//! vs Greedy 55.6% and DP 60.8%.

use sparoa::baselines::Baseline;
use sparoa::bench_support::{load_env, Table, MODELS};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let dev = reg.get("agx_orin").unwrap();
    let mut t = Table::new(
        "Fig.6 — operator distribution (GPU share of schedulable ops, AGX)",
        &["model", "Greedy", "DP", "SAC"],
    );
    let mut means = [0.0f64; 3];
    for model in MODELS {
        let g = zoo.get(model).unwrap();
        let mut row = vec![model.to_string()];
        for (i, b) in [Baseline::SparoaGreedy, Baseline::SparoaDp,
                       Baseline::Sparoa].iter().enumerate()
        {
            let ep = if *b == Baseline::Sparoa { 40 } else { 0 };
            let sched = b.schedule(g, dev, None, 1, ep);
            let share = sched.gpu_share(g);
            means[i] += share / MODELS.len() as f64;
            row.push(format!("{:.1}%", 100.0 * share));
        }
        t.row(row);
    }
    t.row(vec![
        "mean".into(),
        format!("{:.1}%", 100.0 * means[0]),
        format!("{:.1}%", 100.0 * means[1]),
        format!("{:.1}%", 100.0 * means[2]),
    ]);
    t.print();
    println!(
        "\nExpected shape (paper Fig.6): SAC assigns the largest GPU load \
         share (72.6% vs 55.6% greedy / 60.8% DP)."
    );
}
