//! Figure 11: power and energy per inference on AGX Orin.  Paper: SparOA
//! draws more instantaneous power than single-processor baselines (both
//! engines active) yet achieves the lowest energy-per-inference —
//! 7-16% below CoDL — because it finishes so much earlier.

use sparoa::baselines::{Baseline, ALL};
use sparoa::bench_support::{load_env, Table, MODELS};

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let dev = reg.get("agx_orin").unwrap();
    let mut power = Table::new(
        "Fig.11a — mean power per inference (W, AGX Orin)",
        &["baseline", "resnet18", "mbv3-s", "mbv2", "vit_b16", "swin_t"],
    );
    let mut energy = Table::new(
        "Fig.11b — energy per inference (mJ, AGX Orin)",
        &["baseline", "resnet18", "mbv3-s", "mbv2", "vit_b16", "swin_t"],
    );
    let mut e = vec![vec![0.0f64; MODELS.len()]; ALL.len()];
    let mut p = vec![vec![0.0f64; MODELS.len()]; ALL.len()];
    for (mi, model) in MODELS.iter().enumerate() {
        let g = zoo.get(model).unwrap();
        for (bi, b) in ALL.iter().enumerate() {
            let ep = if *b == Baseline::Sparoa { 40 } else { 0 };
            let (_, rep) = b.run(g, dev, None, 1, ep);
            let ledger = rep.ledger();
            p[bi][mi] = ledger.mean_power_w(dev);
            e[bi][mi] = ledger.energy_mj(dev);
        }
    }
    for (bi, b) in ALL.iter().enumerate() {
        let mut prow = vec![b.name().to_string()];
        let mut erow = vec![b.name().to_string()];
        for mi in 0..MODELS.len() {
            prow.push(format!("{:.1}", p[bi][mi]));
            erow.push(format!("{:.2}", e[bi][mi]));
        }
        power.row(prow);
        energy.row(erow);
    }
    power.print();
    energy.print();

    let idx = |target: Baseline| ALL.iter().position(|b| *b == target)
        .unwrap();
    let sparoa = idx(Baseline::Sparoa);
    let codl = idx(Baseline::CoDl);
    let savings: Vec<f64> = (0..MODELS.len())
        .map(|mi| 100.0 * (1.0 - e[sparoa][mi] / e[codl][mi]))
        .collect();
    let lo = savings.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = savings.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nEnergy saving vs CoDL: {lo:.0}%..{hi:.0}% (paper 7%..16%); \
         SparOA power > single-processor baselines but lowest \
         energy-per-inference."
    );
}
