//! Table 3: ±10% threshold-prediction accuracy and model size of the
//! three predictors.  Paper: ours 92.3%/90.6% (~4MB), CNN 36.2%/38.5%
//! (~0.5MB), LR 23.7%/20.4%.  The Transformer-LSTM runs through its AOT
//! HLO artifact via PJRT — the exact path the scheduler queries.

use sparoa::bench_support::{load_env, Table};
use sparoa::predictor::{
    accuracy, PredictorDataset, ThresholdPredictor, N_FEATURES, SEQ_LEN,
};
use sparoa::runtime::Runtime;

fn eval_hlo(rt: &Runtime, artifact: &str, ds: &PredictorDataset)
    -> (f64, f64)
{
    let pred = ThresholdPredictor::with_artifact(rt, artifact);
    let (mut s_acc, mut c_acc, mut n) = (0.0, 0.0, 0.0);
    for (x, y, m) in &ds.sequences {
        let rows: Vec<[f32; N_FEATURES]> = (0..SEQ_LEN)
            .map(|i| {
                let mut r = [0f32; N_FEATURES];
                r.copy_from_slice(&x[i * N_FEATURES..(i + 1) * N_FEATURES]);
                r
            })
            .collect();
        let p = pred.predict_window(&rows).unwrap();
        let (s, c) = accuracy(&p, y, m, 0.1);
        let w = m.iter().sum::<f32>() as f64;
        s_acc += s * w;
        c_acc += c * w;
        n += w;
    }
    (s_acc / n, c_acc / n)
}

fn main() {
    let Some((_, _)) = load_env() else { return };
    let art = sparoa::artifacts_dir();
    let ds = PredictorDataset::load(&art).unwrap();
    let rt = Runtime::new(&art).unwrap();

    let (ours_s, ours_c) =
        eval_hlo(&rt, "predictor/thresh_predictor.hlo.txt", &ds);
    let (cnn_s, cnn_c) =
        eval_hlo(&rt, "predictor/cnn_predictor.hlo.txt", &ds);
    let (mut lr_s, mut lr_c, mut n) = (0.0, 0.0, 0.0);
    for (x, y, m) in &ds.sequences {
        let preds: Vec<(f64, f64)> = (0..SEQ_LEN)
            .map(|i| {
                let mut r = [0f32; N_FEATURES];
                r.copy_from_slice(&x[i * N_FEATURES..(i + 1) * N_FEATURES]);
                ds.lr.predict(&r)
            })
            .collect();
        let (s, c) = accuracy(&preds, y, m, 0.1);
        let w = m.iter().sum::<f32>() as f64;
        lr_s += s * w;
        lr_c += c * w;
        n += w;
    }
    lr_s /= n;
    lr_c /= n;

    let size = |k: &str| {
        ds.model_bytes
            .iter()
            .find(|(name, _)| name == k)
            .map(|(_, b)| *b)
            .unwrap_or(0.0)
    };
    let mut t = Table::new(
        "Table 3 — ±10% prediction accuracy and model size",
        &["predictor", "sparsity acc", "intensity acc", "size"],
    );
    t.row(vec!["LR".into(), format!("{:.1}%", 100.0 * lr_s),
               format!("{:.1}%", 100.0 * lr_c),
               format!("{:.0} B", size("lr"))]);
    t.row(vec!["CNN".into(), format!("{:.1}%", 100.0 * cnn_s),
               format!("{:.1}%", 100.0 * cnn_c),
               format!("{:.2} MB", size("cnn") / 1e6)]);
    t.row(vec!["Ours (Transformer-LSTM)".into(),
               format!("{:.1}%", 100.0 * ours_s),
               format!("{:.1}%", 100.0 * ours_c),
               format!("{:.2} MB", size("ours") / 1e6)]);
    t.print();
    println!(
        "\nExpected shape (paper Table 3): ours >> CNN >> LR on both \
         outputs; ours ~4MB (paper: 92.3%/90.6%, 36.2%/38.5%, 23.7%/20.4%)."
    );
}
