//! Tail-tolerance figure: a fleet where two boards gray-fail —
//! thermally stretched to ~3x their advertised latency for most of the
//! run while staying up and accepting work — the failure mode a
//! liveness check never sees.  The tail extension's headline numbers.
//!
//! Arms:
//! * `off` — no detection, no hedging (bit-identical to the pre-tail
//!   path; its report carries no tail counters);
//! * `breaker` — the gray-failure detector (realized-vs-predicted
//!   dispatch-latency EWMA) trips a per-board circuit breaker; open
//!   boards leave routing/steal/autoscale placement and recover
//!   through low-rate probe dispatches;
//! * `hedge+breaker` — adds hedged dispatch: a deadline-at-risk
//!   interactive head is re-offered to the next-cheapest routable
//!   board, the first finish wins and the loser is cancelled through
//!   the in-flight ledger (lane time and energy refunded, duplicate
//!   work billed as `hedge_waste_us`).
//!
//! Every arm runs the same three seeds and is checked for exact
//! conservation: offered == served + shed + failed, hedged requests
//! settle exactly once.  The virtual-time fleet is deterministic, so
//! every number is machine-independent.  Full runs write the measured
//! lines to `BENCH_tail.json`; `--ci` re-checks conservation, requires
//! hedge+breaker to strictly beat the control on interactive
//! attainment, caps hedge waste at 15% of served busy time, and gates
//! the hedge/off attainment ratio against the committed baseline.

use sparoa::bench_support::{baseline, Table};
use sparoa::device::Proc;
use sparoa::faults::{Fault, FaultPlan};
use sparoa::serve::{
    demo, merge_arrivals, run_fleet, ArrivalPattern, FleetOptions,
    FleetSnapshot, RouterPolicy, SloClass, TailParams, TailPolicy,
    Tenant,
};

const BOARDS: usize = 6;
/// Boards gray-failing through the thermal window.
const GRAY_BOARDS: [usize; 2] = [0, 1];
/// Latency stretch on the gray boards (well past the detector's 1.4x
/// suspect factor).
const GRAY_SCALE: f64 = 2.8;
/// Flood arrival rate as a multiple of the fleet's aggregate capacity
/// — near saturation, so a stretched board builds real queues.
const LOAD: f64 = 0.95;
const N_FLOOD: usize = 500;
const SEEDS: [u64; 3] = [3, 7, 11];
/// `--ci` cap on lane time burned on cancelled losers and duplicate
/// hedge finishes, as a fraction of the fleet's served busy time.
const CI_WASTE_FRAC: f64 = 0.15;
/// `--ci` budget on the hedge/off interactive-attainment ratio drift
/// against the committed baseline.
const CI_RATIO_BUDGET: f64 = 1.05;
const CI_NUM_KEY: &str = "attain_hi_hedge";
const CI_DEN_KEY: &str = "attain_hi_off";

const ARMS: [TailPolicy; 3] = [
    TailPolicy::OFF,
    TailPolicy { hedge: false, breaker: true },
    TailPolicy { hedge: true, breaker: true },
];

struct Arm {
    tail: TailPolicy,
    /// One snapshot per seed.
    snaps: Vec<FleetSnapshot>,
    n_arrivals: Vec<usize>,
}

fn conserved(name: &str, snap: &FleetSnapshot, n: usize) -> bool {
    let offered = snap.aggregate.total_offered();
    let settled = snap.aggregate.total_served()
        + snap.aggregate.total_shed()
        + snap.total_failed();
    if offered as usize != n || settled != offered {
        eprintln!(
            "fig_tail conservation broken in `{name}`: {n} arrivals, \
             offered {offered}, served {} + shed {} + failed {} = \
             {settled}",
            snap.aggregate.total_served(),
            snap.aggregate.total_shed(),
            snap.total_failed()
        );
        return false;
    }
    true
}

/// Interactive-class (class 0) deadline attainment over all seeds.
fn hi_attain(arm: &Arm) -> f64 {
    let (met, offered) = arm.snaps.iter().fold((0u64, 0u64), |(m, o), s| {
        let g = &s.aggregate.per_class[0];
        (m + g.met, o + g.offered)
    });
    met as f64 / offered.max(1) as f64
}

fn sum<T: Fn(&FleetSnapshot) -> f64>(arm: &Arm, f: T) -> f64 {
    arm.snaps.iter().map(f).sum()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ci = args.iter().any(|a| a == "--ci");

    let device = "agx_orin";
    let registry = demo::registry(&sparoa::artifacts_dir(), device)
        .expect("building demo registry");

    // Calibrate the roles (works on both the synthetic and artifact
    // registries): the flood model has the longest full-cap batch, the
    // interactive model the cheapest batch-1 latency.
    let cal: Vec<(f64, f64, f64)> = (0..registry.len())
        .map(|m| {
            let e = registry.get(m);
            let cap = e.gpu_batch_cap.max(1);
            let batch_lat = e.latency_us(Proc::Gpu, cap).unwrap();
            let rate = cap as f64 / batch_lat * 1e6;
            (rate, e.cheapest_latency_us(1).unwrap(), batch_lat)
        })
        .collect();
    let flood = (0..cal.len())
        .max_by(|&a, &b| cal[a].2.total_cmp(&cal[b].2))
        .unwrap();
    let inter = (0..cal.len())
        .min_by(|&a, &b| cal[a].1.total_cmp(&cal[b].1))
        .unwrap();
    assert_ne!(flood, inter, "degenerate registry: one model is both \
                              the flood and the interactive role");
    let (flood_rate, _, flood_batch) = cal[flood];
    let (inter_rate, inter_lat1, _) = cal[inter];

    // The interactive deadline is a modest multiple of its batch-1
    // latency: beatable on a healthy board, doomed behind a stretched
    // one — the hedge's decision margin.
    let deadline_us = (12.0 * inter_lat1).max(1.05 * inter_lat1);
    let classes = vec![
        SloClass::new("interactive", deadline_us, 128, 4.0),
        SloClass::new("best-effort", 20.0 * flood_batch, 512, 1.0),
    ];
    let flood_per_s = LOAD * BOARDS as f64 * flood_rate;
    let horizon_s = N_FLOOD as f64 / flood_per_s;
    let inter_per_s = 0.35 * inter_rate;
    let n_inter = ((inter_per_s * horizon_s) as usize).max(150);
    let tenants = vec![
        Tenant {
            name: "flood-be".into(),
            model: registry.get(flood).name.clone(),
            class: 1,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: flood_per_s,
                n: N_FLOOD,
            },
        },
        Tenant {
            name: "interactive".into(),
            model: registry.get(inter).name.clone(),
            class: 0,
            pattern: ArrivalPattern::Poisson {
                rate_per_s: inter_per_s,
                n: n_inter,
            },
        },
    ];

    // Every model on every board: hedges and breaker re-routing always
    // have an eligible destination.  Round-robin keeps sending fresh
    // work onto the gray boards until the breaker learns better.
    let placement: Vec<Vec<usize>> =
        vec![(0..registry.len()).collect(); BOARDS];
    // Breaker timescales sized to the bench horizon (the defaults suit
    // the longer demo workloads).
    let params = TailParams {
        open_cooldown_us: 8_000.0,
        probe_interval_us: 2_000.0,
        ..TailParams::default()
    };
    let run = |tail: TailPolicy, seed: u64| -> (FleetSnapshot, usize) {
        let arrivals = merge_arrivals(&tenants, seed);
        let horizon = arrivals.last().expect("arrivals").at_us;
        let faults = FaultPlan {
            faults: GRAY_BOARDS
                .iter()
                .flat_map(|&b| {
                    [Proc::Gpu, Proc::Cpu].into_iter().map(move |p| {
                        Fault::Thermal {
                            board: b,
                            proc: p,
                            at_us: 0.15 * horizon,
                            until_us: 0.75 * horizon,
                            scale: GRAY_SCALE,
                        }
                    })
                })
                .collect(),
        };
        let opts = FleetOptions {
            router: RouterPolicy::RoundRobin,
            placement: placement.clone(),
            tail,
            tail_params: params,
            faults,
            ..FleetOptions::new(BOARDS, registry.len())
        };
        let snap =
            run_fleet(&registry, &classes, &tenants, &arrivals, &opts)
                .expect("fleet run");
        (snap, arrivals.len())
    };
    let arms: Vec<Arm> = ARMS
        .into_iter()
        .map(|tail| {
            let (snaps, n_arrivals) = SEEDS
                .iter()
                .map(|&s| run(tail, s))
                .unzip();
            Arm { tail, snaps, n_arrivals }
        })
        .collect();

    let mut ok = true;
    for a in &arms {
        for (s, &n) in a.snaps.iter().zip(&a.n_arrivals) {
            ok &= conserved(a.tail.name(), s, n);
        }
    }

    let mut t = Table::new(
        &format!(
            "tail — {BOARDS} boards ({} gray-failing x{GRAY_SCALE:.1} \
             latency) on {device}, {} seeds",
            GRAY_BOARDS.len(),
            SEEDS.len()
        ),
        &["arm", "interactive attain", "served", "opens", "probes",
          "hedges (won)", "hedge waste ms"],
    );
    for a in &arms {
        t.row(vec![
            a.tail.name().into(),
            format!("{:.1}%", 100.0 * hi_attain(a)),
            format!("{:.0}",
                    sum(a, |s| s.aggregate.total_served() as f64)),
            format!("{:.0}",
                    sum(a, |s| s.total_breaker_opens() as f64)),
            format!("{:.0}", sum(a, |s| s.total_probes() as f64)),
            format!(
                "{:.0} ({:.0})",
                sum(a, |s| s.total_hedges() as f64),
                sum(a, |s| s.total_hedge_wins() as f64)
            ),
            format!("{:.1}",
                    sum(a, |s| s.total_hedge_waste_us()) / 1e3),
        ]);
    }
    t.print();

    let (off, brk, hedge) = (&arms[0], &arms[1], &arms[2]);
    println!(
        "\ngray boards poison the tail until the breaker benches them \
         and hedges rescue at-risk heads: interactive attainment \
         {:.1}% (off) -> {:.1}% (breaker, {:.0} opens) -> {:.1}% \
         (hedge+breaker, {:.0} hedges, {:.1} ms duplicate work).",
        100.0 * hi_attain(off),
        100.0 * hi_attain(brk),
        sum(brk, |s| s.total_breaker_opens() as f64),
        100.0 * hi_attain(hedge),
        sum(hedge, |s| s.total_hedges() as f64),
        sum(hedge, |s| s.total_hedge_waste_us()) / 1e3,
    );

    let lines: Vec<(String, f64)> = vec![
        ("attain_hi_off".into(), hi_attain(off)),
        ("attain_hi_breaker".into(), hi_attain(brk)),
        ("attain_hi_hedge".into(), hi_attain(hedge)),
        ("served_off".into(),
         sum(off, |s| s.aggregate.total_served() as f64)),
        ("served_hedge".into(),
         sum(hedge, |s| s.aggregate.total_served() as f64)),
        ("opens_breaker".into(),
         sum(brk, |s| s.total_breaker_opens() as f64)),
        ("probes_breaker".into(),
         sum(brk, |s| s.total_probes() as f64)),
        ("hedges_hedge".into(),
         sum(hedge, |s| s.total_hedges() as f64)),
        ("hedge_wins_hedge".into(),
         sum(hedge, |s| s.total_hedge_wins() as f64)),
        ("waste_ms_hedge".into(),
         sum(hedge, |s| s.total_hedge_waste_us()) / 1e3),
    ];

    let path = sparoa::repo_root().join("BENCH_tail.json");
    if ci {
        // Hard invariants — the PR acceptance criteria, deterministic
        // on any runner.
        let mut bad = Vec::new();
        if !ok {
            bad.push("conservation failed in at least one arm".into());
        }
        for s in &off.snaps {
            if s.total_suspects() != 0
                || s.total_breaker_opens() != 0
                || s.total_probes() != 0
                || s.total_hedges() != 0
                || s.total_hedge_waste_us() != 0.0
            {
                bad.push("the off arm detected or hedged".into());
                break;
            }
        }
        if sum(brk, |s| s.total_breaker_opens() as f64) == 0.0 {
            bad.push("breaker arm never opened a breaker".into());
        }
        if sum(brk, |s| s.total_hedges() as f64) != 0.0 {
            bad.push("breaker-only arm hedged".into());
        }
        if sum(hedge, |s| s.total_hedges() as f64) == 0.0 {
            bad.push("hedge arm never hedged".into());
        }
        if hi_attain(hedge) <= hi_attain(off) {
            bad.push(format!(
                "hedge+breaker interactive attainment {:.4} <= off \
                 {:.4}",
                hi_attain(hedge),
                hi_attain(off)
            ));
        }
        let busy = sum(hedge, |s| {
            s.aggregate.cpu_busy_us + s.aggregate.gpu_busy_us
        });
        let waste = sum(hedge, |s| s.total_hedge_waste_us());
        if waste > CI_WASTE_FRAC * busy {
            bad.push(format!(
                "hedge waste {waste:.0}us > {:.0}% of {busy:.0}us \
                 served busy time",
                100.0 * CI_WASTE_FRAC
            ));
        }
        if !bad.is_empty() {
            for b in &bad {
                eprintln!("fig_tail invariant failed: {b}");
            }
            std::process::exit(1);
        }
        // Then the committed-baseline drift gate (refuses a missing or
        // bootstrap-placeholder baseline — CI regenerates one first).
        let Some((_, old_ratio)) =
            baseline::committed(&path, CI_NUM_KEY, CI_DEN_KEY)
        else {
            baseline::refuse(&path, "fig_tail", CI_NUM_KEY,
                             CI_DEN_KEY);
        };
        let new_ratio = hi_attain(hedge) / hi_attain(off).max(1e-12);
        baseline::gate_ratio(
            "fig_tail",
            &format!("{CI_NUM_KEY}/{CI_DEN_KEY}"),
            new_ratio,
            old_ratio,
            CI_RATIO_BUDGET,
        );
    } else {
        if !ok {
            std::process::exit(1);
        }
        baseline::write(&path, "tail", &lines);
    }
}
