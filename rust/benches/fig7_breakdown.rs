//! Figure 7: latency breakdown (compute / data transfer / other) for
//! static SparOA (w/o RL, synchronous transfers) vs full SparOA.  Paper:
//! the RL + async path cuts data-transfer latency by 14.1-20.8%.

use sparoa::baselines::Baseline;
use sparoa::bench_support::{load_env, Table, MODELS};
use sparoa::profiler::breakdown;

fn main() {
    let Some((zoo, reg)) = load_env() else { return };
    let dev = reg.get("agx_orin").unwrap();
    let mut t = Table::new(
        "Fig.7 — latency breakdown, static SparOA vs SparOA (AGX, us)",
        &["model", "variant", "compute", "transfer", "launch+other",
          "total"],
    );
    let mut reductions = Vec::new();
    for model in MODELS {
        let g = zoo.get(model).unwrap();
        let (_, static_rep) =
            Baseline::SparoaNoRl.run(g, dev, None, 1, 0);
        let (_, full_rep) = Baseline::Sparoa.run(g, dev, None, 1, 40);
        for (name, rep) in [("static", &static_rep), ("SparOA", &full_rep)] {
            let b = breakdown(rep);
            t.row(vec![
                model.into(),
                name.into(),
                format!("{:.0}", b.compute_us),
                format!("{:.0}", b.transfer_us),
                format!("{:.0}", b.launch_us + b.other_us),
                format!("{:.0}", b.makespan_us),
            ]);
        }
        if static_rep.transfer_us > 0.0 {
            reductions.push(
                100.0 * (1.0 - full_rep.transfer_us
                         / static_rep.transfer_us));
        }
    }
    t.print();
    let lo = reductions.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = reductions.iter().cloned().fold(0.0, f64::max);
    println!(
        "\nTransfer-latency reduction from async + RL: {lo:.1}%..{hi:.1}% \
         (paper: 14.1%..20.8%)."
    );
}
